"""Elastic scaling: group join/leave -> warm-started DFPA re-partition.

The paper's key enabler for self-adaptability is that DFPA needs no prior
model — and its partial estimates are CHEAP to carry.  On a membership
change we keep the surviving groups' FPM points (the paper's §3.2 trick of
reusing all previous benchmark results) and re-partition immediately;
convergence then typically takes 1-2 observation steps instead of a cold
start.  A joining group starts with an optimistic single-point estimate
borrowed from the fastest survivor (it will be corrected by its first
measurement; optimistic starts avoid starving the newcomer).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from ..core.fpm import PiecewiseLinearFPM
from .balance import BalanceController

__all__ = ["elastic_rebalance"]


def elastic_rebalance(
    controller: BalanceController,
    surviving: Sequence[int],
    joined: int = 0,
    *,
    caps: Optional[Sequence[int]] = None,
) -> BalanceController:
    """Build a controller for the new group set.

    ``surviving`` — indices (into the old controller) still alive;
    ``joined``    — number of new groups appended after the survivors.
    """
    models: List[PiecewiseLinearFPM] = [
        PiecewiseLinearFPM.from_points(controller.models[i].as_points())
        for i in surviving
    ]
    donor = None
    for m in models:
        if m.num_points:
            cand = max(m.as_points(), key=lambda p: p[1])
            if donor is None or cand[1] > donor[1]:
                donor = cand
    for _ in range(joined):
        models.append(
            PiecewiseLinearFPM.from_points([donor]) if donor else PiecewiseLinearFPM()
        )
    new = BalanceController(
        n_units=controller.n_units,
        num_groups=len(models),
        eps=controller.eps,
        min_units=controller.min_units,
        smooth=controller.smooth,
        caps=list(caps) if caps is not None else None,
        models=models,
    )
    # Re-partition immediately if every group has at least one point.
    if all(m.num_points for m in new.models):
        from ..core.partition import partition_units

        new.d = partition_units(new.models, new.n_units, new.caps, min_units=new.min_units)
    return new
