"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).

Production target: TPU v5e, 256 chips per pod in a 16x16 ("data","model")
mesh; multi-pod adds a leading "pod" axis over the DCN (2 pods = 512 chips
in the dry-run; the axis scales to O(100) pods — per-pod mesh shape is
unchanged, which is what the 1000+ node design relies on).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "HW"]


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12  # per chip
    HBM_BW = 819e9  # bytes/s per chip
    ICI_BW = 50e9  # bytes/s per link
    HBM_BYTES = 16 * 1024**3


def make_mesh(shape, axes):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
