"""ModelBank: batched FPM evaluation + the vectorized partition path.

Fuzz/property coverage is numpy-randomized (not hypothesis-based) so it runs
in minimal environments:

  * the scalar closed-form ``PiecewiseLinearFPM.alloc_at_time`` agrees with
    ``AnalyticModel`` bisection on randomized (monotone-time) piecewise models;
  * batched ``ModelBank`` queries match the scalar models elementwise on
    arbitrary (including non-monotone) piecewise models;
  * the vectorized partition path matches the seed scalar path to ±1 unit per
    processor, including on the calibrated HCL simulator fixtures.
"""

import numpy as np
import pytest

from repro.core import (
    AnalyticModel,
    BatchedSimulatedExecutor,
    ConstantModel,
    ModelBank,
    PiecewiseLinearFPM,
    SimulatedExecutor,
    dfpa,
    make_hcl_time_fn_batch,
    make_hcl_time_fns,
    partition_units,
    speed_fn_1d,
    speed_fn_1d_batch,
)
from repro.runtime.balance import BalanceController
from repro.runtime.straggler import StragglerDetector


def _random_fpm(rng, k_max=8, monotone=False):
    k = int(rng.integers(1, k_max))
    xs = np.unique(rng.uniform(1.0, 1e4, k))
    ss = rng.uniform(0.5, 500.0, len(xs))
    if monotone:  # non-increasing speed -> strictly increasing time
        ss = np.sort(ss)[::-1]
    return PiecewiseLinearFPM.from_points(list(zip(xs, ss)))


def _random_bank(rng, p, **kw):
    models = [_random_fpm(rng, **kw) for _ in range(p)]
    return models, ModelBank.from_models(models)


# ---------------------------------------------------------------------------
# Scalar closed form vs analytic bisection
# ---------------------------------------------------------------------------


def test_alloc_at_time_closed_form_matches_bisection():
    """On monotone-time models (AnalyticModel's contract) the closed-form
    segment solver and 96-step bisection find the same allocation."""
    rng = np.random.default_rng(42)
    for _ in range(200):
        m = _random_fpm(rng, monotone=True)
        ref = AnalyticModel(m.time)
        t = float(rng.uniform(1e-3, 50.0))
        cap = float(rng.uniform(1.0, 2e4))
        a = m.alloc_at_time(t, cap)
        b = ref.alloc_at_time(t, cap)
        assert a == pytest.approx(b, rel=1e-9, abs=1e-6)


# ---------------------------------------------------------------------------
# Batched bank vs scalar models, elementwise
# ---------------------------------------------------------------------------


def test_bank_matches_scalar_models_elementwise():
    rng = np.random.default_rng(7)
    for _ in range(100):
        p = int(rng.integers(1, 12))
        models, bank = _random_bank(rng, p)
        x = rng.uniform(0.1, 2e4, p)
        assert np.allclose(bank.speed(x), [m.speed(v) for m, v in zip(models, x)], rtol=1e-12)
        assert np.allclose(bank.time(x), [m.time(v) for m, v in zip(models, x)], rtol=1e-12)
        t = float(rng.uniform(1e-3, 100.0))
        caps = rng.uniform(0.5, 1e4, p)
        want = [m.alloc_at_time(t, c) for m, c in zip(models, caps)]
        assert np.allclose(bank.alloc_at_time(t, caps), want, rtol=1e-10, atol=1e-10)


def test_bank_scalar_broadcast_and_edge_inputs():
    rng = np.random.default_rng(3)
    models, bank = _random_bank(rng, 5)
    # scalar x broadcasts across the bank
    assert np.allclose(bank.speed(100.0), [m.speed(100.0) for m in models])
    # non-positive t / caps -> zero allocation
    assert np.all(bank.alloc_at_time(0.0, np.full(5, 10.0)) == 0.0)
    assert np.all(bank.alloc_at_time(1.0, np.zeros(5)) == 0.0)
    # time at x=0 is 0
    assert np.all(bank.time(0.0) == 0.0)


def test_bank_constant_model_adapter():
    models = [ConstantModel(3.0), ConstantModel(7.5)]
    bank = ModelBank.from_models(models)
    for t in (0.1, 1.0, 13.0):
        for cap in (0.5, 4.0, 1e3):
            want = [m.alloc_at_time(t, cap) for m in models]
            assert np.allclose(bank.alloc_at_time(t, np.full(2, cap)), want)
    assert np.allclose(bank.speed(50.0), [3.0, 7.5])


def test_bank_rejects_analytic_models():
    with pytest.raises(TypeError):
        ModelBank.from_models([AnalyticModel(lambda x: x)])


def test_bank_round_trip_and_scaled():
    rng = np.random.default_rng(11)
    models, bank = _random_bank(rng, 4)
    back = bank.to_models()
    for m, b in zip(models, back):
        assert m.as_points() == pytest.approx(b.as_points())
    scale = np.array([0.5, 1.0, 2.0, 3.0])
    scaled = bank.scaled(scale)
    x = rng.uniform(1.0, 1e4, 4)
    assert np.allclose(scaled.speed(x), bank.speed(x) * scale)


# ---------------------------------------------------------------------------
# Vectorized partition path vs seed scalar path
# ---------------------------------------------------------------------------


def test_partition_bank_matches_scalar_randomized():
    rng = np.random.default_rng(123)
    for _ in range(80):
        p = int(rng.integers(2, 10))
        models, bank = _random_bank(rng, p)
        n = int(rng.integers(10, 5000))
        d_scalar = partition_units(models, n, vectorize=False)
        d_bank = partition_units(bank, n)
        assert sum(d_bank) == n
        assert max(abs(a - b) for a, b in zip(d_scalar, d_bank)) <= 1


def test_partition_bank_matches_scalar_with_caps_and_min_units():
    rng = np.random.default_rng(5)
    for _ in range(40):
        p = int(rng.integers(2, 8))
        models, bank = _random_bank(rng, p)
        n = int(rng.integers(4 * p, 500))
        caps = [int(c) for c in rng.integers(n // p, n + 1, p)]
        if sum(caps) < n:
            continue
        d_scalar = partition_units(models, n, caps, min_units=2, vectorize=False)
        d_bank = partition_units(bank, n, caps, min_units=2)
        assert sum(d_bank) == n
        assert all(2 <= di <= ci for di, ci in zip(d_bank, caps))
        assert max(abs(a - b) for a, b in zip(d_scalar, d_bank)) <= 1


def test_partition_bank_matches_scalar_on_hcl_fixtures():
    """Acceptance gate: identical (±1 unit/processor) allocations on FPMs
    sampled from the calibrated HCL simulator."""
    for n in (2048, 5120, 8192):
        specs, _ = make_hcl_time_fns(n)
        models = []
        for s in specs:
            sp = speed_fn_1d(s, n)
            xs = np.geomspace(64, 4 * n, 9)
            models.append(PiecewiseLinearFPM.from_points([(x, sp(x)) for x in xs]))
        bank = ModelBank.from_models(models)
        d_scalar = partition_units(models, n, min_units=1, vectorize=False)
        d_bank = partition_units(bank, n, min_units=1)
        assert sum(d_bank) == n
        assert max(abs(a - b) for a, b in zip(d_scalar, d_bank)) <= 1


def test_dfpa_identical_through_bank_path():
    """DFPA (which now re-partitions through the bank) reproduces the same
    distribution as forcing every re-partition through the scalar path."""
    n = 5120
    _, tfns = make_hcl_time_fns(n)
    rows = [(lambda tf: lambda r: tf(r * n))(tf) for tf in tfns]
    res = dfpa(SimulatedExecutor(time_fns=rows), n, eps=0.025, min_units=1)
    # replay the final models through both partition paths
    d_bank = partition_units(ModelBank.from_models(res.models), n, min_units=1)
    d_scalar = partition_units(res.models, n, min_units=1, vectorize=False)
    assert max(abs(a - b) for a, b in zip(d_bank, d_scalar)) <= 1


# ---------------------------------------------------------------------------
# Zero-allocation convergence (imbalance bugfix, DFPA level)
# ---------------------------------------------------------------------------


def test_dfpa_converges_with_zero_allocation_processor():
    """Regression: with min_units=0 the optimal partition may give a very
    slow processor 0 units; imbalance must ignore it so DFPA can converge."""
    ex = SimulatedExecutor(time_fns=[lambda x: x / 100.0, lambda x: x * 1000.0])
    res = dfpa(ex, 10, eps=0.5, min_units=0)
    assert res.converged
    assert res.d == [10, 0]
    assert res.imbalance == 0.0


# ---------------------------------------------------------------------------
# Batched simulator + executor
# ---------------------------------------------------------------------------


def test_batched_sim_fns_match_scalar():
    n = 5120
    specs, tfns = make_hcl_time_fns(n)
    _, tb = make_hcl_time_fn_batch(n)
    sb = speed_fn_1d_batch(specs, n)
    for x in np.geomspace(1.0, 5e6, 25):
        xv = np.full(len(specs), x)
        assert np.allclose(tb(xv), [tf(float(x)) for tf in tfns], rtol=1e-12)
        assert np.allclose(
            sb(xv), [speed_fn_1d(s, n)(float(x)) for s in specs], rtol=1e-12
        )
    assert np.all(tb(np.zeros(len(specs))) == 0.0)


def test_batched_executor_matches_scalar_executor():
    n = 4096
    _, tfns = make_hcl_time_fns(n)
    _, tb = make_hcl_time_fn_batch(n)
    rows = [(lambda tf: lambda r: tf(r * n))(tf) for tf in tfns]
    r1 = dfpa(SimulatedExecutor(time_fns=rows), n, eps=0.025, min_units=1)
    r2 = dfpa(
        BatchedSimulatedExecutor(
            time_fn_batch=lambda r: tb(np.asarray(r, float) * n), p=len(tfns)
        ),
        n,
        eps=0.025,
        min_units=1,
    )
    assert r1.d == r2.d
    assert r1.iterations == r2.iterations


# ---------------------------------------------------------------------------
# Runtime controllers on the bank
# ---------------------------------------------------------------------------


def test_balance_controller_bank_snapshot_and_rebalance():
    ctl = BalanceController(n_units=64, num_groups=4, eps=0.05)
    # group 3 is half as fast as the rest
    speeds = [4.0, 4.0, 4.0, 2.0]
    for _ in range(6):
        times = [d / s for d, s in zip(ctl.d, speeds)]
        ctl.observe(times)
    bank = ctl.bank()
    assert bank.p == 4
    times = [d / s for d, s in zip(ctl.d, speeds)]
    assert ctl.rebalances >= 1
    # converged: slow group got ~half the units of the fast ones
    assert ctl.d[3] < ctl.d[0]
    assert ctl.imbalance_estimate <= 0.3


def test_straggler_update_batch_matches_scalar():
    rng = np.random.default_rng(17)
    p = 6
    models = [PiecewiseLinearFPM.from_points([(10.0, 5.0), (50.0, 4.0)]) for _ in range(p)]
    bank = ModelBank.from_models(models)
    d = [20] * p
    det_a, det_b = StragglerDetector(), StragglerDetector()
    for step in range(8):
        obs = [models[i].time(d[i]) * (3.5 if (i == 2 and step >= 2) else 1.0) for i in range(p)]
        batch = det_a.update_batch(bank, d, obs)
        scalar = [det_b.update(i, models[i], d[i], obs[i]) for i in range(p)]
        assert batch == scalar
    assert det_a.history == det_b.history
