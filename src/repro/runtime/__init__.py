from .train_loop import TrainState, make_train_step, init_train_state, loss_for_config
from .balance import BalanceController, GroupTimer
from .straggler import StragglerDetector
from .elastic import elastic_rebalance

__all__ = [
    "TrainState",
    "make_train_step",
    "init_train_state",
    "loss_for_config",
    "BalanceController",
    "GroupTimer",
    "StragglerDetector",
    "elastic_rebalance",
]
