"""Bi-objective time/energy walkthrough: one bank layout, two objectives.

A 2-class fleet where the energy ranking deliberately disagrees with the
speed ranking: the "old" parts are a touch faster but burn ~5x the power
of the "new" ones.  The energy subsystem banks per-processor energy laws
as energy-RATE models (``er(x) = x / E(x)``, see ``core/energy.py``) so
the whole speed-bank machinery — padded layout, fold-in, partition —
serves energy unchanged.  The walkthrough builds the makespan/energy
Pareto front, picks its knee, partitions under an explicit energy budget,
and runs one power-capped multi-tenant serving round.

    PYTHONPATH=src python examples/energy_pareto_walkthrough.py
"""

import numpy as np

from repro.core import PiecewiseLinearFPM, SpeedStore
from repro.core.energy import energy_model
from repro.fleet import FleetScheduler, JobSpec

# --- a 2-class fleet: new efficient parts vs old power hogs -----------------
P = 6
CLASSES = ["new", "new", "new", "old", "old", "old"]
SPEED = {"new": 420.0, "old": 500.0}  # chunks/s: the hogs are FASTER
ENERGY = {"new": (3.0, 0.25), "old": (8.0, 1.4)}  # E(x) = a + b*x joules

xs = np.geomspace(1.0, 4096.0, 7)
speed_models = [
    PiecewiseLinearFPM.from_points([(1.0, SPEED[c]), (4096.0, SPEED[c])])
    for c in CLASSES
]
energy_models = [
    energy_model([(x, ENERGY[c][0] + ENERGY[c][1] * x) for x in xs])
    for c in CLASSES
]

# --- 1. one store, two banks: time and energy share the layout --------------
store = SpeedStore.from_models(speed_models, backend="numpy")
store.attach_energy(energy_models)
N = 2000
d_time, t_opt = store.partition(N)
d_energy, _ = store.partition(N, objective="energy")
print(f"time-optimal   d={d_time}  makespan {t_opt:.3f}s  "
      f"energy {store.fleet_energy(d_time):7.1f} J")
print(f"energy-optimal d={d_energy}  makespan "
      f"{max(x / SPEED[c] for x, c in zip(d_energy, CLASSES)):.3f}s  "
      f"energy {store.fleet_energy(d_energy):7.1f} J")

# --- 2. the Pareto front between them + its knee ----------------------------
front = store.pareto_front(N, num_points=9)
k = front.knee()
print(f"\nPareto front ({len(front)} points; * = knee):")
for i in range(len(front)):
    mark = " *" if i == k else "  "
    print(f"{mark} t={front.times[i]:.3f}s  E={front.energies[i]:7.1f} J  "
          f"d={[int(v) for v in front.allocations[i]]}")

# --- 3. an explicit energy budget picks the fastest point that fits ---------
cap = 0.65 * store.fleet_energy(d_time)
d_cap, t_cap = store.partition(N, energy_cap=cap)
print(f"\nbudget {cap:.0f} J: d={d_cap}  makespan {t_cap:.3f}s  "
      f"energy {store.fleet_energy(d_cap):.1f} J "
      f"(work moved off the hogs, bounded slowdown)")

# --- 4. one power-capped multi-tenant serving round -------------------------
loads = {"chat": 1400, "embed": 900}
free = FleetScheduler(P, backend="jax")
capped = FleetScheduler(P, backend="jax")
for fleet in (free, capped):
    for name, n in loads.items():
        fleet.admit(JobSpec(name=name, n=n, min_units=0),
                    models=speed_models, energy_models=energy_models)


def round_energy(ds):
    return sum(
        energy_models[i].time(float(di))
        for d in ds.values() for i, di in enumerate(d) if di > 0
    )


ds_free = free.rebalance()
budget = 0.75 * round_energy(ds_free)
capped.power_cap = budget
ds_cap = capped.rebalance()
print(f"\nserving round, 2 tenants, fleet budget {budget:.0f} J:")
for name in loads:
    print(f"  {name:6s} uncapped d={ds_free[name]} -> capped d={ds_cap[name]}")
print(f"  fleet energy {round_energy(ds_free):.0f} J uncapped, "
      f"{round_energy(ds_cap):.0f} J capped (fits the budget)")
