"""The paper's contribution: Distributed Functional Partitioning Algorithm.

DFPA balances ``n`` equal computation units across ``p`` processors whose
speed functions are *unknown a priori*, to relative accuracy ``eps``:

  1. run the even distribution ``n/p`` everywhere, gather times;
  2. if ``max_{i,j} |t_i - t_j|/t_i <= eps`` -> done;
  3. else turn observations into (partial, piecewise-linear) FPM estimates;
  4. re-partition optimally *for the current estimates* (algorithm [16],
     see ``partition.py``), execute the new distribution, measure;
  5. accumulate the new points into the estimates; goto 4.

.. deprecated::
    The loop now lives on the facade — :meth:`repro.core.scheduler.Scheduler.
    autotune` — where the model estimates are a :class:`SpeedStore` (backend
    resolved once, device carry maintained by ``fold_in``) and the result is
    a typed ``Partition``.  :func:`dfpa` remains as a thin shim: it emits
    ``DeprecationWarning``, delegates to ``Scheduler.autotune`` and repacks
    the ``Partition`` into the legacy :class:`DFPAResult`, preserving the
    exact round-by-round behaviour (the golden-trace suite holds it to
    that).

Extras beyond the bare paper loop (all flagged, all default-compatible):

* ``warm_models`` — start from surviving FPM estimates instead of the even
  distribution (elastic restarts re-use points, the paper's §3.2 trick of
  reusing "the results of all previous benchmarks");
* fixed-point escape by LOCAL PROBING: with a deterministic executor,
  re-running an already-measured distribution cannot improve the estimates,
  so when the partitioner repeats itself short of eps, DFPA probes a 1-unit
  perturbation (slowest processor donates to the fastest) — the new point
  sharpens the piecewise-linear estimate exactly around the operating point
  and re-launches progress;
* ``min_units`` — keep every processor participating (the matrix apps do);
* ``backend="jax"`` — the FPM estimates additionally live on device as a
  ``JaxModelBank`` *carry*: every round's observations are folded in with
  one vectorized sorted insert instead of rebuilding the padded arrays, and
  every re-partition runs the jitted device bisection.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from .executor import Executor
from .fpm import PiecewiseLinearFPM

__all__ = ["DFPAResult", "dfpa"]


@dataclass
class DFPAResult:
    d: List[int]  # final distribution (the paper's output array d)
    times: List[float]  # execution times observed for d (the output array t)
    iterations: int  # number of parallel rounds executed
    converged: bool  # eps test passed (False -> fixed-point/max_iter stop)
    imbalance: float  # final max |t_i - t_j| / t_i
    models: List[PiecewiseLinearFPM]  # the partial FPM estimates built
    history: List[Tuple[List[int], List[float]]] = field(default_factory=list)

    @property
    def points_per_proc(self) -> List[int]:
        return [m.num_points for m in self.models]


def dfpa(
    executor: Executor,
    n: int,
    eps: float,
    *,
    max_iter: int = 100,
    caps: Optional[Sequence[int]] = None,
    min_units: int = 0,
    warm_models: Optional[Sequence[PiecewiseLinearFPM]] = None,
    warm_start_d: Optional[Sequence[int]] = None,
    probe_budget: Optional[int] = None,
    backend: str = "numpy",
) -> DFPAResult:
    """Run DFPA over ``executor``.

    .. deprecated:: use ``Scheduler.autotune`` (see module docstring).
    """
    from .scheduler import Policy, Scheduler
    from .speedstore import SpeedStore, _warn_legacy

    _warn_legacy("dfpa()", "Scheduler.autotune()")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    p = executor.num_procs
    store = (
        SpeedStore.from_models(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in warm_models],
            backend=backend,
        )
        if warm_models is not None
        else SpeedStore.empty(max(p, 1), backend=backend)
    )
    sched = Scheduler(store, policy=Policy.DFPA, backend=backend)
    part = sched.autotune(
        executor, n, eps,
        max_iter=max_iter, caps=caps, min_units=min_units,
        warm_start_d=warm_start_d, probe_budget=probe_budget,
    )
    return DFPAResult(
        d=list(part.allocations),
        times=list(part.times),
        iterations=part.iterations,
        converged=part.converged,
        imbalance=part.imbalance,
        models=part.diagnostics["models"],
        history=part.diagnostics["history"],
    )
