"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.  Local/global alternating, softcaps, GeGLU, post-norms,
query scale 1/sqrt(d_model/num_heads) [arXiv:2408.00118; hf].
"""

import math

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=("local", "attn"),
    window=4096,
    mlp_kind="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    rope_theta=10000.0,
    query_scale=1.0 / math.sqrt(4608 / 32),  # 27b uses d_model/num_heads
    tie_embeddings=True,
    embed_scale=math.sqrt(4608),
    train_accum=4,
    attn_chunk_threshold=4096,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-27b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        window=8,
        query_scale=1.0 / math.sqrt(16),
        embed_scale=8.0,
        xent_chunk=0,
        remat="none",
    )
