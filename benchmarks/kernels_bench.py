"""Kernel benchmarks: Pallas (interpret-mode correctness cost) + jitted
oracle wall times per shape — the §3.1 computational-kernel analogue.

On this CPU container the meaningful numbers are the jnp-oracle wall times
(the compute layer DFPA actually measures here) and the kernels' VMEM
working-set accounting for the TPU target; Pallas wall-clock belongs to
real-TPU runs.
"""

from __future__ import annotations

import io
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def kernels_bench() -> str:
    out = io.StringIO()
    out.write("kernel,shape,host_us_per_call,vmem_working_set_kb\n")
    key = jax.random.PRNGKey(0)

    mm = jax.jit(ref.matmul_update_ref)
    for M, N, K, bm, bn, bk in [(256, 256, 512, 128, 128, 256), (512, 512, 1024, 256, 256, 512)]:
        a = jax.random.normal(key, (M, K), jnp.float32)
        b = jax.random.normal(key, (K, N), jnp.float32)
        c = jnp.zeros((M, N), jnp.float32)
        t = _time(mm, c, a, b)
        vmem = (bm * bk + bk * bn + 2 * bm * bn) * 4 / 1024
        out.write(f"matmul_update,{M}x{N}x{K},{t * 1e6:.0f},{vmem:.0f}\n")

    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    for B, H, S, D, bq, bk_ in [(1, 4, 512, 64, 256, 256), (2, 8, 1024, 128, 256, 256)]:
        q = jax.random.normal(key, (B, H, S, D), jnp.float32) * 0.1
        k = jax.random.normal(key, (B, H, S, D), jnp.float32) * 0.1
        v = jax.random.normal(key, (B, H, S, D), jnp.float32)
        t = _time(fa, q, k, v)
        vmem = (bq * D + 2 * bk_ * D + bq * bk_ + 2 * bq + bq * D) * 4 / 1024
        out.write(f"flash_attention,B{B}H{H}S{S}D{D},{t * 1e6:.0f},{vmem:.0f}\n")

    rg = jax.jit(ref.rglru_scan_ref)
    for B, S, D, bs, bd in [(2, 1024, 512, 256, 512), (4, 2048, 1024, 256, 512)]:
        la = -jax.nn.softplus(jax.random.normal(key, (B, S, D)))
        b = 0.1 * jax.random.normal(key, (B, S, D))
        t = _time(rg, la, b)
        vmem = (3 * bs * bd + bd) * 4 / 1024
        out.write(f"rglru_scan,B{B}S{S}D{D},{t * 1e6:.0f},{vmem:.0f}\n")
    return out.getvalue()
