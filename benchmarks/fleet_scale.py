"""Fleet-scale multi-tenant rounds: stacked driver vs q sequential loops.

The ``FleetScheduler`` claim is economic: q concurrent jobs' rounds cost
ONE stacked partition program (plus, while still measuring, one stacked
fold-in program), where q independent ``Scheduler`` sessions pay q (resp.
2q) device dispatches for the same work.  Two regimes are measured per
(q, p), both post-compile medians:

  * **measurement rounds** (``fleet_round_ms`` / ``seq_round_ms``) — the
    DFPA loop while estimates are still being built: stacked repartition +
    batched measurement + stacked fold-in for ALL q jobs, vs q independent
    jax-backend ``SpeedStore`` sessions (a noisy executor keeps every job
    measuring every round; the fold keeps growing the banks, so this
    regime is partly compute-bound);
  * **steady-state rebalance rounds** (``rebalance_*`` columns) — the
    serving end state the paper targets ("partial estimates sufficient for
    a given accuracy"): models frozen, tenant loads drift every round, and
    the per-round work is re-partitioning everyone —
    ``FleetScheduler.rebalance`` (one stacked program) vs q per-store
    partitions.  This is the dispatch-bound regime where batching pays.

Sweeps q ∈ {1..64} at p=100 and p ∈ {1000, 10000} at q=16 (full mode).

Acceptance gates (exit 1):
  * full mode — at every q >= 16: the stacked driver issues >= q x fewer
    device dispatches per round (all p), and the steady-state rebalance
    round is >= 3x faster wall-clock in the dispatch-bound regime (p=100
    rows; at p >= 1000 a CPU host is bound by the same bisection flops on
    both sides and the ratio converges to ~1x — reported, not gated);
  * quick mode (the CI smoke) — stacked-vs-sequential ALLOCATION PARITY at
    q=8 / p=100: a noise-free fleet must reproduce q independent
    ``Scheduler.autotune`` loops bit-for-bit (allocations, histories,
    folded estimates), plus the dispatch-ratio gate at q=8.

Results are written to ``BENCH_fleet.json``.

    PYTHONPATH=src python benchmarks/fleet_scale.py [--quick] [--out FILE]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

# Bit-identical-to-sequential is the parity gate; that needs doubles.
jax.config.update("jax_enable_x64", True)

from repro.core import (  # noqa: E402
    BatchedSimulatedExecutor2D,
    PiecewiseLinearFPM,
    Scheduler,
    SimulatedExecutor,
    SpeedStore,
)
from repro.fleet import FleetScheduler, JobSpec  # noqa: E402


def make_tenants(q: int, p: int, seed: int = 0):
    """q tenants on one p-processor fleet: per-(job, proc) plateau/knee
    ground truth (the partition_scale fleet shape, one per tenant) plus
    6-point warm banks sampled from it."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-6, 3e-6, (q, p))
    knee = rng.uniform(2e3, 2e4, (q, p))

    def time_fn(X):  # X[q, p] -> T[q, p]
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    warm = []
    for j in range(q):
        models = []
        for i in range(p):
            xs = np.geomspace(16.0, 8.0 * knee[j, i], 6)
            ts = xs * base[j, i] * (
                1.0 + np.where(xs > knee[j, i], 3.0 * (xs - knee[j, i]) / knee[j, i], 0.0)
            )
            models.append(PiecewiseLinearFPM.from_points(list(zip(xs, xs / ts))))
        warm.append(models)
    return time_fn, warm, base, knee


def steady_state_rounds(q, p, *, rounds, warmup, seed=0):
    """Median per-round wall-clock + dispatch counts for both drivers."""
    time_fn, warm, base, knee = make_tenants(q, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    # --- the stacked fleet driver ------------------------------------------
    fleet = FleetScheduler(p, backend="jax")
    for j in range(q):
        fleet.admit(
            JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1,
                    max_iter=10**9, probe_budget=10**9),
            models=warm[j],
        )
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=time_fn, p=p, q=q, job_names=names,
        noise=0.02, rng=np.random.default_rng(seed + 1),
    )

    # --- q sequential jax sessions (the pre-fleet pattern) -----------------
    stores = [
        SpeedStore.from_models(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in warm[j]],
            backend="jax",
        )
        for j in range(q)
    ]
    rng = np.random.default_rng(seed + 2)
    seq_dispatch = 2 * q  # one partition + one fold per job per round

    def seq_round():
        for j in range(q):
            d = stores[j].partition_units(ns[j], min_units=1)
            x = np.asarray(d, dtype=np.float64)
            t = x * base[j] * (
                1.0 + np.where(x > knee[j], 3.0 * (x - knee[j]) / knee[j], 0.0)
            )
            t = np.where(x > 0, np.maximum(
                t * (1.0 + 0.02 * rng.standard_normal(p)), 1e-12), 0.0)
            s = np.where((x > 0) & (t > 0), x / np.where(t > 0, t, 1.0), 1.0)
            stores[j].fold_in(x, s, (x > 0) & (t > 0))

    # Interleaved per-round timing (the partition_scale best_of_pair
    # convention): both drivers advance one round back-to-back, so
    # shared-container load drift hits the pair together and the MEDIAN of
    # per-round ratios stays honest even when absolute times wander.
    fleet_times, seq_times, ratios = [], [], []
    for r in range(warmup + rounds):
        t0 = time.perf_counter()
        fleet.step(ex)
        tf = time.perf_counter() - t0
        t0 = time.perf_counter()
        seq_round()
        tsq = time.perf_counter() - t0
        if r >= warmup:
            fleet_times.append(tf)
            seq_times.append(tsq)
            ratios.append(tsq / tf)
    assert len(fleet.active_jobs) == q, "benchmark jobs must not converge"
    fleet_dispatch = fleet.device_dispatches / fleet.rounds

    return {
        "q": q,
        "p": p,
        "n_per_job": ns[0],
        "rounds_timed": rounds,
        "fleet_round_ms": float(np.median(fleet_times) * 1e3),
        "seq_round_ms": float(np.median(seq_times) * 1e3),
        "wallclock_speedup": float(np.median(ratios)),
        "fleet_dispatches_per_round": fleet_dispatch,
        "seq_dispatches_per_round": float(seq_dispatch),
        "dispatch_ratio": seq_dispatch / fleet_dispatch,
    }


def rebalance_rounds(q, p, *, rounds, warmup, seed=0):
    """The serving steady state: tenant models already learned (the paper's
    'partial estimates sufficient for a given accuracy'), per-round work is
    re-partitioning everyone under drifting loads — ``FleetScheduler.
    rebalance`` (ONE stacked program) vs q per-store partitions.  This is
    the dispatch-bound regime the wall-clock gate runs on."""
    _, warm, _, _ = make_tenants(q, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    fleet = FleetScheduler(p, backend="jax")
    for j in range(q):
        fleet.admit(
            JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1),
            models=warm[j],
        )

    def loads(r):
        return {
            names[j]: ns[j] + ((r * 29 + j * 13) % max(7, p // 10))
            for j in range(q)
        }

    stores = [
        SpeedStore.from_models(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in warm[j]],
            backend="jax",
        )
        for j in range(q)
    ]

    # Interleaved, same rationale as the measurement rounds above.
    d0 = fleet.device_dispatches
    fleet_times, seq_times, ratios = [], [], []
    for r in range(warmup + rounds):
        ld = loads(r)
        t0 = time.perf_counter()
        fleet.rebalance(ld)
        tf = time.perf_counter() - t0
        t0 = time.perf_counter()
        for j in range(q):
            stores[j].partition_units(ld[names[j]], min_units=1)
        tsq = time.perf_counter() - t0
        if r >= warmup:
            fleet_times.append(tf)
            seq_times.append(tsq)
            ratios.append(tsq / tf)
    fleet_dispatch = (fleet.device_dispatches - d0) / (warmup + rounds)

    return {
        "rebalance_fleet_ms": float(np.median(fleet_times) * 1e3),
        "rebalance_seq_ms": float(np.median(seq_times) * 1e3),
        "rebalance_speedup": float(np.median(ratios)),
        "rebalance_fleet_dispatches_per_round": fleet_dispatch,
        "rebalance_seq_dispatches_per_round": float(q),
        "rebalance_dispatch_ratio": q / fleet_dispatch,
    }


def parity_gate(q=8, p=100, seed=11) -> bool:
    """Noise-free fleet vs q independent Scheduler.autotune loops: the
    bit-identity contract the CI smoke enforces (the full fuzz battery
    lives in tests/test_fleet.py)."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-5, 9e-5, (q, p))
    knee = rng.uniform(50.0, 500.0, (q, p))

    def batch_fn(X):
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    ns = [20 * p + 13 * j for j in range(q)]
    ok = True
    indep = []
    for j in range(q):
        fns = [
            (lambda b, k: lambda x: float(
                x * b * (1.0 + (3.0 * (x - k) / k if x > k else 0.0))
            ))(base[j, i], knee[j, i])
            for i in range(p)
        ]
        ex = SimulatedExecutor(time_fns=fns)
        sched = Scheduler(SpeedStore.empty(p, backend="jax"), backend="jax")
        indep.append(sched.autotune(ex, ns[j], 0.03, max_iter=8, min_units=1))
    fleet = FleetScheduler(p, backend="jax")
    names = [f"t{j}" for j in range(q)]
    for j in range(q):
        fleet.admit(JobSpec(name=names[j], n=ns[j], eps=0.03, min_units=1,
                            max_iter=8))
    ex2 = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=batch_fn, p=p, q=q, job_names=names
    )
    results = fleet.run(ex2)
    for j in range(q):
        r_f, r_i = results[names[j]], indep[j]
        if (
            r_f.allocations != r_i.allocations
            or r_f.times != r_i.times
            or r_f.diagnostics["history"] != r_i.diagnostics["history"]
        ):
            print(f"PARITY FAIL: job {names[j]} diverges from its "
                  f"independent Scheduler.autotune loop")
            ok = False
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke: parity gate + small sweep")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args(argv)

    if args.quick:
        sweep = [(1, 100), (8, 100)]
        rounds, warmup = args.rounds or 5, 3
    else:
        sweep = [(1, 100), (2, 100), (4, 100), (8, 100), (16, 100),
                 (32, 100), (64, 100), (16, 1000), (16, 10000)]
        rounds, warmup = args.rounds or 8, 3

    rows = []
    for q, p in sweep:
        row = steady_state_rounds(q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p)
        row.update(
            rebalance_rounds(q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p + 1)
        )
        rows.append(row)
        print(
            f"q={q:3d} p={p:6d}"
            f"  measure {row['fleet_round_ms']:8.2f} vs {row['seq_round_ms']:8.2f} ms"
            f" ({row['wallclock_speedup']:5.2f}x)"
            f"  rebalance {row['rebalance_fleet_ms']:8.2f} vs "
            f"{row['rebalance_seq_ms']:8.2f} ms ({row['rebalance_speedup']:5.2f}x)"
            f"  dispatches {row['fleet_dispatches_per_round']:.1f} vs "
            f"{row['seq_dispatches_per_round']:.0f}"
            f" ({row['dispatch_ratio']:5.1f}x fewer)",
            flush=True,
        )

    print("parity gate (q=8, p=100, noise-free) ...", flush=True)
    parity_ok = parity_gate()
    print("parity:", "OK" if parity_ok else "FAIL")

    payload = {
        "benchmark": "fleet_scale",
        "description": (
            "multi-tenant rounds, FleetScheduler vs q independent "
            "jax-backend sessions: measurement rounds (stacked [q,p,k] "
            "partition + fold-in = 2 programs/round vs 2q; 2% noise keeps "
            "every job measuring, so banks keep growing and large p turns "
            "compute-bound — and at p=10^4 the q-wide [q,p,k] working set "
            "falls out of CPU cache, so the stacked measurement round can "
            "even lose to sequential there) and steady-state rebalance "
            "rounds (models frozen, loads drift: FleetScheduler.rebalance "
            "= 1 program vs q — the dispatch-bound serving regime the >=3x "
            "wall-clock gate runs on at p=100); medians post-compile, "
            "fleet/sequential rounds interleaved so shared-runner load "
            "drift hits both together (speedup = median per-round ratio); "
            "parity = "
            "noise-free fleet reproduces q independent Scheduler.autotune "
            "loops bit-for-bit"
        ),
        "rounds_timed": rounds,
        "parity_q8_p100": parity_ok,
        "sweep": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"-> {args.out}")

    rc = 0
    if not parity_ok:
        rc = 1
    for row in rows:
        if row["q"] >= 16:
            if (
                row["dispatch_ratio"] < row["q"]
                or row["rebalance_dispatch_ratio"] < row["q"]
            ):
                print(f"FAIL: dispatch ratio {row['dispatch_ratio']:.1f}x < "
                      f"q={row['q']} at p={row['p']}")
                rc = 1
            # Wall-clock gate runs on the dispatch-bound serving regime
            # (steady-state rebalance rounds at p=100).  At p >= 1000 on a
            # CPU host both sides are bound by the SAME bisection flops and
            # converge to ~1x — reported, not gated; a real accelerator's
            # dispatch overhead is where the stacked win grows (ROADMAP:
            # real-TPU fleet lane).
            if row["p"] <= 100 and row["rebalance_speedup"] < 3.0:
                print(f"FAIL: steady-state rebalance speedup "
                      f"{row['rebalance_speedup']:.2f}x < 3x at q={row['q']}, "
                      f"p={row['p']}")
                rc = 1
    # quick mode: the dispatch economics must already show at q=8
    if args.quick:
        for row in rows:
            if row["q"] >= 8 and row["dispatch_ratio"] < row["q"]:
                print(f"FAIL: dispatch ratio {row['dispatch_ratio']:.1f}x < "
                      f"q={row['q']} in quick sweep")
                rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
