"""Architecture registry: ``get_config(name)`` / ``list_configs()``.

Each assigned architecture has a module exposing ``CONFIG`` (the exact
published shape) and ``smoke_config()`` (a reduced same-family variant for
CPU tests).  ``SHAPES`` defines the assigned input-shape set.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig

ARCH_IDS = [
    "granite-20b",
    "gemma2-2b",
    "stablelm-12b",
    "gemma2-27b",
    "deepseek-v2-236b",
    "granite-moe-1b-a400m",
    "pixtral-12b",
    "recurrentgemma-2b",
    "seamless-m4t-medium",
    "xlstm-350m",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.smoke_config()


def list_configs() -> List[str]:
    return list(ARCH_IDS)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if (arch, shape) is runnable; else a skip reason (recorded in
    EXPERIMENTS.md).  Per the assignment: long_500k only for sub-quadratic
    archs; decode shapes skip encoder-only archs (none assigned)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return "long_500k skipped: full/global attention is quadratic and the KV cache is unbounded"
    return None
