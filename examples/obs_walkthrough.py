"""Observing the scheduler: spans, counters, Chrome traces, flight recorder.

The paper's overhead claim — partial FPM estimation + repartitioning cost
orders of magnitude below the execution they optimize — is an observability
claim, so PR 10 gave the stack a telemetry substrate.  This walkthrough:

  1. installs a ``Telemetry`` sink and runs a fleet serving session under
     it, then reads the recorded spans/counters/gauges directly;
  2. exports the session as a Chrome-trace JSON (chrome://tracing or
     https://ui.perfetto.dev) and summarizes it with ``repro.obs.report``;
  3. forces a straggler QUARANTINE under a ``FlightRecorder`` and dumps the
     post-incident JSON naming the offender and its strike evidence.

Everything is off by default: with no sink installed every instrumentation
site short-circuits on a no-op (the BENCH_fleet ``obs_overhead`` gate holds
even the ENABLED cost under 2% of a serving epoch).

    PYTHONPATH=src python examples/obs_walkthrough.py
"""

import json
import os
import tempfile

import numpy as np

from repro import obs
from repro.core import PiecewiseLinearFPM
from repro.fleet import FleetScheduler, JobSpec
from repro.obs.report import MetricsSnapshot
from repro.runtime.straggler import StragglerDetector

# --- 1. a fleet serving session under an installed sink --------------------
p, q = 8, 3
rng = np.random.default_rng(0)
base = rng.uniform(1e-4, 4e-4, (q, p))


def times_for(j, d):
    return [x * base[j, i] if x > 0 else 0.0 for i, x in enumerate(d)]


tel = obs.Telemetry()  # unbounded; pass capacity= for a ring
obs.install(tel)  # process-global: every layer now reports
try:
    fleet = FleetScheduler(p, backend="numpy")
    for j in range(q):
        # warm per-replica models (linear: speed 1/base), as a registry or
        # prior session would provide — rebalance needs non-empty FPMs
        warm = [
            PiecewiseLinearFPM.from_points([(1.0, 1.0 / base[j, i]),
                                            (1e6, 1.0 / base[j, i])])
            for i in range(p)
        ]
        fleet.admit(JobSpec(name=f"tenant{j}", n=800 + j, eps=0.05), models=warm)
    for _ in range(4):  # serving epochs: one rebalance + one fold each
        ds = fleet.rebalance()
        fleet.observe({f"tenant{j}": times_for(j, ds[f"tenant{j}"]) for j in range(q)})
finally:
    obs.uninstall()  # back to the no-op

print(f"recorded {len(tel.events)} events")
spans = sorted({e.name for e in tel.spans()})
print(f"span kinds: {spans}")
print(f"counters: {dict(tel.counters)}")
print(f"fleet.rounds gauge: {tel.gauges['fleet.rounds']}")
print(f"public stats (same numbers, no telemetry needed): {fleet.stats()}")

# --- 2. Chrome-trace export + the paper-style report -----------------------
outdir = tempfile.mkdtemp(prefix="obs_walkthrough_")
trace_path = os.path.join(outdir, "fleet_trace.json")
obs.export_chrome_trace(tel, trace_path)
snap = MetricsSnapshot.from_file(trace_path)
print(f"\n-> {trace_path} (open in chrome://tracing)")
print(snap.table())

# --- 3. flight recorder: forensics from a forced QUARANTINE ----------------
flight = obs.FlightRecorder(capacity=256, snapshot_capacity=8)
det = StragglerDetector(factor=1.5, patience=3, patience_hard=6)
# healthy model: 10 units should take 0.01 s
model = PiecewiseLinearFPM.from_points([(1.0, 1000.0), (100.0, 1000.0)])
with obs.use(flight):
    action = None
    for step in range(8):
        flight.snapshot(f"step:{step}", {"predicted": model.time(10.0),
                                         "observed": 0.04})
        # replica 2 persistently 4x slower than its model predicts
        action = det.update(2, model, d_units=10, observed_t=0.04)
        if action.value == "quarantine":
            break
    rec_path = os.path.join(outdir, "quarantine.flightrec.json")
    flight.dump(rec_path, reason="quarantine",
                context={"replica": 2, "action": action.value, "step": step})

dump = json.load(open(rec_path))
print(f"\n-> {rec_path}")
print(f"flight recorder: reason={dump['reason']!r} context={dump['context']}")
strikes = [e for e in dump["events"] if e["name"] == "straggler.strike"]
print(f"ring held {len(dump['events'])} events incl. {len(strikes)} strike "
      f"events; last evidence: {strikes[-1]['attrs']}")
print("\n(serve_trace.py --trace wires all of this into the serving "
      "benchmark: per-replica tracks, overhead gauges, auto-dump on "
      "QUARANTINE or gate failure.)")
