"""BalanceController: the paper's DFPA running ONLINE inside training.

The paper runs dedicated benchmark rounds; in a training loop every global
step already measures exactly what DFPA needs — ``t_i(d_i)`` for the current
distribution — so probing is FREE (beyond-paper integration; flagged in
DESIGN.md).  The controller:

  1. starts from the even distribution (or a warm start from checkpointed
     FPM points after an elastic event);
  2. after each global step, folds the observed per-group times into the
     piecewise-linear FPM estimates (the paper's step 5);
  3. when the imbalance exceeds ``eps``, re-partitions the units with the
     geometric algorithm of [16] (the paper's step 3) — next step runs the
     new distribution;
  4. exposes its FPM points for checkpointing (self-adaptability across
     restarts) and for the straggler detector.

EMA smoothing (``smooth``) de-noises wall-clock measurements — the paper's
deterministic-benchmark assumption does not hold for real step times.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.fpm import PiecewiseLinearFPM, imbalance
from ..core.modelbank import ModelBank
from ..core.partition import partition_units

__all__ = ["BalanceController", "GroupTimer"]


@dataclass
class GroupTimer:
    """Host-side wall-clock timing of one group's step (the paper's
    ``t_i(d_i)`` measurement)."""

    _t0: float = 0.0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        return time.perf_counter() - self._t0


@dataclass
class BalanceController:
    n_units: int  # units per global step (microbatches)
    num_groups: int
    eps: float = 0.1
    min_units: int = 1
    smooth: float = 0.5  # EMA weight of the newest observation
    caps: Optional[Sequence[int]] = None  # per-group HBM unit capacity
    backend: str = "numpy"  # "jax": device-resident bank + jitted partitioner

    models: List[PiecewiseLinearFPM] = field(default_factory=list)
    d: List[int] = field(default_factory=list)
    _ema: Dict[Tuple[int, int], float] = field(default_factory=dict)
    _device_bank: Optional[object] = field(default=None, repr=False)
    rebalances: int = 0
    steps_observed: int = 0

    def __post_init__(self):
        if not self.models:
            self.models = [PiecewiseLinearFPM() for _ in range(self.num_groups)]
        if not self.d:
            base, rem = divmod(self.n_units, self.num_groups)
            self.d = [base + (1 if i < rem else 0) for i in range(self.num_groups)]

    # -- the online DFPA loop -------------------------------------------------

    def observe(self, times: Sequence[float]) -> bool:
        """Fold one global step's per-group times in; returns True if the
        distribution changed (callers must re-split the next step's units)."""
        if len(times) != self.num_groups:
            raise ValueError("times length != num_groups")
        self.steps_observed += 1
        speeds = [1.0] * self.num_groups
        valid = [False] * self.num_groups
        for i, (di, ti) in enumerate(zip(self.d, times)):
            if di <= 0 or ti <= 0:
                continue
            key = (i, di)
            ema = self._ema.get(key)
            ema = ti if ema is None else (1 - self.smooth) * ema + self.smooth * ti
            self._ema[key] = ema
            self.models[i].add_point(float(di), di / ema)
            speeds[i], valid[i] = di / ema, True
        if self.backend == "jax":
            # Fold the EMA-smoothed operating points into the device carry
            # (duplicate d_i replaces the speed, exactly like add_point) —
            # the jitted partitioner below reads the bank without a rebuild.
            self._device_bank = self._carry_bank().fold_in(
                [float(di) for di in self.d], speeds, valid
            )
        if imbalance(times) <= self.eps:  # zero-allocation groups are ignored
            return False
        src = (
            self._device_bank
            if self.backend == "jax" and self._device_bank is not None
            else self.models
        )
        new_d = partition_units(
            src, self.n_units, self.caps,
            min_units=self.min_units, backend=self.backend,
        )
        if new_d == self.d:
            return False
        self.d = new_d
        self.rebalances += 1
        return True

    def bank(self) -> ModelBank:
        """Batched snapshot of the current per-group FPM estimates.

        Rebuilt on demand (the estimates mutate every observed step);
        fleet-wide consumers — e.g. ``StragglerDetector.update_batch`` —
        use this instead of looping over the scalar models.
        """
        return ModelBank.from_models(self.models)

    def _carry_bank(self):
        """The internal fold-in carry (donation-eligible: its buffers may be
        consumed by the next ``observe``)."""
        if self._device_bank is not None:
            return self._device_bank
        from ..core.modelbank_jax import JaxModelBank

        if any(m.num_points > 0 for m in self.models):
            return JaxModelBank.from_models(self.models)
        return JaxModelBank.empty(self.num_groups)

    def device_bank(self):
        """The ``JaxModelBank`` snapshot the jitted partitioner consumes.

        With ``backend="jax"`` this is the incrementally maintained device
        carry (observations folded in each step); otherwise it is built from
        the scalar models on demand.  Either way the controller can hand it
        straight to ``partition_units(..., backend="jax")``.  On platforms
        where the fold-in donates its carry the snapshot is a copy, so the
        next ``observe`` cannot invalidate the caller's reference.
        """
        from ..core.modelbank_jax import DONATES_CARRY

        bank = self._carry_bank()
        return bank.copy() if DONATES_CARRY else bank

    @property
    def imbalance_estimate(self) -> float:
        ts = [m.time(di) for m, di in zip(self.models, self.d) if di > 0 and m.num_points]
        return imbalance(ts)

    # -- persistence (self-adaptability across restarts) ----------------------

    def state_dict(self) -> Dict:
        return {
            "n_units": self.n_units,
            "d": list(self.d),
            "points": [m.as_points() for m in self.models],
        }

    @classmethod
    def from_state(cls, state: Dict, *, eps: float = 0.1, **kw) -> "BalanceController":
        models = [PiecewiseLinearFPM.from_points(p) for p in state["points"]]
        return cls(
            n_units=state["n_units"],
            num_groups=len(models),
            eps=eps,
            models=models,
            d=list(state["d"]),
            **kw,
        )
