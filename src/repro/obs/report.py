"""Summarize a telemetry recording into the paper-style table.

``MetricsSnapshot.from_payload`` accepts either artifact this package
writes — a Chrome-trace export (``{"traceEvents": [...], "repro": ...}``)
or a flight-recorder / ``Telemetry.to_payload()`` dump (``{"events": ...,
"counters": ..., "gauges": ...}``) — and distills the scheduler-stack
signals into one row: the paper's overhead fraction (distribution cost vs
execution time), device dispatches per round, compile counts, speculation
hit rates, and straggler reaction.

Run it on a file::

    python -m repro.obs.report trace.json
"""

from __future__ import annotations

import json
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["MetricsSnapshot", "summarize", "main"]


def _load_counters_gauges(payload: Dict[str, Any]) -> Tuple[Dict, Dict, List]:
    """(counters, gauges, span rows) from either artifact format.  Span rows
    are ``(name, duration_seconds)``."""
    spans: List[Tuple[str, float]] = []
    if "traceEvents" in payload:
        repro = payload.get("repro", {})
        for ev in payload["traceEvents"]:
            if ev.get("ph") == "X":
                spans.append((ev.get("name", "?"), float(ev.get("dur", 0.0)) / 1e6))
        return dict(repro.get("counters", {})), dict(repro.get("gauges", {})), spans
    for e in payload.get("events", []):
        if e.get("kind") == "span":
            spans.append((e.get("name", "?"), float(e["t1"]) - float(e["t0"])))
    return (
        dict(payload.get("counters", {})),
        dict(payload.get("gauges", {})),
        spans,
    )


@dataclass
class MetricsSnapshot:
    """One summarized recording (all fields optional: a recording made by a
    bare ``Scheduler`` simply leaves the fleet/serving rows None)."""

    rounds: Optional[float] = None
    device_dispatches: Optional[float] = None
    dispatches_per_round: Optional[float] = None
    restacks: Optional[float] = None
    recompiles_partition: float = 0.0
    recompiles_fold: float = 0.0
    predispatches: Optional[float] = None
    stale_reads: Optional[float] = None
    speculative_misses: Optional[float] = None
    speculation_hit_rate: Optional[float] = None
    fold_ins: float = 0.0
    overhead_frac: Optional[float] = None
    reaction_epochs: Optional[float] = None
    strikes: float = 0.0
    reprofiles: float = 0.0
    quarantines: float = 0.0
    span_totals: Dict[str, float] = field(default_factory=dict)
    span_counts: Dict[str, int] = field(default_factory=dict)

    @classmethod
    def from_payload(cls, payload: Dict[str, Any]) -> "MetricsSnapshot":
        counters, gauges, spans = _load_counters_gauges(payload)
        snap = cls()
        snap.rounds = gauges.get("fleet.rounds")
        snap.device_dispatches = gauges.get("fleet.device_dispatches")
        if snap.rounds and snap.device_dispatches is not None:
            snap.dispatches_per_round = snap.device_dispatches / snap.rounds
        snap.restacks = gauges.get("fleet.restacks")
        snap.recompiles_partition = counters.get("fleet.recompile.partition", 0.0)
        snap.recompiles_fold = counters.get("fleet.recompile.fold", 0.0)
        snap.predispatches = gauges.get("fleet.predispatches")
        snap.stale_reads = gauges.get("fleet.stale_reads")
        snap.speculative_misses = gauges.get("fleet.speculative_misses")
        if snap.stale_reads is not None and snap.speculative_misses is not None:
            tried = snap.stale_reads + snap.speculative_misses
            if tried > 0:
                snap.speculation_hit_rate = snap.stale_reads / tried
        snap.fold_ins = counters.get("speedstore.fold_in", 0.0)
        snap.overhead_frac = gauges.get("serve.rebalance_overhead_frac")
        snap.reaction_epochs = gauges.get("serve.reaction_epochs")
        snap.strikes = counters.get("straggler.strike", 0.0)
        snap.reprofiles = counters.get("straggler.reprofile", 0.0)
        snap.quarantines = counters.get("straggler.quarantine", 0.0)
        for name, dur in spans:
            snap.span_totals[name] = snap.span_totals.get(name, 0.0) + dur
            snap.span_counts[name] = snap.span_counts.get(name, 0) + 1
        return snap

    @classmethod
    def from_file(cls, path: str) -> "MetricsSnapshot":
        with open(path) as f:
            return cls.from_payload(json.load(f))

    def table(self) -> str:
        """The paper-style summary table as a string."""
        rows: List[Tuple[str, str]] = []

        def add(label: str, v, fmt: str = "{:.4g}") -> None:
            if v is not None:
                rows.append((label, fmt.format(v)))

        add("overhead fraction (rebalance / serving)", self.overhead_frac, "{:.4%}")
        add("rounds", self.rounds, "{:.0f}")
        add("device dispatches", self.device_dispatches, "{:.0f}")
        add("dispatches / round", self.dispatches_per_round)
        add("restacks", self.restacks, "{:.0f}")
        add("recompiles (partition)", self.recompiles_partition, "{:.0f}")
        add("recompiles (fold)", self.recompiles_fold, "{:.0f}")
        add("pre-dispatched partitions", self.predispatches, "{:.0f}")
        add("speculative reads consumed", self.stale_reads, "{:.0f}")
        add("speculative misses", self.speculative_misses, "{:.0f}")
        add("speculation hit rate", self.speculation_hit_rate, "{:.1%}")
        add("fold-ins", self.fold_ins or None, "{:.0f}")
        add("straggler strikes", self.strikes or None, "{:.0f}")
        add("reprofiles", self.reprofiles or None, "{:.0f}")
        add("quarantines", self.quarantines or None, "{:.0f}")
        add("reaction (epochs)", self.reaction_epochs, "{:.0f}")
        width = max((len(k) for k, _ in rows), default=10)
        lines = [f"  {k:<{width}}  {v}" for k, v in rows]
        if self.span_totals:
            lines.append("")
            lines.append("  span wall totals:")
            for name in sorted(self.span_totals, key=self.span_totals.get,
                               reverse=True):
                lines.append(
                    f"    {name:<28} {self.span_totals[name] * 1e3:10.3f} ms"
                    f"  x{self.span_counts[name]}"
                )
        return "\n".join(lines)


def summarize(path: str) -> MetricsSnapshot:
    snap = MetricsSnapshot.from_file(path)
    print(f"== {path}")
    print(snap.table())
    return snap


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.obs.report TRACE_OR_RECORDER_JSON...",
              file=sys.stderr)
        return 2
    for path in argv:
        summarize(path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
