"""Quickstart: the paper's DFPA through the Scheduler facade, in 30 lines.

An application lands on an UNKNOWN heterogeneous cluster (here: the
calibrated HCL simulator).  One ``Scheduler`` session balances the workload
online, without any pre-built performance model, in a handful of rounds —
``autotune`` runs the paper's measurement loop and returns a typed
``Partition``; the warm session stays ready for ``observe``/``join``/
``leave``.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    Scheduler,
    SimulatedExecutor,
    make_hcl_time_fns,
    matmul_app_time_1d,
)

N = 5120  # matrix size: rows to distribute (1 unit = 1 row of A/C)
EPS = 0.025  # paper's tight accuracy

specs, time_fns = make_hcl_time_fns(N)
row_fns = [(lambda tf: lambda rows: tf(rows * N))(tf) for tf in time_fns]

executor = SimulatedExecutor(time_fns=row_fns)
sched = Scheduler()  # DFPA policy, numpy backend — resolved once, here
result = sched.autotune(executor, N, EPS, min_units=1)

print(f"processors        : {len(specs)} ({specs[0].name}..{specs[-1].name})")
print(f"converged         : {result.converged} in {result.iterations} rounds")
print(f"final imbalance   : {result.imbalance:.3f} (eps={EPS})")
print(f"distribution      : min={min(result.allocations)} max={max(result.allocations)} rows")
print(f"model points used : max {max(m.num_points for m in sched.models)} per processor")
print(f"DFPA cost         : {executor.total_cost:.2f}s")
print(f"matmul app time   : {matmul_app_time_1d(time_fns, result.allocations, N):.1f}s")
print("=> partitioning cost is orders of magnitude below the app time,")
print("   with no pre-built performance model — the paper's headline claim.")
