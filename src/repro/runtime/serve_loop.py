"""Serving: prefill/decode engine + DFPA-balanced request dispatch.

Serving is the second place the paper's model fits naturally: per-replica
decode throughput is a *nonlinear* function of batch size (KV-cache
bandwidth, batch-dependent kernel efficiency, HBM spill past a batch
threshold) — a speed function s(x), unknown a priori on a heterogeneous
fleet.  ``ReplicaDispatcher`` runs DFPA over request chunks.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.executor import Executor, RoundLog
from ..core.scheduler import Partition, Policy, Scheduler
from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_cache, prefill

__all__ = ["ServeEngine", "ReplicaDispatcher"]


class ServeEngine:
    """Single-replica engine: jit'd prefill + decode with a fixed KV budget."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int, seq_budget: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.seq_budget = seq_budget
        self._prefill = jax.jit(functools.partial(prefill, cfg=cfg))
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))

    def new_cache(self):
        return init_cache(self.cfg, self.batch, self.seq_budget, self.cfg.dtype)

    def generate(
        self, tokens: jax.Array, max_new: int, *, greedy: bool = True
    ) -> jax.Array:
        """tokens: (B, S_prompt) -> (B, max_new) generated ids."""
        caches = self.new_cache()
        logits, caches = self._prefill(params=self.params, tokens=tokens, caches=caches)
        out = []
        pos = tokens.shape[1]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
        for i in range(1, max_new):
            logits, caches = self._decode(
                params=self.params, token=tok, pos=jnp.asarray(pos, jnp.int32),
                caches=caches,
            )
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out.append(tok)
            pos += 1
        return jnp.concatenate(out, axis=1)


@dataclass
class ReplicaDispatcher:
    """DFPA over request chunks across heterogeneous serving replicas.

    ``replica_run(i, x)`` must process ``x`` request chunks on replica ``i``
    and return the wall time (real engines or simulators both fit).  The
    dispatcher is an ``Executor``; :meth:`balance` drives it through the
    ``Scheduler`` facade and leaves the warm session on ``self.scheduler``
    for the online lifecycle (``observe`` / ``join`` / ``leave``).

    Fleet mode (multi-tenant serving): :meth:`balance_fleet` admits one job
    per tenant request stream into a ``FleetScheduler`` — one stacked device
    bank, one partition + one fold-in program per round for ALL tenants —
    and leaves the warm fleet session on ``self.fleet`` for the online
    lifecycle (``admit`` / ``retire`` / ``resize`` / further ``step`` s).
    With a ``ProfileRegistry`` (plus ``device_classes``) and per-tenant
    ``workload`` tags, tenants warm-start from profiles saved by earlier
    sessions instead of paying cold CPM probes.
    """

    replica_run: Callable[[int, int], float]
    num_replicas: int
    eps: float = 0.1
    logs: List[RoundLog] = field(default_factory=list)
    scheduler: Optional[Scheduler] = None
    fleet: object = None  # warm FleetScheduler session (balance_fleet)

    @property
    def num_procs(self) -> int:
        return self.num_replicas

    def run(self, d: Sequence[int]) -> List[float]:
        times = [
            self.replica_run(i, int(x)) if x > 0 else 0.0 for i, x in enumerate(d)
        ]
        self.logs.append(RoundLog(list(map(int, d)), times, max(times)))
        return times

    def run_jobs(self, names: Sequence[str], D):
        """FleetExecutor protocol: one multi-tenant round — every measuring
        tenant's chunks on every replica (time-sliced per replica, so each
        (tenant, replica) cell is an independent ``replica_run`` call)."""
        import numpy as np

        out = []
        for k, _name in enumerate(names):
            d = [int(v) for v in D[k]]
            times = self.run(d)
            out.append(times)
        return np.asarray(out, dtype=np.float64)

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times)

    def balance(self, n_chunks: int, **kw) -> Partition:
        """Find the balanced chunk distribution for this fleet (the DFPA
        measurement loop, via the facade)."""
        if self.scheduler is None:
            self.scheduler = Scheduler(policy=Policy.DFPA, eps=self.eps)
        return self.scheduler.autotune(self, n_chunks, self.eps, **kw)

    def balance_fleet(
        self,
        tenants: Dict[str, int],
        *,
        backend: str = "jax",
        registry=None,
        device_classes: Optional[Sequence[str]] = None,
        workloads: Optional[Dict[str, str]] = None,
        **kw,
    ) -> Dict[str, Partition]:
        """Balance every tenant's chunk stream concurrently: ``tenants``
        maps tenant name -> its chunk count ``n``; returns tenant ->
        ``Partition``.  One ``FleetScheduler`` round serves all tenants
        (see the class docstring); extra ``kw`` become per-job ``JobSpec``
        fields (``min_units``, ``max_iter``, ...)."""
        from ..fleet import FleetScheduler, JobSpec

        self.fleet = FleetScheduler(
            self.num_replicas,
            backend=backend,
            registry=registry,
            device_classes=device_classes,
            alpha=0.0,
            beta=0.0,
        )
        for name, n in tenants.items():
            self.fleet.admit(
                JobSpec(
                    name=name,
                    n=int(n),
                    eps=self.eps,
                    workload=(workloads or {}).get(name),
                    **kw,
                )
            )
        return self.fleet.run(self)
