"""Decoder-LM assembly: blocks, scan-over-layers, caches, loss.

Depth is organized as ``prefix`` (unscanned, e.g. deepseek-v2's dense first
layer) + ``num_units`` repetitions of ``cfg.pattern`` scanned with
``jax.lax.scan`` over stacked parameters (one XLA program per *pattern unit*
regardless of depth — compile time for granite-20b's 52 layers equals one
unit).  ``cfg.remat == "full"`` wraps the unit body in ``jax.checkpoint``.

Cache pytree: ``{"prefix": (per-layer,), "units": (per-slot stacked,)}`` —
slot caches carry a leading ``num_units`` dim and thread through the scan as
xs/ys; hidden state is the carry.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.params import ParamSpec
from ..sharding.context import maybe_constrain
from .attention import (
    apply_attn,
    apply_mla,
    attn_spec,
    init_attn_cache,
    init_mla_cache,
    mla_spec,
)
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, embedding_spec, mlp_spec, norm_spec, softcap, stacked
from .moe import apply_moe, moe_spec
from .recurrent import (
    apply_mlstm_block,
    apply_rglru_block,
    apply_slstm_block,
    init_mlstm_cache,
    init_rglru_cache,
    init_slstm_cache,
    mlstm_spec,
    rglru_spec,
    slstm_spec,
)

__all__ = [
    "lm_spec",
    "apply_lm",
    "lm_logits",
    "lm_loss",
    "init_cache",
    "prefill",
    "decode_step",
    "block_spec",
    "apply_block",
]

_SELF_CONTAINED = ("mlstm", "slstm")  # kinds with no separate MLP sub-layer


# ---------------------------------------------------------------------------
# Block spec / apply
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, kind: str, *, moe: bool, d_ff: int, cross: bool = False) -> Dict:
    if kind == "mlstm":
        return {"norm": norm_spec(cfg.d_model, cfg.norm_kind), "mix": mlstm_spec(cfg)}
    if kind == "slstm":
        return {"norm": norm_spec(cfg.d_model, cfg.norm_kind), "mix": slstm_spec(cfg)}
    spec: Dict[str, Any] = {"norm1": norm_spec(cfg.d_model, cfg.norm_kind)}
    if kind in ("attn", "local"):
        spec["attn"] = mla_spec(cfg) if cfg.mla else attn_spec(cfg)
    elif kind == "rec":
        spec["rec"] = rglru_spec(cfg)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    if cross:
        spec["norm_x"] = norm_spec(cfg.d_model, cfg.norm_kind)
        spec["xattn"] = attn_spec(cfg, cross=True)
    spec["norm2"] = norm_spec(cfg.d_model, cfg.norm_kind)
    spec["mlp"] = moe_spec(cfg) if moe else mlp_spec(cfg.d_model, d_ff, cfg.mlp_kind)
    if cfg.post_norms:
        spec["post_norm1"] = norm_spec(cfg.d_model, cfg.norm_kind)
        spec["post_norm2"] = norm_spec(cfg.d_model, cfg.norm_kind)
    return spec


def apply_block(
    params: Dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    *,
    moe: bool,
    cache: Optional[Dict] = None,
    decode: bool = False,
    causal: bool = True,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)

    if kind in _SELF_CONTAINED:
        h = apply_norm(params["norm"], x)
        fn = apply_mlstm_block if kind == "mlstm" else apply_slstm_block
        y, new_cache = fn(params["mix"], cfg, h, cache=cache, decode=decode)
        return x + y, new_cache, aux

    h = apply_norm(params["norm1"], x)
    if kind in ("attn", "local"):
        if cfg.mla:
            y, new_cache = apply_mla(params["attn"], cfg, h, positions, cache=cache, decode=decode)
        else:
            y, new_cache = apply_attn(
                params["attn"], cfg, h, positions, kind=kind, causal=causal,
                cache=cache, decode=decode,
            )
    else:  # rec
        y, new_cache = apply_rglru_block(params["rec"], cfg, h, cache=cache, decode=decode)
    if cfg.post_norms:
        y = apply_norm(params["post_norm1"], y)
    x = x + y

    if cross_kv is not None:
        h = apply_norm(params["norm_x"], x)
        y, _ = apply_attn(
            params["xattn"], cfg, h, positions, kind="attn", causal=False, cross_kv=cross_kv
        )
        x = x + y

    h = apply_norm(params["norm2"], x)
    if moe:
        y, aux = apply_moe(params["mlp"], cfg, h)
    else:
        y = apply_mlp(params["mlp"], h, cfg.mlp_kind)
    if cfg.post_norms:
        y = apply_norm(params["post_norm2"], y)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# Model spec
# ---------------------------------------------------------------------------


def _layer_is_moe(cfg: ModelConfig, kind: str, in_prefix: bool) -> bool:
    return cfg.is_moe and not in_prefix and kind not in _SELF_CONTAINED


def lm_spec(cfg: ModelConfig) -> Dict:
    spec: Dict[str, Any] = {"embed": embedding_spec(cfg.vocab_size, cfg.d_model)}
    spec["prefix"] = tuple(
        block_spec(cfg, k, moe=False, d_ff=cfg.prefix_dense_ff or cfg.d_ff)
        for k in cfg.prefix
    )
    spec["units"] = tuple(
        stacked(block_spec(cfg, k, moe=_layer_is_moe(cfg, k, False), d_ff=cfg.d_ff), cfg.num_units)
        for k in cfg.pattern
    )
    spec["final_norm"] = norm_spec(cfg.d_model, cfg.norm_kind)
    if not cfg.tie_embeddings:
        spec["head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return spec


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _kind_cache(cfg: ModelConfig, kind: str, batch: int, seq_budget: int, dtype):
    if kind in ("attn", "local"):
        if cfg.mla:
            return init_mla_cache(cfg, batch, seq_budget, dtype)
        return init_attn_cache(cfg, kind, batch, seq_budget, dtype)
    if kind == "rec":
        return init_rglru_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def _kind_cache_axes(cfg: ModelConfig, kind: str):
    from .attention import attn_cache_axes, mla_cache_axes
    from .recurrent import mlstm_cache_axes, rglru_cache_axes, slstm_cache_axes

    if kind in ("attn", "local"):
        return mla_cache_axes(cfg) if cfg.mla else attn_cache_axes(cfg, kind)
    if kind == "rec":
        return rglru_cache_axes(cfg)
    if kind == "mlstm":
        return mlstm_cache_axes(cfg)
    if kind == "slstm":
        return slstm_cache_axes(cfg)
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig) -> Dict:
    """Logical-axes tree mirroring ``init_cache`` (units get a leading
    'layers' stack axis)."""
    prefix = tuple(_kind_cache_axes(cfg, k) for k in cfg.prefix)
    units = tuple(
        jax.tree_util.tree_map(
            lambda a: ("layers",) + a,
            _kind_cache_axes(cfg, k),
            is_leaf=lambda x: isinstance(x, tuple),
        )
        for k in cfg.pattern
    )
    return {"prefix": prefix, "units": units}


def init_cache(cfg: ModelConfig, batch: int, seq_budget: int, dtype=jnp.bfloat16) -> Dict:
    prefix = tuple(_kind_cache(cfg, k, batch, seq_budget, dtype) for k in cfg.prefix)
    units = tuple(
        jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_units,) + a.shape).copy()
            if isinstance(a, jax.Array)
            else a,
            _kind_cache(cfg, k, batch, seq_budget, dtype),
        )
        for k in cfg.pattern
    )
    return {"prefix": prefix, "units": units}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed_tokens(params: Dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    # Cast BEFORE the gather: the SPMD partitioner then keeps the
    # vocab-sharded table local (masked partial gather + psum of (B,S,d))
    # instead of all-gathering the fp32 master table every step.
    e = params["embed"]["embedding"].astype(cfg.dtype)
    x = e[tokens]
    if cfg.embed_scale != 1.0:
        x = x * jnp.asarray(cfg.embed_scale, cfg.dtype)
    return x


def apply_lm(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S_text)
    positions: jax.Array,  # (S,) over the FULL sequence (prefix + text)
    *,
    caches: Optional[Dict] = None,
    decode: bool = False,
    prefix_embeds: Optional[jax.Array] = None,  # (B, P, d) modality stub
    cross_kv_units: Optional[Tuple] = None,  # enc-dec decoder use
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (hidden (B,S,d), new_caches, aux_loss_sum)."""
    x = _embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = maybe_constrain(x, ("batch", "seq_act", "embed_act"))
    aux_total = jnp.zeros((), jnp.float32)

    new_prefix = []
    for i, kind in enumerate(cfg.prefix):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, aux = apply_block(
            params["prefix"][i], cfg, kind, x, positions,
            moe=False, cache=c, decode=decode, causal=causal,
        )
        new_prefix.append(nc)
        aux_total += aux

    # Scanned units.
    n_slots = len(cfg.pattern)
    unit_params = params["units"]
    unit_caches = caches["units"] if caches is not None else None

    def unit_body(x, slot_params, slot_caches):
        # Sequence-sharded residual stream (no-op without an active mesh);
        # the remat-stored scan carry inherits this sharding.
        x = maybe_constrain(x, ("batch", "seq_act", "embed_act"))
        new_slot_caches = []
        aux_u = jnp.zeros((), jnp.float32)
        for s, kind in enumerate(cfg.pattern):
            c = slot_caches[s] if slot_caches is not None else None
            xkv = cross_kv_units[s] if cross_kv_units is not None else None
            x, nc, aux = apply_block(
                slot_params[s], cfg, kind, x, positions,
                moe=_layer_is_moe(cfg, kind, False), cache=c, decode=decode,
                causal=causal, cross_kv=xkv,
            )
            new_slot_caches.append(nc)
            aux_u += aux
        return x, tuple(new_slot_caches), aux_u

    if cfg.remat == "full":
        unit_body = jax.checkpoint(unit_body)

    if cfg.scan_layers and cfg.num_units > 0:
        if unit_caches is None:
            def scan_fn(carry, xs):
                x, aux_acc = carry
                x, _, aux_u = unit_body(x, xs, None)
                return (x, aux_acc + aux_u), None

            (x, aux_total), _ = jax.lax.scan(scan_fn, (x, aux_total), unit_params)
            new_units = None
        else:
            def scan_fn(carry, xs):
                x, aux_acc = carry
                sp, sc = xs
                x, ncs, aux_u = unit_body(x, sp, sc)
                return (x, aux_acc + aux_u), ncs

            (x, aux_total), new_units = jax.lax.scan(
                scan_fn, (x, aux_total), (unit_params, unit_caches)
            )
    else:
        new_units_list = []
        for u in range(cfg.num_units):
            sp = jax.tree_util.tree_map(lambda a: a[u], unit_params)
            sc = (
                jax.tree_util.tree_map(lambda a: a[u], unit_caches)
                if unit_caches is not None
                else None
            )
            x, ncs, aux_u = unit_body(x, sp, sc)
            aux_total += aux_u
            new_units_list.append(ncs)
        new_units = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_units_list)
            if unit_caches is not None
            else None
        )

    x = apply_norm(params["final_norm"], x)
    new_caches = None
    if caches is not None:
        new_caches = {"prefix": tuple(new_prefix), "units": new_units}
    return x, new_caches, aux_total


def lm_logits(params: Dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["embedding"].T
    else:
        w = params["head"]
    logits = (hidden @ w.astype(hidden.dtype)).astype(cfg.logit_dtype)
    return softcap(logits, cfg.final_softcap)


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy over sequence)
# ---------------------------------------------------------------------------


def lm_loss(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens (B,S), labels (B,S) int32 (-1 = ignore),
    optional prefix_embeds (B,P,d).  Returns (loss, metrics)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    prefix_embeds = batch.get("prefix_embeds")
    B, S_text = tokens.shape
    P = prefix_embeds.shape[1] if prefix_embeds is not None else 0
    S = S_text + P
    positions = jnp.arange(S, dtype=jnp.int32)

    hidden, _, aux = apply_lm(
        params, cfg, tokens, positions, prefix_embeds=prefix_embeds
    )
    hidden = hidden[:, P:]  # loss over text positions only

    if cfg.tie_embeddings:
        w = params["embed"]["embedding"].T
    else:
        w = params["head"]
    w = w.astype(hidden.dtype)

    L = cfg.xent_chunk if cfg.xent_chunk > 0 else S_text
    L = min(L, S_text)
    if S_text % L != 0:
        L = S_text  # fall back to unchunked for odd sizes
    nc = S_text // L
    h_ch = hidden.reshape(B, nc, L, -1).transpose(1, 0, 2, 3)
    y_ch = labels.reshape(B, nc, L).transpose(1, 0, 2)

    # checkpoint: the backward otherwise stores every chunk's fp32 logits
    # stacked — the very buffer the chunking bounds.
    @jax.checkpoint
    def chunk_fn(acc, inp):
        h, y = inp
        logits = (h @ w).astype(jnp.float32)
        logits = softcap(logits, cfg.final_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.clip(y, 0)[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        zl = jnp.square(lse) * mask
        return (
            acc[0] + nll.sum(),
            acc[1] + mask.sum(),
            acc[2] + zl.sum(),
        ), None

    (nll_sum, cnt, zl_sum), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (h_ch, y_ch),
        unroll=cfg.unroll_scans,
    )
    denom = jnp.maximum(cnt, 1.0)
    loss = nll_sum / denom
    if cfg.zloss > 0:
        loss = loss + cfg.zloss * zl_sum / denom
    if cfg.is_moe:
        loss = loss + cfg.aux_loss_weight * aux / max(cfg.num_layers, 1)
    metrics = {"nll": nll_sum / denom, "tokens": cnt, "aux": aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def prefill(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    caches: Dict,
    *,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Run the prompt through the model, filling caches; returns
    (last-position logits (B, V), caches)."""
    B, S_text = tokens.shape
    P = prefix_embeds.shape[1] if prefix_embeds is not None else 0
    positions = jnp.arange(S_text + P, dtype=jnp.int32)
    hidden, caches, _ = apply_lm(
        params, cfg, tokens, positions, caches=caches, prefix_embeds=prefix_embeds
    )
    return lm_logits(params, cfg, hidden[:, -1:])[:, 0], caches


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    token: jax.Array,  # (B, 1)
    pos: jax.Array,  # () int32 — absolute position of this token
    caches: Dict,
) -> Tuple[jax.Array, Dict]:
    positions = pos[None].astype(jnp.int32)
    hidden, caches, _ = apply_lm(
        params, cfg, token, positions, caches=caches, decode=True
    )
    return lm_logits(params, cfg, hidden[:, 0]), caches
