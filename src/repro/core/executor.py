"""Executor protocol: how DFPA runs a distribution and observes times.

The paper's algorithm is distributed — step 4 executes ``d_i`` computation
units on every processor *in parallel* and gathers the times on P1.  The
framework abstracts that behind ``Executor.run(d) -> times`` so the same DFPA
loop drives:

* ``SimulatedExecutor``   — a cluster simulator (benchmarks, tests);
* ``CallableExecutor``    — real wall-clock timing of per-processor callables
  (used with the Pallas/jnp matmul kernels on the host);
* group executors in ``runtime/balance.py`` — per-group jit'd train steps.

``run`` returns *per-processor execution times* for one parallel round; the
round's wall-clock cost is ``max(times)`` plus the collective overhead the
executor models (the paper's gather/scatter of times/allocations).
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Sequence

__all__ = [
    "Executor",
    "FleetExecutor",
    "SimulatedExecutor",
    "BatchedSimulatedExecutor",
    "BatchedSimulatedExecutor2D",
    "DelayedBatchedExecutor",
    "TraceExecutor2D",
    "CallableExecutor",
    "RoundLog",
    "FleetRoundLog",
]


@dataclass
class RoundLog:
    """One DFPA round: the distribution sent out and the times gathered.

    ``t_wall`` is the monotonic wall-clock timestamp the round was logged at
    (the logging component's injectable clock; 0.0 when the producer does
    not stamp).  Excluded from equality so replay comparisons stay
    timestamp-agnostic."""

    d: List[int]
    times: List[float]
    wall_cost: float  # max(times) + modelled collective overhead
    t_wall: float = field(default=0.0, compare=False)


@dataclass
class FleetRoundLog:
    """One multi-tenant fleet round on a TIME-SLICED fleet: every measuring
    tenant's distribution and per-processor times, costed by the busiest
    processor's SUM across tenants — the round's true wall-clock when each
    processor serves its tenants back to back.  (Logging one ``RoundLog``
    per tenant at ``max(times)`` each under-reports the round by up to q×:
    a tenant's own slice finishing fast does not free the processor that is
    still working through the other tenants' slices.)"""

    names: List[str]
    D: List[List[int]]  # D[k][i]: units of tenant k on processor i
    times: List[List[float]]  # per-(tenant, processor) slice times
    proc_busy: List[float]  # per-processor sum across tenants
    wall_cost: float  # max(proc_busy) + modelled collective overhead
    t_wall: float = field(default=0.0, compare=False)  # see RoundLog.t_wall


class Executor(Protocol):
    @property
    def num_procs(self) -> int: ...

    def run(self, d: Sequence[int]) -> List[float]:
        """Execute ``d[i]`` units on processor ``i`` in parallel; return times."""
        ...

    def round_cost(self, times: Sequence[float]) -> float:
        """Wall-clock cost of one parallel round (incl. collectives)."""
        ...


class FleetExecutor(Protocol):
    """Multi-job executor: one round runs several jobs' distributions over
    the SAME fleet of ``num_procs`` processors at once (the
    ``FleetScheduler``'s measurement primitive).  ``run_jobs`` receives the
    NAME of every job measuring this round (names are the stable identity —
    stack lanes shift when jobs retire) plus their distributions
    ``D[len(names), p]`` and returns the matching times — the batched
    analogue of ``Executor.run``."""

    @property
    def num_procs(self) -> int: ...

    def run_jobs(self, names: Sequence[str], D) -> "object":
        """Run ``D[k, i]`` units of job ``names[k]`` on processor ``i``;
        return times of the same ``[len(names), p]`` shape."""
        ...


@dataclass
class SimulatedExecutor:
    """Drives DFPA against ground-truth time functions ``time_fns[i](x)``.

    ``collective_overhead(p)`` models the paper's gather of ``p`` times +
    scatter of ``p`` allocations (latency + per-rank term); ``noise`` optionally
    perturbs observations (multiplicative, reproducible via ``rng``).
    """

    time_fns: Sequence[Callable[[float], float]]
    alpha: float = 1e-4  # collective latency (s)
    beta: float = 1e-6  # per-rank cost (s)
    noise: float = 0.0
    rng: object = None  # numpy Generator when noise > 0
    logs: List[RoundLog] = field(default_factory=list)

    @property
    def num_procs(self) -> int:
        return len(self.time_fns)

    def run(self, d: Sequence[int]) -> List[float]:
        times = []
        for i, di in enumerate(d):
            t = float(self.time_fns[i](float(di))) if di > 0 else 0.0
            if self.noise > 0.0 and self.rng is not None and di > 0:
                t *= 1.0 + self.noise * float(self.rng.standard_normal())
                t = max(t, 1e-12)
            times.append(t)
        self.logs.append(RoundLog(list(map(int, d)), times, self.round_cost(times)))
        return times

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times) + self.alpha + self.beta * self.num_procs

    @property
    def total_cost(self) -> float:
        return sum(l.wall_cost for l in self.logs)


@dataclass
class BatchedSimulatedExecutor:
    """Fleet-scale simulator: ONE vector-valued time function for all ``p``
    processors (e.g. ``simulator.time_fn_1d_batch``), so a round costs one
    array op instead of ``p`` Python calls.  Mirrors ``SimulatedExecutor``'s
    collective-overhead and noise model.
    """

    time_fn_batch: Callable  # x[p] -> t[p], 0 where x <= 0
    p: int
    alpha: float = 1e-4
    beta: float = 1e-6
    noise: float = 0.0
    rng: object = None
    logs: List[RoundLog] = field(default_factory=list)

    @property
    def num_procs(self) -> int:
        return self.p

    def run(self, d: Sequence[int]) -> List[float]:
        import numpy as np

        x = np.asarray(d, dtype=np.float64)
        t = np.asarray(self.time_fn_batch(x), dtype=np.float64)
        t = np.where(x > 0, t, 0.0)
        if self.noise > 0.0 and self.rng is not None:
            jitter = 1.0 + self.noise * self.rng.standard_normal(self.p)
            t = np.where(x > 0, np.maximum(t * jitter, 1e-12), 0.0)
        times = [float(v) for v in t]
        self.logs.append(RoundLog(list(map(int, d)), times, self.round_cost(times)))
        return times

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times) + self.alpha + self.beta * self.num_procs

    @property
    def total_cost(self) -> float:
        return sum(l.wall_cost for l in self.logs)


@dataclass
class BatchedSimulatedExecutor2D:
    """Multi-job fleet simulator: ONE ``[q, p]``-valued time function for all
    ``q`` jobs x ``p`` processors, so a whole fleet round — every admitted
    job's measurement — costs one array op instead of ``q * p`` Python
    calls.  This is the measurement half of the stacked-bank round driver
    (``fleet/scheduler.py``); the 2-D grid partitioner drives its per-column
    inner DFPA loops through it too (one executor for all ``q`` columns).

    ``time_fn_batch_2d(X) -> T`` must accept the full ``[q, p]`` row space
    (rows of jobs not measuring this round are zero; its values there are
    discarded).  ``job_names`` maps job names to rows of that space (row =
    index into the list); without it, names must be integer-like and index
    the rows directly.  Mirrors ``SimulatedExecutor``'s collective-overhead
    and noise model per job: one job's round costs ``max(times) + alpha +
    beta * p``.
    """

    time_fn_batch_2d: Callable  # X[q, p] -> T[q, p], values at X <= 0 ignored
    p: int
    q: int
    job_names: Optional[Sequence[str]] = None  # row k serves job_names[k]
    alpha: float = 1e-4
    beta: float = 1e-6
    noise: float = 0.0
    rng: object = None
    logs: List[RoundLog] = field(default_factory=list)  # one per (job, round)

    @property
    def num_procs(self) -> int:
        return self.p

    def _row(self, name) -> int:
        if self.job_names is not None:
            rows = getattr(self, "_row_of", None)
            if rows is None:
                rows = {nm: i for i, nm in enumerate(self.job_names)}
                self._row_of = rows  # job_names is fixed at construction
            return rows[name]
        return int(name)

    def run_jobs(self, names: Sequence[str], D):
        import numpy as np

        rows = [self._row(nm) for nm in names]
        X = np.zeros((self.q, self.p), dtype=np.float64)
        X[rows] = np.asarray(D, dtype=np.float64)
        T = np.asarray(self.time_fn_batch_2d(X), dtype=np.float64)
        T = np.where(X > 0, T, 0.0)
        if self.noise > 0.0 and self.rng is not None:
            jitter = 1.0 + self.noise * self.rng.standard_normal((self.q, self.p))
            T = np.where(X > 0, np.maximum(T * jitter, 1e-12), 0.0)
        out = T[rows]
        for k, r in enumerate(rows):
            times = [float(v) for v in out[k]]
            self.logs.append(
                RoundLog([int(v) for v in X[r]], times, self.round_cost(times))
            )
        return out

    def run(self, d: Sequence[int]) -> List[float]:
        """Single-job adapter (row 0), so the 2-D executor also satisfies
        the plain ``Executor`` protocol for one-job fleets."""
        name = self.job_names[0] if self.job_names is not None else 0
        return [float(v) for v in self.run_jobs([name], [list(d)])[0]]

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times) + self.alpha + self.beta * self.num_procs

    @property
    def total_cost(self) -> float:
        return sum(l.wall_cost for l in self.logs)


@dataclass
class DelayedBatchedExecutor:
    """Async-completion test double for the pipelined fleet rounds: wraps an
    inner :class:`FleetExecutor` and models WHEN each job's measurement would
    have completed on a real asynchronous platform — without perturbing the
    returned times, so every bit-parity check against the bare inner executor
    still holds.

    Each ``run_jobs`` call delegates to ``inner`` unchanged, then computes a
    simulated finish instant per job: the job's slowest lane time plus a
    configurable per-job ``lane_latency`` (dict or callable ``name ->
    seconds``, e.g. a straggler NIC on one replica).  Ties are broken by a
    seeded permutation, so runs with equal latencies still exercise a
    reproducible *non-submission* completion order.  The observed order is
    appended to ``completions`` as ``(finish_clock, name)`` events and the
    simulated ``clock`` advances to the round's last finish — tier-1 tests
    replay exact interleavings from these events instead of relying on real
    async dispatch timing.
    """

    inner: object  # FleetExecutor (e.g. BatchedSimulatedExecutor2D)
    lane_latency: object = None  # dict/callable name -> extra seconds, or None
    seed: int = 0
    completions: List[tuple] = field(default_factory=list)  # (clock, name)
    clock: float = 0.0

    def __post_init__(self):
        import numpy as np

        self._rng = np.random.default_rng(self.seed)

    @property
    def num_procs(self) -> int:
        return self.inner.num_procs

    def _latency(self, name) -> float:
        lat = self.lane_latency
        if lat is None:
            return 0.0
        if callable(lat):
            return float(lat(name))
        return float(lat.get(name, 0.0))

    def run_jobs(self, names: Sequence[str], D):
        import numpy as np

        T = self.inner.run_jobs(names, D)
        arr = np.asarray(T, dtype=np.float64)
        finish = [
            self.clock + float(arr[k].max()) + self._latency(nm)
            for k, nm in enumerate(names)
        ]
        tie = self._rng.permutation(len(finish))
        for k in sorted(range(len(finish)), key=lambda k: (finish[k], int(tie[k]))):
            self.completions.append((float(finish[k]), str(names[k])))
        if finish:
            self.clock = max(finish)
        return T

    def run(self, d: Sequence[int]) -> List[float]:
        import numpy as np

        times = self.inner.run(d)
        finish = self.clock + float(np.max(np.asarray(times))) if times else self.clock
        self.completions.append((float(finish), "job"))
        self.clock = finish
        return times

    def round_cost(self, times: Sequence[float]) -> float:
        return self.inner.round_cost(times)

    @property
    def logs(self):
        return self.inner.logs

    @property
    def total_cost(self) -> float:
        return self.inner.total_cost


@dataclass
class TraceExecutor2D:
    """Trace-driven fleet executor: the ground-truth time function takes the
    current TRACE CLOCK — ``time_fn_trace_2d(X[q, p], t) -> T[q, p]`` — so
    drifting speed functions, diurnal thermal effects and straggler
    throttles are functions of *when* a round runs, not of how many rounds
    ran.  The serving harness advances ``now`` between epochs (simulated
    trace seconds); each ``run_jobs`` call evaluates the fleet at that
    instant and logs ONE :class:`FleetRoundLog` with the time-sliced round
    cost (the busiest processor's sum across tenants).  Noise mirrors
    ``BatchedSimulatedExecutor2D`` (multiplicative, seeded ``rng``).
    """

    time_fn_trace_2d: Callable  # (X[q, p], t) -> T[q, p], X <= 0 ignored
    p: int
    now: float = 0.0  # the trace clock, advanced by the harness
    alpha: float = 0.0
    beta: float = 0.0
    noise: float = 0.0
    rng: object = None
    logs: List[FleetRoundLog] = field(default_factory=list)

    @property
    def num_procs(self) -> int:
        return self.p

    def run_jobs(self, names: Sequence[str], D):
        import numpy as np

        X = np.asarray(D, dtype=np.float64)
        T = np.asarray(self.time_fn_trace_2d(X, float(self.now)), dtype=np.float64)
        T = np.where(X > 0, T, 0.0)
        if self.noise > 0.0 and self.rng is not None:
            jitter = 1.0 + self.noise * self.rng.standard_normal(X.shape)
            T = np.where(X > 0, np.maximum(T * jitter, 1e-12), 0.0)
        busy = T.sum(axis=0)
        self.logs.append(
            FleetRoundLog(
                names=[str(nm) for nm in names],
                D=[[int(v) for v in row] for row in X],
                times=[[float(v) for v in row] for row in T],
                proc_busy=[float(v) for v in busy],
                wall_cost=float(busy.max()) + self.alpha + self.beta * self.p,
            )
        )
        return T

    def run(self, d: Sequence[int]) -> List[float]:
        """Single-job adapter, so the trace executor also satisfies the
        plain ``Executor`` protocol for one-tenant fleets."""
        return [float(v) for v in self.run_jobs(["job"], [list(d)])[0]]

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times) + self.alpha + self.beta * self.p

    @property
    def total_cost(self) -> float:
        return sum(l.wall_cost for l in self.logs)


@dataclass
class CallableExecutor:
    """Times real per-processor kernels ``fns[i](x)`` with the host clock.

    On a single host the "parallel" round is executed sequentially but costed
    as ``max(times)`` — the quantity the paper's parallel rounds expose.
    """

    fns: Sequence[Callable[[int], None]]
    logs: List[RoundLog] = field(default_factory=list)

    @property
    def num_procs(self) -> int:
        return len(self.fns)

    def run(self, d: Sequence[int]) -> List[float]:
        times = []
        for i, di in enumerate(d):
            if di <= 0:
                times.append(0.0)
                continue
            t0 = _time.perf_counter()
            self.fns[i](int(di))
            times.append(_time.perf_counter() - t0)
        self.logs.append(RoundLog(list(map(int, d)), times, self.round_cost(times)))
        return times

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times)

    @property
    def total_cost(self) -> float:
        return sum(l.wall_cost for l in self.logs)
