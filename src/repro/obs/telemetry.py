"""The telemetry sink: spans, counters, gauges, events — and the no-op.

Design constraints (they shape every API choice here):

* **Near-zero cost when disabled.**  The scheduler stack is instrumented on
  its hot paths (every fleet round, every fold, every bisection).  All
  instrumentation sites follow one pattern::

      tel = _obs_active()
      if tel is not None and tel.enabled:
          ...record...

  so a disabled build executes two attribute checks and nothing else — no
  allocation, no call into this module (``tests/test_obs.py`` locks this
  with a counting stub sink).  ``span()`` context managers are reserved for
  cold paths (examples, harnesses); hot paths use explicit
  ``t0 = tel.clock()`` … ``tel.span_at(name, t0, tel.clock())`` pairs.

* **Never on device paths.**  Telemetry records host-side bookkeeping only;
  no instrumentation site touches arrays bound for a device program, so the
  200-case fuzz-parity lanes hold bit-identically with telemetry on or off.

* **Process-global, import-optional.**  The active sink is a module global
  (``active()`` / ``install()``); instrumented modules import it inside a
  ``try`` so the whole ``repro.obs`` package can be absent (or stubbed by a
  test) without changing scheduler behaviour.

* **Injectable clock.**  ``Telemetry(clock=...)`` makes traces deterministic
  in tests and lets harnesses record on a simulated time axis.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Dict, List, NamedTuple, Optional

__all__ = [
    "Event",
    "Telemetry",
    "NoopTelemetry",
    "NOOP",
    "active",
    "install",
    "uninstall",
    "use",
]


class Event(NamedTuple):
    """One recorded fact.  ``kind`` is ``"span"`` (t0 < t1), ``"counter"``
    (value = increment), ``"gauge"`` (value = level) or ``"event"`` (a point
    occurrence); ``attrs`` carries site-specific context (JSON-safe)."""

    kind: str
    name: str
    t0: float
    t1: float
    value: float
    attrs: Dict[str, Any]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "value": self.value,
            "attrs": dict(self.attrs),
        }


class _Span:
    """Context-manager span (cold paths; hot paths use ``span_at``)."""

    __slots__ = ("_tel", "_name", "_attrs", "_t0")

    def __init__(self, tel: "Telemetry", name: str, attrs: Dict[str, Any]):
        self._tel = tel
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> "_Span":
        self._t0 = self._tel.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tel.span_at(self._name, self._t0, self._tel.clock(), **self._attrs)
        return False


class _NoopSpan:
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()
_EMPTY: Dict[str, Any] = {}


class Telemetry:
    """A recording sink.

    ``capacity`` bounds the event buffer (a ring: oldest events drop) —
    the flight recorder builds on this; ``None`` keeps everything.
    ``counters`` accumulate (name -> running total) and ``gauges`` hold the
    last written level, independent of the ring, so a bounded recorder
    still reports whole-run totals.
    """

    enabled: bool = True

    def __init__(
        self,
        *,
        clock: Callable[[], float] = time.perf_counter,
        capacity: Optional[int] = None,
    ):
        self.clock = clock
        self.capacity = capacity
        self.events: Any = (
            [] if capacity is None else deque(maxlen=int(capacity))
        )
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}

    # -- recording ------------------------------------------------------------

    def span(self, name: str, **attrs: Any) -> _Span:
        """``with tel.span("repartition"): ...`` — records one span event."""
        return _Span(self, name, attrs)

    def span_at(self, name: str, t0: float, t1: float, **attrs: Any) -> None:
        """Record a span with explicit endpoints (hot paths, simulated
        time axes)."""
        self.events.append(Event("span", name, t0, t1, t1 - t0, attrs or _EMPTY))

    def counter(self, name: str, value: float = 1, **attrs: Any) -> None:
        self.counters[name] = self.counters.get(name, 0) + value
        t = self.clock()
        self.events.append(Event("counter", name, t, t, value, attrs or _EMPTY))

    def gauge(self, name: str, value: float, **attrs: Any) -> None:
        self.gauges[name] = value
        t = self.clock()
        self.events.append(Event("gauge", name, t, t, value, attrs or _EMPTY))

    def event(self, name: str, **attrs: Any) -> None:
        t = self.clock()
        self.events.append(Event("event", name, t, t, 1.0, attrs or _EMPTY))

    # -- introspection --------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> List[Event]:
        return [
            e for e in self.events
            if e.kind == "span" and (name is None or e.name == name)
        ]

    def to_payload(self) -> Dict[str, Any]:
        """JSON-safe dump: the (possibly ring-bounded) events plus the
        unbounded counter totals and last gauge levels."""
        return {
            "events": [e.to_dict() for e in self.events],
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
        }

    def clear(self) -> None:
        self.events.clear()
        self.counters.clear()
        self.gauges.clear()


class NoopTelemetry:
    """The disabled default.  ``enabled`` is False so guarded call sites
    skip it entirely; the methods still exist (and do nothing) so an
    unguarded call is safe."""

    enabled: bool = False
    clock = staticmethod(time.perf_counter)

    def span(self, name: str, **attrs: Any) -> _NoopSpan:
        return _NOOP_SPAN

    def span_at(self, name: str, t0: float, t1: float, **attrs: Any) -> None:
        return None

    def counter(self, name: str, value: float = 1, **attrs: Any) -> None:
        return None

    def gauge(self, name: str, value: float, **attrs: Any) -> None:
        return None

    def event(self, name: str, **attrs: Any) -> None:
        return None


NOOP = NoopTelemetry()
_ACTIVE: Any = NOOP


def active() -> Any:
    """The process-global sink every instrumentation site consults."""
    return _ACTIVE


def install(tel: Optional[Any]) -> Any:
    """Make ``tel`` the process-global sink; returns the previous one.
    ``install(None)`` restores the no-op."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tel if tel is not None else NOOP
    return prev


def uninstall() -> None:
    install(None)


class use:
    """``with use(tel): ...`` — scoped install/restore."""

    def __init__(self, tel: Optional[Any]):
        self._tel = tel
        self._prev: Any = None

    def __enter__(self) -> Any:
        self._prev = install(self._tel)
        return self._tel

    def __exit__(self, exc_type, exc, tb) -> bool:
        install(self._prev)
        return False
