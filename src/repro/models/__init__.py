from .config import ModelConfig
from .transformer import (
    decode_step,
    init_cache,
    lm_loss,
    lm_spec,
    prefill,
)

__all__ = ["ModelConfig", "lm_spec", "lm_loss", "init_cache", "prefill", "decode_step"]
