from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .schedule import warmup_cosine
from .compress import compress_bf16, compress_int8_ef, decompress_int8

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "warmup_cosine",
    "compress_bf16",
    "compress_int8_ef",
    "decompress_int8",
]
