"""Jit'd public wrappers: pick the Pallas kernel on TPU, interpret-mode
Pallas for validation, or the jnp oracle — one switch for the whole stack."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention_pallas
from .matmul_update import matmul_update_pallas
from .rglru import rglru_scan_pallas

__all__ = ["matmul_update", "flash_attention", "rglru_scan", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul_update(c, a, b, *, impl: str = "auto", **kw):
    """impl: auto | pallas | interpret | ref"""
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        return ref.matmul_update_ref(c, a, b)
    return matmul_update_pallas(c, a, b, interpret=(impl == "interpret"), **kw)


def flash_attention(q, k, v, *, impl: str = "auto", **kw):
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        kw.pop("bq", None)
        kw.pop("bk", None)
        return ref.flash_attention_ref(q, k, v, **kw)
    return flash_attention_pallas(q, k, v, interpret=(impl == "interpret"), **kw)


def rglru_scan(log_a, b, *, impl: str = "auto", **kw):
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        return ref.rglru_scan_ref(log_a, b)
    return rglru_scan_pallas(log_a, b, interpret=(impl == "interpret"), **kw)
