"""2-D heterogeneous matmul partitioning (paper §3.2), end to end.

Compares the three applications of Fig. 10 on a 4x4 processor grid:
CPM (constant models), FFMPA (pre-built full models), and DFPA
(dynamically built partial models).

    PYTHONPATH=src python examples/matmul_2d_dfpa.py
"""

from repro.core import (
    HCL_SPECS,
    app_time_2d,
    cpm_partition_2d,
    dfpa_partition_2d,
    ffmpa_partition_2d,
    speed_fn_2d,
)

P, Q, M, N = 4, 4, 512, 512
specs = HCL_SPECS[: P * Q]
grid = [[speed_fn_2d(specs[i * Q + j]) for j in range(Q)] for i in range(P)]

cpm, cpm_cost = cpm_partition_2d(grid, M, N)
ff = ffmpa_partition_2d(grid, M, N, eps=0.1)
df = dfpa_partition_2d(grid, M, N, eps=0.1)

t_cpm = app_time_2d(grid, cpm, K=N) + cpm_cost
t_ff = app_time_2d(grid, ff, K=N)
t_df = app_time_2d(grid, df, K=N) + df.bench_cost

print(f"grid {P}x{Q}, matrix {M}x{N} (block units)")
print(f"CPM   : {t_cpm:8.2f}s   (1 benchmark round; misestimates paging nodes)")
print(f"FFMPA : {t_ff:8.2f}s   (needs pre-built full models: expensive offline)")
print(f"DFPA  : {t_df:8.2f}s   ({df.total_rounds} online rounds, "
      f"{df.bench_cost:.2f}s partitioning)")
print(f"\nDFPA column widths: {df.col_widths}")
for j in range(Q):
    print(f"  column {j}: rows {df.row_heights[j]}")
print(f"\nCPM is {t_cpm / t_df:.2f}x slower than DFPA (paper Fig. 10: ~1.25x;")
print("deep-paging nodes make the gap larger on this grid).")
