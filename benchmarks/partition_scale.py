"""Fleet-scale partition latency: seed scalar path vs vectorized ModelBank.

The paper's self-adaptability requirement is that computing an optimal
distribution costs orders of magnitude less than the application it balances.
This benchmark measures that cost directly for both partition paths on
synthetic heterogeneous fleets of p ∈ {10, 100, 1000, 10000} processor
groups (HCL-like piecewise-linear FPMs, ~6 observed points each):

  * scalar — the seed implementation (``vectorize=False``): every bisection
    step on ``t*`` is a p-long Python loop over per-model segment scans;
  * bank   — the ``ModelBank`` path: one numpy pass per bisection step.

Results (latencies, speedup, allocation agreement) are written to
``BENCH_partition.json``.

    PYTHONPATH=src python benchmarks/partition_scale.py [--quick] [--out FILE]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ModelBank, PiecewiseLinearFPM, partition_units


def make_fleet(p: int, seed: int = 0):
    """p heterogeneous piecewise-linear FPMs: plateau speed spanning ~3x,
    cache boost at small x, paging-style decay past a per-proc knee."""
    rng = np.random.default_rng(seed)
    plateau = rng.uniform(1.0, 3.0, p) * 1e6
    knee = rng.uniform(2e3, 2e4, p)
    models = []
    for i in range(p):
        xs = np.geomspace(16.0, 8.0 * knee[i], 6)
        ss = np.where(
            xs <= knee[i],
            plateau[i] * (1.0 + 0.4 * np.exp(-xs / 500.0)),
            plateau[i] / (1.0 + 2.0 * (xs - knee[i]) / knee[i]),
        )
        models.append(PiecewiseLinearFPM.from_points(list(zip(xs, ss))))
    return models


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_sweep(ps, repeats: int, units_per_proc: int = 100, scalar_cutoff: int = 10**9):
    rows = []
    for p in ps:
        models = make_fleet(p, seed=p)
        bank = ModelBank.from_models(models)
        n = units_per_proc * p

        t_bank = best_of(lambda: partition_units(bank, n, min_units=1), repeats)
        d_bank = partition_units(bank, n, min_units=1)

        row = {"p": p, "n": n, "bank_s": t_bank}
        if p <= scalar_cutoff:
            t_scalar = best_of(
                lambda: partition_units(models, n, min_units=1, vectorize=False), repeats
            )
            d_scalar = partition_units(models, n, min_units=1, vectorize=False)
            row["scalar_s"] = t_scalar
            row["speedup"] = t_scalar / t_bank
            row["max_unit_diff"] = int(max(abs(a - b) for a, b in zip(d_scalar, d_bank)))
        rows.append(row)
        msg = f"p={p:6d}  bank={t_bank * 1e3:9.3f} ms"
        if "scalar_s" in row:
            msg += (
                f"  scalar={row['scalar_s'] * 1e3:10.3f} ms"
                f"  speedup={row['speedup']:8.1f}x"
                f"  max|Δd|={row['max_unit_diff']}"
            )
        print(msg, flush=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sweep for CI smoke")
    ap.add_argument("--out", default="BENCH_partition.json")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    if args.quick:
        ps, repeats, cutoff = [10, 100], args.repeats or 2, 10**9
    else:
        ps, repeats, cutoff = [10, 100, 1000, 10000], args.repeats or 3, 10**9

    rows = run_sweep(ps, repeats, scalar_cutoff=cutoff)
    payload = {
        "benchmark": "partition_scale",
        "description": "partition_units latency, seed scalar path vs ModelBank path",
        "units_per_proc": 100,
        "repeats": repeats,
        "sweep": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"-> {args.out}")

    checked = [r for r in rows if "speedup" in r]
    big = [r for r in checked if r["p"] >= 1000]
    if big and min(r["speedup"] for r in big) < 10.0:
        print("WARNING: <10x speedup at p>=1000")
        return 1
    if any(r["max_unit_diff"] > 1 for r in checked):
        print("WARNING: paths disagree by >1 unit")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
