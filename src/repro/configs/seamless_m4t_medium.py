"""seamless-m4t-medium [audio] — 12L d_model=1024 16H d_ff=4096 vocab=256206.

Encoder-decoder; the speech frontend is a STUB supplying precomputed frame
embeddings to the 12-layer encoder; 12-layer text decoder with
cross-attention [arXiv:2308.11596; hf].  LayerNorm + non-gated GELU (4x).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,  # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    pattern=("attn",),
    encoder_layers=12,
    encoder_pattern=("attn",),
    mlp_kind="gelu",
    norm_kind="layernorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    frontend="audio_stub",
    train_accum=4,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        encoder_layers=2,
        xent_chunk=0,
        remat="none",
    )
