"""Per-arch smoke tests (reduced configs) + cache-consistency properties.

Every assigned architecture: one forward/train step on CPU, asserting
output shapes and finite values; prefill+decode must reproduce the full
forward's last-position logits (validates ring buffers, MLA absorbed
decode, recurrent states).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES, shape_applicable
from repro.models.encdec import (
    _cross_kv_all,
    _dec_logits,
    apply_decoder,
    encdec_decode_step,
    encdec_loss,
    encdec_prefill,
    encdec_spec,
    encode,
    init_encdec_cache,
)
from repro.models.frontends import stub_frame_embeddings, stub_patch_embeddings
from repro.models.transformer import (
    apply_lm,
    decode_step,
    init_cache,
    lm_logits,
    lm_loss,
    lm_spec,
    prefill,
)
from repro.nn.params import init_tree, param_count

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key=KEY):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.concatenate([toks[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1)
    batch = {"tokens": toks, "labels": labels}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = stub_patch_embeddings(cfg, B)
    if cfg.is_encdec:
        batch["frames"] = stub_frame_embeddings(cfg, B, S)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss(arch):
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    if cfg.is_encdec:
        params = init_tree(KEY, encdec_spec(cfg))
        loss, metrics = jax.jit(lambda p, b: encdec_loss(p, cfg, b))(params, batch)
    else:
        params = init_tree(KEY, lm_spec(cfg))
        loss, metrics = jax.jit(lambda p, b: lm_loss(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    assert float(metrics["tokens"]) == B * (S - 1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_grads_finite(arch):
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    if cfg.is_encdec:
        params = init_tree(KEY, encdec_spec(cfg))
        g = jax.grad(lambda p: encdec_loss(p, cfg, batch)[0])(params)
    else:
        params = init_tree(KEY, lm_spec(cfg))
        g = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.frontend == "vision_stub":
        cfg = cfg.replace(num_prefix_embeddings=0)
    if cfg.is_moe:
        # Capacity-based drops depend on the sequence length (prefill sees
        # S-1 tokens, the full forward S) — run dropless so the test checks
        # CACHE consistency, not router drop policy.
        cfg = cfg.replace(capacity_factor=8.0)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.is_encdec:
        params = init_tree(KEY, encdec_spec(cfg))
        frames = stub_frame_embeddings(cfg, B, 8)
        enc = encode(params, cfg, frames)
        xkv = _cross_kv_all(params, cfg, enc)
        pos = jnp.arange(S, dtype=jnp.int32)
        hid, _ = apply_decoder(params, cfg, toks, pos, xkv)
        full_logits = _dec_logits(params, cfg, hid[:, -1])
        caches = init_encdec_cache(cfg, B, S, 8)
        _, caches = encdec_prefill(params, cfg, frames, toks[:, :-1], caches)
        logits, _ = encdec_decode_step(params, cfg, toks[:, -1:], jnp.array(S - 1), caches)
    else:
        params = init_tree(KEY, lm_spec(cfg))
        pos = jnp.arange(S, dtype=jnp.int32)
        hid, _, _ = apply_lm(params, cfg, toks, pos)
        full_logits = lm_logits(params, cfg, hid[:, -1])
        caches = init_cache(cfg, B, S)
        _, caches = prefill(params, cfg, toks[:, :-1], caches)
        logits, _ = decode_step(params, cfg, toks[:, -1:], jnp.array(S - 1), caches)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    rel = float(jnp.max(jnp.abs(logits - full_logits))) / scale
    assert rel < 0.05, f"{arch}: decode mismatch rel={rel}"


@pytest.mark.parametrize("arch", ["recurrentgemma-2b"])
def test_sliding_window_ring_buffer(arch):
    """Decode past the window with a window-sized cache: the ring buffer plus
    recurrent state must reproduce the full forward (recurrentgemma's only
    attention is local, so a window-sized budget is lossless)."""
    cfg = get_smoke_config(arch)
    params = init_tree(KEY, lm_spec(cfg))
    W = cfg.window
    total = W + 6
    toks = jax.random.randint(KEY, (1, total), 0, cfg.vocab_size)
    # full forward logits at the last position
    pos = jnp.arange(total, dtype=jnp.int32)
    hid, _, _ = apply_lm(params, cfg, toks, pos)
    want = lm_logits(params, cfg, hid[:, -1])
    # prefill W, then decode the rest one-by-one
    caches = init_cache(cfg, 1, W)
    _, caches = prefill(params, cfg, toks[:, :W], caches)
    for i in range(W, total):
        got, caches = decode_step(params, cfg, toks[:, i : i + 1], jnp.array(i), caches)
    rel = float(jnp.max(jnp.abs(got - want))) / (float(jnp.max(jnp.abs(want))) + 1e-9)
    assert rel < 0.05, f"ring-buffer decode mismatch rel={rel}"


def test_chunked_attention_equals_full():
    cfg = get_smoke_config("granite-20b")
    cfg_chunked = cfg.replace(attn_chunk_threshold=8, attn_q_chunk=4)
    params = init_tree(KEY, lm_spec(cfg))
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.arange(S, dtype=jnp.int32)
    h1, _, _ = apply_lm(params, cfg, toks, pos)
    h2, _, _ = apply_lm(params, cfg_chunked, toks, pos)
    np.testing.assert_allclose(
        np.asarray(h1, np.float32), np.asarray(h2, np.float32), atol=1e-3
    )


def test_scan_vs_unrolled_layers_equal():
    cfg = get_smoke_config("gemma2-2b")
    params = init_tree(KEY, lm_spec(cfg))
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.arange(S, dtype=jnp.int32)
    h1, _, _ = apply_lm(params, cfg, toks, pos)
    h2, _, _ = apply_lm(params, cfg.replace(scan_layers=False), toks, pos)
    # scan and unrolled layers fuse differently -> bf16-level noise only
    np.testing.assert_allclose(
        np.asarray(h1, np.float32), np.asarray(h2, np.float32), atol=0.06
    )


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some pairs drop; output stays finite."""
    cfg = get_smoke_config("granite-moe-1b-a400m").replace(capacity_factor=0.25)
    from repro.models.moe import apply_moe, moe_spec

    params = init_tree(KEY, moe_spec(cfg))
    x = 0.5 * jax.random.normal(KEY, (2, 32, cfg.d_model))
    y, aux = apply_moe(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_full_configs_match_assignment():
    """The exact published shapes from the assignment table."""
    expect = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, H, kv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == H, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff or cfg.d_ff_expert == ff, arch
        assert cfg.vocab_size == V, arch


def test_moe_param_counts_plausible():
    from repro.runtime.train_loop import model_spec_for

    n = param_count(model_spec_for(get_config("deepseek-v2-236b")))
    assert 200e9 < n < 280e9, f"deepseek param count {n/1e9:.1f}B"
    n = param_count(model_spec_for(get_config("granite-20b")))
    assert 18e9 < n < 23e9, f"granite param count {n/1e9:.1f}B"
    n = param_count(model_spec_for(get_config("xlstm-350m")))
    assert 0.2e9 < n < 0.6e9, f"xlstm param count {n/1e6:.0f}M"


def test_long_context_skip_rules():
    quad = [a for a in ARCH_IDS if shape_applicable(get_config(a), SHAPES[3])]
    sub = [a for a in ARCH_IDS if not shape_applicable(get_config(a), SHAPES[3])]
    assert set(sub) == {"recurrentgemma-2b", "xlstm-350m"}
    assert len(quad) == 8
