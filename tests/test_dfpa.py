"""DFPA: the paper's algorithm — convergence proposition, paper-faithfulness
gates (§3.1), warm starts, and behavioural properties."""

import json
import math
import pathlib

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    AnalyticModel,
    SimulatedExecutor,
    dfpa,
    full_model_build_cost,
    imbalance,
    make_grid5000_time_fns,
    make_hcl_time_fns,
    matmul_app_time_1d,
    partition_units,
)


def _row_fns(tfns, n):
    return [(lambda tf: lambda r: tf(r * n))(tf) for tf in tfns]


# ---------------------------------------------------------------------------
# Convergence proposition (paper §2): random shape-valid speed functions
# ---------------------------------------------------------------------------


@st.composite
def _speed_functions(draw):
    """Speed functions satisfying [16]'s shape restrictions: positive,
    eventually monotonically decreasing (here: plateau then decay)."""
    p = draw(st.integers(2, 8))
    fns = []
    for _ in range(p):
        s0 = draw(st.floats(1.0, 100.0))
        knee = draw(st.floats(10.0, 1e4))
        decay = draw(st.floats(0.1, 3.0))

        def t(x, s0=s0, knee=knee, decay=decay):
            if x <= 0:
                return 0.0
            s = s0 if x <= knee else s0 / (1.0 + decay * (x - knee) / knee)
            return x / s

        fns.append(t)
    return fns


@given(fns=_speed_functions(), n=st.integers(100, 20000), eps=st.floats(0.05, 0.3))
@settings(max_examples=60, deadline=None)
def test_convergence_proposition(fns, n, eps):
    """DFPA always terminates and (on deterministic executors) either meets
    eps or reaches a fixed point whose best round is reported."""
    ex = SimulatedExecutor(time_fns=fns)
    res = dfpa(ex, n, eps, min_units=1)
    assert sum(res.d) == n
    assert res.iterations <= 100
    assert res.imbalance == imbalance(res.times) or not res.converged
    if res.converged:
        assert res.imbalance <= eps


# ---------------------------------------------------------------------------
# Paper-faithfulness gates on the calibrated HCL simulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2048, 3072, 4096, 5120, 6144, 7168, 8192])
def test_hcl_converges_fast(n):
    """Gate 2: iteration counts small (paper: 2-11); DFPA reaches eps OR the
    oracle's own integer-granularity floor (eps below the 1-unit resolution
    is infeasible for ANY partitioner — n=6144 hits this)."""
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    ex = SimulatedExecutor(time_fns=rows)
    res = dfpa(ex, n, eps=0.025, min_units=1)
    oracle = partition_units([AnalyticModel(tf) for tf in rows], n, min_units=1)
    oracle_imb = imbalance([tf(d) for tf, d in zip(rows, oracle)])
    assert res.converged or res.imbalance <= oracle_imb * 1.05
    assert res.iterations <= 45
    if n <= 4096:
        assert res.iterations <= 4  # no paging -> almost CPM-fast


def test_dfpa_matches_ffmpa_distribution():
    """Gate 1 (paper §3.1): DFPA returns almost the same distribution as the
    full-model partitioner (FFMPA)."""
    n = 5120
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    ffmpa = partition_units([AnalyticModel(tf) for tf in rows], n, min_units=1)
    ex = SimulatedExecutor(time_fns=rows)
    res = dfpa(ex, n, eps=0.025, min_units=1)
    l1 = sum(abs(a - b) for a, b in zip(res.d, ffmpa))
    assert l1 / n < 0.05  # distributions within 5% L1
    # and both balance within eps on the ground truth
    t_ff = [tf(d) for tf, d in zip(rows, ffmpa)]
    assert imbalance(t_ff) <= 0.05


def test_dfpa_cost_orders_of_magnitude_below_full_model_build():
    """Gate 3: DFPA cost << full-FPM construction (paper: 29s vs 1850s)."""
    n = 8192
    _, tfns = make_hcl_time_fns(n)
    ex = SimulatedExecutor(time_fns=_row_fns(tfns, n))
    res = dfpa(ex, n, eps=0.025, min_units=1)
    dfpa_cost = ex.total_cost

    def fns_for(nn):
        return make_hcl_time_fns(nn)[1]

    build = full_model_build_cost(
        fns_for, [1024 * k for k in range(1, 9)], [i / 80 for i in range(1, 21)]
    )
    assert build / dfpa_cost > 30  # orders of magnitude in the paper's sense
    app = matmul_app_time_1d(tfns, res.d, n)
    assert dfpa_cost / app < 0.15  # contribution <= ~10% (paper gate)


def test_grid5000_two_to_three_iterations():
    """Gate: Table 4 — <= 3 iterations, cost < 1% of the app."""
    for n in [7168, 10240, 12288]:
        specs, tfns = make_grid5000_time_fns(n)
        ex = SimulatedExecutor(time_fns=_row_fns(tfns, n))
        res = dfpa(ex, n, eps=0.025, min_units=1)
        assert res.converged and res.iterations <= 3
        app = matmul_app_time_1d(tfns, res.d, n)
        assert ex.total_cost / app < 0.01


# ---------------------------------------------------------------------------
# Golden-trace regression: convergence behaviour is part of the contract
# ---------------------------------------------------------------------------


def test_dfpa_hcl_golden_trace():
    """Round-by-round allocations and iteration counts on the HCL fixture,
    committed to ``tests/golden/dfpa_hcl.json``.  Refactors of the model
    carry / partition backends (this PR's fold-in, and future ones) must not
    silently change convergence behaviour; if a change is intentional,
    regenerate the golden file and say so in the PR."""
    golden = json.loads(
        (pathlib.Path(__file__).parent / "golden" / "dfpa_hcl.json").read_text()
    )
    n = golden["n"]
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    res = dfpa(
        SimulatedExecutor(time_fns=rows),
        n,
        eps=golden["eps"],
        min_units=golden["min_units"],
    )
    assert res.iterations == golden["iterations"]
    assert res.converged == golden["converged"]
    assert res.d == golden["final_d"]
    assert res.points_per_proc == golden["points_per_proc"]
    assert len(res.history) == len(golden["rounds"])
    for (d, times), want in zip(res.history, golden["rounds"]):
        assert d == want["d"]
        assert times == pytest.approx(want["times"], rel=1e-12)
    assert res.imbalance == pytest.approx(golden["imbalance"], rel=1e-12)


# ---------------------------------------------------------------------------
# Behavioural properties
# ---------------------------------------------------------------------------


def test_even_distribution_shortcut():
    """Step 2: homogeneous processors stop after ONE round."""
    ex = SimulatedExecutor(time_fns=[lambda x: x / 10.0] * 4)
    res = dfpa(ex, 1000, eps=0.05)
    assert res.iterations == 1 and res.converged


def test_warm_start_reduces_iterations():
    n = 5120
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    cold = dfpa(SimulatedExecutor(time_fns=rows), n, eps=0.025, min_units=1)
    warm = dfpa(
        SimulatedExecutor(time_fns=rows), n, eps=0.025, min_units=1,
        warm_models=cold.models,
    )
    assert warm.iterations <= max(cold.iterations // 2, 2)
    assert warm.converged


def test_dfpa_with_noise_still_terminates():
    n = 4096
    _, tfns = make_hcl_time_fns(n)
    ex = SimulatedExecutor(
        time_fns=_row_fns(tfns, n), noise=0.02, rng=np.random.default_rng(7)
    )
    res = dfpa(ex, n, eps=0.10, min_units=1, max_iter=40)
    assert sum(res.d) == n
    assert res.iterations <= 40


def test_input_validation():
    ex = SimulatedExecutor(time_fns=[lambda x: x] * 4)
    with pytest.raises(ValueError):
        dfpa(ex, 2, eps=0.1)  # n < p
    with pytest.raises(ValueError):
        dfpa(ex, 100, eps=0.0)
