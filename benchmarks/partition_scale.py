"""Fleet-scale partition latency: scalar vs numpy ModelBank vs jitted jax
bank — all driven through the ``SpeedStore``/``Scheduler`` facade.

The paper's self-adaptability requirement is that computing an optimal
distribution costs orders of magnitude less than the application it balances.
This benchmark measures that cost directly for all three partition backends
on synthetic heterogeneous fleets of p ∈ {10, 100, 1000, 10000} processor
groups (HCL-like piecewise-linear FPMs, ~6 observed points each):

  * scalar — the seed implementation (``SpeedStore`` backend ``"scalar"``):
    every bisection step on ``t*`` is a p-long Python loop over per-model
    segment scans;
  * bank   — the ``ModelBank`` path: one numpy pass per bisection step;
  * jax    — the ``JaxModelBank`` path: the whole t* search + integer
    completion under ``jax.jit``.  Two numbers matter: the one-time compile
    cost, and the steady-state repartition latency afterwards.

Completion-mode columns: the synthetic fleets are monotone-time, so the
default (``completion="auto"``) routes the JAX backend through the
threshold-count completion; on the numpy host path "auto" stays on the lazy
heap (the PR 5 routing fix — ``bank_threshold_s`` records what the forced
threshold pass costs there: ~one extra continuous solve).  Each backend is
also timed with the exact per-unit completion forced (``*_exact_s``
columns).  ``jax_completion_speedup`` is
the headline ratio — at p=10^5 the sequential masked-argmin loop (~p/2
``while_loop`` iterations) is what used to block millisecond repartitioning,
and the acceptance gate requires the threshold path to beat it by >= 10x
there.  A divergence gate asserts fast-vs-exact MAKESPAN equality (and
reports allocation diffs) at every swept p; at p=1000 it is enforced in the
CI smoke (exit 1).

Facade-overhead columns: each banked backend is timed twice — as a *direct*
kernel call (``_partition_units_bank`` / ``JaxModelBank.partition_units``)
and through the facade (``SpeedStore.partition_units``: validation +
pre-resolved dispatch).  ``facade_overhead_pct`` is the facade tax; the
acceptance gate is <= 5% at p=1000 (exit 1 otherwise).

Float32 drift columns (full sweep, p=10^4 AND p=10^5): the jax backend
re-runs with a float32 bank (dtype plumbing keeps the whole jitted pipeline
in f32) and records the max/total unit drift vs the float64 numpy reference
— the data behind the ``SpeedStore(dtype=...)`` serving-fleet policy (zero
drift at p=10^4; worst case ±1 unit at p=10^5).

Hierarchical columns (p >= 1000): the same fleet solved through the
two-level ``Hierarchy`` route (groups of 100 at p=1000, 1000 above) —
``hier_s`` is the numpy inner path, ``hier_jax_s``/``hier_jax_compile_s``
the jitted block path.  The hierarchy solves an outer t* on ``g`` group
aggregates then ``g`` independent inner solves over cache-sized blocks,
trading exactness for locality: ``hier_makespan_ratio`` (two-level vs flat
makespan) is gated <= 1.12 at every swept p, matching the fuzz-test
envelope (empirical worst over 340 random monotone fleets is ~1.10).

p=10^6 row (full sweep): a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` builds eight
125k-processor group banks via ``Hierarchy.from_group_banks`` (the flat
``[p, k]`` bank is never materialized) and repartitions n=20p units under
``sharding="shard_map"``.  Gates: the allocation sums to n, and
``max_shard_elems`` — the largest bank block any one device holds — is
>= 4x smaller than the flat bank (expected 8x with 8 emulated devices).

The jax sweep runs with x64 enabled and asserts its allocations are
BIT-IDENTICAL to the numpy bank at every swept p (exit code 1 otherwise —
CI runs the quick sweep, so parity is enforced on every PR).

Results are written to ``BENCH_partition.json``.

    PYTHONPATH=src python benchmarks/partition_scale.py \
        [--quick] [--backend numpy|jax|both] [--out FILE]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import Hierarchy, ModelBank, PiecewiseLinearFPM, SpeedStore
from repro.core.partition import _partition_units_bank, _prep_unit_caps


def make_fleet(p: int, seed: int = 0):
    """p heterogeneous piecewise-linear FPMs: plateau speed spanning ~3x,
    cache boost at small x, paging-style decay past a per-proc knee."""
    rng = np.random.default_rng(seed)
    plateau = rng.uniform(1.0, 3.0, p) * 1e6
    knee = rng.uniform(2e3, 2e4, p)
    models = []
    for i in range(p):
        xs = np.geomspace(16.0, 8.0 * knee[i], 6)
        ss = np.where(
            xs <= knee[i],
            plateau[i] * (1.0 + 0.4 * np.exp(-xs / 500.0)),
            plateau[i] / (1.0 + 2.0 * (xs - knee[i]) / knee[i]),
        )
        models.append(PiecewiseLinearFPM.from_points(list(zip(xs, ss))))
    return models


def make_fleet_bank(p: int, seed: int = 0) -> ModelBank:
    """Same fleet distribution as :func:`make_fleet`, built directly as a
    ``ModelBank`` with vectorized numpy (no per-model Python objects) — the
    only way to stand up the p=10^6 row's 125k-processor group banks in
    milliseconds instead of minutes.  Draw order matches ``make_fleet`` so
    identical seeds give bit-identical fleets (parity-checked in tests)."""
    rng = np.random.default_rng(seed)
    plateau = (rng.uniform(1.0, 3.0, p) * 1e6)[:, None]
    knee = rng.uniform(2e3, 2e4, p)[:, None]
    xs = np.exp(
        np.linspace(0.0, 1.0, 6)[None, :] * (np.log(8.0 * knee) - np.log(16.0))
        + np.log(16.0)
    )
    ss = np.where(
        xs <= knee,
        plateau * (1.0 + 0.4 * np.exp(-xs / 500.0)),
        plateau / (1.0 + 2.0 * (xs - knee) / knee),
    )
    return ModelBank(xs=xs, ss=ss, counts=np.full(p, 6, dtype=np.int64))


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def best_of_pair(fn_a, fn_b, repeats: int):
    """Interleaved timing for two implementations of the same work.

    Returns ``(best_a, best_b, ratio)`` where ``ratio`` is the MEDIAN over
    iterations of ``t_b / t_a`` *within the same iteration*.  Within one
    iteration the two sides run back-to-back, so shared-container load noise
    hits both together and their ratio stays honest even when the absolute
    best-of times land in different load windows; the median then rejects
    the iterations where a noise spike split the pair.  The facade-tax gate
    uses this ratio, not the difference of bests."""
    best_a = best_b = float("inf")
    ratios = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        ta = time.perf_counter() - t0
        t0 = time.perf_counter()
        fn_b()
        tb = time.perf_counter() - t0
        best_a = min(best_a, ta)
        best_b = min(best_b, tb)
        ratios.append(tb / ta)
    return best_a, best_b, float(np.median(ratios))


def run_sweep(ps, repeats: int, backend: str, units_per_proc: int = 100,
              scalar_cutoff: int = 10**9, f32_ps=()):
    if backend in ("jax", "both"):
        import jax

        # Bit-identical-to-numpy is the acceptance gate; that needs doubles.
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp

        from repro.core import JaxModelBank

    rows = []
    for p in ps:
        models = make_fleet(p, seed=p)
        bank = ModelBank.from_models(models)
        bank_store = SpeedStore.from_bank(bank)
        n = units_per_proc * p
        icaps = _prep_unit_caps(p, n, None, 1)

        def makespan(d):
            return float(np.max(bank.time(np.asarray(d, dtype=np.float64))))

        # The synthetic fleets are monotone-time, so jax "auto" routes to
        # threshold-count (host "auto" stays on the heap since the PR 5
        # routing fix — the forced bank_threshold_s column is the host
        # comparison); assert it so a generator change can't silently turn
        # the completion columns into a no-op comparison.
        assert bank.is_monotone(), "benchmark fleet must be monotone-time"
        ex_reps = max(1, min(repeats, 2)) if p >= 10**5 else repeats

        # Direct kernel vs the facade (validation + pre-resolved dispatch),
        # interleaved so container-load drift cannot fake an overhead.  The
        # pair repeats adapt to a ~1s budget: small-p ops are milliseconds,
        # so dozens of samples keep the median ratio well under the shared-
        # runner noise floor that a fixed 7 would leave it exposed to.
        direct_fn = lambda: _partition_units_bank(bank, n, list(icaps), min_units=1)
        facade_fn = lambda: bank_store.partition_units(n, min_units=1)
        t_est = best_of(direct_fn, 1)
        pair_reps = min(41, max(repeats, 7, int(1.0 / max(t_est, 1e-3))))
        t_direct, t_facade, ratio = best_of_pair(direct_fn, facade_fn, pair_reps)
        d_bank = bank_store.partition_units(n, min_units=1)

        # Exact per-unit completion forced on the numpy bank (the lazy heap;
        # since the PR 5 routing fix this is also what "auto" runs on the
        # host path) plus the FORCED threshold column — the data behind
        # keeping host-auto on the heap: the threshold pass costs ~one extra
        # continuous solve here, a win only on the jitted backends.
        t_bank_exact = best_of(
            lambda: _partition_units_bank(
                bank, n, list(icaps), min_units=1, completion="greedy"
            ),
            ex_reps,
        )
        d_bank_exact, _ = _partition_units_bank(
            bank, n, list(icaps), min_units=1, completion="greedy"
        )
        t_bank_threshold = best_of(
            lambda: _partition_units_bank(
                bank, n, list(icaps), min_units=1, completion="threshold"
            ),
            ex_reps,
        )
        d_bank_threshold, _ = _partition_units_bank(
            bank, n, list(icaps), min_units=1, completion="threshold"
        )

        row = {
            "p": p,
            "n": n,
            "bank_s": t_direct,
            "bank_exact_s": t_bank_exact,
            "bank_threshold_s": t_bank_threshold,
            "facade_s": t_facade,
            "facade_overhead_pct": 100.0 * (ratio - 1.0),
            "completion_max_unit_diff": int(
                max(abs(a - b) for a, b in zip(d_bank_threshold, d_bank_exact))
            ),
            "completion_makespan_equal": makespan(d_bank_threshold)
            == makespan(d_bank_exact),
        }
        assert d_bank == d_bank_exact, "host auto must equal the greedy heap"
        if backend in ("numpy", "both") and p <= scalar_cutoff:
            scalar_store = SpeedStore.from_models(models, backend="scalar")
            t_scalar = best_of(
                lambda: scalar_store.partition_units(n, min_units=1), repeats
            )
            d_scalar = scalar_store.partition_units(n, min_units=1)
            row["scalar_s"] = t_scalar
            row["speedup"] = t_scalar / t_direct
            row["max_unit_diff"] = int(max(abs(a - b) for a, b in zip(d_scalar, d_bank)))
        if backend in ("jax", "both"):
            jbank = JaxModelBank.from_bank(bank)
            jax_store = SpeedStore.from_jax_bank(jbank)

            def jax_direct():
                return jbank.partition_units(n, icaps, min_units=1)

            def jax_facade():
                return jax_store.partition_units(n, min_units=1)

            def jax_exact():
                return jbank.partition_units(
                    n, icaps, min_units=1, completion="greedy"
                )

            t0 = time.perf_counter()
            jax_direct()  # traces + compiles for this fleet shape
            t_compile = time.perf_counter() - t0
            t_est = best_of(jax_direct, 1)  # post-compile
            jpair_reps = min(41, max(repeats, 7, int(1.0 / max(t_est, 1e-3))))
            t_jax, t_jax_facade, jratio = best_of_pair(
                jax_direct, jax_facade, jpair_reps
            )  # interleaved
            d_jax = jax_facade()
            jax_exact()  # compile the per-unit-completion variant
            t_jax_exact = best_of(jax_exact, ex_reps)  # steady-state
            d_jax_exact = jax_exact()
            row["jax_compile_s"] = t_compile
            row["jax_steady_s"] = t_jax
            row["jax_exact_s"] = t_jax_exact
            row["jax_completion_speedup"] = t_jax_exact / t_jax
            row["jax_facade_s"] = t_jax_facade
            row["jax_facade_overhead_pct"] = 100.0 * (jratio - 1.0)
            row["jax_vs_bank_speedup"] = t_direct / t_jax
            row["jax_max_unit_diff"] = int(
                max(abs(a - b) for a, b in zip(d_jax, d_bank))
            )
            row["jax_completion_max_unit_diff"] = int(
                max(abs(int(a) - int(b)) for a, b in zip(d_jax, d_jax_exact))
            )
            row["completion_makespan_equal"] = bool(
                row["completion_makespan_equal"]
                and makespan(np.asarray(d_jax)) == makespan(np.asarray(d_jax_exact))
            )
            if p in f32_ps:
                # Same pipeline in float32: the bank's dtype flows through
                # every jitted constant, so this is a true f32 run.
                jb32 = JaxModelBank(
                    xs=jnp.asarray(bank.xs, jnp.float32),
                    ss=jnp.asarray(bank.ss, jnp.float32),
                    counts=jnp.asarray(bank.counts),
                )
                d32 = jb32.partition_units(n, icaps, min_units=1)
                diffs = np.abs(np.asarray(d32) - np.asarray(d_bank))
                row["jax_f32_max_unit_diff"] = int(diffs.max())
                row["jax_f32_total_unit_drift"] = int(diffs.sum())
                row["jax_f32_drift_frac_of_n"] = float(diffs.sum() / n)
        if p >= 1000:
            # Two-level route over the same fleet: groups sized to keep each
            # inner block cache-resident.  Near-optimal (gated <= 1.12x flat
            # makespan), and the only route that scales past the flat bank's
            # memory wall — see the p=10^6 subprocess row.
            gsize = 100 if p <= 1000 else 1000
            groups = (np.arange(p) // gsize).tolist()
            caps_np = np.asarray(icaps, dtype=np.int64)
            hn = Hierarchy.from_bank(bank, groups, backend="numpy")
            t_hier = best_of(
                lambda: hn.partition_units(n, caps_np, min_units=1), ex_reps
            )
            d_hier = hn.partition_units(n, caps_np, min_units=1)
            assert sum(d_hier) == n
            row["hier_group_size"] = gsize
            row["hier_s"] = t_hier
            row["hier_makespan_ratio"] = makespan(d_hier) / makespan(d_bank)
            if backend in ("jax", "both"):
                hj = Hierarchy.from_bank(bank, groups, backend="jax")

                def hier_jax():
                    return hj.partition_units(n, caps_np, min_units=1)

                t0 = time.perf_counter()
                d_hj = hier_jax()  # traces + compiles outer-agg + inner blocks
                row["hier_jax_compile_s"] = time.perf_counter() - t0
                row["hier_jax_s"] = best_of(hier_jax, ex_reps)
                assert sum(d_hj) == n
                row["hier_makespan_ratio"] = max(
                    row["hier_makespan_ratio"],
                    makespan(d_hj) / makespan(d_bank),
                )
        rows.append(row)
        msg = (
            f"p={p:6d}  bank={t_direct * 1e3:9.3f} ms"
            f" (exact {t_bank_exact * 1e3:9.3f} ms,"
            f" thr {t_bank_threshold * 1e3:9.3f} ms)"
            f"  facade=+{row['facade_overhead_pct']:5.2f}%"
        )
        if "scalar_s" in row:
            msg += (
                f"  scalar={row['scalar_s'] * 1e3:10.3f} ms"
                f"  speedup={row['speedup']:8.1f}x"
                f"  max|Δd|={row['max_unit_diff']}"
            )
        if "jax_steady_s" in row:
            msg += (
                f"  jax={row['jax_steady_s'] * 1e3:9.3f} ms"
                f" (compile {row['jax_compile_s']:6.2f} s,"
                f" facade +{row['jax_facade_overhead_pct']:.2f}%)"
                f"  jax_exact={row['jax_exact_s'] * 1e3:9.3f} ms"
                f" ({row['jax_completion_speedup']:6.1f}x)"
                f"  jax_max|Δd|={row['jax_max_unit_diff']}"
            )
        if "jax_f32_max_unit_diff" in row:
            msg += (
                f"  f32|Δd|max={row['jax_f32_max_unit_diff']}"
                f" Σ={row['jax_f32_total_unit_drift']}"
            )
        if "hier_s" in row:
            msg += (
                f"  hier={row['hier_s'] * 1e3:9.3f} ms"
            )
            if "hier_jax_s" in row:
                msg += (
                    f"  hier_jax={row['hier_jax_s'] * 1e3:9.3f} ms"
                    f" (compile {row['hier_jax_compile_s']:6.2f} s)"
                )
            msg += f"  makespan x{row['hier_makespan_ratio']:.4f}"
        print(msg, flush=True)
    return rows


def _p1e6_row() -> dict:
    """Worker for the p=10^6 row — run in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set BEFORE jax
    imports (device count is fixed at first import)."""
    import jax

    jax.config.update("jax_enable_x64", True)
    g, p_g = 8, 125_000
    p = g * p_g
    t0 = time.perf_counter()
    banks = [make_fleet_bank(p_g, seed=1000 + i) for i in range(g)]
    h = Hierarchy.from_group_banks(banks, backend="jax", sharding="shard_map")
    t_build = time.perf_counter() - t0
    n = 20 * p
    caps = np.full(p, n, dtype=np.int64)  # uncapped, vectorized-validation path
    t0 = time.perf_counter()
    d = h.partition_units(n, caps, min_units=1)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    d = h.partition_units(n, caps, min_units=1)
    t_steady = time.perf_counter() - t0
    return {
        "p": p,
        "g": g,
        "n": n,
        "ndev": len(jax.devices()),
        "build_s": t_build,
        "first_call_s": t_first,
        "steady_s": t_steady,
        "max_shard_elems": int(h.max_shard_elems()),
        "flat_bank_elems": 2 * p * 6,
        "sum_equals_n": int(np.sum(np.asarray(d, dtype=np.int64))) == n,
    }


def run_p1e6_subprocess() -> dict | None:
    """Launch :func:`_p1e6_row` in a fresh interpreter with 8 emulated XLA
    host devices.  Returns the row dict, or None on failure."""
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--p1e6-row"],
        env=env,
        capture_output=True,
        text=True,
    )
    for line in proc.stdout.splitlines():
        if line.startswith("P1E6_ROW "):
            return json.loads(line[len("P1E6_ROW "):])
    print("p=10^6 subprocess failed:", proc.stdout[-1000:], proc.stderr[-1000:])
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sweep for CI smoke")
    ap.add_argument("--backend", choices=["numpy", "jax", "both"], default="both")
    ap.add_argument("--out", default="BENCH_partition.json")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--p1e6-row", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.p1e6_row:
        print("P1E6_ROW " + json.dumps(_p1e6_row()), flush=True)
        return 0

    if args.quick:
        # p=1000 is included so the p==1000 acceptance gates (facade tax,
        # jax-vs-bank steady state, completion-mode divergence) actually run
        # in the CI smoke, not just in full sweeps.  The scalar column is
        # skipped above p=100 to keep the smoke fast; the gates don't need it.
        ps, repeats, cutoff = [10, 100, 1000], args.repeats or 2, 100
        f32_ps = ()  # drift quantification is a full-sweep question
    else:
        # p=10^5 is the threshold-count completion's target scale (the
        # >=10x fast-vs-per-unit gate below); the seed scalar path stops at
        # p=10^4 (it already takes ~2 minutes per call there).  Float32
        # drift is measured at BOTH serving scales — the dtype-policy docs
        # in speedstore.py cite the pair.
        ps, repeats, cutoff = [10, 100, 1000, 10000, 100000], args.repeats or 3, 10**4
        f32_ps = (10**4, 10**5)

    rows = run_sweep(ps, repeats, args.backend, scalar_cutoff=cutoff, f32_ps=f32_ps)

    p1e6 = None
    if not args.quick and args.backend in ("jax", "both"):
        print("p=10^6 hier shard_map row (subprocess, 8 emulated devices) ...",
              flush=True)
        p1e6 = run_p1e6_subprocess()
        if p1e6 is not None:
            print(
                f"p={p1e6['p']}  build={p1e6['build_s']:.2f} s"
                f"  first={p1e6['first_call_s']:.1f} s"
                f"  steady={p1e6['steady_s']:.1f} s"
                f"  shard_elems={p1e6['max_shard_elems']:,} vs flat "
                f"{p1e6['flat_bank_elems']:,}"
                f"  sum==n: {p1e6['sum_equals_n']}",
                flush=True,
            )

    payload = {
        "benchmark": "partition_scale",
        "description": (
            "partition_units latency via the SpeedStore/Scheduler facade: "
            "seed scalar path vs numpy ModelBank vs jitted JaxModelBank "
            "(x64; steady-state = post-compile; facade_* columns measure the "
            "facade's validation+dispatch tax over the raw kernels; "
            "*_exact_s columns force the per-unit greedy completion vs the "
            "default threshold-count completion on these monotone fleets, "
            "with jax_completion_speedup the fast-vs-per-unit ratio gated "
            ">=10x at p=10^5; jax_f32_* columns quantify float32 drift at "
            "p=10^4 and p=10^5; hier_* columns time the two-level Hierarchy "
            "route at p>=1000 with its makespan gated <= 1.12x flat; the "
            "p1e6 block is the from_group_banks + shard_map feasibility row "
            "on 8 emulated devices, gated on sum==n and >=4x smaller "
            "per-device bank blocks than flat)"
        ),
        "units_per_proc": 100,
        "repeats": repeats,
        "backend": args.backend,
        "sweep": rows,
    }
    if p1e6 is not None:
        payload["p1e6"] = p1e6
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"-> {args.out}")

    rc = 0
    checked = [r for r in rows if "speedup" in r]
    big = [r for r in checked if r["p"] >= 1000]
    if big and min(r["speedup"] for r in big) < 10.0:
        print("WARNING: <10x speedup at p>=1000")
        rc = 1
    if any(r["max_unit_diff"] > 1 for r in checked):
        print("WARNING: scalar/bank paths disagree by >1 unit")
        rc = 1
    # Facade tax gate at the paper-scale fleet (p=1000, the same anchor as
    # the jax-vs-bank gate below): the unified API must cost <= 5% over the
    # raw kernel.  Other p are latency-noise dominated on shared runners
    # (the real tax is an O(p) validation pass, ~60us at p=1000) and are
    # reported informationally.
    over = [r for r in rows if r["p"] == 1000 and r["facade_overhead_pct"] > 5.0]
    if over:
        print("FAIL: facade overhead > 5% at p=1000:",
              [(r["p"], round(r["facade_overhead_pct"], 2)) for r in over])
        rc = 1
    for r in rows:
        if r["p"] > 1000 and r["facade_overhead_pct"] > 5.0:
            print(f"note: facade overhead {r['facade_overhead_pct']:.2f}% at "
                  f"p={r['p']} (informational; shared-runner noise floor)")
    jaxed = [r for r in rows if "jax_max_unit_diff" in r]
    if jaxed:
        import jax

        if jax.default_backend() == "cpu":
            # Bit-identity is a CPU contract (same FPU, same reduction
            # order); on accelerators a 1-ulp sum difference may move one
            # boundary unit, so there only >1-unit drift is a failure.
            if any(r["jax_max_unit_diff"] != 0 for r in jaxed):
                print("FAIL: jax allocations not bit-identical to the numpy bank")
                rc = 1
        elif any(r["jax_max_unit_diff"] > 1 for r in jaxed):
            print("FAIL: jax allocations differ from the numpy bank by >1 unit")
            rc = 1
    # Hard gate at the paper-scale fleet (p=1000): steady-state jitted
    # repartition must not lose to the numpy bank.  Larger p is reported but
    # informational.
    slow = [r for r in jaxed if r["p"] == 1000 and r["jax_steady_s"] > r["bank_s"]]
    if slow:
        print("FAIL: jax steady-state slower than numpy bank at p=1000")
        rc = 1
    for r in jaxed:
        if r["p"] > 1000 and r["jax_steady_s"] > r["bank_s"]:
            print(f"note: jax steady-state behind numpy bank at p={r['p']} "
                  f"({r['jax_steady_s']*1e3:.0f} ms vs {r['bank_s']*1e3:.0f} ms)")
    # Completion-mode divergence gate: the threshold-count fast path (what
    # "auto" picks on these monotone fleets) must hit the SAME makespan as
    # the exact per-unit completion.  Enforced at p=1000 (runs in the CI
    # smoke); other p are reported.
    div = [r for r in rows if not r.get("completion_makespan_equal", True)]
    if any(r["p"] == 1000 for r in div):
        print("FAIL: threshold-count completion diverges from the per-unit "
              "completion makespan at p=1000")
        rc = 1
    for r in div:
        if r["p"] != 1000:
            print(f"note: completion-mode makespan divergence at p={r['p']}")
    # The tentpole acceptance gate: at p=10^5 the threshold-count completion
    # must beat the sequential per-unit jax completion by >= 10x steady-state
    # (full sweeps only — quick mode stops at p=1000).
    big_jax = [r for r in jaxed if r["p"] >= 10**5]
    if big_jax and min(r["jax_completion_speedup"] for r in big_jax) < 10.0:
        print("FAIL: threshold-count completion < 10x over the per-unit jax "
              "completion at p=10^5")
        rc = 1
    # Hierarchical near-optimality gate: the two-level makespan must stay
    # within the fuzz-test envelope of the flat optimum at every swept p.
    bad_hier = [r for r in rows if r.get("hier_makespan_ratio", 1.0) > 1.12]
    if bad_hier:
        print("FAIL: hierarchical makespan > 1.12x flat:",
              [(r["p"], round(r["hier_makespan_ratio"], 4)) for r in bad_hier])
        rc = 1
    # p=10^6 feasibility gates: the allocation is exact in total, and
    # shard_map actually bounds per-device memory (8 emulated devices ->
    # expect 8x; gate at >= 4x so a device-count drop to 4 still passes).
    if not args.quick and args.backend in ("jax", "both"):
        if p1e6 is None:
            print("FAIL: p=10^6 row did not run")
            rc = 1
        else:
            if not p1e6["sum_equals_n"]:
                print("FAIL: p=10^6 hier allocation does not sum to n")
                rc = 1
            if p1e6["max_shard_elems"] * 4 > p1e6["flat_bank_elems"]:
                print(f"FAIL: p=10^6 per-shard bank {p1e6['max_shard_elems']:,}"
                      f" elems not >=4x below flat {p1e6['flat_bank_elems']:,}")
                rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
