"""Functional performance models (FPMs).

The paper represents the speed of a processor by a function ``s(x)`` of problem
size ``x`` (number of equal-size computation units).  DFPA never builds the full
function; it maintains a *partial piecewise-linear estimate* from the points
observed so far, with the paper's three update rules (§2, step 5):

  * ``x < x_(1)``  : the segment ``(0, s(x_(1))) -> (x_(1), s(x_(1)))`` is replaced by
    ``(0, s(x)) -> (x, s(x)) -> (x_(1), s(x_(1)))``  (constant extension to the left
    of the leftmost observed point);
  * ``x > x_(m)``  : the constant continuation to the right is re-anchored at the
    new rightmost point;
  * ``x_(k) < x < x_(k+1)``: the point is inserted and the segment split.

All of which reduce to: keep a sorted set of observed ``(x, s)`` points, evaluate
by linear interpolation between points and constant extension outside them.

Models expose two queries used by the geometric partitioner (``partition.py``):

  * ``time(x)``            — execution-time estimate ``x / s(x)``;
  * ``alloc_at_time(t, cap)`` — ``max { x in [0, cap] : time(x) <= t }``, the
    workload the processor can finish within ``t``.  This is the primitive of the
    line-through-origin algorithm of [16]: the optimal allocations are
    ``x_i = alloc_i(t*)`` for the smallest ``t*`` with ``sum_i x_i >= n``.

``alloc_at_time`` is monotone non-decreasing in ``t`` *by construction* (the
feasible set only grows with ``t``), so bisection over ``t`` is valid for any
shape of the speed estimate — the implementation does not rely on monotonicity
of ``s`` itself, only positivity.
"""

from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Callable, List, Protocol, Sequence, Tuple

__all__ = [
    "SpeedModel",
    "PiecewiseLinearFPM",
    "ConstantModel",
    "AnalyticModel",
    "imbalance",
]


def imbalance(times: Sequence[float]) -> float:
    """The paper's balance metric: ``max_{i,j} |t_i - t_j| / t_i``.

    Maximised by ``t_i = min``, ``t_j = max`` so it equals ``(max - min)/min``
    over the *working* processors.  Entries ``<= 0`` are processors that
    received no units this round (legal under ``min_units=0``) — they are
    ignored, not treated as infinitely imbalanced, so a distribution whose
    working processors finish simultaneously is balanced no matter how many
    processors sat out.  Fewer than two positive entries -> 0 (trivially
    balanced).
    """
    ts = [float(t) for t in times if float(t) > 0.0]
    if len(ts) < 2:
        return 0.0
    tmin, tmax = min(ts), max(ts)
    return (tmax - tmin) / tmin


class SpeedModel(Protocol):
    """What the geometric partitioner needs from a performance model."""

    def speed(self, x: float) -> float: ...

    def time(self, x: float) -> float: ...

    def alloc_at_time(self, t: float, cap: float) -> float: ...


@dataclass
class PiecewiseLinearFPM:
    """Partial piecewise-linear estimate of a speed function (the paper's FPM).

    ``xs``/``ss`` hold the sorted observed points.  ``on_duplicate`` controls
    what happens when the same problem size is re-measured: ``"replace"``
    trusts the newest observation (the paper's behaviour — later measurements
    reflect the current state of the machine), ``"mean"`` averages.
    """

    xs: List[float] = field(default_factory=list)
    ss: List[float] = field(default_factory=list)
    on_duplicate: str = "replace"

    # -- construction ------------------------------------------------------

    @classmethod
    def from_points(cls, pts: Sequence[Tuple[float, float]], **kw) -> "PiecewiseLinearFPM":
        m = cls(**kw)
        for x, s in pts:
            m.add_point(x, s)
        return m

    @classmethod
    def from_constant(cls, x: float, s: float, **kw) -> "PiecewiseLinearFPM":
        """The DFPA step-2 initial approximation: a constant model ``s(x) = s``
        anchored at the first observation ``(x, s)``."""
        return cls.from_points([(x, s)], **kw)

    # -- the paper's update rule --------------------------------------------

    def add_point(self, x: float, s: float) -> None:
        if not (x > 0.0):
            raise ValueError(f"problem size must be positive, got {x}")
        if not (s > 0.0) or not math.isfinite(s):
            raise ValueError(f"speed must be positive and finite, got {s}")
        i = bisect.bisect_left(self.xs, x)
        if i < len(self.xs) and self.xs[i] == x:
            if self.on_duplicate == "mean":
                self.ss[i] = 0.5 * (self.ss[i] + s)
            else:
                self.ss[i] = s
            return
        self.xs.insert(i, x)
        self.ss.insert(i, s)

    # -- evaluation ----------------------------------------------------------

    @property
    def num_points(self) -> int:
        return len(self.xs)

    def speed(self, x: float) -> float:
        if not self.xs:
            raise ValueError("empty FPM")
        if x <= self.xs[0]:
            return self.ss[0]
        if x >= self.xs[-1]:
            return self.ss[-1]
        k = bisect.bisect_right(self.xs, x) - 1
        x0, x1 = self.xs[k], self.xs[k + 1]
        s0, s1 = self.ss[k], self.ss[k + 1]
        w = (x - x0) / (x1 - x0)
        return s0 + w * (s1 - s0)

    def time(self, x: float) -> float:
        if x <= 0.0:
            return 0.0
        return x / self.speed(x)

    # -- the partitioner primitive -------------------------------------------

    def alloc_at_time(self, t: float, cap: float) -> float:
        """``max { x in [0, cap] : x / s(x) <= t }`` in closed form per segment.

        Within a segment ``s(x) = s0 + m (x - x0)`` the constraint
        ``x <= t * s(x)`` is linear:  ``x (1 - t m) <= t (s0 - m x0)``.
        """
        if t <= 0.0 or cap <= 0.0 or not self.xs:
            return 0.0
        best = 0.0

        # Region [0, x_1]: constant speed ss[0].
        x_lo = min(self.xs[0], cap)
        best = max(best, min(t * self.ss[0], x_lo))

        # Interior segments.
        for k in range(len(self.xs) - 1):
            x0, x1 = self.xs[k], self.xs[k + 1]
            if x0 >= cap:
                break
            x1c = min(x1, cap)
            s0 = self.ss[k]
            m = (self.ss[k + 1] - s0) / (x1 - x0)
            a = 1.0 - t * m
            b = t * (s0 - m * x0)
            if a > 0.0:
                ub = b / a
                if ub >= x0:
                    best = max(best, min(ub, x1c))
            elif a == 0.0:
                if b >= 0.0:
                    best = max(best, x1c)
            else:  # a < 0: feasible for x >= b/a; segment top is feasible
                if x1c >= b / a:
                    best = max(best, x1c)

        # Region [x_m, cap]: constant speed ss[-1].
        if cap > self.xs[-1]:
            ub = t * self.ss[-1]
            if ub >= self.xs[-1]:
                best = max(best, min(ub, cap))
        return best

    def as_points(self) -> List[Tuple[float, float]]:
        return list(zip(self.xs, self.ss))


@dataclass
class ConstantModel:
    """CPM: a single positive number.  ``time(x) = x / s``."""

    s: float

    def speed(self, x: float) -> float:  # noqa: ARG002 - constant by definition
        return self.s

    def time(self, x: float) -> float:
        return x / self.s if x > 0 else 0.0

    def alloc_at_time(self, t: float, cap: float) -> float:
        if t <= 0.0:
            return 0.0
        return min(t * self.s, cap)


@dataclass
class AnalyticModel:
    """Wraps an arbitrary ground-truth time function ``t(x)`` (used by the
    simulator and by FFMPA when the 'full model' is analytic rather than
    piecewise).  Requires ``t`` to be non-decreasing in ``x`` — true for any
    real workload (more units never take less total time) — and solves
    ``alloc_at_time`` by bisection on ``x``.
    """

    time_fn: Callable[[float], float]

    def time(self, x: float) -> float:
        return self.time_fn(x) if x > 0 else 0.0

    def speed(self, x: float) -> float:
        t = self.time(x)
        return x / t if t > 0 else math.inf

    def alloc_at_time(self, t: float, cap: float) -> float:
        if t <= 0.0 or cap <= 0.0:
            return 0.0
        if self.time(cap) <= t:
            return cap
        lo, hi = 0.0, cap  # invariant: time(lo) <= t < time(hi)
        for _ in range(96):
            mid = 0.5 * (lo + hi)
            if self.time(mid) <= t:
                lo = mid
            else:
                hi = mid
        return lo
