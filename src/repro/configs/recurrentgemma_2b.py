"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000.  RG-LRU recurrent blocks + local attention in a 2:1 pattern
(rec, rec, attn), window 2048, GeGLU, d_rnn=2560, conv width 4
[arXiv:2402.19427; hf].  Sub-quadratic: runs long_500k.

26 layers does not divide the 3-layer pattern; following the published
model, the final truncated unit is dropped to 24 scanned layers + 2 prefix
(rec, rec) layers = 26.
"""

import math

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rec", "rec", "local"),
    prefix=("rec", "rec"),
    prefix_dense_ff=7680,
    window=2048,
    mlp_kind="geglu",
    d_rnn=2560,
    conv_width=4,
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=math.sqrt(2560),
    query_scale=1.0 / math.sqrt(256),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-smoke",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=8,
        d_rnn=64,
        embed_scale=8.0,
        query_scale=1.0 / math.sqrt(16),
        xent_chunk=0,
        remat="none",
    )
