"""Persistent profile registry: partial speed-function estimates that
outlive the session that measured them.

The paper's economic argument is that *partial* estimates — a handful of
(size, speed) points per processor — are already sufficient for a given
accuracy.  Those points are expensive only the first time: they are paid for
in real measurement rounds (CPM probes, DFPA iterations).  A multi-tenant
fleet sees the same (device class, workload) pairs over and over, so the
registry keys each partial estimate by ``(device_class, workload_tag)`` and
merges it back in when a new job is admitted: the newcomer's first
distribution is computed from *yesterday's* points instead of an even split,
and the DFPA loop starts from round ~k instead of round 1 (the warm-start
path of ``Scheduler.autotune`` / ``FleetScheduler.admit``).

Key scheme
----------

One entry per ``(device_class, workload_tag)`` — NOT per processor: two A100
groups running the same decode workload share a speed function up to noise,
and sharing the entry is exactly what makes the registry useful for a job
that lands on *different* processors of the same classes.  Entries hold
plain ``[(x, speed), ...]`` point lists, the same representation as
``PiecewiseLinearFPM.as_points()`` / the ``SpeedStore.state_dict``
``points`` field, and merging follows ``add_point`` semantics: a duplicate
``x`` replaces the stored speed (freshest observation wins), anything else
sorted-inserts.

Failure policy
--------------

A registry must never take a fleet down: a missing file, corrupt JSON, or a
malformed entry degrades to a cold start with a ``UserWarning`` — the job
just pays the measurement rounds it would have paid without a registry.

Staleness and bounds
--------------------

Profiles age: a driver update or thermal re-limit changes a device class's
speed function, and yesterday's points then *mislead* the warm start.  Two
mechanisms keep the registry honest:

* every entry carries an ``observed_at`` timestamp (refreshed on
  ``record``); ``FleetScheduler`` compares a warm-started job's FIRST
  measured round against the warm prediction and, beyond
  ``staleness_tol``, calls :meth:`drop` on the offending entry with a
  ``UserWarning`` — the job continues from its fresh measurements;
* ``max_entries`` bounds the registry LRU-style (dict insertion order;
  ``get``/``record`` refresh recency), so a long-lived fleet cycling
  through many workloads cannot grow it without bound.

``observed_at`` is an OPTIONAL JSON field: state dicts written by older
sessions load fine (no timestamp -> treated as never refreshed, first in
line for eviction), and older sessions ignore the extra field — the
round-trip stays backward-compatible in both directions (``VERSION`` stays
1).

Energy profiles
---------------

Energy profiles ride alongside speed ones under the SAME key scheme:
``record_energy``/``get_energy``/``warm_energy_models`` mirror the speed
trio, storing energy-RATE points (``er_i(x) = x / E_i(x)`` — the
representation of ``core/energy.py``, so the same positive/sorted
validation applies).  Evicting or dropping a speed entry removes its
energy sibling; persistence adds an OPTIONAL ``energy_entries`` list that
older readers ignore (``VERSION`` still 1).
"""

from __future__ import annotations

import json
import os
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.fpm import PiecewiseLinearFPM

try:  # telemetry is optional: the registry runs identically without repro.obs
    from ..obs.telemetry import active as _obs_active
except ImportError:  # pragma: no cover - obs layer absent
    def _obs_active():
        return None

__all__ = ["ProfileRegistry"]


def _warn(message: str, *, stacklevel: int = 2, **attrs) -> None:
    """``warnings.warn`` with a structured telemetry mirror: the warning
    behaviour is byte-identical (same message, category, user-facing
    stacklevel), but an installed sink also gets a ``registry.warning``
    event carrying the machine-readable fields — so cold-start causes show
    up in traces without scraping warning text."""
    tel = _obs_active()
    if tel is not None and tel.enabled:
        tel.event("registry.warning", message=message, **attrs)
    warnings.warn(message, UserWarning, stacklevel=stacklevel + 1)

Point = Tuple[float, float]


def _valid_points(points) -> Optional[List[Point]]:
    """Validate one entry's point list; None (not a raise) on any malformed
    shape — the caller warns and falls back to a cold start."""
    try:
        out = [(float(x), float(s)) for x, s in points]
    except (TypeError, ValueError):
        return None
    if not out:
        return None
    for x, s in out:
        if not (x > 0.0 and s > 0.0) or x != x or s != s or x == float("inf") or s == float("inf"):
            return None
    if any(b[0] < a[0] for a, b in zip(out, out[1:])):
        return None
    return out


class ProfileRegistry:
    """(device-class, workload-tag)-keyed store of partial FPM estimates.

    ``get``/``record`` are the in-memory protocol; ``state_dict``/
    ``from_state`` mirror the repo's persistence convention and
    ``save``/``load`` wrap them in JSON-on-disk.  ``warm_models`` and
    ``record_job`` are the fleet-facing pair: models out on admit, points
    back on retire.
    """

    VERSION = 1

    def __init__(
        self,
        entries: Optional[Dict[Tuple[str, str], List[Point]]] = None,
        *,
        max_entries: Optional[int] = None,
    ):
        if max_entries is not None and int(max_entries) < 1:
            raise ValueError(f"max_entries must be >= 1 (got {max_entries})")
        self._entries: Dict[Tuple[str, str], List[Point]] = dict(entries or {})
        self._observed: Dict[Tuple[str, str], float] = {}
        # energy-RATE point lists keyed like _entries (see module docstring)
        self._energy: Dict[Tuple[str, str], List[Point]] = {}
        self.max_entries = int(max_entries) if max_entries is not None else None
        self._evict()

    # -- in-memory protocol ---------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[str, str]) -> bool:
        return tuple(key) in self._entries

    def keys(self):
        return self._entries.keys()

    def _touch(self, key: Tuple[str, str]) -> None:
        # Recency = dict insertion order; re-inserting moves the key to the
        # end, so eviction pops from the front.
        self._entries[key] = self._entries.pop(key)

    def _evict(self) -> None:
        if self.max_entries is None:
            return
        while len(self._entries) > self.max_entries:
            key = next(iter(self._entries))
            del self._entries[key]
            self._observed.pop(key, None)
            self._energy.pop(key, None)

    def observed_at(self, device_class: str, workload: str) -> Optional[float]:
        """When this entry's points were last recorded (``record``'s ``now``),
        or None for entries that predate the timestamp field."""
        return self._observed.get((str(device_class), str(workload)))

    def drop(self, device_class: str, workload: str) -> bool:
        """Remove one entry (the staleness path: a warm prediction that the
        first measured round contradicts).  True if something was dropped."""
        key = (str(device_class), str(workload))
        self._observed.pop(key, None)
        self._energy.pop(key, None)
        return self._entries.pop(key, None) is not None

    def get(self, device_class: str, workload: str) -> Optional[List[Point]]:
        """The stored points for one (class, workload) pair, or None."""
        key = (str(device_class), str(workload))
        pts = self._entries.get(key)
        if pts is None:
            return None
        ok = _valid_points(pts)
        if ok is None:
            _warn(
                f"profile registry entry ({device_class!r}, {workload!r}) is "
                "malformed; ignoring it (cold start)",
                kind="malformed_entry",
                device_class=str(device_class),
                workload=str(workload),
            )
            return None
        self._touch(key)
        return list(ok)

    def record(
        self,
        device_class: str,
        workload: str,
        points: Sequence[Point],
        *,
        now: Optional[float] = None,
    ) -> None:
        """Merge one estimate's points into its entry (``add_point``
        semantics: duplicate ``x`` replaces — freshest observation wins).
        ``now`` overrides the ``observed_at`` timestamp (tests)."""
        key = (str(device_class), str(workload))
        merged = PiecewiseLinearFPM.from_points(self._entries.get(key, []))
        for x, s in points:
            merged.add_point(float(x), float(s))
        self._entries.pop(key, None)
        self._entries[key] = [(float(x), float(s)) for x, s in merged.as_points()]
        self._observed[key] = float(now) if now is not None else time.time()
        self._evict()

    # -- energy profiles (same keys, energy-rate points) ----------------------

    def get_energy(self, device_class: str, workload: str) -> Optional[List[Point]]:
        """The stored energy-rate points for one (class, workload) pair, or
        None.  Malformed entries degrade exactly like :meth:`get`."""
        key = (str(device_class), str(workload))
        pts = self._energy.get(key)
        if pts is None:
            return None
        ok = _valid_points(pts)
        if ok is None:
            _warn(
                f"energy profile entry ({device_class!r}, {workload!r}) is "
                "malformed; ignoring it",
                kind="malformed_energy_entry",
                device_class=str(device_class),
                workload=str(workload),
            )
            return None
        return list(ok)

    def record_energy(
        self, device_class: str, workload: str, points: Sequence[Point]
    ) -> None:
        """Merge energy-rate points into the key's energy entry (duplicate
        ``x`` replaces — freshest observation wins)."""
        key = (str(device_class), str(workload))
        merged = PiecewiseLinearFPM.from_points(self._energy.get(key, []))
        for x, s in points:
            merged.add_point(float(x), float(s))
        self._energy[key] = [(float(x), float(s)) for x, s in merged.as_points()]

    def warm_energy_models(
        self, device_classes: Sequence[str], workload: Optional[str]
    ) -> Optional[List[PiecewiseLinearFPM]]:
        """One energy-rate model per processor, or None unless EVERY
        processor's class has a valid energy entry (a partial energy bank
        cannot price a fleet-wide cap, so it is all-or-nothing — unlike
        speed warm starts, where a cold row just costs measurement rounds)."""
        if workload is None:
            return None
        models = []
        for cls_ in device_classes:
            pts = self.get_energy(cls_, workload)
            if not pts:
                return None
            models.append(PiecewiseLinearFPM.from_points(pts))
        return models

    # -- the fleet-facing pair ------------------------------------------------

    def warm_models(
        self, device_classes: Sequence[str], workload: Optional[str]
    ) -> List[PiecewiseLinearFPM]:
        """One model per processor, warm where the registry has a valid
        entry for that processor's class, empty (cold) otherwise."""
        models = []
        for cls_ in device_classes:
            pts = self.get(cls_, workload) if workload is not None else None
            models.append(
                PiecewiseLinearFPM.from_points(pts) if pts else PiecewiseLinearFPM()
            )
        return models

    def record_job(
        self,
        device_classes: Sequence[str],
        workload: Optional[str],
        models: Sequence[PiecewiseLinearFPM],
        *,
        now: Optional[float] = None,
        energy_models: Optional[Sequence[PiecewiseLinearFPM]] = None,
    ) -> None:
        """Fold a retiring job's learned estimates back in, processor by
        processor in index order (same-class processors merge into one
        entry; deterministic, so a registry round-trip is reproducible).
        ``energy_models`` (energy-rate FPMs) ride along into the energy
        entries when given."""
        if workload is None:
            return
        for cls_, m in zip(device_classes, models):
            pts = m.as_points() if getattr(m, "num_points", 0) > 0 else []
            if pts:
                self.record(cls_, workload, pts, now=now)
        if energy_models is not None:
            for cls_, m in zip(device_classes, energy_models):
                pts = m.as_points() if getattr(m, "num_points", 0) > 0 else []
                if pts:
                    self.record_energy(cls_, workload, pts)

    # -- persistence (the state_dict protocol + JSON on disk) -----------------

    def state_dict(self) -> Dict:
        out = []
        for (c, w), pts in sorted(self._entries.items()):
            e = {"device_class": c, "workload": w, "points": [[x, s] for x, s in pts]}
            ts = self._observed.get((c, w))
            if ts is not None:
                e["observed_at"] = ts  # optional field: older readers ignore it
            out.append(e)
        state = {"version": self.VERSION, "entries": out}
        if self._energy:
            # optional field: older readers ignore it (VERSION stays 1)
            state["energy_entries"] = [
                {"device_class": c, "workload": w, "points": [[x, s] for x, s in pts]}
                for (c, w), pts in sorted(self._energy.items())
            ]
        return state

    @classmethod
    def from_state(
        cls, state: Dict, *, max_entries: Optional[int] = None
    ) -> "ProfileRegistry":
        entries: Dict[Tuple[str, str], List[Point]] = {}
        observed: Dict[Tuple[str, str], float] = {}
        raw = state.get("entries")
        if not isinstance(raw, list):
            raise ValueError("registry state has no entries list")
        for e in raw:
            pts = _valid_points(e.get("points", []))
            if pts is None:
                _warn(
                    f"skipping malformed registry entry "
                    f"({e.get('device_class')!r}, {e.get('workload')!r})",
                    kind="malformed_state_entry",
                    device_class=str(e.get("device_class")),
                    workload=str(e.get("workload")),
                )
                continue
            key = (str(e["device_class"]), str(e["workload"]))
            entries[key] = pts
            ts = e.get("observed_at")
            if isinstance(ts, (int, float)) and ts == ts:
                observed[key] = float(ts)
        reg = cls(entries, max_entries=max_entries)
        reg._observed = {k: observed[k] for k in observed if k in reg._entries}
        for e in state.get("energy_entries") or []:
            pts = _valid_points(e.get("points", []))
            if pts is None:
                _warn(
                    f"skipping malformed energy registry entry "
                    f"({e.get('device_class')!r}, {e.get('workload')!r})",
                    kind="malformed_state_energy_entry",
                    device_class=str(e.get("device_class")),
                    workload=str(e.get("workload")),
                )
                continue
            reg._energy[(str(e["device_class"]), str(e["workload"]))] = pts
        return reg

    def save(self, path: str) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.state_dict(), f, indent=1)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "ProfileRegistry":
        """Load from disk; ANY failure — missing file, corrupt JSON, wrong
        shape — warns and returns an empty registry (cold start), never
        raises: a broken profile cache must not take the fleet down."""
        try:
            with open(path) as f:
                state = json.load(f)
        except FileNotFoundError:
            _warn(
                f"profile registry {path!r} not found; starting cold",
                kind="not_found",
                path=str(path),
            )
            return cls()
        except (OSError, json.JSONDecodeError) as e:
            _warn(
                f"profile registry {path!r} unreadable ({e}); starting cold",
                kind="unreadable",
                path=str(path),
                error=str(e),
            )
            return cls()
        try:
            return cls.from_state(state)
        except (ValueError, KeyError, TypeError) as e:
            _warn(
                f"profile registry {path!r} malformed ({e}); starting cold",
                kind="malformed",
                path=str(path),
                error=str(e),
            )
            return cls()
