"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Code LM, llama-arch per the assignment [arXiv:2405.04324; hf].  d_ff = 4x
d_model -> non-gated GELU MLP; MQA (kv=1); RoPE; untied head.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    pattern=("attn",),
    mlp_kind="gelu",
    rope_theta=10000.0,
    tie_embeddings=False,
    train_accum=4,
    attn_chunk_threshold=4096,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="granite-20b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        xent_chunk=0,
        remat="none",
    )
