"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["matmul_update_ref", "flash_attention_ref", "rglru_scan_ref"]


def matmul_update_ref(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """C += A @ B with fp32 accumulation (the paper's panel-update kernel)."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return (c.astype(jnp.float32) + acc).astype(c.dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Kv, Sk, D)
    v: jax.Array,  # (B, Kv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    G = H // Kv
    if scale is None:
        scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned queries
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -2.0e38)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vr)


def rglru_scan_ref(log_a: jax.Array, b: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """h_t = exp(log_a_t) * h_{t-1} + b_t over axis 1.  (B, S, D) fp32."""

    def step(h, inp):
        la, bb = inp
        h = jnp.exp(la) * h + bb
        return h, h

    B, S, D = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (log_a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
