"""Data-partitioning algorithms over performance models.

Implements the building blocks the paper composes:

* ``partition_continuous`` — the geometric algorithm of [16] (Lastovetsky &
  Reddy, IJHPCA 2007): the optimal allocations ``x_i`` lie on a straight line
  through the origin of the (size, speed) plane, i.e. all processors finish at
  the same time ``t* = x_i / s_i(x_i)``.  We find the smallest ``t`` such that
  ``sum_i alloc_i(t) >= n`` by bisection; ``alloc_i(t) = max{x <= cap_i :
  x/s_i(x) <= t}`` is supplied by the model (monotone in ``t`` by construction,
  so bisection is exact regardless of the shape of the speed estimate).

* ``partition_units`` — the integer version used by DFPA: continuous solution,
  floor, then a greedy min-makespan completion (each leftover unit goes to the
  processor whose completion time after receiving it is smallest).  This is the
  "distribution of computation units" the paper's step 3 sends out.

* ``cpm_partition`` — the conventional constant-performance-model distribution
  (speed constants, proportional allocation), the paper's baseline.

.. deprecated::
    The module-level functions are **legacy shims**: the scalar-vs-bank-vs-jax
    dispatch they used to re-derive per call now happens ONCE, at
    ``SpeedStore`` construction (``core/speedstore.py``), and the lifecycle
    around them (observe → repartition → adapt) lives on the ``Scheduler``
    facade (``core/scheduler.py``).  They emit ``DeprecationWarning`` and
    delegate; new code should build a ``SpeedStore`` (or ``Scheduler``) and
    call its methods.  The private ``_partition_*`` kernels below remain the
    single implementation all paths share — the facade calls them with the
    backend pre-resolved.

Three execution paths share identical semantics (see the "three backends,
one semantics" section in ``modelbank.py``):

* **bank path** (default, backend ``"numpy"``) — the models are adapted into
  a ``ModelBank`` and every bisection step evaluates all ``p`` processors'
  segment inequalities in ONE numpy pass; the integer completion uses a lazy
  heap.  This is the fleet-scale host path: thousands of processors partition
  in sub-millisecond time (``benchmarks/partition_scale.py``).
* **jax path** (backend ``"jax"``) — the bank lives on device as a
  ``JaxModelBank`` and the whole ``t*`` bisection + integer completion runs
  under ``jax.jit`` (``modelbank_jax.py``); after the one-time compile a
  repartition costs microseconds and composes with a jitted training step.
  With x64 enabled its allocations are bit-identical to the numpy bank.
* **scalar path** — the original per-model Python loop, used automatically
  when a model has no piecewise representation (``AnalyticModel``) or when
  the scalar backend is forced (the scaling benchmark's baseline).
"""

from __future__ import annotations

import heapq
import math
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .fpm import SpeedModel
from .modelbank import ModelBank

__all__ = [
    "partition_continuous",
    "partition_units",
    "cpm_partition",
]

Models = Union[Sequence[SpeedModel], ModelBank]

# Iteration count of the most recent host-side t* bisection (scalar or bank
# kernel) — a telemetry tap read by SpeedStore.partition after a host solve.
# The jax kernel runs its fixed-trip loop on device and does not report here.
_LAST_BISECTION_STEPS: int = 0


# ---------------------------------------------------------------------------
# Internal kernels — the single implementation behind SpeedStore and the
# legacy shims.  Validation mirrors the seed public functions exactly so the
# facade raises the same ValueErrors in the same order.
# ---------------------------------------------------------------------------


def _total_alloc(models: Sequence[SpeedModel], t: float, caps: Sequence[float]) -> float:
    return sum(m.alloc_at_time(t, c) for m, c in zip(models, caps))


def _prep_continuous_caps(p: int, n: float, caps: Optional[Sequence[float]]) -> List[float]:
    """Cap normalization + feasibility check shared by every backend."""
    caps = list(caps) if caps is not None else [float(n)] * p
    caps = [min(float(c), float(n)) for c in caps]
    if sum(caps) < n:
        raise ValueError(f"infeasible: sum(caps)={sum(caps)} < n={n}")
    return caps


def _continuous_scalar(
    models: Sequence[SpeedModel],
    n: float,
    caps: Optional[Sequence[float]] = None,
    *,
    rel_tol: float = 1e-12,
    max_steps: int = 200,
) -> Tuple[List[float], float]:
    p = len(models)
    if p == 0:
        raise ValueError("no processors")
    if n <= 0:
        return [0.0] * p, 0.0
    caps = _prep_continuous_caps(p, n, caps)
    return _partition_continuous_scalar(models, n, caps, rel_tol=rel_tol, max_steps=max_steps)


def _continuous_bank(
    bank: ModelBank,
    n: float,
    caps: Optional[Sequence[float]] = None,
    *,
    rel_tol: float = 1e-12,
    max_steps: int = 200,
) -> Tuple[List[float], float]:
    p = len(bank)
    if p == 0:
        raise ValueError("no processors")
    if n <= 0:
        return [0.0] * p, 0.0
    caps = _prep_continuous_caps(p, n, caps)
    return _partition_continuous_bank(bank, n, caps, rel_tol=rel_tol, max_steps=max_steps)


def _prep_unit_caps(
    p: int, n: int, caps: Optional[Sequence[int]], min_units: int
) -> List[int]:
    """Integer-partition validation shared by every backend (the silent
    min_units-shortfall fix: any ``cap < min_units`` refuses loudly)."""
    if n < 0:
        raise ValueError("n must be non-negative")
    if min_units * p > n:
        raise ValueError(f"min_units={min_units} infeasible for n={n}, p={p}")
    icaps = [int(c) for c in caps] if caps is not None else [n] * p
    if min_units > 0:
        for i, c in enumerate(icaps):
            if c < min_units:
                raise ValueError(
                    f"min_units={min_units} infeasible: caps[{i}]={c} < min_units"
                )
    return icaps


def _partition_continuous_scalar(
    models: Sequence[SpeedModel],
    n: float,
    caps: List[float],
    *,
    rel_tol: float,
    max_steps: int,
) -> Tuple[List[float], float]:
    """The seed per-model Python loop (one ``alloc_at_time`` call per model per
    bisection step) — kept as the fallback for non-piecewise models and as the
    benchmark baseline."""
    # Exponential search for an upper bound on t*.
    hi = max(m.time(min(1.0, c)) for m, c in zip(models, caps) if c > 0)
    hi = max(hi, 1e-9)
    for _ in range(200):
        if _total_alloc(models, hi, caps) >= n:
            break
        hi *= 2.0
    else:  # pragma: no cover - guarded by the feasibility check above
        raise RuntimeError("could not bracket t*")
    lo = 0.0
    # Bisection: invariant total(lo) < n <= total(hi).
    steps = 0
    for _ in range(max_steps):
        steps += 1
        mid = 0.5 * (lo + hi)
        if _total_alloc(models, mid, caps) >= n:
            hi = mid
        else:
            lo = mid
        if hi - lo <= rel_tol * hi:
            break
    global _LAST_BISECTION_STEPS
    _LAST_BISECTION_STEPS = steps
    t_star = hi
    xs = [m.alloc_at_time(t_star, c) for m, c in zip(models, caps)]
    total = sum(xs)
    if total > 0:
        # alloc_at_time(t_star) may slightly overshoot n; rescale the excess
        # proportionally so the continuous solution sums exactly to n.
        excess = total - n
        if excess > 0:
            xs = [x - excess * (x / total) for x in xs]
    return xs, t_star


def _partition_continuous_bank(
    bank: ModelBank,
    n: float,
    caps: List[float],
    *,
    rel_tol: float,
    max_steps: int,
) -> Tuple[List[float], float]:
    """Bank path: the same bisection, one array op per step."""
    caps_arr = np.asarray(caps, dtype=np.float64)
    active = caps_arr > 0.0
    if np.any(active & (bank.counts == 0)):
        raise ValueError("empty FPM")
    # Exponential search for an upper bound on t*.
    t_init = bank.time(np.minimum(1.0, caps_arr))
    hi = float(t_init[active].max(initial=0.0))
    hi = max(hi, 1e-9)
    for _ in range(200):
        if bank.total_alloc(hi, caps_arr) >= n:
            break
        hi *= 2.0
    else:  # pragma: no cover - guarded by the feasibility check above
        raise RuntimeError("could not bracket t*")
    lo = 0.0
    steps = 0
    for _ in range(max_steps):
        steps += 1
        mid = 0.5 * (lo + hi)
        if bank.total_alloc(mid, caps_arr) >= n:
            hi = mid
        else:
            lo = mid
        if hi - lo <= rel_tol * hi:
            break
    global _LAST_BISECTION_STEPS
    _LAST_BISECTION_STEPS = steps
    t_star = hi
    xs = bank.alloc_at_time(t_star, caps_arr)
    total = float(xs.sum())
    if total > 0:
        excess = total - n
        if excess > 0:
            xs = xs - excess * (xs / total)
    return list(map(float, xs)), t_star


def _partition_units_scalar(
    models: Sequence[SpeedModel], n: int, icaps: List[int], *, min_units: int
) -> Tuple[List[int], float]:
    p = len(models)
    fcaps = [float(c) for c in icaps]
    xs, t_star = _continuous_scalar(models, float(n), fcaps)
    d = [max(min_units, int(math.floor(x))) for x in xs]
    d = [min(di, ci) for di, ci in zip(d, icaps)]
    leftover = n - sum(d)
    if leftover < 0:
        # min_units pushed us over n: take units back from the processors whose
        # per-unit time is largest (removing from the slowest hurts least).
        order = sorted(range(p), key=lambda i: models[i].time(d[i]) / max(d[i], 1), reverse=True)
        k = 0
        while leftover < 0:
            i = order[k % p]
            if d[i] > min_units:
                d[i] -= 1
                leftover += 1
            k += 1
    # Greedy completion: each leftover unit to the processor minimizing the
    # resulting completion time (ties -> larger fractional remainder).
    rem = [x - math.floor(x) for x in xs]
    for _ in range(leftover):
        best_i, best_key = -1, None
        for i in range(p):
            if d[i] + 1 > icaps[i]:
                continue
            key = (models[i].time(d[i] + 1), -rem[i])
            if best_key is None or key < best_key:
                best_i, best_key = i, key
        if best_i < 0:
            raise ValueError("caps infeasible during integer completion")
        d[best_i] += 1
    assert sum(d) == n
    return d, t_star


def _threshold_prefill_bank(
    bank: ModelBank,
    d0: np.ndarray,
    caps_arr: np.ndarray,
    leftover: int,
    t_star: float,
    *,
    rel_tol: float = 1e-12,
    max_steps: int = 200,
) -> Tuple[np.ndarray, int]:
    """Threshold-count bulk completion for monotone-time banks.

    On a monotone bank the per-unit greedy processes unit increments in
    globally sorted ``(time, -rem, index)`` order, so instead of popping
    units one at a time we bisect a time threshold ``t``:

        count(t) = sum_i clip(floor(alloc_at_time(t, cap_i)), d0_i, cap_i)
                   - sum_i d0_i

    is the number of leftover units the greedy would have granted by the
    time it reaches ``t``.  Bisection maintains the strict bracket
    ``count(lo) < leftover <= count(hi)``; everything counted at ``lo`` is
    granted in one array op, and only the boundary-tied remainder (at least
    1, typically a handful) is returned for the exact greedy to place — so
    tie-breaking, infeasibility behaviour and in practice the allocations
    themselves stay bit-identical to the per-unit path, at the cost of one
    more bisection instead of ~p/2 sequential pops.

    Mirrored expression-for-expression by ``_threshold_prefill`` in
    ``modelbank_jax.py`` (same doubling bracket, same after-update early
    exit), so the two banked backends take identical branch sequences under
    x64.
    """
    caps_f = caps_arr.astype(np.float64)
    base_total = int(d0.sum())

    def count(t: float) -> Tuple[int, np.ndarray]:
        g = np.clip(
            np.floor(bank.alloc_at_time(t, caps_f)).astype(np.int64), d0, caps_arr
        )
        return int(g.sum()) - base_total, g

    # Bracket: alloc(t -> inf) -> caps and sum(caps) >= n, so doubling from
    # the continuous solve's t* always terminates.
    hi = max(float(t_star), 1e-9)
    for _ in range(200):
        c_hi, _ = count(hi)
        if c_hi >= leftover:
            break
        hi *= 2.0
    else:  # pragma: no cover - guarded by the feasibility checks above
        raise RuntimeError("could not bracket the completion threshold")
    lo = 0.0
    for _ in range(max_steps):
        mid = 0.5 * (lo + hi)
        c_mid, _ = count(mid)
        if c_mid >= leftover:
            hi = mid
        else:
            lo = mid
        if hi - lo <= rel_tol * hi:
            break
    c_lo, g_lo = count(lo)
    return g_lo, leftover - c_lo


def _partition_units_bank(
    bank: ModelBank, n: int, icaps: List[int], *, min_units: int,
    completion: str = "auto",
) -> Tuple[List[int], float]:
    """Vectorized floor + integer completion.

    ``completion`` selects how the leftover units are placed (see the
    "completion modes" section in ``modelbank.py``): ``"greedy"`` is the
    per-unit lazy heap, ``"threshold"`` forces the threshold-count bulk
    grant, ``"auto"`` (default) keeps the lazy heap ON THIS HOST PATH —
    the heap was never the numpy bottleneck, and the threshold pass costs
    ~one extra continuous solve here, so auto only routes to threshold-count
    on the jitted backends (where the per-unit ``while_loop``'s serial
    dispatch dominated).  All modes share the heap for the final boundary
    units, so tie-breaking is identical: each unit goes to the processor
    with the smallest ``(time(d+1), -frac_remainder, index)``.
    """
    if completion not in ("auto", "threshold", "greedy"):
        raise ValueError(f"unknown completion mode {completion!r}")
    p = bank.p
    caps_arr = np.asarray(icaps, dtype=np.int64)
    xs_list, t_star = _continuous_bank(bank, float(n), [float(c) for c in icaps])
    xs = np.asarray(xs_list, dtype=np.float64)
    d = np.maximum(min_units, np.floor(xs).astype(np.int64))
    d = np.minimum(d, caps_arr)
    leftover = int(n - d.sum())

    if leftover < 0:
        # Vectorized analogue of the scalar take-back: largest per-unit time
        # first, round-robin until the overshoot is gone.
        with np.errstate(invalid="ignore"):
            per_unit = bank.time(d.astype(np.float64)) / np.maximum(d, 1)
        order = sorted(range(p), key=lambda i: per_unit[i], reverse=True)
        k = 0
        while leftover < 0:
            i = order[k % p]
            if d[i] > min_units:
                d[i] -= 1
                leftover += 1
            k += 1

    rem = xs - np.floor(xs)
    # "auto" deliberately skips the threshold prefill here: on the host path
    # it costs ~one extra continuous solve while the lazy heap below is
    # already cheap (the prefill pays off only on the jitted backends, where
    # "auto" does engage it for monotone banks).  Forcing "threshold" is
    # still honoured — monotonicity is the caller's claim then.
    if leftover > 0 and completion == "threshold":
        d, leftover = _threshold_prefill_bank(bank, d, caps_arr, leftover, t_star)
    if leftover > 0:
        # Initial candidate times at d+1 for the whole bank in one pass; each
        # processor keeps exactly one heap entry, refreshed when it wins a unit.
        t_next = bank.time((d + 1).astype(np.float64))
        heap = [
            (float(t_next[i]), -float(rem[i]), i)
            for i in range(p)
            if d[i] + 1 <= caps_arr[i]
        ]
        heapq.heapify(heap)
        while leftover > 0:
            if not heap:
                raise ValueError("caps infeasible during integer completion")
            _, negrem, i = heapq.heappop(heap)
            d[i] += 1
            leftover -= 1
            if d[i] + 1 <= caps_arr[i]:
                heapq.heappush(heap, (bank.time_one(i, float(d[i] + 1)), negrem, i))
    assert int(d.sum()) == n
    return [int(v) for v in d], t_star


# ---------------------------------------------------------------------------
# Legacy shims — delegate to the SpeedStore facade (backend resolved once
# there), emitting DeprecationWarning at the call site.
# ---------------------------------------------------------------------------


def partition_continuous(
    models: Models,
    n: float,
    caps: Optional[Sequence[float]] = None,
    *,
    rel_tol: float = 1e-12,
    max_steps: int = 200,
    vectorize: bool = True,
    backend: str = "numpy",
) -> Tuple[List[float], float]:
    """Continuous optimal partition of ``n`` units across ``models``.

    .. deprecated:: use ``SpeedStore.partition_continuous`` (the backend is
       resolved once at store construction instead of per call).
    """
    from .speedstore import SpeedStore, _warn_legacy

    _warn_legacy("partition_continuous()", "SpeedStore.partition_continuous()")
    store = SpeedStore.resolve(models, backend=backend, vectorize=vectorize)
    return store.partition_continuous(n, caps, rel_tol=rel_tol, max_steps=max_steps)


def partition_units(
    models: Models,
    n: int,
    caps: Optional[Sequence[int]] = None,
    *,
    min_units: int = 0,
    vectorize: bool = True,
    backend: str = "numpy",
) -> List[int]:
    """Integer partition of ``n`` equal computation units.

    .. deprecated:: use ``SpeedStore.partition_units`` / ``Scheduler.partition``
       (the backend is resolved once at store construction instead of per call).
    """
    from .speedstore import SpeedStore, _warn_legacy

    _warn_legacy("partition_units()", "SpeedStore.partition_units()")
    store = SpeedStore.resolve(models, backend=backend, vectorize=vectorize)
    return store.partition_units(n, caps, min_units=min_units)


def cpm_partition(speeds: Sequence[float], n: int, caps: Optional[Sequence[int]] = None) -> List[int]:
    """Conventional CPM distribution: proportional to constant speeds.

    .. deprecated:: use ``Scheduler.from_speeds(speeds).partition(n)``.
    """
    from .speedstore import SpeedStore, _warn_legacy

    _warn_legacy("cpm_partition()", "Scheduler.from_speeds(...).partition()")
    return SpeedStore.from_speeds(speeds).partition_units(n, caps)
