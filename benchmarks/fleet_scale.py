"""Fleet-scale multi-tenant rounds: stacked driver vs q sequential loops.

The ``FleetScheduler`` claim is economic: q concurrent jobs' rounds cost
ONE stacked partition program (plus, while still measuring, one stacked
fold-in program), where q independent ``Scheduler`` sessions pay q (resp.
2q) device dispatches for the same work.  Two regimes are measured per
(q, p), both post-compile medians:

  * **measurement rounds** (``fleet_round_ms`` / ``seq_round_ms``) — the
    DFPA loop while estimates are still being built: stacked repartition +
    batched measurement + stacked fold-in for ALL q jobs, vs q independent
    jax-backend ``SpeedStore`` sessions (a noisy executor keeps every job
    measuring every round; the fold keeps growing the banks, so this
    regime is partly compute-bound);
  * **steady-state rebalance rounds** (``rebalance_*`` columns) — the
    serving end state the paper targets ("partial estimates sufficient for
    a given accuracy"): models frozen, tenant loads drift every round, and
    the per-round work is re-partitioning everyone —
    ``FleetScheduler.rebalance`` (one stacked program) vs q per-store
    partitions.  This is the dispatch-bound regime where batching pays;
  * **pipelined serving epochs** (``pipeline_*`` columns) — the same
    steady state run as full rebalance+observe epochs, sync vs
    ``pipeline=True`` depth 1: the sync epoch serializes the fold with the
    next partition (the partition reads the carry the fold writes), the
    pipelined epoch partitions against the double-buffered previous carry
    and pre-dispatches the next epoch's partition from ``observe`` while
    the fold is in flight, so both device programs overlap the inter-epoch
    host work.  Gated: the pipelined epoch must beat sync at q >= 16,
    p=100 (and at the q=8 quick-mode smoke row), with a 3-attempt median
    retry guarding every wall-clock gate against host-profile jitter.

Sweeps q ∈ {1..64} at p=100 and p ∈ {1000, 10000} at q=16 (full mode).

Hierarchical rows (full mode): the same (q=16, p ∈ {1000, 10000}) sweep
re-run with ``groups=`` set (two-level repartition: host outer solve on the
cached ``[g, k_g]`` aggregates + one cache-blocked inner program per job)
— the p=10^4 row is where the flat stacked ``[q, p, k]`` program falls out
of CPU cache and loses to sequential (the seed measured 0.45x); the
two-level route must recover it to >= 1.0x (gated).

Cold-start rows (full mode): wall-clock from process start to the first
partition of a warm-admitted job, measured in a SUBPROCESS so jit tracing
is genuinely cold, with ``compilation_cache_dir=`` pointed at a shared
directory — run twice: the second run loads compiled kernels from the
persistent cache instead of re-tracing.

Acceptance gates (exit 1):
  * full mode — at every q >= 16: the stacked driver issues >= q x fewer
    device dispatches per round (all p), and the steady-state rebalance
    round is >= 2.5x faster wall-clock in the dispatch-bound regime (p=100
    rows; at p >= 1000 a CPU host is bound by the same bisection flops on
    both sides and the ratio converges to ~1x — reported, not gated);
    PLUS the hierarchical recovery gate: the hier measurement round at
    (q=16, p=10000) must be >= 1.0x vs sequential;
  * quick mode (the CI smoke) — stacked-vs-sequential ALLOCATION PARITY at
    q=8 / p=100: a noise-free fleet must reproduce q independent
    ``Scheduler.autotune`` loops bit-for-bit (allocations, histories,
    folded estimates), plus the dispatch-ratio gate at q=8, PLUS the
    pipeline-vs-sync bit-parity gate (depth 0 and depth 1 reproduce the
    sync fleet bit-for-bit on the deterministic run) and the flaky-guarded
    pipelined-epoch wall-clock smoke at q=8, PLUS the
    hierarchical consistency gate: a single-group hier fleet reproduces
    the flat fleet bit-for-bit and a multi-group hier fleet converges to a
    makespan within 5% of flat, PLUS the lane-bucket gate: a
    ``lane_buckets=True`` fleet stays bit-identical to an unbucketed one
    and an admit within a power-of-two bucket reuses both compiled device
    programs (zero recompiles).

Results are written to ``BENCH_fleet.json``.

    PYTHONPATH=src python benchmarks/fleet_scale.py [--quick] [--out FILE]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

# Bit-identical-to-sequential is the parity gate; that needs doubles.
jax.config.update("jax_enable_x64", True)

from repro.core import (  # noqa: E402
    BatchedSimulatedExecutor2D,
    PiecewiseLinearFPM,
    Scheduler,
    SimulatedExecutor,
    SpeedStore,
)
from repro.fleet import FleetScheduler, JobSpec  # noqa: E402


def make_tenants(q: int, p: int, seed: int = 0):
    """q tenants on one p-processor fleet: per-(job, proc) plateau/knee
    ground truth (the partition_scale fleet shape, one per tenant) plus
    6-point warm banks sampled from it."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-6, 3e-6, (q, p))
    knee = rng.uniform(2e3, 2e4, (q, p))

    def time_fn(X):  # X[q, p] -> T[q, p]
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    warm = []
    for j in range(q):
        models = []
        for i in range(p):
            xs = np.geomspace(16.0, 8.0 * knee[j, i], 6)
            ts = xs * base[j, i] * (
                1.0 + np.where(xs > knee[j, i], 3.0 * (xs - knee[j, i]) / knee[j, i], 0.0)
            )
            models.append(PiecewiseLinearFPM.from_points(list(zip(xs, xs / ts))))
        warm.append(models)
    return time_fn, warm, base, knee


def steady_state_rounds(q, p, *, rounds, warmup, seed=0, groups=None):
    """Median per-round wall-clock + dispatch counts for both drivers."""
    time_fn, warm, base, knee = make_tenants(q, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    # --- the stacked fleet driver ------------------------------------------
    fleet = FleetScheduler(p, backend="jax", groups=groups)
    for j in range(q):
        fleet.admit(
            JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1,
                    max_iter=10**9, probe_budget=10**9),
            models=warm[j],
        )
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=time_fn, p=p, q=q, job_names=names,
        noise=0.02, rng=np.random.default_rng(seed + 1),
    )

    # --- q sequential jax sessions (the pre-fleet pattern) -----------------
    stores = [
        SpeedStore.from_models(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in warm[j]],
            backend="jax",
        )
        for j in range(q)
    ]
    rng = np.random.default_rng(seed + 2)
    seq_dispatch = 2 * q  # one partition + one fold per job per round

    def seq_round():
        for j in range(q):
            d = stores[j].partition_units(ns[j], min_units=1)
            x = np.asarray(d, dtype=np.float64)
            t = x * base[j] * (
                1.0 + np.where(x > knee[j], 3.0 * (x - knee[j]) / knee[j], 0.0)
            )
            t = np.where(x > 0, np.maximum(
                t * (1.0 + 0.02 * rng.standard_normal(p)), 1e-12), 0.0)
            s = np.where((x > 0) & (t > 0), x / np.where(t > 0, t, 1.0), 1.0)
            stores[j].fold_in(x, s, (x > 0) & (t > 0))

    # Interleaved per-round timing (the partition_scale best_of_pair
    # convention): both drivers advance one round back-to-back, so
    # shared-container load drift hits the pair together and the MEDIAN of
    # per-round ratios stays honest even when absolute times wander.
    fleet_times, seq_times, ratios = [], [], []
    for r in range(warmup + rounds):
        t0 = time.perf_counter()
        fleet.step(ex)
        tf = time.perf_counter() - t0
        t0 = time.perf_counter()
        seq_round()
        tsq = time.perf_counter() - t0
        if r >= warmup:
            fleet_times.append(tf)
            seq_times.append(tsq)
            ratios.append(tsq / tf)
    assert len(fleet.active_jobs) == q, "benchmark jobs must not converge"
    fleet_dispatch = fleet.device_dispatches / fleet.rounds

    return {
        "q": q,
        "p": p,
        "n_per_job": ns[0],
        "rounds_timed": rounds,
        "fleet_round_ms": float(np.median(fleet_times) * 1e3),
        "seq_round_ms": float(np.median(seq_times) * 1e3),
        "wallclock_speedup": float(np.median(ratios)),
        "fleet_dispatches_per_round": fleet_dispatch,
        "seq_dispatches_per_round": float(seq_dispatch),
        "dispatch_ratio": seq_dispatch / fleet_dispatch,
    }


def rebalance_rounds(q, p, *, rounds, warmup, seed=0, groups=None):
    """The serving steady state: tenant models already learned (the paper's
    'partial estimates sufficient for a given accuracy'), per-round work is
    re-partitioning everyone under drifting loads — ``FleetScheduler.
    rebalance`` (ONE stacked program) vs q per-store partitions.  This is
    the dispatch-bound regime the wall-clock gate runs on."""
    _, warm, _, _ = make_tenants(q, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    fleet = FleetScheduler(p, backend="jax", groups=groups)
    for j in range(q):
        fleet.admit(
            JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1),
            models=warm[j],
        )

    def loads(r):
        return {
            names[j]: ns[j] + ((r * 29 + j * 13) % max(7, p // 10))
            for j in range(q)
        }

    stores = [
        SpeedStore.from_models(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in warm[j]],
            backend="jax",
        )
        for j in range(q)
    ]

    # Interleaved, same rationale as the measurement rounds above.
    d0 = fleet.device_dispatches
    fleet_times, seq_times, ratios = [], [], []
    for r in range(warmup + rounds):
        ld = loads(r)
        t0 = time.perf_counter()
        fleet.rebalance(ld)
        tf = time.perf_counter() - t0
        t0 = time.perf_counter()
        for j in range(q):
            stores[j].partition_units(ld[names[j]], min_units=1)
        tsq = time.perf_counter() - t0
        if r >= warmup:
            fleet_times.append(tf)
            seq_times.append(tsq)
            ratios.append(tsq / tf)
    fleet_dispatch = (fleet.device_dispatches - d0) / (warmup + rounds)

    return {
        "rebalance_fleet_ms": float(np.median(fleet_times) * 1e3),
        "rebalance_seq_ms": float(np.median(seq_times) * 1e3),
        "rebalance_speedup": float(np.median(ratios)),
        "rebalance_fleet_dispatches_per_round": fleet_dispatch,
        "rebalance_seq_dispatches_per_round": float(q),
        "rebalance_dispatch_ratio": q / fleet_dispatch,
    }


def pipeline_rounds(q, p, *, rounds, warmup, seed=0, depth=1):
    """Steady-state serving epochs under a FIXED tenancy, sync vs
    ``pipeline=True``: each epoch is ``rebalance()`` (one stacked
    partition) + ``observe(times)`` (one stacked fold-in).  The sync epoch
    serializes — its partition reads the carry the previous epoch's fold
    writes, so the timed ``rebalance`` waits for the fold before the
    partition even starts.  The depth-1 pipeline partitions against the
    double-buffered PREVIOUS carry (a speculative read, validated against
    the seen sets — serving tenants admitted with learned models never
    populate them, so every read is consumed) and ``observe`` pre-dispatches
    the next epoch's partition while its fold is still in flight: the next
    ``rebalance`` only fetches, and both device programs overlap the
    inter-epoch host work.  Interleaved per-epoch timing, same convention
    as the other regimes."""
    _, warm, base, knee = make_tenants(q, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    def mk(pipeline):
        fleet = FleetScheduler(
            p, backend="jax", pipeline=pipeline, pipeline_depth=depth
        )
        for j in range(q):
            fleet.admit(
                JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1),
                models=warm[j],
            )
        return fleet

    def times_for(ds, rng):
        out = {}
        for j, nm in enumerate(names):
            x = np.asarray(ds[nm], dtype=np.float64)
            t = x * base[j] * (
                1.0 + np.where(x > knee[j], 3.0 * (x - knee[j]) / knee[j], 0.0)
            )
            t = np.where(x > 0, np.maximum(
                t * (1.0 + 0.02 * rng.standard_normal(p)), 1e-12), 0.0)
            out[nm] = [float(v) for v in t]
        return out

    sync, pipe = mk(False), mk(True)
    # identical noise streams: the two fleets see the same observations as
    # long as their trajectories agree, so the comparison stays apples to
    # apples even though wall-clock is the only gated quantity
    rng_s = np.random.default_rng(seed + 5)
    rng_p = np.random.default_rng(seed + 5)
    sync_times, pipe_times, ratios = [], [], []
    for r in range(warmup + rounds):
        t0 = time.perf_counter()
        ds = sync.rebalance()
        sync.observe(times_for(ds, rng_s))
        ts = time.perf_counter() - t0
        t0 = time.perf_counter()
        dp = pipe.rebalance()
        pipe.observe(times_for(dp, rng_p))
        tp = time.perf_counter() - t0
        if r >= warmup:
            sync_times.append(ts)
            pipe_times.append(tp)
            ratios.append(ts / tp)
    return {
        "pipeline_round_ms": float(np.median(pipe_times) * 1e3),
        "pipeline_sync_round_ms": float(np.median(sync_times) * 1e3),
        "pipeline_speedup": float(np.median(ratios)),
        "pipeline_stale_reads": pipe.stale_reads,
        "pipeline_speculative_misses": pipe.speculative_misses,
        "pipeline_predispatches": pipe.predispatches,
    }


def obs_overhead_rounds(q, p, *, rounds, warmup, seed=0, tel=None):
    """ENABLED-telemetry overhead on serving epochs: two identical fleets
    (same warm models, same noise stream) advance interleaved — one under an
    installed ``repro.obs.Telemetry`` sink, one under the default no-op —
    and the gated metric is the median per-epoch wall ratio
    disabled/enabled (``obs_speedup``; 1.0 = free, the gate holds it
    >= 0.98, i.e. enabled within 2% of disabled).  The telemetry sink is
    ring-bounded so the recording itself cannot grow the round."""
    from repro import obs

    if tel is None:
        tel = obs.Telemetry(capacity=8192)
    _, warm, base, knee = make_tenants(q, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    def mk():
        fleet = FleetScheduler(p, backend="jax")
        for j in range(q):
            fleet.admit(
                JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1),
                models=[
                    PiecewiseLinearFPM.from_points(m.as_points())
                    for m in warm[j]
                ],
            )
        return fleet

    def times_for(ds, rng):
        out = {}
        for j, nm in enumerate(names):
            x = np.asarray(ds[nm], dtype=np.float64)
            t = x * base[j] * (
                1.0 + np.where(x > knee[j], 3.0 * (x - knee[j]) / knee[j], 0.0)
            )
            t = np.where(x > 0, np.maximum(
                t * (1.0 + 0.02 * rng.standard_normal(p)), 1e-12), 0.0)
            out[nm] = [float(v) for v in t]
        return out

    on, off = mk(), mk()
    rng_on = np.random.default_rng(seed + 9)
    rng_off = np.random.default_rng(seed + 9)
    on_times, off_times, ratios = [], [], []
    for r in range(warmup + rounds):
        obs.install(tel)
        try:
            t0 = time.perf_counter()
            ds = on.rebalance()
            on.observe(times_for(ds, rng_on))
            t_on = time.perf_counter() - t0
        finally:
            obs.uninstall()
        t0 = time.perf_counter()
        ds = off.rebalance()
        off.observe(times_for(ds, rng_off))
        t_off = time.perf_counter() - t0
        if r >= warmup:
            on_times.append(t_on)
            off_times.append(t_off)
            ratios.append(t_off / t_on)
    return {
        "obs_q": q,
        "obs_p": p,
        "obs_enabled_round_ms": float(np.median(on_times) * 1e3),
        "obs_disabled_round_ms": float(np.median(off_times) * 1e3),
        "obs_speedup": float(np.median(ratios)),
        "obs_events_recorded": len(tel.events),
    }


def _median_retry(measure, metric_key, threshold, attempts=3):
    """Flaky-guard for wall-clock gates: measure once; only when the gated
    metric misses ``threshold`` re-measure (``attempts`` total) and keep
    the attempt with the MEDIAN metric.  One jittery round on a loaded CI
    host can no longer fail a parity-correct build — and cannot rescue a
    genuinely slow one either, since the median of three must pass (the
    PR 6 recalibration note made host-profile jitter a known hazard)."""
    row = measure(0)
    row["attempts"] = 1
    if row[metric_key] >= threshold:
        return row
    rows = [row] + [measure(a) for a in range(1, attempts)]
    rows.sort(key=lambda r: r[metric_key])
    row = rows[len(rows) // 2]
    row["attempts"] = attempts
    return row


def pipeline_parity_gate(q=8, p=100, seed=17) -> bool:
    """pipeline-vs-sync bit-parity (the CI smoke): on a deterministic
    measuring fleet every depth-1 speculation misses its seen-set
    validation, so the pipelined autotune trajectory must reproduce the
    sync fleet bit-for-bit at depth 0 AND depth 1 (the 200-case fuzz
    battery lives in tests/test_fleet_pipeline.py)."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-5, 9e-5, (q, p))
    knee = rng.uniform(50.0, 500.0, (q, p))

    def batch_fn(X):
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    ns = [20 * p + 13 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    def run(pipeline, pipeline_depth):
        fleet = FleetScheduler(
            p, backend="jax", pipeline=pipeline, pipeline_depth=pipeline_depth
        )
        for j in range(q):
            fleet.admit(JobSpec(name=names[j], n=ns[j], eps=0.03, min_units=1,
                                max_iter=8))
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=batch_fn, p=p, q=q, job_names=names
        )
        return fleet.run(ex)

    sync = run(False, 1)
    ok = True
    for pipeline_depth in (0, 1):
        piped = run(True, pipeline_depth)
        for nm in names:
            r_p, r_s = piped[nm], sync[nm]
            if (
                r_p.allocations != r_s.allocations
                or r_p.times != r_s.times
                or r_p.diagnostics["history"] != r_s.diagnostics["history"]
            ):
                print(f"PIPELINE PARITY FAIL: job {nm} diverges from sync "
                      f"at depth {pipeline_depth}")
                ok = False
    return ok


def parity_gate(q=8, p=100, seed=11) -> bool:
    """Noise-free fleet vs q independent Scheduler.autotune loops: the
    bit-identity contract the CI smoke enforces (the full fuzz battery
    lives in tests/test_fleet.py)."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-5, 9e-5, (q, p))
    knee = rng.uniform(50.0, 500.0, (q, p))

    def batch_fn(X):
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    ns = [20 * p + 13 * j for j in range(q)]
    ok = True
    indep = []
    for j in range(q):
        fns = [
            (lambda b, k: lambda x: float(
                x * b * (1.0 + (3.0 * (x - k) / k if x > k else 0.0))
            ))(base[j, i], knee[j, i])
            for i in range(p)
        ]
        ex = SimulatedExecutor(time_fns=fns)
        sched = Scheduler(SpeedStore.empty(p, backend="jax"), backend="jax")
        indep.append(sched.autotune(ex, ns[j], 0.03, max_iter=8, min_units=1))
    fleet = FleetScheduler(p, backend="jax")
    names = [f"t{j}" for j in range(q)]
    for j in range(q):
        fleet.admit(JobSpec(name=names[j], n=ns[j], eps=0.03, min_units=1,
                            max_iter=8))
    ex2 = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=batch_fn, p=p, q=q, job_names=names
    )
    results = fleet.run(ex2)
    for j in range(q):
        r_f, r_i = results[names[j]], indep[j]
        if (
            r_f.allocations != r_i.allocations
            or r_f.times != r_i.times
            or r_f.diagnostics["history"] != r_i.diagnostics["history"]
        ):
            print(f"PARITY FAIL: job {names[j]} diverges from its "
                  f"independent Scheduler.autotune loop")
            ok = False
    return ok


def hier_parity_gate(q=4, p=100, seed=23) -> bool:
    """The hierarchical consistency contract (the CI smoke):

    * a SINGLE-group hier fleet must reproduce the flat fleet bit-for-bit
      (the outer level degenerates to "one group takes all n");
    * a MULTI-group hier fleet (4 groups of 25) must converge every job to
      a makespan within 5% of the flat fleet's.
    """
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-5, 9e-5, (q, p))
    knee = rng.uniform(50.0, 500.0, (q, p))

    def batch_fn(X):
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    ns = [20 * p + 13 * j for j in range(q)]
    names = [f"t{j}" for j in range(q)]

    def run(groups):
        fleet = FleetScheduler(p, backend="jax", groups=groups)
        for j in range(q):
            fleet.admit(JobSpec(name=names[j], n=ns[j], eps=0.03,
                                min_units=1, max_iter=8))
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=batch_fn, p=p, q=q, job_names=names
        )
        return fleet.run(ex)

    flat = run(None)
    hier1 = run([0] * p)
    hier4 = run([i % 4 for i in range(p)])
    ok = True
    for j, nm in enumerate(names):
        if hier1[nm].allocations != flat[nm].allocations:
            print(f"HIER PARITY FAIL: single-group fleet diverges from flat "
                  f"for job {nm}")
            ok = False
        m_flat, m_hier = flat[nm].makespan, hier4[nm].makespan
        if not (m_hier <= m_flat * 1.05 + 1e-12):
            print(f"HIER PARITY FAIL: multi-group makespan {m_hier:.4f} vs "
                  f"flat {m_flat:.4f} for job {nm}")
            ok = False
        if sum(hier4[nm].allocations) != ns[j]:
            print(f"HIER PARITY FAIL: multi-group allocations of {nm} do not "
                  f"sum to n")
            ok = False
    return ok


def bucket_gate(p=50, seed=41) -> bool:
    """Lane-bucket contract (the CI smoke): with ``lane_buckets=True`` the
    jax stack is padded to the next power-of-two lane count with masked
    dead lanes, so (a) allocations stay bit-identical to the unbucketed
    fleet, and (b) admitting a tenant WITHIN the bucket reuses both
    compiled device programs — zero recompiles across the admit."""
    from repro.core import modelbank_jax as mbj

    _, warm, _, _ = make_tenants(4, p, seed=seed)
    ns = [100 * p + 7 * j for j in range(4)]
    names = [f"t{j}" for j in range(4)]

    def mk(buckets):
        fl = FleetScheduler(p, backend="jax", reserve_knots=16,
                            lane_buckets=buckets)
        for j in range(3):
            fl.admit(JobSpec(name=names[j], n=ns[j], eps=1e-12, min_units=1),
                     models=warm[j])
        return fl

    plain, bucketed = mk(False), mk(True)
    ok = True
    if plain.rebalance() != bucketed.rebalance():
        print("BUCKET FAIL: bucketed fleet diverges from plain at q=3")
        ok = False
    if int(bucketed._stacked.counts.shape[0]) != 4:
        print("BUCKET FAIL: q=3 stack not padded to 4 lanes")
        ok = False

    # Warm BOTH device programs at the padded shape before taking the
    # cache baseline — the fold program only compiles on first observe.
    obs = {names[0]: [0.1 * (i + 1) for i in range(p)]}
    bucketed.observe(obs)
    bucketed.rebalance()
    c0 = mbj._partition_units_jit._cache_size()
    f0 = mbj._fold_in_jit._cache_size()
    bucketed.admit(JobSpec(name=names[3], n=ns[3], eps=1e-12, min_units=1),
                   models=warm[3])
    ds = bucketed.rebalance()
    bucketed.observe({names[3]: [0.1 * (i + 1) for i in range(p)]})
    dc = mbj._partition_units_jit._cache_size() - c0
    df = mbj._fold_in_jit._cache_size() - f0
    if dc or df:
        print(f"BUCKET FAIL: admit within the 4-lane bucket recompiled "
              f"(partition +{dc}, fold +{df})")
        ok = False
    if sum(ds[names[3]]) != ns[3]:
        print("BUCKET FAIL: padded-lane allocations do not sum to n")
        ok = False

    # Parity must survive the admit too (plain replays the same fold).
    plain.observe(obs)
    plain.admit(JobSpec(name=names[3], n=ns[3], eps=1e-12, min_units=1),
                models=warm[3])
    if plain.rebalance() != ds:
        print("BUCKET FAIL: bucketed fleet diverges from plain after admit")
        ok = False
    return ok


_COLDSTART_WORKER = r"""
import sys, time
t0 = time.perf_counter()
import numpy as np
from repro.core import PiecewiseLinearFPM
from repro.fleet import FleetScheduler, JobSpec

p, cache_dir = int(sys.argv[1]), sys.argv[2]
rng = np.random.default_rng(0)
base = rng.uniform(1e-6, 3e-6, p)
knee = rng.uniform(2e3, 2e4, p)
warm = []
for i in range(p):
    xs = np.geomspace(16.0, 8.0 * knee[i], 6)
    ts = xs * base[i] * (
        1.0 + np.where(xs > knee[i], 3.0 * (xs - knee[i]) / knee[i], 0.0)
    )
    warm.append(PiecewiseLinearFPM.from_points(list(zip(xs, xs / ts))))
fleet = FleetScheduler(p, backend="jax", compilation_cache_dir=cache_dir)
fleet.admit(JobSpec(name="t0", n=100 * p, eps=1e-12, min_units=1), models=warm)
fleet.rebalance({"t0": 100 * p})
print("COLDSTART_MS", (time.perf_counter() - t0) * 1e3)
"""


def coldstart_first_partition(p=1000):
    """Wall-clock from interpreter start to the first partition of a
    warm-admitted job, in a fresh subprocess (cold jit traces), with the
    persistent compilation cache dir shared between two runs: the second
    run's compiles load from disk."""
    import os
    import subprocess
    import sys
    import tempfile

    def run_once(cache_dir):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = subprocess.run(
            [sys.executable, "-c", _COLDSTART_WORKER, str(p), cache_dir],
            capture_output=True, text=True, env=env, timeout=600,
        )
        for line in out.stdout.splitlines():
            if line.startswith("COLDSTART_MS"):
                return float(line.split()[1])
        raise RuntimeError(f"coldstart worker failed: {out.stderr[-2000:]}")

    with tempfile.TemporaryDirectory(prefix="jaxcache_") as d:
        cold = run_once(d)
        warm = run_once(d)
    return {
        "p": p,
        "coldstart_first_partition_ms": cold,
        "coldstart_cached_ms": warm,
        "coldstart_cache_speedup": cold / warm,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke: parity gate + small sweep")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export the obs-overhead regime's telemetry as a "
                         "Chrome-trace JSON (open in chrome://tracing)")
    args = ap.parse_args(argv)

    if args.quick:
        sweep = [(1, 100), (8, 100)]
        rounds, warmup = args.rounds or 5, 3
    else:
        sweep = [(1, 100), (2, 100), (4, 100), (8, 100), (16, 100),
                 (32, 100), (64, 100), (16, 1000), (16, 10000)]
        rounds, warmup = args.rounds or 8, 3

    if args.quick:
        hier_sweep = []
    else:
        # re-run the q=16 large-p rows through the two-level route; p=10^4
        # is the cache-wall row the recovery gate runs on
        hier_sweep = [(16, 1000, 100), (16, 10000, 1000)]

    rows = []
    for q, p in sweep:
        row = steady_state_rounds(q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p)
        # the 2.5x wall-clock gate runs on these rows: flaky-guarded
        gated_wallclock = q >= 16 and p <= 100
        if gated_wallclock:
            row.update(_median_retry(
                lambda a: rebalance_rounds(
                    q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p + 1 + a
                ),
                "rebalance_speedup", 2.5,
            ))
        else:
            row.update(rebalance_rounds(
                q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p + 1
            ))
        # pipelined serving epochs vs sync (gated below sync at q >= 16
        # and, in quick mode, at the q=8 smoke row — both flaky-guarded)
        if gated_wallclock or (args.quick and q >= 8):
            row.update(_median_retry(
                lambda a: pipeline_rounds(
                    q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p + 2 + a
                ),
                "pipeline_speedup", 1.0,
            ))
        else:
            row.update(pipeline_rounds(
                q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p + 2
            ))
        rows.append(row)
        print(
            f"q={q:3d} p={p:6d}"
            f"  measure {row['fleet_round_ms']:8.2f} vs {row['seq_round_ms']:8.2f} ms"
            f" ({row['wallclock_speedup']:5.2f}x)"
            f"  rebalance {row['rebalance_fleet_ms']:8.2f} vs "
            f"{row['rebalance_seq_ms']:8.2f} ms ({row['rebalance_speedup']:5.2f}x)"
            f"  pipeline {row['pipeline_round_ms']:8.2f} vs "
            f"{row['pipeline_sync_round_ms']:8.2f} ms "
            f"({row['pipeline_speedup']:5.2f}x)"
            f"  dispatches {row['fleet_dispatches_per_round']:.1f} vs "
            f"{row['seq_dispatches_per_round']:.0f}"
            f" ({row['dispatch_ratio']:5.1f}x fewer)",
            flush=True,
        )

    for q, p, gsize in hier_sweep:
        groups = [i // gsize for i in range(p)]
        row = steady_state_rounds(
            q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p, groups=groups
        )
        row.update(
            rebalance_rounds(
                q, p, rounds=rounds, warmup=warmup, seed=q * 1000 + p + 1,
                groups=groups,
            )
        )
        row["hier"] = True
        row["group_size"] = gsize
        rows.append(row)
        print(
            f"q={q:3d} p={p:6d} HIER(g={p // gsize})"
            f"  measure {row['fleet_round_ms']:8.2f} vs {row['seq_round_ms']:8.2f} ms"
            f" ({row['wallclock_speedup']:5.2f}x)"
            f"  rebalance {row['rebalance_fleet_ms']:8.2f} vs "
            f"{row['rebalance_seq_ms']:8.2f} ms ({row['rebalance_speedup']:5.2f}x)",
            flush=True,
        )

    # Telemetry overhead: ENABLED recording must stay within 2% of the
    # disabled no-op on a serving epoch (flaky-guarded like every other
    # wall-clock gate).  Quick mode runs it at the q=8 smoke row.
    from repro import obs
    from repro.obs.chrometrace import export_chrome_trace

    oq, op = (8, 100) if args.quick else (16, 100)
    obs_tel = obs.Telemetry(capacity=8192)
    print(f"telemetry overhead (q={oq}, p={op}, enabled vs disabled) ...",
          flush=True)
    obs_row = _median_retry(
        lambda a: obs_overhead_rounds(
            oq, op, rounds=rounds, warmup=warmup,
            seed=oq * 1000 + op + 5 + a, tel=obs_tel,
        ),
        "obs_speedup", 0.98,
    )
    print(f"  enabled {obs_row['obs_enabled_round_ms']:.2f} ms vs disabled "
          f"{obs_row['obs_disabled_round_ms']:.2f} ms "
          f"({obs_row['obs_speedup']:.3f}x, "
          f"{obs_row['obs_events_recorded']} events)", flush=True)
    if args.trace:
        export_chrome_trace(obs_tel, args.trace)
        print(f"-> {args.trace}")

    coldstart = None
    if not args.quick:
        print("cold-start (p=1000, fresh subprocess, shared compilation "
              "cache) ...", flush=True)
        coldstart = coldstart_first_partition(p=1000)
        print(f"  cold {coldstart['coldstart_first_partition_ms']:.0f} ms, "
              f"cached {coldstart['coldstart_cached_ms']:.0f} ms "
              f"({coldstart['coldstart_cache_speedup']:.2f}x)", flush=True)

    print("parity gate (q=8, p=100, noise-free) ...", flush=True)
    parity_ok = parity_gate()
    print("parity:", "OK" if parity_ok else "FAIL")

    print("pipeline parity gate (q=8, p=100, depth 0 and 1) ...", flush=True)
    pipeline_ok = pipeline_parity_gate()
    print("pipeline parity:", "OK" if pipeline_ok else "FAIL")

    print("hier consistency gate (q=4, p=100, noise-free) ...", flush=True)
    hier_ok = hier_parity_gate()
    print("hier consistency:", "OK" if hier_ok else "FAIL")

    print("lane-bucket gate (q=3->4 lanes, p=50, zero recompiles) ...",
          flush=True)
    bucket_ok = bucket_gate()
    print("lane buckets:", "OK" if bucket_ok else "FAIL")

    payload = {
        "benchmark": "fleet_scale",
        "description": (
            "multi-tenant rounds, FleetScheduler vs q independent "
            "jax-backend sessions: measurement rounds (stacked [q,p,k] "
            "partition + fold-in = 2 programs/round vs 2q; 2% noise keeps "
            "every job measuring, so banks keep growing and large p turns "
            "compute-bound — and at p=10^4 the q-wide [q,p,k] working set "
            "falls out of CPU cache, so the stacked measurement round can "
            "even lose to sequential there) and steady-state rebalance "
            "rounds (models frozen, loads drift: FleetScheduler.rebalance "
            "= 1 program vs q — the dispatch-bound serving regime the >=2.5x "
            "wall-clock gate runs on at p=100) and pipelined serving epochs "
            "(rebalance+observe per epoch, sync vs pipeline=True depth 1: "
            "double-buffered carry + pre-dispatched next partition overlap "
            "the fold and the inter-epoch host work — gated below sync at "
            "q>=16, p=100, 3-attempt median retry on every wall-clock "
            "gate); medians post-compile, "
            "fleet/sequential rounds interleaved so shared-runner load "
            "drift hits both together (speedup = median per-round ratio); "
            "parity = "
            "noise-free fleet reproduces q independent Scheduler.autotune "
            "loops bit-for-bit"
        ),
        "rounds_timed": rounds,
        "parity_q8_p100": parity_ok,
        "pipeline_parity_q8_p100": pipeline_ok,
        "hier_parity_q4_p100": hier_ok,
        "bucket_q3_p50": bucket_ok,
        "sweep": rows,
        "obs_overhead": obs_row,
    }
    if coldstart is not None:
        payload["coldstart"] = coldstart
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"-> {args.out}")

    rc = 0
    if not parity_ok:
        rc = 1
    if not pipeline_ok:
        print("FAIL: pipelined fleet diverges from sync on the deterministic "
              "parity run at q=8, p=100")
        rc = 1
    if not hier_ok:
        print("FAIL: hierarchical route diverges from flat at q=4, p=100")
        rc = 1
    if not bucket_ok:
        print("FAIL: lane buckets diverge from plain or recompile within "
              "a bucket at q=3->4, p=50")
        rc = 1
    if obs_row["obs_speedup"] < 0.98:
        print(f"FAIL: ENABLED telemetry costs more than 2% of a serving "
              f"epoch at q={obs_row['obs_q']}, p={obs_row['obs_p']} "
              f"({obs_row['obs_speedup']:.3f}x vs >= 0.98x)")
        rc = 1
    for row in rows:
        if row.get("hier"):
            # Hier rows deliberately trade per-round dispatches (one extra
            # outer program per lane) for inner cache locality — they are
            # gated on wall-clock recovery below, not on dispatch ratios.
            continue
        if row["q"] >= 16:
            if (
                row["dispatch_ratio"] < row["q"]
                or row["rebalance_dispatch_ratio"] < row["q"]
            ):
                print(f"FAIL: dispatch ratio {row['dispatch_ratio']:.1f}x < "
                      f"q={row['q']} at p={row['p']}")
                rc = 1
            # Wall-clock gate runs on the dispatch-bound serving regime
            # (steady-state rebalance rounds at p=100).  At p >= 1000 on a
            # CPU host both sides are bound by the SAME bisection flops and
            # converge to ~1x — reported, not gated; a real accelerator's
            # dispatch overhead is where the stacked win grows (ROADMAP:
            # real-TPU fleet lane).  The threshold is host-profile
            # dependent: the sequential side is pure per-program dispatch
            # overhead x q, so hosts with cheap dispatch compress the ratio
            # (one recorded host measures 4.0-4.5x, another 2.8x on the
            # IDENTICAL code).  2.5x guards the "multiples faster" claim
            # across profiles.
            if row["p"] <= 100 and row["rebalance_speedup"] < 2.5:
                print(f"FAIL: steady-state rebalance speedup "
                      f"{row['rebalance_speedup']:.2f}x < 2.5x at q={row['q']}, "
                      f"p={row['p']}")
                rc = 1
            # The pipelined serving epoch must beat the sync epoch where
            # dispatch overlap pays (the serialized fold->partition wait is
            # per-round overhead at every p, but gated on the same
            # dispatch-bound rows as the rebalance gate; flaky-guarded by
            # the 3-attempt median retry above).
            if row["p"] <= 100 and row["pipeline_speedup"] < 1.0:
                print(f"FAIL: pipelined round {row['pipeline_round_ms']:.2f} ms "
                      f"not below sync {row['pipeline_sync_round_ms']:.2f} ms "
                      f"at q={row['q']}, p={row['p']} "
                      f"({row['pipeline_speedup']:.2f}x)")
                rc = 1
    # Recovery gate: the hierarchical route must break the p=10^4 cache
    # wall — the seed flat stacked round lost to sequential there (0.45x);
    # two-level with cache-blocked inner groups must be >= 1.0x.
    for row in rows:
        if row.get("hier") and row["q"] == 16 and row["p"] == 10000:
            if row["wallclock_speedup"] < 1.0:
                print(f"FAIL: hier measurement round {row['wallclock_speedup']:.2f}x"
                      f" < 1.0x vs sequential at q=16, p=10^4 (cache wall "
                      f"not recovered)")
                rc = 1
    # quick mode: the dispatch economics must already show at q=8, and the
    # pipelined epoch must not lose to sync (flaky-guarded wall-clock smoke)
    if args.quick:
        for row in rows:
            if row["q"] >= 8 and row["dispatch_ratio"] < row["q"]:
                print(f"FAIL: dispatch ratio {row['dispatch_ratio']:.1f}x < "
                      f"q={row['q']} in quick sweep")
                rc = 1
            if row["q"] >= 8 and row["pipeline_speedup"] < 1.0:
                print(f"FAIL: pipelined round {row['pipeline_speedup']:.2f}x "
                      f"vs sync in quick sweep at q={row['q']}")
                rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
