"""Benchmarks reproducing the paper's tables/figures on the calibrated
simulator (one function per artifact; all return CSV strings).

Paper artifacts:
  Table 2 — DFPA-based vs FFMPA-based app time (1-D matmul, HCL cluster)
  Table 3 — eps = 10% vs 2.5%
  Table 4 — Grid5000: 28 nodes, <= 3 iterations, < 1% cost
  Table 5 — 2-D DFPA cost fractions
  Fig. 6  — n=5120 convergence trace (borderline paging)
  Fig. 10 — CPM vs DFPA vs FFMPA 2-D app performance
"""

from __future__ import annotations

import io
from typing import List

from repro.core import (
    AnalyticModel,
    HCL_SPECS,
    SimulatedExecutor,
    app_time_2d,
    cpm_partition_2d,
    dfpa,
    dfpa_partition_2d,
    ffmpa_partition_2d,
    full_model_build_cost,
    imbalance,
    make_grid5000_time_fns,
    make_hcl_time_fns,
    matmul_app_time_1d,
    partition_units,
    speed_fn_2d,
)


def _row_fns(tfns, n):
    return [(lambda tf: lambda r: tf(r * n))(tf) for tf in tfns]


def table2_dfpa_cost() -> str:
    """Table 2: FFMPA-app vs DFPA-app times; DFPA cost and iterations."""
    out = io.StringIO()
    out.write("n,ffmpa_app_s,dfpa_app_total_s,ratio,dfpa_cost_s,dfpa_iters\n")
    for n in [2048, 3072, 4096, 5120, 6144, 7168, 8192]:
        _, tfns = make_hcl_time_fns(n)
        rows = _row_fns(tfns, n)
        ffmpa_d = partition_units([AnalyticModel(tf) for tf in rows], n, min_units=1)
        t_ffmpa = matmul_app_time_1d(tfns, ffmpa_d, n)
        ex = SimulatedExecutor(time_fns=rows)
        res = dfpa(ex, n, eps=0.025, min_units=1)
        t_dfpa = matmul_app_time_1d(tfns, res.d, n) + ex.total_cost
        out.write(
            f"{n},{t_ffmpa:.2f},{t_dfpa:.2f},{t_dfpa / t_ffmpa:.3f},"
            f"{ex.total_cost:.2f},{res.iterations}\n"
        )
    # the paper's headline: full-model construction cost vs DFPA cost
    build = full_model_build_cost(
        lambda nn: make_hcl_time_fns(nn)[1],
        [1024 * k for k in range(1, 9)],
        [i / 80 for i in range(1, 21)],
    )
    out.write(f"full_model_build_s,{build:.0f},,,,\n")
    return out.getvalue()


def table3_epsilon() -> str:
    """Table 3: eps = 10% vs 2.5% — iterations grow mildly, cost barely."""
    out = io.StringIO()
    out.write("n,eps,matmul_s,dfpa_cost_s,dfpa_iters,imbalance\n")
    for n in [2048, 3072, 4096, 5120, 6144, 7168, 8192]:
        for eps in (0.10, 0.025):
            _, tfns = make_hcl_time_fns(n)
            ex = SimulatedExecutor(time_fns=_row_fns(tfns, n))
            res = dfpa(ex, n, eps=eps, min_units=1)
            app = matmul_app_time_1d(tfns, res.d, n)
            out.write(
                f"{n},{eps},{app:.2f},{ex.total_cost:.2f},{res.iterations},{res.imbalance:.4f}\n"
            )
    return out.getvalue()


def table4_scale() -> str:
    """Table 4: Grid5000 (28 heterogeneous nodes) + a 512-group fleet."""
    out = io.StringIO()
    out.write("cluster,n,matmul_s,dfpa_cost_s,dfpa_iters,cost_pct\n")
    for n in [7168, 10240, 12288]:
        for eps in (0.10, 0.025):
            _, tfns = make_grid5000_time_fns(n)
            ex = SimulatedExecutor(time_fns=_row_fns(tfns, n))
            res = dfpa(ex, n, eps=eps, min_units=1)
            app = matmul_app_time_1d(tfns, res.d, n)
            out.write(
                f"grid5000-eps{eps},{n},{app:.2f},{ex.total_cost:.3f},"
                f"{res.iterations},{100 * ex.total_cost / (app + ex.total_cost):.2f}\n"
            )
    # beyond-paper scale: 512 heterogeneous groups (the production mesh's
    # pod-group count at 1000+ nodes), speeds spread 3x + capacity knees
    import numpy as np

    rng = np.random.default_rng(42)
    speeds = rng.uniform(1.0, 3.0, 512)
    knees = rng.integers(24, 64, 512)

    def gfn(i):
        def t(x):
            base = x / speeds[i]
            if x > knees[i]:
                base += (x - knees[i]) ** 1.5 / speeds[i]
            return base

        return t

    ex = SimulatedExecutor(time_fns=[gfn(i) for i in range(512)])
    res = dfpa(ex, 512 * 32, eps=0.1, min_units=1, max_iter=40)
    out.write(
        f"fleet512,{512 * 32},,{ex.total_cost:.3f},{res.iterations},"
        f"imb={res.imbalance:.3f}\n"
    )
    return out.getvalue()


def fig6_convergence() -> str:
    """Fig. 6: per-iteration trace at n=5120 (borderline paging nodes)."""
    n = 5120
    _, tfns = make_hcl_time_fns(n)
    ex = SimulatedExecutor(time_fns=_row_fns(tfns, n))
    res = dfpa(ex, n, eps=0.025, min_units=1)
    out = io.StringIO()
    out.write("iter,imbalance,d_min,d_max,t_max_s\n")
    for i, (d, t) in enumerate(res.history):
        out.write(f"{i + 1},{imbalance(t):.4f},{min(d)},{max(d)},{max(t):.4f}\n")
    return out.getvalue()


def _grid(p, q, b=32):
    specs = (HCL_SPECS * 2)[: p * q]
    return [[speed_fn_2d(specs[i * q + j], b) for j in range(q)] for i in range(p)]


def table5_2d() -> str:
    """Table 5: DFPA-based 2-D matmul cost fractions vs problem size."""
    out = io.StringIO()
    out.write("M=N,total_s,dfpa_cost_s,rounds,matmul_s,cost_pct\n")
    for n in [256, 384, 512, 768]:
        grid = _grid(4, 4)
        res = dfpa_partition_2d(grid, n, n, eps=0.1)
        app = app_time_2d(grid, res, K=n)
        out.write(
            f"{n},{app + res.bench_cost:.2f},{res.bench_cost:.2f},"
            f"{res.total_rounds},{app:.2f},{100 * res.bench_cost / (app + res.bench_cost):.1f}\n"
        )
    return out.getvalue()


def fig10_compare() -> str:
    """Fig. 10: CPM vs DFPA vs FFMPA 2-D matmul (speed = 1/app-time)."""
    out = io.StringIO()
    out.write("M=N,cpm_total_s,dfpa_total_s,ffmpa_total_s,cpm_vs_dfpa_slowdown\n")
    for n in [256, 384, 512, 768]:
        grid = _grid(4, 4)
        cpm, cpm_cost = cpm_partition_2d(grid, n, n)
        dfpa_res = dfpa_partition_2d(grid, n, n, eps=0.1)
        ff = ffmpa_partition_2d(grid, n, n, eps=0.1)
        t_cpm = app_time_2d(grid, cpm, K=n) + cpm_cost
        t_dfpa = app_time_2d(grid, dfpa_res, K=n) + dfpa_res.bench_cost
        t_ff = app_time_2d(grid, ff, K=n)
        out.write(
            f"{n},{t_cpm:.2f},{t_dfpa:.2f},{t_ff:.2f},{t_cpm / t_dfpa:.2f}\n"
        )
    return out.getvalue()
