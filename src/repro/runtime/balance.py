"""BalanceController: the paper's DFPA running ONLINE inside training.

.. deprecated::
    The online loop now lives on the facade —
    :class:`repro.core.scheduler.Scheduler` (``observe`` / ``repartition`` /
    ``state_dict``) — with the model estimates in a ``SpeedStore`` whose
    backend is resolved once at construction.  ``BalanceController`` remains
    as a thin wrapper that delegates every method to an internal
    ``Scheduler`` (``observe``/``bank``/``device_bank`` emit
    ``DeprecationWarning``); behaviour is unchanged, including the jax
    device-resident carry.

The paper runs dedicated benchmark rounds; in a training loop every global
step already measures exactly what DFPA needs — ``t_i(d_i)`` for the current
distribution — so probing is FREE.  The controller:

  1. starts from the even distribution (or a warm start from checkpointed
     FPM points after an elastic event);
  2. after each global step, folds the observed per-group times into the
     piecewise-linear FPM estimates (the paper's step 5);
  3. when the imbalance exceeds ``eps``, re-partitions the units with the
     geometric algorithm of [16] (the paper's step 3);
  4. exposes its FPM points for checkpointing and the straggler detector.

EMA smoothing (``smooth``) de-noises wall-clock measurements — the paper's
deterministic-benchmark assumption does not hold for real step times.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from ..core.fpm import PiecewiseLinearFPM
from ..core.modelbank import ModelBank
from ..core.scheduler import Policy, Scheduler
from ..core.speedstore import SpeedStore, _warn_legacy

__all__ = ["BalanceController", "GroupTimer"]


@dataclass
class GroupTimer:
    """Host-side wall-clock timing of one group's step (the paper's
    ``t_i(d_i)`` measurement)."""

    _t0: float = 0.0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        return time.perf_counter() - self._t0


class BalanceController:
    """Legacy online-DFPA controller; now a shim over ``Scheduler``."""

    def __init__(
        self,
        n_units: int,
        num_groups: int,
        eps: float = 0.1,
        min_units: int = 1,
        smooth: float = 0.5,
        caps: Optional[Sequence[int]] = None,
        backend: str = "numpy",
        models: Optional[List[PiecewiseLinearFPM]] = None,
        d: Optional[List[int]] = None,
    ):
        store = (
            SpeedStore.from_models(models, backend=backend)
            if models
            else SpeedStore.empty(num_groups, backend=backend)
        )
        self._sched = Scheduler(
            store,
            policy=Policy.DFPA,
            n_units=n_units,
            eps=eps,
            min_units=min_units,
            caps=caps,
            smooth=smooth,
            backend=backend,
        )
        if d:
            self._sched.d = list(d)

    @classmethod
    def _wrap(cls, sched: Scheduler) -> "BalanceController":
        """Adopt an existing Scheduler without re-initialising (the elastic
        shim's path)."""
        self = object.__new__(cls)
        self._sched = sched
        return self

    # -- delegated configuration / state --------------------------------------

    @property
    def n_units(self) -> int:
        return self._sched.n_units

    @property
    def num_groups(self) -> int:
        return self._sched.num_groups

    @property
    def eps(self) -> float:
        return self._sched.eps

    @property
    def min_units(self) -> int:
        return self._sched.min_units

    @property
    def smooth(self) -> float:
        return self._sched.smooth

    @property
    def caps(self):
        return self._sched.caps

    @property
    def backend(self) -> str:
        return self._sched.backend

    @property
    def models(self) -> List[PiecewiseLinearFPM]:
        return self._sched.store.models

    @property
    def d(self) -> List[int]:
        return self._sched.d

    @d.setter
    def d(self, value) -> None:
        self._sched.d = list(value)

    @property
    def rebalances(self) -> int:
        return self._sched.rebalances

    @property
    def steps_observed(self) -> int:
        return self._sched.steps_observed

    @property
    def _ema(self) -> Dict:
        return self._sched._ema

    @property
    def _device_bank(self):
        return self._sched.store._jbank

    @_device_bank.setter
    def _device_bank(self, value) -> None:
        self._sched.store._jbank = value

    # -- the online DFPA loop -------------------------------------------------

    def observe(self, times: Sequence[float]) -> bool:
        """Fold one global step's per-group times in; returns True if the
        distribution changed.

        .. deprecated:: use ``Scheduler.observe``.
        """
        _warn_legacy("BalanceController.observe()", "Scheduler.observe()")
        return self._sched.observe(times)

    def bank(self) -> ModelBank:
        """Batched snapshot of the current per-group FPM estimates.

        .. deprecated:: use ``Scheduler.store.bank()``.
        """
        _warn_legacy("BalanceController.bank()", "SpeedStore.bank()")
        return self._sched.store.bank()

    def _carry_bank(self):
        """The internal fold-in carry (donation-eligible: its buffers may be
        consumed by the next ``observe``)."""
        return self._sched.store._carry()

    def device_bank(self):
        """The ``JaxModelBank`` snapshot the jitted partitioner consumes.

        .. deprecated:: use ``Scheduler.store.device_bank()``.
        """
        _warn_legacy("BalanceController.device_bank()", "SpeedStore.device_bank()")
        return self._sched.store.device_bank()

    def reprofile(self, group: int) -> None:
        """Invalidate a group's FPM estimate (straggler recovery)."""
        self._sched.reprofile(group)

    @property
    def imbalance_estimate(self) -> float:
        return self._sched.imbalance_estimate

    # -- persistence (self-adaptability across restarts) ----------------------

    def state_dict(self) -> Dict:
        """Full config + estimates (the legacy keys ``n_units``/``d``/
        ``points`` survive; ``backend``/``smooth``/``eps``/``min_units``/
        ``caps`` now round-trip too — the state-asymmetry fix)."""
        return self._sched.state_dict()

    @classmethod
    def from_state(cls, state: Dict, *, eps: Optional[float] = None, **kw) -> "BalanceController":
        models = [PiecewiseLinearFPM.from_points(p) for p in state["points"]]
        cfg = dict(
            eps=state.get("eps", 0.1) if eps is None else eps,
            min_units=state.get("min_units", 1),
            smooth=state.get("smooth", 0.5),
            caps=state.get("caps"),
            backend=state.get("backend", "numpy"),
        )
        cfg.update(kw)
        self = cls(
            n_units=state["n_units"],
            num_groups=len(models),
            models=models,
            d=list(state["d"]),
            **cfg,
        )
        self._sched._ema = {
            (int(g), int(du)): float(v) for g, du, v in state.get("ema", [])
        }
        self._sched.rebalances = int(state.get("rebalances", 0))
        self._sched.steps_observed = int(state.get("steps_observed", 0))
        return self
