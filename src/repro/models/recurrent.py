"""Recurrent mixers: RG-LRU (recurrentgemma) and mLSTM / sLSTM (xLSTM).

All three carry O(1)-in-sequence decode state — these are the archs that run
the ``long_500k`` shape.  Training/prefill paths avoid sequential scans where
the math allows:

  * RG-LRU — ``jax.lax.associative_scan`` over (decay, input) pairs
    (log-depth; the Pallas chunked kernel is the TPU perf path);
  * mLSTM  — chunkwise-parallel form (intra-chunk L x L attention-like
    matrices + inter-chunk (dk x dv) state passing, exponential-gate
    stabilizers carried per chunk);
  * sLSTM  — genuinely sequential (gates depend on h_{t-1}); ``lax.scan``.

Cache conventions:
  rec:   {"h": (B, d_rnn), "conv": (B, w-1, d_rnn)}
  mlstm: {"C": (B, H, dk, dv), "n": (B, H, dk), "m": (B, H), "conv": (B, w-1, d_in)}
  slstm: {"c","n","h","m": (B, d)}
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.params import ParamSpec
from .config import ModelConfig

__all__ = [
    "rglru_spec",
    "apply_rglru_block",
    "init_rglru_cache",
    "mlstm_spec",
    "apply_mlstm_block",
    "init_mlstm_cache",
    "slstm_spec",
    "apply_slstm_block",
    "init_slstm_cache",
]

_RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# causal depthwise conv1d (shared by rec / mlstm blocks)
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array]) -> Tuple[jax.Array, Optional[jax.Array]]:
    """x: (B, S, D), w: (W, D) depthwise filter. state: (B, W-1, D) history."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, D)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1) :] if W > 1 else None
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU block (recurrentgemma)
# ---------------------------------------------------------------------------


def rglru_spec(cfg: ModelConfig) -> Dict:
    d, dr, w = cfg.d_model, cfg.d_rnn, cfg.conv_width
    return {
        "wx_gate": ParamSpec((d, dr), ("embed", "rnn")),  # gelu branch
        "wx_rnn": ParamSpec((d, dr), ("embed", "rnn")),  # conv+rglru branch
        "conv_w": ParamSpec((w, dr), ("conv", "rnn"), init="normal", scale=0.1),
        "conv_b": ParamSpec((dr,), ("rnn",), init="zeros"),
        "wa": ParamSpec((dr, dr), ("rnn", "rnn")),  # recurrence gate r_t
        "ba": ParamSpec((dr,), ("rnn",), init="zeros"),
        "wi": ParamSpec((dr, dr), ("rnn", "rnn")),  # input gate i_t
        "bi": ParamSpec((dr,), ("rnn",), init="zeros"),
        "lam": ParamSpec((dr,), ("rnn",), init="normal", scale=0.5),  # Λ
        "wo": ParamSpec((dr, d), ("rnn", "embed")),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
    }


def rglru_cache_axes(cfg: ModelConfig) -> Dict:
    return {"h": ("batch", "rnn"), "conv": ("batch", "conv", "rnn")}


def mlstm_cache_axes(cfg: ModelConfig) -> Dict:
    return {
        "C": ("batch", "heads", "head_dim", "head_dim"),
        "n": ("batch", "heads", "head_dim"),
        "m": ("batch", "heads"),
        "conv": ("batch", "conv", "mlp"),
    }


def slstm_cache_axes(cfg: ModelConfig) -> Dict:
    return {"c": ("batch", "rnn"), "n": ("batch", "rnn"), "h": ("batch", "rnn"), "m": ("batch", "rnn")}


def _rglru_scan(log_a: jax.Array, b: jax.Array, h0: Optional[jax.Array]) -> jax.Array:
    """h_t = exp(log_a_t) * h_{t-1} + b_t along axis 1 (fp32)."""

    def combine(left, right):
        la_l, b_l = left
        la_r, b_r = right
        return la_l + la_r, jnp.exp(la_r) * b_l + b_r

    la, bb = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    h = bb
    if h0 is not None:
        h = h + jnp.exp(la) * h0[:, None]
    return h


def apply_rglru_block(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    cache: Optional[Dict] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["wx_gate"].astype(dtype), approximate=True)
    u = x @ params["wx_rnn"].astype(dtype)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, params["conv_w"], conv_state)
    u = u + params["conv_b"].astype(dtype)

    # RG-LRU gates (fp32 recurrence for stability).
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["wa"].astype(jnp.float32) + params["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ params["wi"].astype(jnp.float32) + params["bi"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * uf)

    h0 = cache["h"] if cache is not None else None
    if decode:
        assert cache is not None and x.shape[1] == 1
        h_new = jnp.exp(log_a[:, 0]) * cache["h"] + b[:, 0]
        h = h_new[:, None]
        new_cache = {"h": h_new, "conv": new_conv}
    else:
        h = _rglru_scan(log_a, b, h0)
        new_cache = None
        if cache is not None:
            new_cache = {"h": h[:, -1], "conv": new_conv}
    y = (h.astype(dtype) * gate) @ params["wo"].astype(dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = 2 * d  # up-projection factor 2 (xLSTM mLSTM block)
    H = cfg.num_heads
    hd = di // H
    w = cfg.conv_width
    return {
        "w_up": ParamSpec((d, di), ("embed", "mlp")),
        "w_gate": ParamSpec((d, di), ("embed", "mlp")),
        "conv_w": ParamSpec((w, di), ("conv", "mlp"), init="normal", scale=0.1),
        "wq": ParamSpec((di, H, hd), ("mlp", "heads", "head_dim")),
        "wk": ParamSpec((di, H, hd), ("mlp", "heads", "head_dim")),
        "wv": ParamSpec((di, H, hd), ("mlp", "heads", "head_dim")),
        "wif": ParamSpec((di, 2 * H), ("mlp", "heads")),  # i/f gate projections
        "bif": ParamSpec((2 * H,), ("heads",), init="zeros"),
        "out_norm": {"scale": ParamSpec((di,), ("mlp",), init="ones")},
        "w_down": ParamSpec((di, d), ("mlp", "embed")),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di = 2 * cfg.d_model
    H = cfg.num_heads
    hd = di // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
    }


def _mlstm_chunk(carry, inp, *, scale):
    """One chunk of the chunkwise-parallel mLSTM (all fp32).

    carry: (C, n, m)  —  C: (B,H,dk,dv), n: (B,H,dk), m: (B,H)
    inp:   q,k,v: (B,L,H,hd);  li, lf: (B,H,L) log input/forget gates
    """
    C, n, m = carry
    q, k, v, li, lf = inp
    B, L, H, hd = q.shape
    q = q.transpose(0, 2, 1, 3)  # (B,H,L,hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    b = jnp.cumsum(lf, axis=-1)  # (B,H,L) inclusive log-decay
    # intra-chunk log weights: W[i,j] = b_i - b_j + li_j  (j <= i)
    W = b[..., :, None] - b[..., None, :] + li[..., None, :]
    tril = jnp.tril(jnp.ones((L, L), bool))
    W = jnp.where(tril, W, -jnp.inf)
    a_inter = b + m[..., None]  # log coeff of the carried state per row
    m_row = jnp.maximum(jnp.max(W, axis=-1), a_inter)  # (B,H,L)
    D = jnp.exp(W - m_row[..., None])
    c_int = jnp.exp(a_inter - m_row)  # (B,H,L)

    S = (q @ k.transpose(0, 1, 3, 2)) * scale * D  # (B,H,L,L)
    h_num = S @ v + c_int[..., None] * ((q * scale) @ C)
    n_vec = S.sum(-1) + c_int * jnp.einsum("bhld,bhd->bhl", q * scale, n)
    denom = jnp.maximum(jnp.abs(n_vec), jnp.exp(-m_row))
    h = h_num / denom[..., None]  # (B,H,L,hd_v)

    # advance the state to the end of the chunk
    bL = b[..., -1:]  # (B,H,1)
    w_end = bL - b + li  # (B,H,L) weight of each position into the new state
    m_new = jnp.maximum(bL[..., 0] + m, jnp.max(w_end, axis=-1))
    scale_old = jnp.exp(bL[..., 0] + m - m_new)
    wexp = jnp.exp(w_end - m_new[..., None])
    C_new = scale_old[..., None, None] * C + jnp.einsum("bhl,bhld,bhle->bhde", wexp, k, v)
    n_new = scale_old[..., None] * n + jnp.einsum("bhl,bhld->bhd", wexp, k)
    return (C_new, n_new, m_new), h.transpose(0, 2, 1, 3)  # (B,L,H,hd)


def apply_mlstm_block(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    cache: Optional[Dict] = None,
    decode: bool = False,
    chunk: int = 256,
) -> Tuple[jax.Array, Optional[Dict]]:
    dtype = x.dtype
    B, Sq, d = x.shape
    di = 2 * d
    H = cfg.num_heads
    hd = di // H
    scale = 1.0 / math.sqrt(hd)

    up = x @ params["w_up"].astype(dtype)
    gate = x @ params["w_gate"].astype(dtype)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(up, params["conv_w"], conv_state)
    u = jax.nn.silu(u)

    q = jnp.einsum("bsd,dhk->bshk", u, params["wq"].astype(dtype)).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", u, params["wk"].astype(dtype)).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", up, params["wv"].astype(dtype)).astype(jnp.float32)
    gif = (u @ params["wif"].astype(dtype)).astype(jnp.float32) + params["bif"].astype(jnp.float32)
    li = gif[..., :H].transpose(0, 2, 1)  # (B,H,S) log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(gif[..., H:]).transpose(0, 2, 1)  # log forget

    if decode:
        assert cache is not None and Sq == 1
        (C, n, m), h = _mlstm_chunk(
            (cache["C"], cache["n"], cache["m"]), (q, k, v, li, lf), scale=scale
        )
        new_cache = {"C": C, "n": n, "m": m, "conv": new_conv}
    else:
        if cache is not None:
            state = (cache["C"], cache["n"], cache["m"])
        else:
            state = (
                jnp.zeros((B, H, hd, hd), jnp.float32),
                jnp.zeros((B, H, hd), jnp.float32),
                jnp.full((B, H), -1e30, jnp.float32),
            )
        L = min(chunk, Sq)
        if Sq % L != 0:
            raise ValueError(f"seq {Sq} not divisible by mlstm chunk {L}")
        nc = Sq // L

        @jax.checkpoint
        def step(carry, inp):
            return _mlstm_chunk(carry, inp, scale=scale)

        xs = tuple(
            a.reshape(B, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
            for a in (q, k, v)
        ) + tuple(
            a.reshape(B, a.shape[1], nc, L).transpose(2, 0, 1, 3) for a in (li, lf)
        )
        state, hs = jax.lax.scan(step, state, xs, unroll=cfg.unroll_scans)
        h = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
        new_cache = None
        if cache is not None:
            new_cache = {"C": state[0], "n": state[1], "m": state[2], "conv": new_conv}

    h = h.reshape(B, Sq, di).astype(dtype)
    # per-feature RMS norm then gated output
    hf = h.astype(jnp.float32)
    hn = hf * jax.lax.rsqrt(jnp.mean(jnp.square(hf), -1, keepdims=True) + 1e-6)
    h = (hn * params["out_norm"]["scale"].astype(jnp.float32)).astype(dtype)
    y = (h * jax.nn.silu(gate)) @ params["w_down"].astype(dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def slstm_spec(cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    ff = int(math.ceil(4.0 / 3.0 * d / 64) * 64)  # post-FFN, proj factor 4/3
    return {
        "wx": ParamSpec((d, 4 * d), ("embed", "mlp")),  # z,i,f,o x-projections
        "r": ParamSpec((H, hd, 4 * hd), ("heads", "head_dim", "mlp")),  # block-diag recurrent
        "b": ParamSpec((4 * d,), ("mlp",), init="zeros"),
        "out_norm": {"scale": ParamSpec((d,), ("embed",), init="ones")},
        "ffn": {
            "wi_gate": ParamSpec((d, ff), ("embed", "mlp")),
            "wi_up": ParamSpec((d, ff), ("embed", "mlp")),
            "wo": ParamSpec((ff, d), ("mlp", "embed")),
        },
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, d), -1e30, jnp.float32)}


def _slstm_step(params, cfg, carry, xt):
    """One sLSTM time step (fp32). xt: (B, 4d) pre-projected gates."""
    c, n, h, m = carry
    B, d = c.shape
    H = cfg.num_heads
    hd = d // H
    # recurrent contribution: block-diagonal per head
    hr = h.reshape(B, H, hd)
    rec = jnp.einsum("bhk,hkf->bhf", hr, params["r"].astype(jnp.float32)).reshape(B, 4 * d)
    g = xt + rec + params["b"].astype(jnp.float32)
    z, gi, gf, go = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(go)
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def apply_slstm_block(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    cache: Optional[Dict] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    dtype = x.dtype
    B, S, d = x.shape
    xg = (x @ params["wx"].astype(dtype)).astype(jnp.float32)  # (B,S,4d)
    if cache is not None:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        z = lambda: jnp.zeros((B, d), jnp.float32)
        carry = (z(), z(), z(), jnp.full((B, d), -1e30, jnp.float32))

    if decode:
        assert S == 1
        carry, h = _slstm_step(params, cfg, carry, xg[:, 0])
        hs = h[:, None]
    else:
        def step(c, xt):
            return _slstm_step(params, cfg, c, xt)

        carry, hs = jax.lax.scan(step, carry, xg.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)

    new_cache = None
    if cache is not None:
        new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}

    hf = hs * jax.lax.rsqrt(jnp.mean(jnp.square(hs), -1, keepdims=True) + 1e-6)
    h = (hf * params["out_norm"]["scale"].astype(jnp.float32)).astype(dtype)
    # post gated FFN (proj factor 4/3)
    f = params["ffn"]
    gate = h @ f["wi_gate"].astype(dtype)
    up = h @ f["wi_up"].astype(dtype)
    y = (jax.nn.gelu(gate, approximate=True) * up) @ f["wo"].astype(dtype)
    return y, new_cache
