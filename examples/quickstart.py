"""Quickstart: the paper's DFPA in 30 lines.

An application lands on an UNKNOWN heterogeneous cluster (here: the
calibrated HCL simulator).  DFPA balances the workload online, without any
pre-built performance model, in a handful of rounds.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    SimulatedExecutor,
    dfpa,
    imbalance,
    make_hcl_time_fns,
    matmul_app_time_1d,
)

N = 5120  # matrix size: rows to distribute (1 unit = 1 row of A/C)
EPS = 0.025  # paper's tight accuracy

specs, time_fns = make_hcl_time_fns(N)
row_fns = [(lambda tf: lambda rows: tf(rows * N))(tf) for tf in time_fns]

executor = SimulatedExecutor(time_fns=row_fns)
result = dfpa(executor, N, EPS, min_units=1)

print(f"processors        : {len(specs)} ({specs[0].name}..{specs[-1].name})")
print(f"converged         : {result.converged} in {result.iterations} rounds")
print(f"final imbalance   : {result.imbalance:.3f} (eps={EPS})")
print(f"distribution      : min={min(result.d)} max={max(result.d)} rows")
print(f"model points used : max {max(result.points_per_proc)} per processor")
print(f"DFPA cost         : {executor.total_cost:.2f}s")
print(f"matmul app time   : {matmul_app_time_1d(time_fns, result.d, N):.1f}s")
print("=> partitioning cost is orders of magnitude below the app time,")
print("   with no pre-built performance model — the paper's headline claim.")
