"""Energy subsystem: bi-objective banks, Pareto fronts, capped partitions.

The energy bank IS a speed bank over the energy-rate representation
``er_i(x) = x / E_i(x)`` (see ``core/energy.py``), so the whole fuzz-parity
regime of ``test_modelbank_jax.py`` applies verbatim one level up.  This
suite locks:

  * energy queries (``energy_at`` / ``fleet_energy``) bit-identical between
    the numpy and jax banks (x64), elementwise equal to the scalar
    ``E_i(x)`` the rate models encode;
  * ``fold_energy`` reproduces the scalar add-point update on both banked
    backends;
  * the makespan/energy Pareto front — thresholds, caps and metrics are
    computed host-side, so the numpy and jax fronts (times, energies AND
    allocations) must agree bit-for-bit, with the scalar backend matching
    allocation-for-allocation;
  * front endpoints equal the PURE time-/energy-objective partitions
    exactly, times strictly increase and energies strictly decrease along
    the front, and ``objective="time"`` stays bit-identical to a store with
    no energy attached (the do-no-harm lock);
  * ``capped_energy_partition`` allocations respect the time threshold's
    reachable set and infeasible thresholds return None, never raise.

Lanes follow the repo convention: 200-case numpy-rng lanes under ``slow``,
tier-1 smoke versions always on, a hypothesis lane through ``_hyp``.
"""

import numpy as np
import pytest

from _hyp import given, settings, st

import jax
from jax.experimental import enable_x64

from repro.core import PiecewiseLinearFPM, Scheduler, SpeedStore
from repro.core.energy import (
    ParetoFront,
    capped_energy_partition,
    energy_model,
    pareto_front,
)
from repro.core.modelbank import ModelBank

BIT_EXACT = jax.default_backend() == "cpu"

BACKENDS = ("scalar", "numpy", "jax")


# ---------------------------------------------------------------------------
# Case generation: heterogeneous speed + affine energy, all rows non-empty
# ---------------------------------------------------------------------------


def _case_from_raw(speed_rows, energy_params, n, caps_frac, min_units):
    models = [PiecewiseLinearFPM.from_points(r) for r in speed_rows]
    xs = sorted({x for r in speed_rows for x, _ in r})
    emods = [
        energy_model([(x, a + b * x) for x in xs]) for a, b in energy_params
    ]
    return dict(
        models=models, emods=emods, energy_params=energy_params,
        n=n, caps_frac=caps_frac, min_units=min_units,
    )


def _random_case(rng):
    # p and the knot count are drawn from small fixed sets so the jax
    # lane's [T, p, k] programs amortize across cases (one compile per
    # shape, same policy as test_modelbank_jax's K_PAD padding)
    p = int(rng.choice([3, 5]))
    grid = np.sort(rng.uniform(1.0, 1e4, 5))
    rows = []
    for i in range(p):
        ss = rng.uniform(0.5, 500.0, len(grid))
        rows.append(list(zip(grid.tolist(), ss.tolist())))
    # heterogeneous energy efficiency: per-proc affine E(x) = a + b x with
    # b spread over ~40x, so time- and energy-optimal partitions differ and
    # the front is non-degenerate for most draws (degenerate draws still
    # exercise the single-point-front path)
    energy_params = [
        (float(rng.uniform(1.0, 50.0)), float(rng.uniform(0.05, 2.0)))
        for _ in range(p)
    ]
    n = int(rng.integers(max(2 * p, 8), 3000))
    caps_frac = rng.uniform(0.6, 1.0, p).tolist() if rng.random() < 0.4 else None
    min_units = int(rng.integers(0, 2))
    return _case_from_raw(rows, energy_params, n, caps_frac, min_units)


def _caps(case):
    if case["caps_frac"] is None:
        return None
    lo = max(1, case["min_units"])
    return [lo + int(f * case["n"]) for f in case["caps_frac"]]


def _stores(case):
    out = {}
    for backend in BACKENDS:
        st_ = SpeedStore.from_models(case["models"], backend=backend)
        st_.attach_energy(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in case["emods"]]
        )
        out[backend] = st_
    return out


def _scalar_energy(case, d):
    """Ground-truth total energy of an allocation through the SCALAR rate
    models (interpolation happens in rate space, so off-grid energies are
    model-predicted, not affine — the affine law is exact only at knots)."""
    return sum(
        float(m.time(float(di)))
        for m, di in zip(case["emods"], d)
        if di > 0
    )


# ---------------------------------------------------------------------------
# The parity checkers (one description drives every lane)
# ---------------------------------------------------------------------------


def _check_energy_query_parity(case):
    stores = _stores(case)
    p = len(case["models"])
    rng = np.random.default_rng(int(case["n"]))
    d = rng.integers(0, 200, p).astype(np.float64)
    ref = np.asarray(
        [float(m.time(float(di))) if di > 0 else np.nan
         for m, di in zip(case["emods"], d)]
    )
    vals = {b: np.asarray(stores[b].energy_at(d), dtype=np.float64)
            for b in BACKENDS}
    for b in BACKENDS:
        act = d > 0
        assert np.allclose(vals[b][act], ref[act], rtol=1e-9), b
    # at the knots the affine law E(x) = a + b x is exact
    grid = sorted({x for m in case["models"] for x, _ in m.as_points()})
    x0 = float(grid[0])
    at_knot = np.asarray(stores["numpy"].energy_at(np.full(p, x0)))
    want = np.asarray([a + b * x0 for a, b in case["energy_params"]])
    assert np.allclose(at_knot, want, rtol=1e-9)
    if BIT_EXACT:
        np.testing.assert_array_equal(vals["numpy"], vals["jax"])
    fe = {b: stores[b].fleet_energy(d) for b in BACKENDS}
    assert fe["numpy"] == fe["scalar"]
    if BIT_EXACT:
        assert fe["numpy"] == fe["jax"]


def _check_objective_time_unchanged(case):
    """The do-no-harm lock: attaching energy and passing objective="time"
    must not move a single unit on any backend."""
    caps = _caps(case)
    for backend in BACKENDS:
        plain = SpeedStore.from_models(case["models"], backend=backend)
        d0, t0 = plain.partition(case["n"], caps, min_units=case["min_units"])
        st_ = _stores(case)[backend]
        d1, t1 = st_.partition(
            case["n"], caps, min_units=case["min_units"], objective="time"
        )
        assert d1 == d0 and t1 == t0, backend


def _check_front_parity(case):
    stores = _stores(case)
    caps = _caps(case)
    n, mu = case["n"], case["min_units"]
    fronts = {
        b: stores[b].pareto_front(n, caps, min_units=mu, num_points=9)
        for b in BACKENDS
    }
    for b, f in fronts.items():
        assert isinstance(f, ParetoFront) and len(f) >= 1, b
        # every front point is a valid partition
        for d in f.allocations:
            assert int(d.sum()) == n
            if caps is not None:
                assert all(int(v) <= c for v, c in zip(d, caps))
            assert all(int(v) >= mu for v in d)
        # strict bi-objective monotonicity
        assert all(f.times[i] < f.times[i + 1] for i in range(len(f) - 1)), b
        assert all(
            f.energies[i] > f.energies[i + 1] for i in range(len(f) - 1)
        ), b
        # endpoints ARE the pure solutions
        d_time, _ = stores[b].partition(n, caps, min_units=mu)
        assert list(f.allocations[0]) == d_time, b
        d_energy, _ = stores[b].partition(n, caps, min_units=mu, objective="energy")
        if len(f) > 1:
            assert list(f.allocations[-1]) == d_energy, b
        # reported energies match the affine ground truth
        for d, e in zip(f.allocations, f.energies):
            assert np.isclose(e, _scalar_energy(case, d), rtol=1e-9), b
    # the numpy and jax fronts are the same object bit-for-bit
    fa, fb = fronts["numpy"], fronts["scalar"]
    np.testing.assert_array_equal(fa.allocations, fb.allocations)
    if BIT_EXACT:
        fj = fronts["jax"]
        np.testing.assert_array_equal(fa.times, fj.times)
        np.testing.assert_array_equal(fa.energies, fj.energies)
        np.testing.assert_array_equal(fa.allocations, fj.allocations)


def _check_capped_partition(case):
    stores = _stores(case)
    sbank = ModelBank.from_models(case["models"])
    ebank = ModelBank.from_models(case["emods"])
    caps = _caps(case)
    n, mu = case["n"], case["min_units"]
    front = stores["numpy"].pareto_front(n, caps, min_units=mu, num_points=7)
    icaps = [n] * len(case["models"]) if caps is None else caps
    t_lo, t_hi = float(front.times[0]), float(front.times[-1])
    # at (and beyond) the slow end every threshold is feasible
    d = capped_energy_partition(
        sbank, ebank, n, icaps, t_hi * 1.5, floor_d=front.allocations[0],
        min_units=mu,
    )
    assert d is not None and sum(d) == n
    assert all(v <= c for v, c in zip(d, icaps))
    # an absurdly tight threshold without a floor is infeasible -> None
    assert (
        capped_energy_partition(sbank, ebank, n, icaps, t_lo * 1e-6, min_units=mu)
        is None
    )


def _check_fold_energy_parity(case):
    p = len(case["models"])
    rng = np.random.default_rng(int(case["n"]) + 1)
    obs = [
        (rng.uniform(1.0, 1e4, p), rng.uniform(1.0, 1e3, p))
        for _ in range(3)
    ]
    queries = rng.uniform(1.0, 1e4, p)
    vals = {}
    for backend in ("numpy", "jax"):
        st_ = SpeedStore.from_models(case["models"], backend=backend)
        for x, e in obs:
            st_.fold_energy(x, e)
        vals[backend] = np.asarray(st_.energy_at(queries), dtype=np.float64)
    assert np.all(np.isfinite(vals["numpy"]))
    if BIT_EXACT:
        np.testing.assert_array_equal(vals["numpy"], vals["jax"])


def _check_all(case):
    _check_energy_query_parity(case)
    _check_objective_time_unchanged(case)
    _check_front_parity(case)
    _check_capped_partition(case)
    _check_fold_energy_parity(case)


# ---------------------------------------------------------------------------
# Tier-1 smokes + slow fuzz lanes
# ---------------------------------------------------------------------------


def test_energy_parity_smoke(rng):
    with enable_x64():
        for _ in range(25):
            _check_all(_random_case(rng))


@pytest.mark.slow
def test_energy_parity_fuzz_lane():
    rng = np.random.default_rng(42)
    with enable_x64():
        for _ in range(200):
            _check_all(_random_case(rng))


@st.composite
def _hyp_cases(draw):
    p = draw(st.integers(min_value=2, max_value=6))
    k = draw(st.integers(min_value=3, max_value=6))
    grid = sorted(
        set(
            draw(
                st.lists(
                    st.floats(min_value=1.0, max_value=1e4,
                              allow_nan=False, allow_infinity=False),
                    min_size=k, max_size=k,
                )
            )
        )
    ) or [1.0]
    rows = [
        list(zip(grid, draw(st.lists(
            st.floats(min_value=0.5, max_value=500.0,
                      allow_nan=False, allow_infinity=False),
            min_size=len(grid), max_size=len(grid)))))
        for _ in range(p)
    ]
    energy_params = [
        (
            draw(st.floats(min_value=1.0, max_value=50.0,
                           allow_nan=False, allow_infinity=False)),
            draw(st.floats(min_value=0.05, max_value=2.0,
                           allow_nan=False, allow_infinity=False)),
        )
        for _ in range(p)
    ]
    n = draw(st.integers(min_value=max(2 * p, 8), max_value=2000))
    return _case_from_raw(rows, energy_params, n, None, 0)


@pytest.mark.slow
@given(case=_hyp_cases())
@settings(max_examples=200, deadline=None)
def test_energy_parity_fuzz_hypothesis(case):
    with enable_x64():
        _check_all(case)


# ---------------------------------------------------------------------------
# Unit behaviour: front picking, validation, persistence, scheduler dispatch
# ---------------------------------------------------------------------------


def _simple_case():
    rng = np.random.default_rng(5)
    return _random_case(rng)


def test_pareto_pick_and_knee():
    f = ParetoFront(
        times=np.asarray([1.0, 2.0, 4.0]),
        energies=np.asarray([30.0, 20.0, 10.0]),
        allocations=np.asarray([[3, 1], [2, 2], [1, 3]], dtype=np.int64),
    )
    assert f.pick(None) == f.knee()
    assert f.pick(25.0) == 1  # fastest point within budget
    assert f.pick(10.0) == 2
    assert f.pick(5.0) == 2  # unattainable budget -> best effort (last)
    assert f.pick(1e9) == 0
    d = f.as_dict()
    assert d["times"] == [1.0, 2.0, 4.0] and len(d["allocations"]) == 3


def test_energy_model_validation():
    with pytest.raises(ValueError):
        energy_model([(0.0, 5.0)])
    with pytest.raises(ValueError):
        energy_model([(10.0, -1.0)])
    m = energy_model([(10.0, 5.0), (20.0, 8.0)])
    # rate representation: time(x) under the rate model IS E(x)
    assert np.isclose(m.time(10.0), 5.0) and np.isclose(m.time(20.0), 8.0)


def test_attach_energy_validation():
    case = _simple_case()
    st_ = SpeedStore.from_models(case["models"], backend="numpy")
    with pytest.raises(ValueError, match="energy models"):
        st_.attach_energy(case["emods"][:-1])
    with pytest.raises(ValueError, match="need energy models"):
        st_.partition(case["n"], objective="energy")
    with pytest.raises(ValueError, match="no energy models"):
        st_.pareto_front(case["n"])
    st_.attach_energy(case["emods"])
    with pytest.raises(ValueError, match="objective"):
        st_.partition(case["n"], objective="power")


def test_state_dict_roundtrips_energy():
    case = _simple_case()
    st_ = _stores(case)["numpy"]
    state = st_.state_dict()
    assert "energy_points" in state
    st2 = SpeedStore.from_state(state)
    assert st2.has_energy
    f1 = st_.pareto_front(case["n"], num_points=5)
    f2 = st2.pareto_front(case["n"], num_points=5)
    np.testing.assert_array_equal(f1.allocations, f2.allocations)
    # a plain store's state has no energy field and loads clean
    plain = SpeedStore.from_models(case["models"], backend="numpy")
    assert "energy_points" not in plain.state_dict()
    assert not SpeedStore.from_state(plain.state_dict()).has_energy


def test_scheduler_objective_dispatch():
    case = _simple_case()
    caps = _caps(case)
    sched = Scheduler(
        SpeedStore.from_models(case["models"], backend="numpy"),
        backend="numpy", n_units=case["n"],
    )
    d_time = sched.partition(caps=caps).allocations
    sched.attach_energy(case["emods"])
    assert sched.partition(caps=caps, objective="time").allocations == d_time
    front = sched.pareto_front(caps=caps)  # dispatch uses the default grid
    knee = front.knee()
    part = sched.partition(caps=caps, objective="pareto")
    assert part.allocations == [int(v) for v in front.allocations[knee]]
    capped = sched.partition(
        caps=caps, energy_cap=float(front.energies[0]) * 0.999
    )
    idx = front.pick(float(front.energies[0]) * 0.999)
    assert capped.allocations == [int(v) for v in front.allocations[idx]]
    # state round-trip carries the energy models
    sched2 = Scheduler.from_state(sched.state_dict())
    assert sched2.store.has_energy


def test_scheduler_objective_needs_energy_and_flat_mode():
    case = _simple_case()
    sched = Scheduler(
        SpeedStore.from_models(case["models"], backend="numpy"),
        backend="numpy", n_units=case["n"],
    )
    with pytest.raises(ValueError, match="need energy models"):
        sched.partition(objective="energy")
    p = len(case["models"])
    hier = Scheduler(
        SpeedStore.from_models(case["models"], backend="numpy"),
        backend="numpy", n_units=case["n"], groups=[i % 2 for i in range(p)],
    )
    hier.store.attach_energy(case["emods"])
    with pytest.raises(ValueError, match="objective"):
        hier.partition(objective="energy")
