"""Straggler mitigation via FPM residuals (beyond-paper use of the model).

The paper's FPM predicts what a healthy group's step SHOULD take at its
current allocation.  A group whose observed time exceeds its own prediction
by ``factor`` for ``patience`` consecutive steps is flagged:

  * REPROFILE — its FPM points are stale (thermal throttling, recovered
    preemption): invalidate them so DFPA re-learns the speed function;
  * QUARANTINE — persistent (factor_hard) offender: remove from the group
    set entirely (the elastic path redistributes its units).

This turns the paper's performance model into a health detector — the
observation→model→action loop the paper uses for balance, reused for fault
tolerance.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.fpm import PiecewiseLinearFPM
from ..core.modelbank import ModelBank

try:  # telemetry is optional: detection runs identically without repro.obs
    from ..obs.telemetry import active as _obs_active
except ImportError:  # pragma: no cover - obs layer absent
    def _obs_active():
        return None

__all__ = ["StragglerAction", "StragglerDetector"]


class StragglerAction(Enum):
    NONE = "none"
    REPROFILE = "reprofile"
    QUARANTINE = "quarantine"


@dataclass
class StragglerDetector:
    factor: float = 1.5  # observed / predicted ratio that counts as a strike
    factor_hard: float = 3.0  # instant-escalation ratio
    patience: int = 3  # consecutive strikes before REPROFILE
    patience_hard: int = 6  # consecutive strikes before QUARANTINE

    # Both maps are keyed by the CURRENT group index space.  When the group
    # set changes (``Scheduler.resize``/``join``/``leave``), the detector
    # must be remapped through :meth:`remap` — carrying it across a resize
    # unmapped makes every survivor inherit its departed neighbour's strike
    # count and can falsely quarantine a healthy group.
    strikes: Dict[int, int] = field(default_factory=dict)
    history: List[tuple] = field(default_factory=list)

    def remap(self, surviving: Sequence[int], joined: int = 0) -> "StragglerDetector":
        """New detector for a resized group set: survivor ``surviving[j]``
        keeps its strike count under its new index ``j``, departed groups'
        strikes are dropped, and ``joined`` newcomers start clean.
        ``history`` rows are remapped the same way (departed groups' rows
        dropped) so post-resize forensics read in the new index space."""
        new_of = {int(old): new for new, old in enumerate(surviving)}
        det = StragglerDetector(
            factor=self.factor,
            factor_hard=self.factor_hard,
            patience=self.patience,
            patience_hard=self.patience_hard,
        )
        det.strikes = {
            new_of[g]: s for g, s in self.strikes.items() if g in new_of
        }
        det.history = [
            (new_of[row[0]], *row[1:]) for row in self.history if row[0] in new_of
        ]
        return det

    def update(
        self,
        group: int,
        model: PiecewiseLinearFPM,
        d_units: int,
        observed_t: float,
    ) -> StragglerAction:
        if model.num_points == 0 or d_units <= 0 or observed_t <= 0:
            return StragglerAction.NONE
        predicted = model.time(float(d_units))
        if predicted <= 0:
            return StragglerAction.NONE
        ratio = observed_t / predicted
        self.history.append((group, d_units, predicted, observed_t, ratio))
        return self._strike(group, ratio)

    def update_batch(
        self,
        bank: ModelBank,
        d_units: Sequence[int],
        observed: Sequence[float],
    ) -> List[StragglerAction]:
        """Fleet-wide strike update: ONE batched ``bank.time`` pass predicts
        every group's healthy step time, then the scalar strike automaton runs
        only on the few groups whose prediction is usable.

        ``bank`` is the controller's model-bank snapshot
        (``BalanceController.bank()``); returns one action per group.
        Equivalent to calling :meth:`update` per group, without the ``p``
        scalar ``time`` evaluations.
        """
        d = np.asarray(d_units, dtype=np.float64)
        obs = np.asarray(observed, dtype=np.float64)
        predicted = bank.time(d)
        usable = (bank.counts > 0) & (d > 0) & (obs > 0) & (predicted > 0)
        actions = [StragglerAction.NONE] * bank.p
        for g in np.nonzero(usable)[0]:
            g = int(g)
            ratio = float(obs[g] / predicted[g])
            self.history.append((g, int(d[g]), float(predicted[g]), float(obs[g]), ratio))
            actions[g] = self._strike(g, ratio)
        return actions

    def _strike(self, group: int, ratio: float) -> StragglerAction:
        if ratio < self.factor:
            self.strikes[group] = 0
            return StragglerAction.NONE
        s = self.strikes.get(group, 0) + (2 if ratio >= self.factor_hard else 1)
        self.strikes[group] = s
        if s >= self.patience_hard:
            self.strikes[group] = 0
            self._report(group, ratio, s, StragglerAction.QUARANTINE)
            return StragglerAction.QUARANTINE
        if s >= self.patience:
            self._report(group, ratio, s, StragglerAction.REPROFILE)
            return StragglerAction.REPROFILE
        self._report(group, ratio, s, StragglerAction.NONE)
        return StragglerAction.NONE

    def _report(
        self, group: int, ratio: float, strikes: int, verdict: StragglerAction
    ) -> None:
        """Mirror a strike (and its verdict, if any) into telemetry with the
        (predicted, observed) evidence from the matching history row."""
        tel = _obs_active()
        if tel is None or not tel.enabled:
            return
        evidence = {}
        if self.history and self.history[-1][0] == group:
            _, d_units, predicted, observed, _ = self.history[-1]
            evidence = {
                "d_units": int(d_units),
                "predicted": float(predicted),
                "observed": float(observed),
            }
        tel.counter("straggler.strike")
        tel.event("straggler.strike", group=int(group), ratio=float(ratio),
                  strikes=int(strikes), **evidence)
        if verdict is not StragglerAction.NONE:
            tel.counter(f"straggler.{verdict.value}")
            tel.event("straggler.verdict", group=int(group),
                      action=verdict.value, ratio=float(ratio),
                      strikes=int(strikes), **evidence)

    def reprofile(self, controller, group: int) -> None:
        """Invalidate a group's FPM (keep only the freshest operating point
        so the partitioner stays feasible).

        ``Scheduler`` / ``BalanceController`` implement this themselves
        (``Scheduler.reprofile``; wired automatically by
        ``Scheduler.straggler_actions``) — delegate when available, keep the
        legacy in-place mutation for duck-typed controllers."""
        if hasattr(controller, "reprofile"):
            controller.reprofile(group)
            return
        m = controller.models[group]
        if m.num_points > 1:
            # keep the most recent point at the current allocation if present
            di = controller.d[group]
            pts = [(x, s) for x, s in m.as_points() if x == float(di)]
            controller.models[group] = (
                PiecewiseLinearFPM.from_points(pts) if pts else PiecewiseLinearFPM()
            )
        keys = [k for k in controller._ema if k[0] == group]
        for k in keys:
            del controller._ema[k]
        # The controller's device-resident bank carry (backend="jax") now
        # disagrees with the scalar models; drop it so it rebuilds lazily.
        if getattr(controller, "_device_bank", None) is not None:
            controller._device_bank = None
