"""Encoder-decoder assembly (seamless-m4t): audio-stub encoder + text decoder.

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d) directly.  Decoder blocks carry
self-attention (causal, cached) + cross-attention over the encoder output.
Cross K/V are computed once per layer at encode time and cached for decode.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.context import maybe_constrain
from .attention import apply_attn, attn_spec, init_attn_cache
from .config import ModelConfig
from .layers import apply_norm, norm_spec, stacked
from .transformer import (
    _embed_tokens,
    apply_block,
    block_spec,
    lm_logits,
    softcap,
)

__all__ = [
    "encdec_spec",
    "encode",
    "apply_decoder",
    "encdec_loss",
    "init_encdec_cache",
    "encdec_prefill",
    "encdec_decode_step",
]


def _enc_units(cfg: ModelConfig) -> int:
    return cfg.encoder_layers // len(cfg.encoder_pattern)


def encdec_spec(cfg: ModelConfig) -> Dict:
    from .layers import embedding_spec

    spec: Dict[str, Any] = {
        "encoder": {
            "units": tuple(
                stacked(block_spec(cfg, k, moe=False, d_ff=cfg.d_ff), _enc_units(cfg))
                for k in cfg.encoder_pattern
            ),
            "final_norm": norm_spec(cfg.d_model, cfg.norm_kind),
        },
        "decoder": {
            "embed": embedding_spec(cfg.vocab_size, cfg.d_model),
            "units": tuple(
                stacked(
                    block_spec(cfg, k, moe=False, d_ff=cfg.d_ff, cross=True),
                    cfg.num_units,
                )
                for k in cfg.pattern
            ),
            "final_norm": norm_spec(cfg.d_model, cfg.norm_kind),
        },
    }
    return spec


def encode(params: Dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder hidden (B, S_enc, d)."""
    x = frames.astype(cfg.dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def unit_body(x, slot_params):
        x = maybe_constrain(x, ("batch", "seq_act", "embed_act"))
        for s, kind in enumerate(cfg.encoder_pattern):
            x, _, _ = apply_block(
                slot_params[s], cfg, kind, x, positions, moe=False, causal=False
            )
        return x

    if cfg.remat == "full":
        unit_body = jax.checkpoint(unit_body)

    if cfg.scan_layers:
        def scan_fn(x, xs):
            return unit_body(x, xs), None

        x, _ = jax.lax.scan(scan_fn, x, params["encoder"]["units"])
    else:
        n_units = _enc_units(cfg)
        for u in range(n_units):
            sp = jax.tree_util.tree_map(lambda a: a[u], params["encoder"]["units"])
            x = unit_body(x, sp)
    return apply_norm(params["encoder"]["final_norm"], x)


def _cross_kv_all(params: Dict, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute per-unit, per-slot cross K/V: stacked (U, B, S_enc, Kv, hd)."""
    dtype = enc_out.dtype

    def per_slot(slot_params):
        xk = jnp.einsum("bsd,udhk->ubshk", enc_out, slot_params["xattn"]["wk"].astype(dtype))
        xv = jnp.einsum("bsd,udhk->ubshk", enc_out, slot_params["xattn"]["wv"].astype(dtype))
        return xk, xv

    return tuple(per_slot(sp) for sp in params["decoder"]["units"])


def apply_decoder(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    positions: jax.Array,
    cross_kv: Tuple,  # per-slot (xk, xv), stacked (U, B, S_enc, Kv, hd)
    *,
    caches: Optional[Dict] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    dp = params["decoder"]
    x = _embed_tokens(dp, cfg, tokens)
    unit_caches = caches["units"] if caches is not None else None

    def unit_body(x, slot_params, slot_caches, slot_xkv):
        x = maybe_constrain(x, ("batch", "seq_act", "embed_act"))
        ncs = []
        for s, kind in enumerate(cfg.pattern):
            c = slot_caches[s] if slot_caches is not None else None
            x, nc, _ = apply_block(
                slot_params[s], cfg, kind, x, positions, moe=False,
                cache=c, decode=decode, causal=True, cross_kv=slot_xkv[s],
            )
            ncs.append(nc)
        return x, tuple(ncs)

    if cfg.remat == "full":
        unit_body = jax.checkpoint(unit_body)

    xkv_stacked = tuple((xk, xv) for xk, xv in cross_kv)
    if cfg.scan_layers:
        if unit_caches is None:
            def scan_fn(x, xs):
                sp, sxkv = xs
                x, _ = unit_body(x, sp, None, sxkv)
                return x, None

            x, _ = jax.lax.scan(scan_fn, x, (dp["units"], xkv_stacked))
            new_units = None
        else:
            def scan_fn(x, xs):
                sp, sc, sxkv = xs
                x, ncs = unit_body(x, sp, sc, sxkv)
                return x, ncs

            x, new_units = jax.lax.scan(
                scan_fn, x, (dp["units"], unit_caches, xkv_stacked)
            )
    else:
        new_units_list = []
        for u in range(cfg.num_units):
            at_u = lambda a: a[u]
            sp = jax.tree_util.tree_map(at_u, dp["units"])
            sxkv = jax.tree_util.tree_map(at_u, xkv_stacked)
            sc = (
                jax.tree_util.tree_map(at_u, unit_caches)
                if unit_caches is not None
                else None
            )
            x, ncs = unit_body(x, sp, sc, sxkv)
            new_units_list.append(ncs)
        new_units = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_units_list)
            if unit_caches is not None
            else None
        )

    x = apply_norm(dp["final_norm"], x)
    new_caches = {"units": new_units} if caches is not None else None
    return x, new_caches


def _dec_logits(params: Dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    w = params["decoder"]["embed"]["embedding"].T
    logits = (hidden @ w.astype(hidden.dtype)).astype(cfg.logit_dtype)
    return softcap(logits, cfg.final_softcap)


def encdec_loss(params: Dict, cfg: ModelConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    """batch: frames (B, S_enc, d), tokens (B, S_dec), labels (B, S_dec)."""
    enc_out = encode(params, cfg, batch["frames"])
    cross_kv = _cross_kv_all(params, cfg, enc_out)
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    hidden, _ = apply_decoder(params, cfg, tokens, positions, cross_kv)

    w = params["decoder"]["embed"]["embedding"].T.astype(hidden.dtype)
    L = cfg.xent_chunk if 0 < cfg.xent_chunk <= S and S % cfg.xent_chunk == 0 else S
    nc = S // L
    h_ch = hidden.reshape(B, nc, L, -1).transpose(1, 0, 2, 3)
    y_ch = labels.reshape(B, nc, L).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_fn(acc, inp):
        h, y = inp
        logits = (h @ w).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.clip(y, 0)[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        return (acc[0] + ((lse - gold) * mask).sum(), acc[1] + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros(()), jnp.zeros(())), (h_ch, y_ch), unroll=cfg.unroll_scans
    )
    loss = nll / jnp.maximum(cnt, 1.0)
    return loss, {"nll": loss, "tokens": cnt, "aux": jnp.zeros(())}


# -- serving -----------------------------------------------------------------


def init_encdec_cache(cfg: ModelConfig, batch: int, seq_budget: int, enc_len: int, dtype=jnp.bfloat16) -> Dict:
    """Decoder self-attn caches + slots for cached cross K/V."""
    U = cfg.num_units
    Kv, hd = cfg.num_kv_heads, cfg.head_dim

    def slot_cache(kind):
        base = init_attn_cache(cfg, kind, batch, seq_budget, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (U,) + a.shape).copy(), base
        )

    units = tuple(slot_cache(k) for k in cfg.pattern)
    xkv = tuple(
        (
            jnp.zeros((U, batch, enc_len, Kv, hd), dtype),
            jnp.zeros((U, batch, enc_len, Kv, hd), dtype),
        )
        for _ in cfg.pattern
    )
    return {"units": units, "cross_kv": xkv}


def encdec_prefill(
    params: Dict, cfg: ModelConfig, frames: jax.Array, tokens: jax.Array, caches: Dict
) -> Tuple[jax.Array, Dict]:
    enc_out = encode(params, cfg, frames)
    cross_kv = _cross_kv_all(params, cfg, enc_out)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    hidden, new_caches = apply_decoder(
        params, cfg, tokens, positions, cross_kv, caches={"units": caches["units"]}
    )
    out = {"units": new_caches["units"], "cross_kv": cross_kv}
    return _dec_logits(params, cfg, hidden[:, -1:])[:, 0], out


def encdec_decode_step(
    params: Dict, cfg: ModelConfig, token: jax.Array, pos: jax.Array, caches: Dict
) -> Tuple[jax.Array, Dict]:
    positions = pos[None].astype(jnp.int32)
    hidden, new_caches = apply_decoder(
        params, cfg, token, positions, caches["cross_kv"],
        caches={"units": caches["units"]}, decode=True,
    )
    out = {"units": new_caches["units"], "cross_kv": caches["cross_kv"]}
    return _dec_logits(params, cfg, hidden[:, 0]), out
