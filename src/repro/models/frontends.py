"""Modality frontend STUBS (per the assignment, `[vlm]`/`[audio]` entries
specify the transformer BACKBONE only).

``input_specs()`` in the launcher supplies ShapeDtypeStructs for precomputed
patch/frame embeddings; these helpers generate deterministic concrete values
for smoke tests and examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["stub_patch_embeddings", "stub_frame_embeddings"]


def stub_patch_embeddings(cfg: ModelConfig, batch: int, seed: int = 0) -> jax.Array:
    """Vision stub: (B, num_prefix_embeddings, d_model) 'patch embeddings'."""
    key = jax.random.PRNGKey(seed)
    return 0.02 * jax.random.normal(
        key, (batch, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32
    ).astype(cfg.dtype)


def stub_frame_embeddings(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> jax.Array:
    """Audio stub: (B, seq, d_model) 'speech frame embeddings'."""
    key = jax.random.PRNGKey(seed)
    return 0.02 * jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32).astype(
        cfg.dtype
    )
