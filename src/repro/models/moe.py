"""Mixture-of-Experts: top-k router + per-sequence sort-based dispatch.

TPU-native design (hardware-adaptation note, DESIGN.md §2):

  * routing / top-k / per-sequence sort run in auto-SPMD land (row-local
    ops on batch-sharded arrays — no communication);
  * the dispatch scatter and combine gather run inside ``jax.shard_map``
    MANUAL over the batch mesh axes: data-dependent scatters/gathers are
    provably local per shard, which the auto partitioner cannot infer — it
    otherwise replicates the (B, S*k, d) update arrays and all-reduces
    them (measured 117 s of collectives per step on granite-moe before
    this restructure; see EXPERIMENTS.md §Perf);
  * the expert FFN einsum runs in auto land between two sharding
    constraints (batch->data ... experts->model): the SPMD partitioner
    emits exactly the canonical expert-parallel all-to-all pair at those
    boundaries, and handles the FSDP gathers of expert weights.

Capacity: per-sequence C = ceil(S*k/E * factor) (Switch-style group
capacity, group = sequence); overflow drops to the residual path.  Shared
experts (deepseek-v2) are dense matmuls in auto land.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..nn.params import ParamSpec
from ..sharding.context import current_activation_mesh, maybe_constrain
from .config import ModelConfig

__all__ = ["moe_spec", "apply_moe"]


def moe_spec(cfg: ModelConfig) -> Dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    spec = {
        "router": ParamSpec((d, E), ("embed", "experts"), init="normal", scale=0.02),
        "wi_gate": ParamSpec((E, d, ff), ("experts", "embed", "mlp")),
        "wi_up": ParamSpec((E, d, ff), ("experts", "embed", "mlp")),
        "wo": ParamSpec((E, ff, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts > 0:
        sff = ff * cfg.num_shared_experts
        spec["shared"] = {
            "wi_gate": ParamSpec((d, sff), ("embed", "mlp")),
            "wi_up": ParamSpec((d, sff), ("embed", "mlp")),
            "wo": ParamSpec((sff, d), ("mlp", "embed")),
        }
    return spec


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dispatch_local(x, slot_pair, EC, slot_lo=0, slot_hi=None):
    """Scatter tokens into the (B, EC+1, d) buffer — local math.

    ``slot_pair`` is (B, S, k) in TOKEN order; the k choices are scattered
    one at a time so every live operand is (B, S, d) — a single fused
    (B, S*k, d) gather/scatter costs k x the hidden size in live buffers
    (measured 7.5 GiB fp32 instances on deepseek's k=6).

    With ``slot_lo/hi`` the body keeps only its model rank's expert slots
    (slot - slot_lo), everything else going to the drop row: expert
    parallelism with ZERO dispatch communication (x is replicated over the
    model axis anyway)."""
    Bl, S, d = x.shape
    k = slot_pair.shape[-1]
    brow = jnp.arange(Bl)[:, None]
    if slot_hi is not None:
        mine = (slot_pair >= slot_lo) & (slot_pair < slot_hi)
        slot_pair = jnp.where(mine, slot_pair - slot_lo, EC)
    buf = jnp.zeros((Bl, EC + 1, d), x.dtype)
    for i in range(k):
        buf = buf.at[brow, slot_pair[:, :, i]].add(x)
    return buf


def _combine_local(out_flat, slot_pair, gk_pair, slot_lo=0, slot_hi=None):
    """Gather expert outputs back to token positions — local math, one
    choice at a time (see _dispatch_local).  With slot windowing each model
    rank combines only its experts' outputs (caller psums over model)."""
    Bl, EC, d = out_flat.shape
    brow = jnp.arange(Bl)[:, None]
    k = slot_pair.shape[-1]
    if slot_hi is not None:
        mine = (slot_pair >= slot_lo) & (slot_pair < slot_hi)
        gk_pair = gk_pair * mine
        slot_pair = jnp.where(mine, slot_pair - slot_lo, 0)
    S = slot_pair.shape[1]
    y = jnp.zeros((Bl, S, d), out_flat.dtype)
    for i in range(k):
        sl = jnp.minimum(slot_pair[:, :, i], EC - 1)
        y = y + out_flat[brow, sl] * gk_pair[:, :, i, None].astype(out_flat.dtype)
    return y


def apply_moe(params: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  See module docstring."""
    dtype = x.dtype
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    Sk = S * k

    # ---- routing (auto land: row-local on batch-sharded arrays) ----------
    logits = (x @ params["router"].astype(dtype)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    C = min(max(int(math.ceil(S * k / E * cfg.capacity_factor)), 1), Sk)

    e_flat = eidx.reshape(B, Sk)
    g_flat = gate.reshape(B, Sk)
    brow = jnp.arange(B)[:, None]
    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_sort = jnp.take_along_axis(e_flat, order, axis=1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(
        e_sort
    )  # (B,E)
    pos_in_e = jnp.arange(Sk)[None, :] - jnp.take_along_axis(starts, e_sort, axis=1)
    keep_sorted = pos_in_e < C
    slot_sorted = jnp.where(keep_sorted, e_sort * C + pos_in_e, E * C)
    # Back to TOKEN order: (B, S, k) per-choice slots and kept gates — the
    # dispatch/combine then work on (B, S, d)-sized operands per choice.
    slot_pair = (
        jnp.zeros((B, Sk), jnp.int32).at[brow, order].set(slot_sorted).reshape(B, S, k)
    )
    gk_pair = (
        jnp.zeros((B, Sk), jnp.float32)
        .at[brow, order]
        .set(g_flat[brow, order] * keep_sorted)
        .reshape(B, S, k)
    )

    # ---- dispatch / FFN / combine -----------------------------------------
    # Expert parallelism with ZERO dispatch communication: the residual is
    # replicated over the model axis, so each model rank scatters only ITS
    # experts' slots into a local (B_loc, E_loc*C, d) buffer; the expert FFN
    # runs in auto land (FSDP weight gathers handled by the partitioner);
    # each rank combines its experts' outputs and one psum over the model
    # axis finishes the job — O(B*S*d) comm per layer, vs the all-gathers
    # of the token array an auto-land scatter costs (EXPERIMENTS.md §Perf).
    mesh = current_activation_mesh()
    manual = None
    if mesh is not None:
        baxes = _batch_axes(mesh)
        nshard = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
        msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
        if baxes and B % nshard == 0 and E % msize == 0:
            manual = baxes + ("model",)

    if manual is not None:
        E_loc = E // msize
        bspec = P(_batch_axes(mesh))
        x_in = maybe_constrain(x, ("batch", None, None))
        slot_pair = maybe_constrain(slot_pair, ("batch", None, None))
        gk_pair = maybe_constrain(gk_pair, ("batch", None, None))

        def disp(xx, ss):
            lo = jax.lax.axis_index("model") * E_loc * C
            return _dispatch_local(xx, ss, E_loc * C, lo, lo + E_loc * C)

        buf = jax.shard_map(
            disp, mesh=mesh,
            in_specs=(bspec, bspec),
            out_specs=P(_batch_axes(mesh), "model"),
            axis_names=set(manual),
            check_vma=False,
        )(x_in, slot_pair)
        # global view: (B, msize*(E_loc*C+1), d), model-sharded on dim 1
        h = buf.reshape(B, msize, E_loc * C + 1, d)[:, :, : E_loc * C]
        h = h.reshape(B, E, C, d)
        h = maybe_constrain(h, ("batch", "experts", None, "embed_act"))
    else:
        buf = _dispatch_local(x, slot_pair, E * C)
        h = buf[:, : E * C].reshape(B, E, C, d)

    # Pin the bf16 casts to the weights' own sharding: the partitioner
    # otherwise FSDP-gathers the fp32 masters and converts after — 2x the
    # gather bytes and fp32 weight buffers held across the remat schedule.
    wi_g = maybe_constrain(params["wi_gate"].astype(dtype), ("experts", "embed", "mlp"))
    wi_u = maybe_constrain(params["wi_up"].astype(dtype), ("experts", "embed", "mlp"))
    wo = maybe_constrain(params["wo"].astype(dtype), ("experts", "mlp", "embed"))
    gct = jnp.einsum("becd,edf->becf", h, wi_g)
    up = jnp.einsum("becd,edf->becf", h, wi_u)
    out = jnp.einsum("becf,efd->becd", jax.nn.silu(gct) * up, wo)

    if manual is not None:
        out = maybe_constrain(out, ("batch", "experts", None, "embed_act"))
        out_flat = out.reshape(B, E * C, d)

        def comb(oo, ss, gg):
            lo = jax.lax.axis_index("model") * E_loc * C
            y = _combine_local(oo, ss, gg, lo, lo + E_loc * C)
            return jax.lax.psum(y, "model")

        y = jax.shard_map(
            comb, mesh=mesh,
            in_specs=(P(_batch_axes(mesh), "model"), bspec, bspec),
            out_specs=bspec,
            axis_names=set(manual),
            check_vma=False,
        )(out_flat, slot_pair, gk_pair)
    else:
        out_flat = out.reshape(B, E * C, d)
        y = _combine_local(out_flat, slot_pair, gk_pair)
    y = maybe_constrain(y, ("batch", "seq_act", "embed_act"))

    # Load-balancing aux loss (per sequence, averaged) — all local math.
    counts = jnp.concatenate(
        [starts[:, 1:] - starts[:, :-1], Sk - starts[:, -1:]], axis=1
    ).astype(jnp.float32)
    frac = counts / Sk
    mean_p = probs.mean(axis=1)
    aux = E * jnp.mean(jnp.sum(frac * mean_p, axis=-1))

    if cfg.num_shared_experts > 0:
        sp = params["shared"]
        g = x @ sp["wi_gate"].astype(dtype)
        u = x @ sp["wi_up"].astype(dtype)
        y = y + (jax.nn.silu(g) * u) @ sp["wo"].astype(dtype)

    return y, aux
