"""Chunked RG-LRU linear-recurrence Pallas TPU kernel.

Computes ``h_t = exp(log_a_t) * h_{t-1} + b_t`` along the sequence.  The grid
is (batch, D/bd, S/bs) with the sequence dim innermost-sequential: a VMEM
scratch carries the running state across sequence blocks, and the intra-block
recurrence uses a log-depth associative scan — O(S/bs) HBM sweeps with no
host-level sequential launch, the TPU-native replacement for the per-element
CPU recurrence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["rglru_scan_pallas"]


def _kernel(la_ref, b_ref, h_ref, carry_ref, *, ns: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    la = la_ref[0]  # (bs, bd) fp32
    b = b_ref[0]

    def combine(left, right):
        la_l, b_l = left
        la_r, b_r = right
        return la_l + la_r, jnp.exp(la_r) * b_l + b_r

    la_c, b_c = jax.lax.associative_scan(combine, (la, b), axis=0)
    h = b_c + jnp.exp(la_c) * carry_ref[...]
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("bs", "bd", "interpret"))
def rglru_scan_pallas(
    log_a: jax.Array,  # (B, S, D) fp32
    b: jax.Array,  # (B, S, D) fp32
    *,
    bs: int = 256,
    bd: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, D = log_a.shape
    bs = min(bs, S)
    bd = min(bd, D)
    if S % bs or D % bd:
        raise ValueError(f"(S={S}, D={D}) not divisible by blocks ({bs},{bd})")
    ns = S // bs
    grid = (B, D // bd, ns)
    return pl.pallas_call(
        functools.partial(_kernel, ns=ns),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda bb, db, sb: (bb, sb, db)),
            pl.BlockSpec((1, bs, bd), lambda bb, db, sb: (bb, sb, db)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda bb, db, sb: (bb, sb, db)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), log_a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(log_a, b)
