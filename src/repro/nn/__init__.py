from .params import ParamSpec, axes_tree, init_tree, param_count, spec_tree_shapes

__all__ = ["ParamSpec", "axes_tree", "init_tree", "param_count", "spec_tree_shapes"]
