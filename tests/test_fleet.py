"""FleetScheduler: the multi-job batched parity battery + profile registry.

The fleet contract is BIT-IDENTITY: one ``FleetScheduler`` driving q jobs
through its stacked-bank lock-step rounds must produce, for every job,
exactly what q independent ``Scheduler.autotune`` loops would have —
allocations, measured times, round histories, convergence verdicts, bench
costs AND the folded FPM estimates.  That holds through mid-flight
``admit``/``retire``, mixed per-job ``n``/``eps``/``caps``/``min_units``,
and adversarial non-monotone jobs (whose lanes demote to the exact per-unit
completion without touching their neighbours' threshold routing).

Fuzz lanes follow the repo convention: an always-on numpy-rng lane plus a
hypothesis lane through the optional ``tests/_hyp.py`` shim, >= 200 cases
each under the ``slow`` marker, with small smoke versions in tier-1.

The registry suite locks the persistence satellite: a warm start from a
saved registry reproduces the donor session's next-round allocations
bit-identically, and corrupt/missing registries degrade to a cold start
with a warning, never a crash.
"""

import json

import numpy as np
import pytest

from _hyp import given, settings, st

import jax
from jax.experimental import enable_x64

from repro.core import (
    BatchedSimulatedExecutor2D,
    PiecewiseLinearFPM,
    Policy,
    Scheduler,
    SimulatedExecutor,
    SpeedStore,
)
from repro.core import modelbank_jax as mbj
from repro.core.scheduler import _even
from repro.fleet import FleetScheduler, JobSpec, ProfileRegistry

BIT_EXACT = jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Ground-truth fleets: per-(job, proc) time functions, scalar + batched views
# ---------------------------------------------------------------------------


def _knee_params(rng, q, p):
    base = rng.uniform(1e-4, 2e-3, (q, p))
    knee = rng.uniform(5.0, 80.0, (q, p))
    return base, knee


def _knee_time(base, knee, x):
    t = x * base
    return t + np.where(x > knee, (x - knee) * base * 4.0, 0.0)


def _scalar_fns(base, knee, j):
    """Job j's per-processor scalar time fns (for SimulatedExecutor)."""
    return [
        (lambda b, k: lambda x: float(_knee_time(b, k, float(x))))(
            base[j, i], knee[j, i]
        )
        for i in range(base.shape[1])
    ]


def _batch_fn(base, knee):
    """The same fns as one [q, p] array op (for BatchedSimulatedExecutor2D).
    Identical float64 arithmetic to the scalar fns, so times are bit-equal."""

    def fn(X):
        return _knee_time(base, knee, X)

    return fn


def _dip_fns(p, K=30.0):
    """Adversarial job: time DROPS 10x past K, so observed speed jumps up
    and the job's FPM bank turns non-monotone — its lane must demote to the
    exact per-unit completion.  Per-proc base speeds span 8x so the DFPA
    allocations straddle K and the dip is actually observed."""
    a = np.asarray([1e-3 * (2.0**i) for i in range(p)])
    scalar = [
        (lambda ai: lambda x: float(ai * x if x < K else 0.1 * ai * x))(a[i])
        for i in range(p)
    ]

    def batch_row(x_row):
        return np.where(x_row < K, a * x_row, 0.1 * a * x_row)

    return scalar, batch_row


# ---------------------------------------------------------------------------
# The parity checker: fleet rounds vs q independent Scheduler.autotune loops
# ---------------------------------------------------------------------------


def _random_fleet_case(rng):
    p = int(rng.integers(2, 7))
    q = int(rng.integers(1, 5))
    base, knee = _knee_params(rng, q, p)
    jobs = []
    for j in range(q):
        n = int(rng.integers(max(2 * p, 8), 60 * p))
        min_units = int(rng.integers(0, 2))
        caps = None
        if rng.random() < 0.4:
            lo = max(1, min_units)
            # each cap >= 0.6 n keeps every case feasible at p >= 2
            caps = [lo + int(f * n) for f in rng.uniform(0.6, 1.0, p)]
        jobs.append(
            dict(
                n=n,
                eps=float(rng.uniform(0.02, 0.25)),
                caps=caps,
                min_units=min_units,
                max_iter=int(rng.integers(3, 12)),
            )
        )
    return dict(p=p, q=q, base=base, knee=knee, jobs=jobs)


def _independent_results(case, backend):
    """q separate Scheduler.autotune sessions — the reference trajectories."""
    p, base, knee = case["p"], case["base"], case["knee"]
    out = []
    for j, kw in enumerate(case["jobs"]):
        ex = SimulatedExecutor(time_fns=_scalar_fns(base, knee, j))
        sched = Scheduler(SpeedStore.empty(p, backend=backend), backend=backend)
        res = sched.autotune(
            ex,
            kw["n"],
            kw["eps"],
            max_iter=kw["max_iter"],
            caps=kw["caps"],
            min_units=kw["min_units"],
        )
        out.append(
            dict(
                res=res,
                cost=ex.total_cost,
                points=[m.as_points() for m in sched.store.models],
            )
        )
    return out


def _fleet_results(case, backend):
    p, q, base, knee = case["p"], case["q"], case["base"], case["knee"]
    fleet = FleetScheduler(p, backend=backend)
    for j, kw in enumerate(case["jobs"]):
        fleet.admit(
            JobSpec(
                name=str(j),
                n=kw["n"],
                eps=kw["eps"],
                caps=kw["caps"],
                min_units=kw["min_units"],
                max_iter=kw["max_iter"],
            )
        )
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(base, knee),
        p=p,
        q=q,
        job_names=[str(j) for j in range(q)],
    )
    results = fleet.run(ex)
    return fleet, results


def _assert_job_parity(ref, part, cost, points):
    res = ref["res"]
    assert part.allocations == res.allocations
    assert part.times == res.times
    assert part.iterations == res.iterations
    assert part.converged == res.converged
    assert part.imbalance == res.imbalance
    assert part.diagnostics["history"] == res.diagnostics["history"]
    assert cost == ref["cost"]
    assert points == ref["points"]


def _check_fleet_parity(case, backend):
    indep = _independent_results(case, backend)
    fleet, results = _fleet_results(case, backend)
    for j in range(case["q"]):
        name = str(j)
        _assert_job_parity(
            indep[j],
            results[name],
            fleet.bench_cost(name),
            [m.as_points() for m in fleet.models(name)],
        )
    # the tentpole economics: one partition + one fold program per round,
    # regardless of q (vs 2q for the sequential loops)
    if backend == "jax":
        assert fleet.device_dispatches <= 2 * fleet.rounds


# ---------------------------------------------------------------------------
# Deterministic parity + the dispatch-count contract
# ---------------------------------------------------------------------------


def test_fleet_parity_three_jobs_jax():
    rng = np.random.default_rng(100)
    case = _random_fleet_case(rng)
    with enable_x64():
        _check_fleet_parity(case, "jax")


def test_fleet_parity_numpy_backend():
    rng = np.random.default_rng(101)
    for _ in range(5):
        _check_fleet_parity(_random_fleet_case(rng), "numpy")


def test_fleet_parity_scalar_backend():
    """The seed scalar loop is a first-class fleet backend too (the 2-D
    grid driver inherits whatever backend the Scheduler session was built
    with, including 'scalar')."""
    rng = np.random.default_rng(105)
    for _ in range(3):
        _check_fleet_parity(_random_fleet_case(rng), "scalar")


def test_partition_grid_scalar_backend_still_works():
    """Regression: routing _grid_dfpa through the fleet driver must not
    drop the scalar backend the Scheduler facade accepts."""
    from repro.core import HCL_SPECS, speed_fn_2d

    specs = HCL_SPECS[:4]
    grid = [[speed_fn_2d(specs[i * 2 + j]) for j in range(2)] for i in range(2)]
    part = Scheduler(grid=grid, policy=Policy.GRID2D, backend="scalar").partition_grid(
        64, 64, eps=0.2
    )
    assert sum(part.col_widths) == 64
    for rows in part.row_heights:
        assert sum(rows) == 64


def test_fleet_parity_smoke_fuzz_jax():
    """Tier-1 jax smoke: 6 random fleets through the full parity checker."""
    rng = np.random.default_rng(102)
    with enable_x64():
        for _ in range(6):
            _check_fleet_parity(_random_fleet_case(rng), "jax")


@pytest.mark.slow
def test_fleet_parity_fuzz_numpy_lane():
    rng = np.random.default_rng(103)
    for _ in range(200):
        _check_fleet_parity(_random_fleet_case(rng), "numpy")


@pytest.mark.slow
def test_fleet_parity_fuzz_jax_lane():
    """200 fuzzed fleets on the stacked device path (shapes kept small so
    the jit cache amortizes across cases)."""
    rng = np.random.default_rng(104)
    with enable_x64():
        for _ in range(200):
            case = _random_fleet_case(rng)
            _check_fleet_parity(case, "jax")


@st.composite
def _fleet_cases(draw):
    p = draw(st.integers(min_value=2, max_value=5))
    q = draw(st.integers(min_value=1, max_value=3))
    base = np.asarray(
        draw(
            st.lists(
                st.lists(
                    st.floats(min_value=1e-4, max_value=2e-3,
                              allow_nan=False, allow_infinity=False),
                    min_size=p, max_size=p,
                ),
                min_size=q, max_size=q,
            )
        )
    )
    knee = np.asarray(
        draw(
            st.lists(
                st.lists(
                    st.floats(min_value=5.0, max_value=80.0,
                              allow_nan=False, allow_infinity=False),
                    min_size=p, max_size=p,
                ),
                min_size=q, max_size=q,
            )
        )
    )
    jobs = []
    for _ in range(q):
        n = draw(st.integers(min_value=max(2 * p, 8), max_value=60 * p))
        min_units = draw(st.integers(min_value=0, max_value=1))
        jobs.append(
            dict(
                n=n,
                eps=draw(st.floats(min_value=0.02, max_value=0.25)),
                caps=None,
                min_units=min_units,
                max_iter=draw(st.integers(min_value=3, max_value=10)),
            )
        )
    return dict(p=p, q=q, base=base, knee=knee, jobs=jobs)


@pytest.mark.slow
@given(case=_fleet_cases())
@settings(max_examples=200, deadline=None)
def test_fleet_parity_fuzz_hypothesis(case):
    _check_fleet_parity(case, "numpy")


# ---------------------------------------------------------------------------
# Mid-flight admit / retire
# ---------------------------------------------------------------------------


def test_admit_mid_flight_matches_independent():
    """A job admitted at fleet round k runs exactly the autotune loop it
    would have run in its own session — lock-stepping with strangers (and
    the restack its admission forces) must not perturb anyone."""
    rng = np.random.default_rng(200)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)
    specs = [
        JobSpec(name=str(j), n=40 + 30 * j, eps=0.05, min_units=1, max_iter=8)
        for j in range(q)
    ]
    case = dict(
        p=p, q=q, base=base, knee=knee,
        jobs=[
            dict(n=s.n, eps=s.eps, caps=None, min_units=1, max_iter=8)
            for s in specs
        ],
    )
    with enable_x64():
        indep = _independent_results(case, "jax")
        fleet = FleetScheduler(p, backend="jax")
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee), p=p, q=q,
            job_names=[str(j) for j in range(q)],
        )
        fleet.admit(specs[0])
        fleet.step(ex)
        fleet.step(ex)
        fleet.admit(specs[1])  # mid-flight; restack next round
        fleet.step(ex)
        fleet.admit(specs[2])
        results = fleet.run(ex)
    for j in range(q):
        name = str(j)
        _assert_job_parity(
            indep[j], results[name], fleet.bench_cost(name),
            [m.as_points() for m in fleet.models(name)],
        )


def test_retire_mid_flight_prefix_and_survivors():
    """Retiring a running job returns its best-so-far Partition whose
    history is a prefix of the independent run's; survivors are unaffected
    bit-for-bit."""
    rng = np.random.default_rng(201)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)
    case = dict(
        p=p, q=q, base=base, knee=knee,
        jobs=[
            dict(n=50 + 40 * j, eps=1e-6, caps=None, min_units=1, max_iter=9)
            for j in range(q)
        ],
    )
    with enable_x64():
        indep = _independent_results(case, "jax")
        fleet = FleetScheduler(p, backend="jax")
        for j in range(q):
            kw = case["jobs"][j]
            fleet.admit(
                JobSpec(name=str(j), n=kw["n"], eps=kw["eps"], min_units=1,
                        max_iter=kw["max_iter"])
            )
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee), p=p, q=q,
            job_names=[str(j) for j in range(q)],
        )
        for _ in range(3):
            fleet.step(ex)
        retired = fleet.retire("1")
        assert "1" not in fleet.jobs
        results = fleet.run(ex)
    full = indep[1]["res"].diagnostics["history"]
    got = retired.diagnostics["history"]
    assert got == full[: len(got)] and 0 < len(got) <= 3
    for j in (0, 2):
        _assert_job_parity(
            indep[j], results[str(j)], fleet.bench_cost(str(j)),
            [m.as_points() for m in fleet.models(str(j))],
        )


def test_resize_equals_warm_readmission():
    """resize(n') keeps the estimates and restarts the loop — bit-identical
    to retiring the job and re-admitting it warm-started from the same
    models with the new n."""
    rng = np.random.default_rng(202)
    p = 4
    base, knee = _knee_params(rng, 1, p)
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(base, knee), p=p, q=1, job_names=["a"]
    )
    with enable_x64():
        fleet = FleetScheduler(p, backend="jax")
        fleet.admit(JobSpec(name="a", n=60, eps=0.03, min_units=1, max_iter=4))
        fleet.run(ex)
        snapshot = [
            PiecewiseLinearFPM.from_points(m.as_points()) for m in fleet.models("a")
        ]
        fleet.resize("a", n=100)
        res_resized = fleet.run(ex)["a"]

        fleet2 = FleetScheduler(p, backend="jax")
        fleet2.admit(
            JobSpec(name="a", n=100, eps=0.03, min_units=1, max_iter=4),
            models=snapshot,
        )
        res_fresh = fleet2.run(ex)["a"]
    assert res_resized.allocations == res_fresh.allocations
    assert res_resized.diagnostics["history"] == res_fresh.diagnostics["history"]
    assert sum(res_resized.allocations) == 100


def test_rebalance_drops_stale_result_and_reports_live_view():
    """After a converged tenant's load drifts, rebalance() must not keep
    serving the old cached Partition: snapshot() reports the live (new-n)
    distribution."""
    rng = np.random.default_rng(203)
    p = 4
    base, knee = _knee_params(rng, 1, p)
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(base, knee), p=p, q=1, job_names=["a"]
    )
    with enable_x64():
        fleet = FleetScheduler(p, backend="jax")
        fleet.admit(JobSpec(name="a", n=60, eps=0.3, min_units=1, max_iter=6))
        fleet.run(ex)
        assert fleet.result("a").converged
        d_new = fleet.rebalance({"a": 120})["a"]
    assert sum(d_new) == 120
    snap = fleet.snapshot("a")
    assert snap.allocations == d_new and sum(snap.allocations) == 120
    with pytest.raises(ValueError, match="not finished"):
        fleet.result("a")


# ---------------------------------------------------------------------------
# Adversarial non-monotone job: demotes only its own lane
# ---------------------------------------------------------------------------


def test_adversarial_job_demotes_only_its_own_lane(monkeypatch):
    """One tenant with a time-dip (non-monotone) workload shares the fleet
    with monotone tenants: the stacked partition must run with a MIXED
    per-lane mask (spied on the jit kernel), the adversarial job's bank
    must classify non-monotone, and every job — adversarial included —
    must still match its independent loop bit-for-bit."""
    rng = np.random.default_rng(300)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)
    dip_scalar, dip_row = _dip_fns(p)

    def batch(X):
        T = _knee_time(base, knee, X)
        T[1] = dip_row(X[1])
        return T

    real = mbj._partition_units_jit
    masks = []

    def spy(*args, **kw):
        masks.append(np.array(args[8]))
        return real(*args, **kw)

    monkeypatch.setattr(mbj, "_partition_units_jit", spy)

    with enable_x64():
        # independent references
        indep = []
        for j in range(q):
            fns = dip_scalar if j == 1 else _scalar_fns(base, knee, j)
            ex1 = SimulatedExecutor(time_fns=fns)
            sched = Scheduler(SpeedStore.empty(p, backend="jax"), backend="jax")
            res = sched.autotune(ex1, 90, 0.02, max_iter=6, min_units=1)
            indep.append(
                dict(res=res, cost=ex1.total_cost,
                     points=[m.as_points() for m in sched.store.models])
            )
        masks.clear()
        fleet = FleetScheduler(p, backend="jax")
        for j in range(q):
            fleet.admit(JobSpec(name=str(j), n=90, eps=0.02, min_units=1, max_iter=6))
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=batch, p=p, q=q, job_names=[str(j) for j in range(q)]
        )
        results = fleet.run(ex)

    for j in range(q):
        _assert_job_parity(
            indep[j], results[str(j)], fleet.bench_cost(str(j)),
            [m.as_points() for m in fleet.models(str(j))],
        )
    # the adversarial job's host bank is non-monotone, neighbours' are not
    # (resolved via the bank: the cached flag is invalidated by every fold)
    assert fleet._jobs["1"].bank().is_monotone() is False
    assert fleet._jobs["0"].bank().is_monotone() is True
    assert fleet._jobs["2"].bank().is_monotone() is True
    # ... and at least one stacked call ran with a mixed per-lane mask
    stacked_masks = [m for m in masks if m.shape == (q,)]
    assert any(m[1] == False and m[0] and m[2] for m in stacked_masks)  # noqa: E712


# ---------------------------------------------------------------------------
# Per-job knobs: mixed n / eps / caps / completion
# ---------------------------------------------------------------------------


def test_mixed_caps_and_min_units_respected():
    rng = np.random.default_rng(400)
    p = 5
    base, knee = _knee_params(rng, 2, p)
    caps = [8, 40, 40, 40, 40]
    with enable_x64():
        fleet = FleetScheduler(p, backend="jax")
        fleet.admit(JobSpec(name="capped", n=60, eps=0.05, caps=caps, min_units=1))
        fleet.admit(JobSpec(name="free", n=95, eps=0.05, min_units=2))
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee), p=p, q=2,
            job_names=["capped", "free"],
        )
        results = fleet.run(ex)
    d_c = results["capped"].allocations
    assert sum(d_c) == 60 and all(1 <= v <= c for v, c in zip(d_c, caps))
    d_f = results["free"].allocations
    assert sum(d_f) == 95 and all(v >= 2 for v in d_f)


def test_admit_validation_mirrors_autotune():
    fleet = FleetScheduler(4, backend="numpy")
    with pytest.raises(ValueError, match="n >= p"):
        fleet.admit(JobSpec(name="a", n=3))
    with pytest.raises(ValueError, match="eps"):
        fleet.admit(JobSpec(name="a", n=8, eps=0.0))
    with pytest.raises(ValueError, match="min_units"):
        fleet.admit(JobSpec(name="a", n=8, caps=[1, 8, 8, 8], min_units=2))
    with pytest.raises(ValueError, match="warm_start_d"):
        fleet.admit(JobSpec(name="a", n=8, warm_start_d=[1, 1, 1]))
    fleet.admit(JobSpec(name="a", n=8))
    with pytest.raises(ValueError, match="already admitted"):
        fleet.admit(JobSpec(name="a", n=12))
    with pytest.raises(ValueError, match="completion"):
        fleet.admit(JobSpec(name="b", n=8, completion="fast"))


# ---------------------------------------------------------------------------
# Profile registry: warm-start round-trip + corruption fallbacks
# ---------------------------------------------------------------------------

CLASSES = ["cpu", "cpu", "gpu", "gpu"]


def _class_fns(p=4):
    """Same-class processors share EXACT time fns, so class-keyed profile
    merging is lossless and the round-trip can be bit-identical."""
    per_class = {"cpu": (9e-4, 25.0), "gpu": (3e-4, 70.0)}
    a = np.asarray([[per_class[c][0] for c in CLASSES]])
    k = np.asarray([[per_class[c][1] for c in CLASSES]])
    return a, k


def test_registry_roundtrip_reproduces_donor_allocations(tmp_path):
    """Warm-starting from a saved registry reproduces the donor session's
    next-round allocations bit-identically."""
    a, k = _class_fns()
    p = 4
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(a, k), p=p, q=1, job_names=["donor"]
    )
    with enable_x64():
        reg = ProfileRegistry()
        donor = FleetScheduler(
            p, backend="jax", registry=reg, device_classes=CLASSES
        )
        donor.admit(JobSpec(name="donor", n=80, eps=1e-9, min_units=1,
                            max_iter=4, workload="matmul"))
        donor.run(ex)
        # what the donor would do next: a repartition from its estimates
        donor_sched = Scheduler(
            SpeedStore.from_models(
                [PiecewiseLinearFPM.from_points(m.as_points())
                 for m in donor.models("donor")],
                backend="jax",
            ),
            backend="jax",
        )
        want = donor_sched.partition(80, min_units=1).allocations

        donor.save_profiles()
        path = tmp_path / "profiles.json"
        reg.save(str(path))

        reg2 = ProfileRegistry.load(str(path))
        fleet2 = FleetScheduler(
            p, backend="jax", registry=reg2, device_classes=CLASSES
        )
        fleet2.admit(JobSpec(name="fresh", n=80, eps=1e-9, min_units=1,
                             max_iter=1, workload="matmul"))
        ex2 = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(a, k), p=p, q=1, job_names=["fresh"]
        )
        fleet2.run(ex2)
    first_d = fleet2._jobs["fresh"].history[0][0]
    assert first_d == want  # NOT the even split: warm start engaged
    assert first_d != _even(80, p)


def test_registry_missing_workload_starts_cold():
    a, k = _class_fns()
    reg = ProfileRegistry()
    reg.record("cpu", "other-workload", [(10.0, 5.0)])
    with enable_x64():
        fleet = FleetScheduler(
            4, backend="jax", registry=reg, device_classes=CLASSES
        )
        fleet.admit(JobSpec(name="j", n=80, eps=0.05, min_units=1, max_iter=2,
                            workload="matmul"))
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(a, k), p=4, q=1, job_names=["j"]
        )
        fleet.run(ex)
    assert fleet._jobs["j"].history[0][0] == _even(80, 4)


def test_registry_missing_file_warns_and_starts_cold(tmp_path):
    with pytest.warns(UserWarning, match="not found"):
        reg = ProfileRegistry.load(str(tmp_path / "nope.json"))
    assert len(reg) == 0


def test_registry_corrupt_json_warns_and_starts_cold(tmp_path):
    path = tmp_path / "broken.json"
    path.write_text("{ this is not json")
    with pytest.warns(UserWarning, match="unreadable"):
        reg = ProfileRegistry.load(str(path))
    assert len(reg) == 0
    path.write_text(json.dumps({"version": 1, "entries": "nope"}))
    with pytest.warns(UserWarning, match="malformed"):
        reg = ProfileRegistry.load(str(path))
    assert len(reg) == 0


def test_registry_malformed_entry_skipped_with_warning(tmp_path):
    path = tmp_path / "mixed.json"
    path.write_text(
        json.dumps(
            {
                "version": 1,
                "entries": [
                    {"device_class": "cpu", "workload": "w",
                     "points": [[10.0, 5.0], [20.0, 4.0]]},
                    {"device_class": "gpu", "workload": "w",
                     "points": [[-3.0, 5.0]]},  # non-positive x
                    {"device_class": "tpu", "workload": "w",
                     "points": [[30.0, "bad"]]},
                ],
            }
        )
    )
    with pytest.warns(UserWarning, match="malformed"):
        reg = ProfileRegistry.load(str(path))
    assert reg.get("cpu", "w") == [(10.0, 5.0), (20.0, 4.0)]
    assert ("gpu", "w") not in reg and ("tpu", "w") not in reg
    # warm_models: valid class warm, broken/absent classes cold
    models = reg.warm_models(["cpu", "gpu"], "w")
    assert models[0].num_points == 2 and models[1].num_points == 0


def test_registry_merge_keeps_freshest_on_duplicate_x():
    reg = ProfileRegistry()
    reg.record("cpu", "w", [(10.0, 5.0), (20.0, 4.0)])
    reg.record("cpu", "w", [(10.0, 6.0), (30.0, 3.0)])
    assert reg.get("cpu", "w") == [(10.0, 6.0), (20.0, 4.0), (30.0, 3.0)]


# ---------------------------------------------------------------------------
# Energy: power-capped repartition + registry energy entries
# ---------------------------------------------------------------------------


def _energy_fixtures(p, seed):
    """Heterogeneous speed + affine energy models (fast rows power-hungry)."""
    from repro.core.energy import energy_model

    rng = np.random.default_rng(seed)
    xs = [1.0, 10.0, 50.0, 200.0, 800.0]
    speed = [
        PiecewiseLinearFPM.from_points(
            [(x, float(1.0 + 2.0 * rng.random()) * (1.0 + 0.1 * (i % 3)))
             for x in xs]
        )
        for i in range(p)
    ]
    energy = [
        energy_model(
            [(x, 3.0 * (i + 1) + float(0.1 + rng.random()) * x) for x in xs]
        )
        for i in range(p)
    ]
    return speed, energy


def _fleet_round_energy(fleet, name, d):
    job = fleet._jobs[name]
    e = job.ebank().time(np.asarray(d, dtype=np.float64))
    return float(e.sum())


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_power_cap_binds_and_fits_budget(backend):
    """A binding power_cap yields per-round allocations whose predicted
    fleet energy fits the budget; an uncapped fleet with identical models
    overspends it (the cap actually binds)."""
    from repro.core.partition import _partition_units_bank

    p = 5

    def build(cap):
        fl = FleetScheduler(p, backend=backend, power_cap=cap)
        for j, n in enumerate((300, 500)):
            sm, em = _energy_fixtures(p, seed=10 + j)
            fl.admit(JobSpec(str(j), n), models=sm, energy_models=em)
        return fl

    with enable_x64():
        free = build(None)
        ds0 = free.rebalance()
        e_free = sum(_fleet_round_energy(free, nm, d) for nm, d in ds0.items())
        # the energy-optimal floor: per-job min-max-energy partitions
        e_floor = 0.0
        for nm, job in free._jobs.items():
            de, _ = _partition_units_bank(
                job.ebank(), job.spec.n, [int(c) for c in job.icaps],
                min_units=0,
            )
            e_floor += _fleet_round_energy(free, nm, de)
        assert e_floor < e_free  # non-degenerate: the cap can bind
        cap = 0.5 * (e_floor + e_free)

        capped = build(cap)
        ds1 = capped.rebalance()
        e_capped = sum(
            _fleet_round_energy(capped, nm, d) for nm, d in ds1.items()
        )
    assert e_capped <= cap + 1e-9
    assert e_free > cap  # uncapped would overspend
    for nm, d in ds1.items():
        assert sum(d) == capped._jobs[nm].spec.n


def test_power_cap_none_is_bit_identical():
    """power_cap=None must not perturb a single allocation (do-no-harm)."""
    p = 4
    with enable_x64():
        a = FleetScheduler(p, backend="jax")
        b = FleetScheduler(p, backend="jax", power_cap=None)
        sm, em = _energy_fixtures(p, seed=3)
        for fl in (a, b):
            fl.admit(JobSpec("t", 200), models=sm, energy_models=em)
        assert a.rebalance() == b.rebalance()


def test_power_cap_unpriced_jobs_run_time_optimal():
    """Jobs without energy models keep their time-optimal allocations and
    are excluded from the budget."""
    p = 4
    sm, em = _energy_fixtures(p, seed=7)
    with enable_x64():
        free = FleetScheduler(p, backend="jax")
        free.admit(JobSpec("u", 240), models=sm)
        want = free.rebalance()["u"]
        capped = FleetScheduler(p, backend="jax", power_cap=1e-6)
        capped.admit(JobSpec("u", 240), models=sm)
        assert capped.rebalance()["u"] == want


def test_power_cap_infeasible_degrades_to_energy_optimal():
    from repro.core.partition import _partition_units_bank

    p = 4
    sm, em = _energy_fixtures(p, seed=9)
    with enable_x64():
        fl = FleetScheduler(p, backend="numpy", power_cap=1e-9)
        fl.admit(JobSpec("t", 200), models=sm, energy_models=em)
        d = fl.rebalance()["t"]
        job = fl._jobs["t"]
        de, _ = _partition_units_bank(
            job.ebank(), 200, [int(c) for c in job.icaps], min_units=0
        )
    assert d == [int(v) for v in de]


def test_registry_energy_entries_roundtrip(tmp_path):
    """Energy profiles persist beside speed ones and warm-start the next
    session's admits; older-format states load clean without them."""
    p = 4
    classes = ["cpu", "cpu", "gpu", "gpu"]
    # same-class rows share energy models so class-keyed merging is lossless
    from repro.core.energy import energy_model

    xs = [1.0, 10.0, 100.0]
    per_class = {"cpu": (5.0, 0.9), "gpu": (20.0, 0.3)}
    em = [
        energy_model([(x, per_class[c][0] + per_class[c][1] * x) for x in xs])
        for c in classes
    ]
    sm, _ = _energy_fixtures(p, seed=1)
    reg = ProfileRegistry()
    fl = FleetScheduler(p, backend="numpy", registry=reg, device_classes=classes)
    fl.admit(JobSpec("d", 100, workload="decode"), models=sm, energy_models=em)
    fl.rebalance()
    fl.retire("d")
    path = tmp_path / "profiles.json"
    reg.save(str(path))
    reg2 = ProfileRegistry.load(str(path))
    warm = reg2.warm_energy_models(classes, "decode")
    assert warm is not None and len(warm) == p
    assert warm[0].as_points() == em[0].as_points()
    # a new admit picks the energy profile up from the registry
    fl2 = FleetScheduler(p, backend="numpy", registry=reg2, device_classes=classes)
    fl2.admit(JobSpec("d2", 100, workload="decode"), models=sm)
    assert fl2._jobs["d2"].energy_models is not None
    # all-or-nothing: a class without an energy entry means no warm bank
    assert reg2.warm_energy_models(["cpu", "tpu"], "decode") is None
    # pre-energy states (no energy_entries field) load clean
    state = reg2.state_dict()
    state.pop("energy_entries")
    assert ProfileRegistry.from_state(state).warm_energy_models(
        classes, "decode"
    ) is None


# ---------------------------------------------------------------------------
# Lane buckets: padded stacks, bit parity, zero recompiles within a bucket
# ---------------------------------------------------------------------------


def test_lane_buckets_bit_parity_and_zero_recompiles():
    """Bucketed fleets serve bit-identical allocations to unbucketed ones,
    and an admit WITHIN a power-of-two bucket reuses both compiled device
    programs (zero recompiles — the satellite's contract)."""
    p = 5

    def mk(buckets):
        fl = FleetScheduler(
            p, backend="jax", reserve_knots=16, lane_buckets=buckets
        )
        for j in range(3):
            sm, _ = _energy_fixtures(p, seed=30 + j)
            fl.admit(JobSpec(f"j{j}", 150 + 40 * j), models=sm)
        return fl

    with enable_x64():
        plain, bucketed = mk(False), mk(True)
        assert plain.rebalance() == bucketed.rebalance()
        # pad 3 -> 4 lanes: the stacked carry is wider than the job list
        assert int(bucketed._stacked.counts.shape[0]) == 4
        assert len(bucketed._stack_names) == 3

        # warm both programs at the padded shape, then admit within bucket
        bucketed.observe({"j0": [0.1 * (i + 1) for i in range(p)]})
        bucketed.rebalance()
        c0 = mbj._partition_units_jit._cache_size()
        f0 = mbj._fold_in_jit._cache_size()
        sm, _ = _energy_fixtures(p, seed=33)
        bucketed.admit(JobSpec("j3", 400), models=sm)
        ds = bucketed.rebalance()
        bucketed.observe({"j3": [0.1 * (i + 1) for i in range(p)]})
        assert mbj._partition_units_jit._cache_size() == c0
        assert mbj._fold_in_jit._cache_size() == f0
        assert sum(ds["j3"]) == 400

        # parity holds after the admit too (same folds replayed)
        plain.observe({"j0": [0.1 * (i + 1) for i in range(p)]})
        plain.admit(JobSpec("j3", 400), models=sm)
        assert plain.rebalance() == ds


def test_lane_buckets_full_autotune_parity():
    """The measured lock-step loop (step/run) is bit-identical under
    bucketing — dead lanes must be exact no-ops through partition AND
    fold."""
    rng = np.random.default_rng(500)
    p, q = 4, 3  # q=3 pads to 4: one dead lane in every program
    base, knee = _knee_params(rng, q, p)

    def run(buckets):
        fleet = FleetScheduler(p, backend="jax", lane_buckets=buckets)
        for j in range(q):
            fleet.admit(
                JobSpec(name=str(j), n=50 + 30 * j, eps=0.05, min_units=1,
                        max_iter=6)
            )
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee), p=p, q=q,
            job_names=[str(j) for j in range(q)],
        )
        results = fleet.run(ex)
        return fleet, results

    with enable_x64():
        fa, ra = run(False)
        fb, rb = run(True)
    for j in range(q):
        name = str(j)
        assert ra[name].allocations == rb[name].allocations
        assert ra[name].diagnostics["history"] == rb[name].diagnostics["history"]
        assert [m.as_points() for m in fa.models(name)] == [
            m.as_points() for m in fb.models(name)
        ]


# ---------------------------------------------------------------------------
# Serving fleet mode
# ---------------------------------------------------------------------------


def test_replica_dispatcher_fleet_mode():
    from repro.runtime.serve_loop import ReplicaDispatcher

    base = [4e-4, 2e-4, 8e-4, 3e-4]

    def replica_run(i, x):
        t = x * base[i]
        if x > 30:
            t += (x - 30) * base[i] * 3.0
        return t

    disp = ReplicaDispatcher(replica_run, 4, eps=0.15)
    with enable_x64():
        results = disp.balance_fleet(
            {"chat": 48, "embed": 96}, backend="jax", min_units=1
        )
        assert set(results) == {"chat", "embed"}
        assert sum(results["chat"].allocations) == 48
        assert sum(results["embed"].allocations) == 96
        assert disp.fleet is not None and disp.fleet.jobs == ["chat", "embed"]
        # the warm session keeps serving: resize a tenant and continue
        # (inside the same x64 scope — the device carry's dtype is fixed)
        disp.fleet.resize("chat", n=64)
        more = disp.fleet.run(disp)
    assert sum(more["chat"].allocations) == 64
