"""The telemetry subsystem (PR 10): sink API, Chrome-trace export, flight
recorder, and — most importantly — the two invariants the instrumentation
must never break:

* DISABLED is free: with no sink installed (the default), every
  instrumented layer makes ZERO obs-layer calls (proven with a counting
  stub) and produces bit-identical results to a build where the obs
  package is absent (proven by monkeypatching every module's guarded
  ``_obs_active`` hook to ``None``).
* Telemetry is read-only: running the SAME work with and without a sink
  yields identical allocations/histories — recording never perturbs the
  schedule.

Plus the satellite regressions: the public ``FleetScheduler.stats()``
counter snapshot (deterministic serving replay must report
``speculative_misses == 0`` — every depth-1 speculative read is consumed
when serving tenants never populate seen sets), registry warnings mirrored
as structured events without changing warning behaviour, the
injectable-clock ``t_wall`` stamps on the typed serving log, and the
flight-recorder dump naming a quarantined replica with strike evidence.
"""

import json
import warnings

import numpy as np
import pytest

from repro import obs
from repro.core import PiecewiseLinearFPM
from repro.fleet import FleetScheduler, JobSpec, ProfileRegistry
from repro.obs.chrometrace import to_chrome_trace
from repro.obs.report import MetricsSnapshot
from repro.runtime.serve_loop import ReplicaDispatcher
from repro.runtime.straggler import StragglerAction, StragglerDetector

from test_fleet import enable_x64  # noqa: F401  (the x64 scope helper)


# ---------------------------------------------------------------------------
# helpers: a small serving fleet under warm models
# ---------------------------------------------------------------------------

P, Q = 6, 3


def _warm_models(base_row):
    return [
        PiecewiseLinearFPM.from_points([(1.0, 1.0 / b), (1e6, 1.0 / b)])
        for b in base_row
    ]


def _mk_serving_fleet(backend="numpy", **kw):
    rng = np.random.default_rng(7)
    base = rng.uniform(1e-4, 5e-4, (Q, P))
    fleet = FleetScheduler(P, backend=backend, **kw)
    for j in range(Q):
        fleet.admit(
            JobSpec(name=f"t{j}", n=400 + 3 * j, eps=0.05, min_units=1),
            models=_warm_models(base[j]),
        )
    return fleet, base


def _serve_epochs(fleet, base, epochs=5):
    """Deterministic serving replay: rebalance + observe, no noise."""
    for _ in range(epochs):
        ds = fleet.rebalance()
        times = {
            f"t{j}": [x * base[j, i] if x > 0 else 0.0
                      for i, x in enumerate(ds[f"t{j}"])]
            for j in range(Q)
        }
        fleet.observe(times)
    return fleet


# ---------------------------------------------------------------------------
# Telemetry sink API
# ---------------------------------------------------------------------------


def test_telemetry_records_all_kinds():
    tel = obs.Telemetry()
    tel.span_at("work", 1.0, 1.5, n=3)
    tel.counter("hits")
    tel.counter("hits", 2)
    tel.gauge("theta", 0.25)
    tel.gauge("theta", 0.75)  # last value wins
    tel.event("boom", who="r2")
    assert tel.enabled
    assert tel.counters["hits"] == 3
    assert tel.gauges["theta"] == 0.75
    spans = tel.spans()
    assert [s.name for s in spans] == ["work"]
    assert spans[0].t1 - spans[0].t0 == pytest.approx(0.5)
    assert spans[0].attrs == {"n": 3}
    kinds = sorted(e.kind for e in tel.events)
    assert kinds == ["counter", "counter", "event", "gauge", "gauge", "span"]
    payload = tel.to_payload()
    assert payload["counters"]["hits"] == 3
    tel.clear()
    assert not tel.events and not tel.counters and not tel.gauges


def test_telemetry_ring_bound():
    tel = obs.Telemetry(capacity=4)
    for i in range(10):
        tel.event("e", i=i)
    assert len(tel.events) == 4
    assert [e.attrs["i"] for e in tel.events] == [6, 7, 8, 9]
    # counters/gauges aggregate regardless of the ring
    for i in range(10):
        tel.counter("c")
    assert tel.counters["c"] == 10


def test_install_active_use():
    assert obs.active() is obs.NOOP
    assert not obs.NOOP.enabled
    tel = obs.Telemetry()
    obs.install(tel)
    try:
        assert obs.active() is tel
    finally:
        obs.uninstall()
    assert obs.active() is obs.NOOP
    with obs.use(tel) as got:
        assert got is tel and obs.active() is tel
    assert obs.active() is obs.NOOP
    # NOOP swallows every call without recording
    obs.NOOP.span_at("x", 0.0, 1.0)
    obs.NOOP.counter("x")
    obs.NOOP.gauge("x", 1.0)
    obs.NOOP.event("x")


# ---------------------------------------------------------------------------
# instrumented layers record; recording never perturbs results
# ---------------------------------------------------------------------------


def test_fleet_serving_records_spans_and_gauges():
    tel = obs.Telemetry()
    fleet, base = _mk_serving_fleet()
    with obs.use(tel):
        _serve_epochs(fleet, base, epochs=3)
    names = {e.name for e in tel.spans()}
    assert {"fleet.rebalance", "fleet.observe"} <= names
    # every stats() field is exported as a fleet.* gauge each round
    for key, val in fleet.stats().items():
        assert tel.gauges[f"fleet.{key}"] == val


def test_telemetry_is_read_only():
    fa, base = _mk_serving_fleet()
    fb, _ = _mk_serving_fleet()
    with obs.use(obs.Telemetry()):
        _serve_epochs(fa, base, epochs=4)
    _serve_epochs(fb, base, epochs=4)
    for j in range(Q):
        assert fa.snapshot(f"t{j}").allocations == fb.snapshot(f"t{j}").allocations
    assert fa.stats() == fb.stats()


class _CountingDisabledSink:
    """enabled=False stub: any recording call is an instrumentation bug."""

    enabled = False

    def __init__(self):
        self.calls = 0

    def _bump(self, *a, **k):
        self.calls += 1

    span = span_at = counter = gauge = event = _bump
    clock = staticmethod(lambda: 0.0)


def test_disabled_sink_means_zero_obs_calls():
    """Every site must check ``enabled`` BEFORE calling any recording
    method — the disabled path does zero obs-layer work."""
    stub = _CountingDisabledSink()
    obs.install(stub)
    try:
        fleet, base = _mk_serving_fleet()
        _serve_epochs(fleet, base, epochs=3)
    finally:
        obs.uninstall()
    assert stub.calls == 0


def test_absent_obs_package_bit_identical(monkeypatch):
    """Simulate the obs package being absent (every guarded ``_obs_active``
    hook returns None, as the ImportError fallback does) and require
    bit-identical serving results."""
    import repro.core.hierarchy as hierarchy
    import repro.core.scheduler as core_scheduler
    import repro.core.speedstore as speedstore
    import repro.fleet.registry as registry
    import repro.fleet.scheduler as fleet_scheduler
    import repro.runtime.serve_loop as serve_loop
    import repro.runtime.straggler as straggler

    fa, base = _mk_serving_fleet()
    _serve_epochs(fa, base, epochs=4)

    for mod in (fleet_scheduler, core_scheduler, speedstore, hierarchy,
                registry, serve_loop, straggler):
        monkeypatch.setattr(mod, "_obs_active", lambda: None)
    fb, _ = _mk_serving_fleet()
    _serve_epochs(fb, base, epochs=4)

    for j in range(Q):
        assert fa.snapshot(f"t{j}").allocations == fb.snapshot(f"t{j}").allocations
    assert fa.stats() == fb.stats()


# ---------------------------------------------------------------------------
# public stats(): the satellite regression
# ---------------------------------------------------------------------------


def test_stats_shape_and_types():
    fleet, base = _mk_serving_fleet()
    _serve_epochs(fleet, base, epochs=2)
    st = fleet.stats()
    assert set(st) == {
        "rounds", "restacks", "device_dispatches", "predispatches",
        "stale_reads", "speculation_hits", "speculative_misses",
    }
    assert all(isinstance(v, int) for v in st.values())
    assert st["rounds"] == fleet.rounds
    assert st["speculation_hits"] == st["stale_reads"]


def test_deterministic_serving_replay_has_zero_speculative_misses():
    """Depth-1 pipelined serving: the pre-dispatched partition reads the
    previous carry speculatively, but serving tenants (admitted with
    learned models, never measuring) keep empty seen sets — every
    speculative read must be CONSUMED, none discarded."""
    with enable_x64():
        fleet, base = _mk_serving_fleet(backend="jax", pipeline=True,
                                        pipeline_depth=1)
        _serve_epochs(fleet, base, epochs=6)
    st = fleet.stats()
    assert st["speculative_misses"] == 0
    assert st["stale_reads"] > 0  # the pipeline really speculated
    assert st["predispatches"] > 0


# ---------------------------------------------------------------------------
# Chrome trace + report
# ---------------------------------------------------------------------------


def test_chrome_trace_is_valid(tmp_path):
    tel = obs.Telemetry()
    fleet, base = _mk_serving_fleet()
    with obs.use(tel):
        _serve_epochs(fleet, base, epochs=3)
        tel.gauge("demo.gauge", 0.5)
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(tel, str(path))
    trace = json.loads(path.read_text())
    evs = trace["traceEvents"]
    assert evs, "empty trace"
    for e in evs:
        assert e["ph"] in ("X", "C", "i", "M")
        assert "name" in e and "pid" in e
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 0 and "ts" in e for e in xs)
    assert {e["name"] for e in xs} >= {"fleet.rebalance", "fleet.observe"}
    # the sidecar block carries the aggregates for repro.obs.report
    assert trace["repro"]["gauges"]["demo.gauge"] == 0.5
    assert trace["repro"]["gauges"]["fleet.rounds"] == fleet.rounds


def test_report_snapshot_roundtrip(tmp_path):
    tel = obs.Telemetry()
    fleet, base = _mk_serving_fleet()
    with obs.use(tel):
        _serve_epochs(fleet, base, epochs=3)
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(tel, str(path))
    snap = MetricsSnapshot.from_file(str(path))
    assert snap.rounds == fleet.rounds
    assert snap.speculative_misses == fleet.speculative_misses
    table = snap.table()
    assert "rounds" in table and "span wall totals" in table
    # the module CLI parses the same file (smoke the __main__ path)
    from repro.obs import report
    assert report.main([str(path)]) == 0


def test_lazy_metrics_snapshot_attribute():
    import repro.obs as pkg
    assert pkg.MetricsSnapshot is MetricsSnapshot
    with pytest.raises(AttributeError):
        pkg.no_such_symbol


# ---------------------------------------------------------------------------
# registry warnings -> structured events (behaviour unchanged)
# ---------------------------------------------------------------------------


def test_registry_warning_mirrored_as_event(tmp_path):
    tel = obs.Telemetry()
    missing = str(tmp_path / "nope.json")
    with obs.use(tel):
        with pytest.warns(UserWarning, match="not found"):
            reg = ProfileRegistry.load(missing)
    assert isinstance(reg, ProfileRegistry)
    evs = [e for e in tel.events if e.name == "registry.warning"]
    assert len(evs) == 1
    assert evs[0].attrs["kind"] == "not_found"
    assert evs[0].attrs["path"] == missing
    assert "not found" in evs[0].attrs["message"]


def test_registry_warning_fires_without_telemetry(tmp_path):
    # no sink installed: the warning still fires, nothing else happens
    with pytest.warns(UserWarning, match="unreadable|Expecting|malformed"):
        bad = tmp_path / "bad.json"
        bad.write_text("{nope")
        ProfileRegistry.load(str(bad))


def test_registry_malformed_entry_event():
    tel = obs.Telemetry()
    reg = ProfileRegistry()
    reg._entries[("cpu", "matmul")] = "garbage"  # corrupt one entry in place
    with obs.use(tel):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = reg.get("cpu", "matmul")
    assert out is None
    assert any(issubclass(x.category, UserWarning) for x in w)
    evs = [e for e in tel.events if e.name == "registry.warning"]
    assert evs and evs[0].attrs["kind"] == "malformed_entry"
    assert evs[0].attrs["device_class"] == "cpu"


# ---------------------------------------------------------------------------
# straggler events + flight recorder
# ---------------------------------------------------------------------------


def _quarantine_under(sink):
    det = StragglerDetector(factor=1.5, patience=3, patience_hard=6)
    model = PiecewiseLinearFPM.from_points([(1.0, 1000.0), (100.0, 1000.0)])
    with obs.use(sink):
        for _ in range(8):
            act = det.update(2, model, d_units=10, observed_t=0.04)
            if act is StragglerAction.QUARANTINE:
                return act
    return act


def test_straggler_strike_events_carry_evidence():
    tel = obs.Telemetry()
    act = _quarantine_under(tel)
    assert act is StragglerAction.QUARANTINE
    strikes = [e for e in tel.events if e.name == "straggler.strike"]
    verdicts = [e for e in tel.events if e.name == "straggler.verdict"]
    assert strikes and verdicts
    ev = strikes[-1].attrs
    assert ev["group"] == 2
    assert ev["ratio"] == pytest.approx(4.0)
    assert ev["predicted"] == pytest.approx(0.01)
    assert ev["observed"] == pytest.approx(0.04)
    assert verdicts[-1].attrs["action"] == "quarantine"
    assert tel.counters["straggler.quarantine"] == 1


def test_flight_recorder_dump_names_offender(tmp_path):
    flight = obs.FlightRecorder(capacity=64, snapshot_capacity=4)
    flight.snapshot("pre", {"allocations": [10, 10, 10]})
    act = _quarantine_under(flight)
    assert act is StragglerAction.QUARANTINE
    path = tmp_path / "incident.flightrec.json"
    flight.dump(str(path), reason="quarantine",
                context={"replica": 2, "epoch": 5})
    dump = json.loads(path.read_text())
    assert dump["kind"] == "flight-recorder"
    assert dump["reason"] == "quarantine"
    assert dump["context"]["replica"] == 2
    assert dump["snapshots"][0]["label"] == "pre"
    strikes = [e for e in dump["events"] if e["name"] == "straggler.strike"]
    assert strikes and strikes[-1]["attrs"]["group"] == 2
    assert strikes[-1]["attrs"]["observed"] == pytest.approx(0.04)


def test_flight_recorder_ring_and_snapshot_bounds():
    flight = obs.FlightRecorder(capacity=8, snapshot_capacity=2)
    for i in range(20):
        flight.event("e", i=i)
        flight.snapshot(f"s{i}", {"i": i})
    assert len(flight.events) == 8
    assert len(flight.snapshots) == 2
    assert [s["label"] for s in flight.snapshots] == ["s18", "s19"]


# ---------------------------------------------------------------------------
# typed serving log: t_wall stamps from an injectable clock
# ---------------------------------------------------------------------------


def test_serving_log_t_wall_monotonic_from_injected_clock():
    base = [4e-4, 2e-4, 8e-4, 3e-4]

    def replica_run(i, x):
        return x * base[i] if x > 0 else 0.0

    ticks = iter(np.arange(100.0, 200.0, 0.5))
    disp = ReplicaDispatcher(replica_run, 4, eps=0.15,
                             clock=lambda: float(next(ticks)))
    disp.balance(96)
    assert disp.logs, "balance() appended no rounds"
    stamps = [log.t_wall for log in disp.logs]
    assert all(b > a for a, b in zip(stamps, stamps[1:]))
    assert stamps[0] >= 100.0  # came from the injected clock
    # t_wall is excluded from equality: replay comparisons ignore it
    a = disp.logs[0]
    b = type(a)(**{**a.__dict__, "t_wall": -1.0})
    assert a == b
