"""The geometric partitioner of [16]: equal-time optimality + integer laws."""

import math

import pytest
from _hyp import given, settings, st

from repro.core.fpm import AnalyticModel, ConstantModel, PiecewiseLinearFPM
from repro.core.partition import cpm_partition, partition_continuous, partition_units


def test_constant_speeds_proportional():
    assert cpm_partition([1, 2, 3], 600) == [100, 200, 300]
    assert cpm_partition([1, 1], 5) in ([3, 2], [2, 3])


def test_continuous_equal_times():
    """The paper's geometric condition: x_i / s_i(x_i) all equal at the opt."""
    models = [
        AnalyticModel(lambda x: x / 10.0),
        AnalyticModel(lambda x: x / 20.0 + 1e-4 * x**1.3),
        AnalyticModel(lambda x: x / 5.0),
    ]
    xs, t_star = partition_continuous(models, 1000.0)
    assert sum(xs) == pytest.approx(1000.0, rel=1e-6)
    times = [m.time(x) for m, x in zip(models, xs)]
    for t in times:
        assert t == pytest.approx(t_star, rel=1e-5)


@st.composite
def _models(draw):
    p = draw(st.integers(2, 8))
    out = []
    for i in range(p):
        pts = draw(
            st.lists(
                st.tuples(st.floats(1.0, 1e4), st.floats(0.5, 500.0)),
                min_size=1,
                max_size=6,
                unique_by=lambda q: q[0],
            )
        )
        out.append(PiecewiseLinearFPM.from_points(pts))
    return out


@given(models=_models(), n=st.integers(10, 5000))
@settings(max_examples=100, deadline=None)
def test_integer_partition_laws(models, n):
    d = partition_units(models, n)
    assert sum(d) == n
    assert all(di >= 0 for di in d)


@given(models=_models(), n=st.integers(20, 2000))
@settings(max_examples=50, deadline=None)
def test_min_units_respected(models, n):
    d = partition_units(models, n, min_units=2)
    assert sum(d) == n
    assert all(di >= 2 for di in d)


def test_caps_respected_and_infeasible_raises():
    models = [ConstantModel(1.0), ConstantModel(1.0)]
    d = partition_units(models, 10, caps=[3, 10])
    assert d == [3, 7]
    with pytest.raises(ValueError):
        partition_units(models, 10, caps=[3, 3])


def test_integer_solution_near_optimal_makespan():
    """Greedy completion: integer makespan within one unit-time of cont. t*."""
    models = [ConstantModel(s) for s in [3.0, 7.0, 11.0, 2.0]]
    n = 997
    d = partition_units(models, n)
    makespan = max(m.time(di) for m, di in zip(models, d))
    _, t_star = partition_continuous(models, float(n))
    slowest_unit = max(1.0 / s.s for s in models)
    assert makespan <= t_star + slowest_unit + 1e-9
