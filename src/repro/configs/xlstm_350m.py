"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304.

Alternating mLSTM / sLSTM blocks [arXiv:2405.04517; unverified].  d_ff=0:
blocks are self-contained (mLSTM block carries a 2x up/down projection;
sLSTM block a 4/3 gated post-FFN).  Fully recurrent decode state ->
sub-quadratic; runs long_500k.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm", "slstm"),
    conv_width=4,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        vocab_size=512,
        xent_chunk=0,
        remat="none",
    )
