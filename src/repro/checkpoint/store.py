"""Atomic, async, resharding checkpoints.

Design for 1000+ node fleets (DESIGN.md §3):

  * ATOMIC   — write to ``<dir>/tmp.<step>``, fsync, then ``os.replace`` to
    ``<dir>/step_<n>``; a crash mid-write can never corrupt the latest good
    checkpoint; ``latest`` symlink updated last.
  * ASYNC    — ``CheckpointManager.save_async`` snapshots to host memory
    (device_get) synchronously (cheap) and writes in a background thread, so
    training resumes immediately; ``wait()`` joins before the next save.
  * RESHARD  — restore takes the *current* mesh/shardings and device_puts
    each tensor to its new layout: restarting on a different device count
    (elastic restart) is the normal path, not a special case.
  * MANIFEST — JSON with step, config name, mesh shape, data-pipeline state,
    and the flattened tree paths, so a restore can validate compatibility
    before touching any tensor data.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": int(step),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    # update 'latest' pointer last (atomic symlink swap)
    link = os.path.join(directory, "latest")
    tmp_link = os.path.join(directory, ".latest.tmp")
    if os.path.lexists(tmp_link):
        os.remove(tmp_link)
    os.symlink(os.path.basename(final), tmp_link)
    os.replace(tmp_link, link)
    return final


def load_checkpoint(
    directory: str,
    like: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) re-shards each tensor
    to the CURRENT mesh — the elastic-restart path.  Returns (tree, manifest).
    """
    if step is None:
        path = os.path.join(directory, "latest")
        if not os.path.exists(path):
            raise FileNotFoundError(f"no checkpoint in {directory}")
        path = os.path.realpath(path)
    else:
        path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        _SEP.join(_path_str(p) for p in pth)
        for pth, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    missing = [k for k in paths if k not in data]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}... ({len(missing)})")
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
    )
    out = []
    for key, leaf_like, shd in zip(paths, leaves_like, shard_leaves):
        arr = data[key]
        want_dtype = getattr(leaf_like, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class CheckpointManager:
    """Async wrapper with retention: keeps the last ``keep`` checkpoints."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any, *, extra=None) -> None:
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training continues

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            d for d in os.listdir(self.directory) if d.startswith("step_")
        )
        for d in steps[: -self.keep] if self.keep > 0 else []:
            import shutil

            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        link = os.path.join(self.directory, "latest")
        if not os.path.exists(link):
            return None
        return int(os.path.basename(os.path.realpath(link)).split("_")[1])
