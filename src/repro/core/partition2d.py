"""Nested 2-D partitioning (paper §3.2) + CPM / FFMPA baselines.

The 2-D heterogeneous matmul distributes an ``M x N`` block matrix over a
``p x q`` processor grid: column widths ``n_j`` (outer) and per-column row
heights ``m_ij`` (inner).  The paper's DFPA-based algorithm:

  1. start even: ``n_j = N/q``, ``m_ij = M/p``;
  2. for each column j IN PARALLEL, run DFPA on the column's rows (this
     *estimates a 1-D projection of the 2-D FPM* at width ``n_j``);
  3. if the global imbalance <= eps -> done; else set
     ``n_j ∝ sum_i s_ij(m_ij, n_j)`` (column width proportional to the
     column's speed sum) and goto 2.

.. deprecated::
    The algorithms now live on the facade — construct
    ``Scheduler(grid=grid, policy=Policy.GRID2D | CPM | FFMPA)`` and call
    ``partition_grid(M, N)`` (or ``repartition_grid`` for the batched
    no-benchmark refresh).  The functions below are thin shims: they emit
    ``DeprecationWarning``, delegate to the facade and repack the typed
    ``Partition`` into the legacy :class:`Grid2DResult`.

This module keeps the result dataclass, the evaluation helper
:func:`app_time_2d`, and the pure grid helpers the facade's implementation
shares (`_col_times`, `_rebalance_widths`, `_flat_imbalance`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from .fpm import PiecewiseLinearFPM, imbalance

__all__ = [
    "Grid2DResult",
    "bank_repartition_2d",
    "dfpa_partition_2d",
    "cpm_partition_2d",
    "ffmpa_partition_2d",
    "app_time_2d",
]

SpeedFn2D = Callable[[float, float], float]  # g(m_b, n_b) -> units/s


@dataclass
class Grid2DResult:
    col_widths: List[int]  # n_j, len q
    row_heights: List[List[int]]  # m[j][i], q x p
    outer_iterations: int
    total_rounds: int  # total DFPA parallel rounds across all columns
    bench_cost: float  # wall-clock spent benchmarking (parallel-round model)
    converged: bool
    imbalance: float
    times: List[List[float]] = field(default_factory=list)  # t[j][i]


def _col_times(
    grid: Sequence[Sequence[SpeedFn2D]], j: int, widths: Sequence[int], rows: Sequence[int]
) -> List[float]:
    w = widths[j]
    return [
        (r * w) / grid[i][j](float(r), float(w)) if r > 0 else 0.0
        for i, r in enumerate(rows)
    ]


def _flat_imbalance(times: List[List[float]]) -> float:
    # imbalance() ignores zero-allocation entries itself.
    return imbalance([t for col in times for t in col])


def _rebalance_widths(widths: List[int], times: List[List[float]], rows, N: int, *, damp: float = 0.5) -> List[int]:
    """Outer step (ii): widths ∝ column speed sums, RELAXED by ``damp`` —
    the undamped update oscillates when speeds bend with the allocation
    (paging/nonlinear regions)."""
    q = len(widths)
    col_speed = []
    for j in range(q):
        s = sum(
            (rows[j][i] * widths[j]) / times[j][i]
            for i in range(len(rows[j]))
            if times[j][i] > 0
        )
        col_speed.append(s)
    tot = sum(col_speed)
    target = [N * s / tot for s in col_speed]
    blended = [
        (1.0 - damp) * w + damp * t for w, t in zip(widths, target)
    ]
    new_widths = [max(int(round(b)), 1) for b in blended]
    diff = N - sum(new_widths)
    order = sorted(range(q), key=lambda j: blended[j] - new_widths[j], reverse=(diff > 0))
    k = 0
    while diff != 0:
        j = order[k % q]
        step = 1 if diff > 0 else -1
        if new_widths[j] + step >= 1:
            new_widths[j] += step
            diff -= step
        k += 1
    return new_widths


def _to_grid2d(part) -> Grid2DResult:
    """Repack a facade ``Partition`` into the legacy result type."""
    diag = part.diagnostics
    return Grid2DResult(
        col_widths=list(part.col_widths),
        row_heights=[list(r) for r in part.row_heights],
        outer_iterations=part.iterations,
        total_rounds=diag.get("total_rounds", 0),
        bench_cost=diag.get("bench_cost", 0.0),
        converged=part.converged,
        imbalance=part.imbalance,
        times=[list(t) for t in diag.get("times", [])],
    )


def bank_repartition_2d(
    fpms: Sequence[Sequence[PiecewiseLinearFPM]],
    fpm_width: Sequence[Sequence[Optional[int]]],
    widths: Sequence[int],
    M: int,
    *,
    min_units: int = 1,
    backend: str = "numpy",
) -> List[List[int]]:
    """Re-partition EVERY column's rows from the surviving FPM estimates in
    one call — no new benchmarks.

    .. deprecated:: use ``Scheduler.repartition_grid``.
    """
    from .scheduler import Policy, Scheduler
    from .speedstore import _warn_legacy

    _warn_legacy("bank_repartition_2d()", "Scheduler.repartition_grid()")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    sched = Scheduler(policy=Policy.GRID2D, backend=backend)
    return sched.repartition_grid(fpms, fpm_width, widths, M, min_units=min_units)


def dfpa_partition_2d(
    grid: Sequence[Sequence[SpeedFn2D]],
    M: int,
    N: int,
    eps: float,
    *,
    max_outer: int = 40,
    inner_max_iter: int = 15,
    width_tol: float = 0.02,
    min_units: int = 1,
    backend: str = "numpy",
) -> Grid2DResult:
    """DFPA-based nested 2-D partitioning over ground-truth speeds ``grid``.

    .. deprecated:: use ``Scheduler(grid=grid, policy=Policy.GRID2D)
       .partition_grid(M, N, eps=...)``.
    """
    from .scheduler import Policy, Scheduler
    from .speedstore import _warn_legacy

    _warn_legacy("dfpa_partition_2d()", "Scheduler.partition_grid()")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    sched = Scheduler(grid=grid, policy=Policy.GRID2D, backend=backend)
    part = sched.partition_grid(
        M, N, eps=eps, max_outer=max_outer, inner_max_iter=inner_max_iter,
        width_tol=width_tol, min_units=min_units,
    )
    return _to_grid2d(part)


def cpm_partition_2d(
    grid: Sequence[Sequence[SpeedFn2D]], M: int, N: int
) -> Tuple[Grid2DResult, float]:
    """The conventional baseline: ONE benchmark round at the even distribution
    gives each processor a speed constant; rows/columns split proportionally.
    Returns (result, bench_cost).

    .. deprecated:: use ``Scheduler(grid=grid, policy=Policy.CPM)
       .partition_grid(M, N)``.
    """
    from .scheduler import Policy, Scheduler
    from .speedstore import _warn_legacy

    _warn_legacy("cpm_partition_2d()", "Scheduler.partition_grid()")
    part = Scheduler(grid=grid, policy=Policy.CPM).partition_grid(M, N)
    res = _to_grid2d(part)
    return res, res.bench_cost


def ffmpa_partition_2d(
    grid: Sequence[Sequence[SpeedFn2D]],
    M: int,
    N: int,
    eps: float,
    *,
    max_outer: int = 50,
) -> Grid2DResult:
    """FFMPA baseline [18]: the FULL models are given (pre-built), so the
    nested iteration runs entirely on the host with zero benchmark cost.

    .. deprecated:: use ``Scheduler(grid=grid, policy=Policy.FFMPA)
       .partition_grid(M, N, eps=...)``.
    """
    from .scheduler import Policy, Scheduler
    from .speedstore import _warn_legacy

    _warn_legacy("ffmpa_partition_2d()", "Scheduler.partition_grid()")
    part = Scheduler(grid=grid, policy=Policy.FFMPA).partition_grid(
        M, N, eps=eps, max_outer=max_outer
    )
    return _to_grid2d(part)


def app_time_2d(
    grid: Sequence[Sequence[SpeedFn2D]],
    result,
    K: int,
    *,
    bcast_overhead: float = 1.0e-3,
) -> float:
    """Full 2-D matmul app time: K pivot steps, each costing the slowest
    processor's panel update + broadcast overhead (paper Fig. 7(a)).

    Accepts either the legacy :class:`Grid2DResult` or a facade
    ``Partition`` — both expose ``col_widths`` / ``row_heights``.
    """
    step = 0.0
    for j, w in enumerate(result.col_widths):
        for i, r in enumerate(result.row_heights[j]):
            if r > 0:
                step = max(step, (r * w) / grid[i][j](float(r), float(w)))
    return K * (step + bcast_overhead)
