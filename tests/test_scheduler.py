"""Scheduler facade: parity with the legacy free functions across all three
backends and all four policies, the online lifecycle, full-fidelity state
round-trips, and analytic sample-and-bank.

This file (with ``test_scheduler_shims.py``) runs in CI under
``-W error::DeprecationWarning``: everything the facade does internally must
be warning-free — new code cannot sneak back onto the shimmed legacy API.
Legacy calls made *for comparison* are wrapped in :func:`legacy`.
"""

import contextlib
import warnings

import numpy as np
import pytest

from repro.core import (
    AnalyticModel,
    HCL_SPECS,
    Partition,
    Policy,
    Scheduler,
    SimulatedExecutor,
    SpeedStore,
    imbalance,
    make_hcl_time_fns,
    sample_analytic_points,
    speed_fn_2d,
)
from repro.core.fpm import PiecewiseLinearFPM


@contextlib.contextmanager
def legacy():
    """Run a deliberately-deprecated legacy call without tripping the
    ``-W error::DeprecationWarning`` CI lane."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield


def _fleet(p, seed=0):
    rng = np.random.default_rng(seed)
    models = []
    for _ in range(p):
        k = int(rng.integers(2, 7))
        xs = np.sort(rng.uniform(1.0, 1e4, k))
        ss = rng.uniform(0.5, 500.0, k)
        models.append(PiecewiseLinearFPM.from_points(list(zip(xs, ss))))
    return models


def _row_fns(tfns, n):
    return [(lambda tf: lambda r: tf(r * n))(tf) for tf in tfns]


# ---------------------------------------------------------------------------
# SpeedStore: one resolution, three backends, legacy-identical partitions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scalar", "numpy", "jax"])
def test_speedstore_partition_matches_legacy(backend):
    models = _fleet(6, seed=3)
    n, caps, mu = 1234, [400, 500, 300, 600, 200, 400], 2
    if backend == "jax":
        from jax.experimental import enable_x64

        with enable_x64():
            store = SpeedStore.from_models(models, backend="jax")
            assert store.backend == "jax"
            got = store.partition_units(n, caps, min_units=mu)
            with legacy():
                from repro.core import partition_units

                want = partition_units(models, n, caps, min_units=mu, backend="jax")
    else:
        store = SpeedStore.from_models(models, backend=backend)
        assert store.backend == backend
        got = store.partition_units(n, caps, min_units=mu)
        with legacy():
            from repro.core import partition_units

            want = partition_units(
                models, n, caps, min_units=mu, vectorize=(backend != "scalar")
            )
    assert got == want
    assert sum(got) == n


def test_speedstore_backend_resolved_once():
    models = _fleet(4)
    auto = SpeedStore.from_models(models)
    assert auto.backend == "numpy"  # piecewise -> banked
    analytic = SpeedStore.from_models([AnalyticModel(lambda x: x / 5.0)] * 3)
    assert analytic.backend == "scalar"  # no piecewise representation
    forced = SpeedStore.from_models(models, backend="scalar")
    assert forced.backend == "scalar"
    # requesting a banked backend for unbankable models falls back, once
    fb = SpeedStore.from_models([AnalyticModel(lambda x: x / 5.0)] * 3, backend="numpy")
    assert fb.backend == "scalar"


def test_speedstore_query_protocol():
    models = _fleet(5, seed=9)
    store = SpeedStore.from_models(models)
    x = np.array([10.0, 50.0, 100.0, 5.0, 2000.0])
    np.testing.assert_allclose(
        store.speeds(x), [m.speed(float(v)) for m, v in zip(models, x)]
    )
    np.testing.assert_allclose(
        store.times(x), [m.time(float(v)) for m, v in zip(models, x)]
    )
    caps = np.full(5, 1e4)
    np.testing.assert_allclose(
        store.alloc_at_time(0.5, caps),
        [m.alloc_at_time(0.5, 1e4) for m in models],
    )


def test_speedstore_fold_in_updates_models():
    store = SpeedStore.empty(3)
    store.fold_in([10.0, 20.0, 30.0], [1.0, 2.0, 3.0], [True, False, True])
    assert store.num_points == [1, 0, 1]
    assert store.models[0].as_points() == [(10.0, 1.0)]
    assert store.models[2].as_points() == [(30.0, 3.0)]


def test_speedstore_infeasible_raises_all_backends():
    models = _fleet(4)
    for backend in ("scalar", "numpy"):
        store = SpeedStore.from_models(models, backend=backend)
        with pytest.raises(ValueError, match="min_units"):
            store.partition_units(3, min_units=1)  # min_units * p > n
        with pytest.raises(ValueError, match="min_units"):
            store.partition_units(20, caps=[0, 20, 20, 20], min_units=1)
        with pytest.raises(ValueError, match="infeasible"):
            store.partition_units(100, caps=[10, 10, 10, 10])


# ---------------------------------------------------------------------------
# Policy parity: the facade reproduces every legacy policy entry point
# ---------------------------------------------------------------------------


def test_policy_cpm_matches_legacy():
    speeds = [1.0, 2.0, 3.0, 2.5]
    part = Scheduler.from_speeds(speeds).partition(600)
    with legacy():
        from repro.core import cpm_partition

        want = cpm_partition(speeds, 600)
    assert part.allocations == want
    assert part.policy is Policy.CPM
    assert part.d == part.allocations  # legacy-friendly alias


def test_policy_ffmpa_matches_legacy():
    n = 2048
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    models = [AnalyticModel(tf) for tf in rows]
    part = Scheduler.from_models(models, policy=Policy.FFMPA).partition(n, min_units=1)
    with legacy():
        from repro.core import partition_units

        want = partition_units([AnalyticModel(tf) for tf in rows], n, min_units=1)
    assert part.allocations == want
    assert part.t_star is not None and part.t_star > 0
    assert part.makespan == pytest.approx(max(part.times))


def test_policy_dfpa_matches_legacy():
    n = 2048
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    part = Scheduler().autotune(SimulatedExecutor(time_fns=rows), n, 0.025, min_units=1)
    with legacy():
        from repro.core import dfpa

        res = dfpa(SimulatedExecutor(time_fns=rows), n, 0.025, min_units=1)
    assert part.allocations == res.d
    assert part.iterations == res.iterations
    assert part.converged == res.converged
    assert part.imbalance == pytest.approx(res.imbalance, rel=1e-12)
    assert [h[0] for h in part.diagnostics["history"]] == [h[0] for h in res.history]


def test_policy_grid2d_matches_legacy():
    p, q, M, N = 3, 3, 256, 256
    specs = HCL_SPECS[: p * q]
    grid = [[speed_fn_2d(specs[i * q + j]) for j in range(q)] for i in range(p)]
    part = Scheduler(grid=grid, policy=Policy.GRID2D).partition_grid(M, N, eps=0.1)
    with legacy():
        from repro.core import cpm_partition_2d, dfpa_partition_2d, ffmpa_partition_2d

        want = dfpa_partition_2d(grid, M, N, eps=0.1)
        cpm_want, cpm_cost = cpm_partition_2d(grid, M, N)
        ff_want = ffmpa_partition_2d(grid, M, N, eps=0.1)
    assert part.col_widths == want.col_widths
    assert part.row_heights == want.row_heights
    assert part.iterations == want.outer_iterations
    assert part.diagnostics["bench_cost"] == pytest.approx(want.bench_cost)
    # the flat allocations view is the column-major row flatten
    assert part.allocations == [r for col in part.row_heights for r in col]

    cpm_part = Scheduler(grid=grid, policy=Policy.CPM).partition_grid(M, N)
    assert cpm_part.col_widths == cpm_want.col_widths
    assert cpm_part.row_heights == cpm_want.row_heights
    assert cpm_part.diagnostics["bench_cost"] == pytest.approx(cpm_cost)

    ff_part = Scheduler(grid=grid, policy=Policy.FFMPA).partition_grid(
        M, N, eps=0.1, max_outer=50
    )
    assert ff_part.col_widths == ff_want.col_widths
    assert ff_part.row_heights == ff_want.row_heights


def test_grid2d_jax_backend_matches_numpy():
    from jax.experimental import enable_x64

    p, q, M = 3, 2, 128
    rng = np.random.default_rng(5)
    widths = [40, 44]
    fpms = [[PiecewiseLinearFPM() for _ in range(q)] for _ in range(p)]
    fpm_width = [[None] * q for _ in range(p)]
    for i in range(p):
        for j in range(q):
            for r in rng.uniform(4, M, 4):
                fpms[i][j].add_point(float(r), float(rng.uniform(1.0, 30.0)))
            fpm_width[i][j] = widths[j]
    rows_np = Scheduler(policy=Policy.GRID2D).repartition_grid(
        fpms, fpm_width, widths, M
    )
    with enable_x64():
        rows_jax = Scheduler(policy=Policy.GRID2D, backend="jax").repartition_grid(
            fpms, fpm_width, widths, M
        )
    assert rows_np == rows_jax
    assert all(sum(r) == M for r in rows_np)


# ---------------------------------------------------------------------------
# The online lifecycle: observe / repartition / join / leave / stragglers
# ---------------------------------------------------------------------------


def test_observe_rebalances_like_balance_controller():
    speeds = [1.0, 2.0, 3.0, 2.0]

    def drive(obj):
        trace = []
        for _ in range(20):
            times = [d / s if d > 0 else 0.0 for d, s in zip(obj.d, speeds)]
            obj.observe(times)
            trace.append(list(obj.d))
        return trace

    sched = Scheduler(n_units=64, num_groups=4, eps=0.08, min_units=1, smooth=1.0)
    with legacy():
        from repro.runtime.balance import BalanceController

        ctrl = BalanceController(n_units=64, num_groups=4, eps=0.08, smooth=1.0)
        want = drive(ctrl)
    got = drive(sched)
    assert got == want
    assert sched.rebalances == ctrl.rebalances


def test_repartition_returns_partition():
    sched = Scheduler(n_units=60, num_groups=3, eps=0.05, min_units=1, smooth=1.0)
    for _ in range(6):
        times = [d / s if d > 0 else 0.0 for d, s in zip(sched.d, [1.0, 2.0, 3.0])]
        sched.observe(times)
    part = sched.repartition()
    assert isinstance(part, Partition)
    assert sum(part.allocations) == 60
    assert part.backend == "numpy"
    assert part.t_star is not None


def test_per_call_caps_are_one_shot():
    """Regression: per-call ``caps`` used to overwrite ``self.caps`` and
    silently constrain every later repartition/observe/autotune in the
    session.  They are one-shot now; ``persist_caps=True`` opts back in."""
    models = _fleet(4, seed=11)

    sched = Scheduler(SpeedStore.from_models(models), n_units=60, min_units=1)
    free = sched.partition().allocations
    hot = int(np.argmax(free))  # cap the busiest processor so it binds
    caps = [100] * 4
    caps[hot] = 1
    assert free[hot] > 1
    capped = sched.partition(caps=caps).allocations
    assert capped[hot] == 1
    assert sched.caps is None  # session state untouched
    assert sched.repartition().allocations == free  # failing before the fix

    sticky = Scheduler(SpeedStore.from_models(models), n_units=60, min_units=1)
    assert sticky.partition(caps=caps, persist_caps=True).allocations[hot] == 1
    assert sticky.caps == caps
    assert sticky.repartition().allocations[hot] == 1

    # construction-time caps still persist (they are session state)
    sess = Scheduler(
        SpeedStore.from_models(models), n_units=60, min_units=1, caps=caps
    )
    assert sess.partition().allocations[hot] == 1
    assert sess.repartition().allocations[hot] == 1


def test_join_leave_lifecycle():
    sched = Scheduler(n_units=60, num_groups=3, eps=0.05, min_units=1, smooth=1.0)
    for _ in range(12):
        times = [d / s if d > 0 else 0.0 for d, s in zip(sched.d, [1.0, 2.0, 3.0])]
        sched.observe(times)
    pts_before = sched.models[0].num_points
    sched.leave(2)
    assert sched.num_groups == 2
    assert sum(sched.d) == 60
    assert sched.models[0].num_points == pts_before  # survivors keep points
    sched.join(1)
    assert sched.num_groups == 3
    assert sum(sched.d) == 60
    assert sched.models[2].num_points == 1  # donor-seeded newcomer
    assert sched.d[2] > 0  # not starved


def test_resize_matches_legacy_elastic():
    def build():
        s = Scheduler(n_units=60, num_groups=3, eps=0.05, min_units=1, smooth=1.0)
        for _ in range(10):
            times = [d / sp if d > 0 else 0.0 for d, sp in zip(s.d, [1.0, 2.0, 3.0])]
            s.observe(times)
        return s

    sched = build()
    new = sched.resize([0, 2], joined=1, caps=None)
    with legacy():
        from repro.runtime.balance import BalanceController
        from repro.runtime.elastic import elastic_rebalance

        ctrl = BalanceController(
            n_units=60, num_groups=3, eps=0.05, smooth=1.0,
            models=[PiecewiseLinearFPM.from_points(m.as_points()) for m in sched.models],
            d=list(sched.d),
        )
        want = elastic_rebalance(ctrl, surviving=[0, 2], joined=1)
    assert new.d == want.d
    assert [m.as_points() for m in new.models] == [m.as_points() for m in want.models]


def test_straggler_actions_auto_reprofile():
    from repro.runtime.straggler import StragglerAction, StragglerDetector

    sched = Scheduler(
        n_units=40, num_groups=2, eps=0.05, min_units=1, smooth=1.0,
        detector=StragglerDetector(factor=1.5, patience=2, patience_hard=99),
    )
    sched.observe([2.0, 1.0])
    sched.observe([d / 2.0 for d in sched.d])
    pts_before = sched.models[0].num_points
    assert pts_before >= 1
    # group 0 suddenly 4x slower than its model predicts -> strikes -> reprofile
    healthy = [m.time(d) for m, d in zip(sched.models, sched.d)]
    acts = []
    for _ in range(3):
        acts.append(sched.straggler_actions([healthy[0] * 4.0, healthy[1]]))
    assert any(a[0] is StragglerAction.REPROFILE for a in acts)
    assert sched.models[0].num_points <= 1  # estimate invalidated


# ---------------------------------------------------------------------------
# State round-trip: full config, bit-identical next-round allocations
# ---------------------------------------------------------------------------


def _drive_rounds(sched, speeds, rounds=3):
    ds = []
    for _ in range(rounds):
        times = [d / s if d > 0 else 0.0 for d, s in zip(sched.d, speeds)]
        sched.observe(times)
        ds.append(list(sched.d))
    return ds


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_state_roundtrip_bit_identical_next_round(backend):
    """Regression for the legacy ``BalanceController.from_state`` kwarg
    asymmetry: ``state_dict`` now carries backend/smooth/eps/min_units/caps
    AND the EMA state, so a restored scheduler's next rounds are
    bit-identical to the uninterrupted run."""
    ctx = contextlib.nullcontext()
    if backend == "jax":
        from jax.experimental import enable_x64

        ctx = enable_x64()
    speeds = [4.0, 3.0, 1.5, 2.0]
    with ctx:
        sched = Scheduler(
            n_units=64, num_groups=4, eps=0.03, min_units=1, smooth=0.7,
            caps=[40, 40, 40, 40], backend=backend,
        )
        _drive_rounds(sched, speeds, rounds=4)
        state = sched.state_dict()

        restored = Scheduler.from_state(state)
        assert restored.backend == backend
        assert restored.smooth == sched.smooth
        assert restored.eps == sched.eps
        assert restored.min_units == sched.min_units
        assert restored.caps == sched.caps
        assert restored.d == sched.d
        assert restored._ema == sched._ema

        want = _drive_rounds(sched, speeds, rounds=3)
        got = _drive_rounds(restored, speeds, rounds=3)
    assert got == want


def test_balance_controller_state_carries_full_config():
    """The legacy wrapper's state now round-trips backend and smooth too."""
    with legacy():
        from repro.runtime.balance import BalanceController

        ctrl = BalanceController(
            n_units=32, num_groups=2, eps=0.2, min_units=1, smooth=0.9
        )
        ctrl.observe([2.0, 1.0])
        state = ctrl.state_dict()
        assert state["smooth"] == 0.9
        assert state["backend"] == "numpy"
        back = BalanceController.from_state(state)
        assert back.eps == 0.2
        assert back.smooth == 0.9
        assert back.d == ctrl.d
        assert back._ema == ctrl._ema


# ---------------------------------------------------------------------------
# Analytic sample-and-bank (ROADMAP: FFMPA baselines on the vectorized path)
# ---------------------------------------------------------------------------


def test_sample_analytic_points_hits_tolerance():
    m = AnalyticModel(lambda x: x / (50.0 + 10.0 * np.log1p(x)))  # smooth speed
    pts = sample_analytic_points(m, hi=5000.0, tol=0.005)
    fit = PiecewiseLinearFPM.from_points(pts)
    for x in np.geomspace(1.0, 5000.0, 64):
        assert fit.speed(float(x)) == pytest.approx(m.speed(float(x)), rel=0.02)


def test_analytic_models_ride_the_bank_path():
    n = 2048
    _, tfns = make_hcl_time_fns(n)
    rows = _row_fns(tfns, n)
    models = [AnalyticModel(tf) for tf in rows]
    store = SpeedStore.from_models(
        models, analytic_tol=0.002, analytic_hi=float(n), analytic_max_points=256
    )
    assert store.backend == "numpy"  # sampled -> banked, no scalar fallback
    d_bank = store.partition_units(n, min_units=1)
    with legacy():
        from repro.core import partition_units

        d_exact = partition_units([AnalyticModel(tf) for tf in rows], n, min_units=1)
    assert sum(d_bank) == n
    # sampled models approximate the analytic oracle: near-identical makespan
    ms_bank = max(tf(d) for tf, d in zip(rows, d_bank))
    ms_exact = max(tf(d) for tf, d in zip(rows, d_exact))
    assert ms_bank <= ms_exact * 1.02
    imb = imbalance([tf(d) for tf, d in zip(rows, d_bank) if d > 0])
    assert imb <= 0.05


def test_grid_ffmpa_sample_and_bank_close_to_scalar():
    p, q, M, N = 3, 3, 192, 192
    specs = HCL_SPECS[: p * q]
    grid = [[speed_fn_2d(specs[i * q + j]) for j in range(q)] for i in range(p)]
    exact = Scheduler(grid=grid, policy=Policy.FFMPA).partition_grid(M, N, eps=0.1, max_outer=50)
    banked = Scheduler(grid=grid, policy=Policy.FFMPA, analytic_tol=0.005).partition_grid(
        M, N, eps=0.1, max_outer=50
    )
    from repro.core import app_time_2d

    t_exact = app_time_2d(grid, exact, K=N)
    t_banked = app_time_2d(grid, banked, K=N)
    assert t_banked <= t_exact * 1.05


# ---------------------------------------------------------------------------
# Construction / validation
# ---------------------------------------------------------------------------


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        Scheduler(backend="Jax")
    with pytest.raises(ValueError, match="backend"):
        SpeedStore.from_models(_fleet(2), backend="cuda")


def test_partition_requires_units_or_grid():
    with pytest.raises(ValueError, match="n_units"):
        Scheduler(num_groups=2).partition()
    with pytest.raises(ValueError, match="grid"):
        Scheduler(num_groups=2).partition_grid(8, 8)
