"""Fuzz-parity harness: scalar vs numpy-bank vs jax-bank, locked together.

Three implementations of the partitioning algorithm coexist (see the "three
backends, one semantics" section in ``core/modelbank.py``); this suite is
what makes that safe.  Every property runs twice:

  * a **hypothesis** lane (through the optional ``tests/_hyp.py`` shim;
    skipped cleanly when hypothesis is not installed), >= 200 generated
    cases per property;
  * a **numpy-rng** lane that always runs, 200 seeded cases per property,
    so minimal environments still exercise the parity surface.

Both lanes drive the same ``_check_*`` functions over randomly generated
banks *including the degenerate rows*: empty models, single-point models,
duplicate x's (collapsed by the FPM update rule), and zero caps.

Parity contract asserted here:

  * ``speed`` / ``time`` / ``alloc_at_time`` bit-identical between the numpy
    and jax banks (x64), elementwise equal to the scalar models on non-empty
    rows, NaN on empty rows for both banks;
  * ``partition_units``: all three paths sum to ``n``, respect caps and
    ``min_units``, the numpy and jax banks agree bit-for-bit, and all three
    hit the same makespan (allocations may tie-break differently between the
    scalar and banked continuous solvers; the makespan must not drift);
  * infeasible inputs raise ``ValueError`` on all three paths (including the
    ``cap < min_units`` silent-shortfall case this PR fixed);
  * ``fold_in`` (the device-resident DFPA carry) reproduces the scalar
    ``add_point`` update rule exactly, duplicates included;
  * the stacked ``[q, p, k]`` bank partitions every column exactly as the
    per-column calls do.

The jax lane runs under ``jax.experimental.enable_x64`` so its float ops are
IEEE-double identical to numpy's — that is what makes bit-equality a fair
assertion (float32 would differ by a unit here and there).
"""

import numpy as np
import pytest

from _hyp import given, settings, st

import jax
from jax.experimental import enable_x64

from repro.core import (
    BatchedSimulatedExecutor,
    ModelBank,
    PiecewiseLinearFPM,
    SimulatedExecutor,
    dfpa,
    make_hcl_time_fn_batch,
    make_hcl_time_fns,
    partition_units,
    speed_fn_2d,
    speed_fn_2d_batch,
    time_fn_2d_batch,
)
from repro.core.modelbank_jax import JaxModelBank
from repro.core.partition import _partition_units_bank, _prep_unit_caps
from repro.core.partition2d import bank_repartition_2d
from repro.runtime.balance import BalanceController

K_PAD = 8  # pad every jax bank to one width -> one jit compile per p

# Bit-equality with numpy relies on XLA's sum-reduction order matching
# numpy's — contractually true only where both run on the same CPU FPU.  On
# accelerator backends a 1-ulp reduction difference can legitimately move a
# boundary unit, so there the parity contract relaxes to identical makespans.
BIT_EXACT = jax.default_backend() == "cpu"
cpu_bit_exact = pytest.mark.skipif(
    not BIT_EXACT, reason="bit-identical traces are a CPU-backend contract"
)


def _jax_bank(bank: ModelBank) -> JaxModelBank:
    jb = JaxModelBank.from_bank(bank)
    xs, ss = jb._padded_to(K_PAD)
    return JaxModelBank(xs=xs, ss=ss, counts=jb.counts)


# ---------------------------------------------------------------------------
# Case generation: one description drives both fuzz lanes
# ---------------------------------------------------------------------------


def _case_from_raw(rows, n, caps_frac, min_units):
    """rows: per-processor point lists (possibly empty / duplicated xs)."""
    models = [PiecewiseLinearFPM.from_points(r) for r in rows]
    return dict(models=models, n=n, caps_frac=caps_frac, min_units=min_units)


def _random_rows(rng, p, allow_empty=True):
    rows = []
    for _ in range(p):
        k = int(rng.integers(0 if allow_empty else 1, 8))
        if k == 0:
            rows.append([])
            continue
        xs = rng.uniform(1.0, 1e4, k)
        if rng.random() < 0.3:  # provoke duplicate x's (FPM replaces)
            xs = np.round(xs / 100.0) * 100.0 + 1.0
        ss = rng.uniform(0.5, 500.0, k)
        rows.append(list(zip(xs.tolist(), ss.tolist())))
    return rows


def _random_case(rng, allow_empty=True):
    p = int(rng.integers(1, 9))
    rows = _random_rows(rng, p, allow_empty=allow_empty)
    n = int(rng.integers(max(2 * p, 4), 3000))
    caps_frac = rng.uniform(0.0, 1.0, p).tolist()
    min_units = int(rng.integers(0, 3))
    return _case_from_raw(rows, n, caps_frac, min_units)


# Strategy construction parses under the no-hypothesis shim too (the shim's
# `st` yields stubs; `given` then skips the test before anything runs).
@st.composite
def _cases(draw, allow_empty=True):
    p = draw(st.integers(min_value=1, max_value=8))
    rows = []
    for _ in range(p):
        k = draw(st.integers(min_value=0 if allow_empty else 1, max_value=7))
        pts = draw(
            st.lists(
                st.tuples(
                    st.floats(min_value=1.0, max_value=1e4,
                              allow_nan=False, allow_infinity=False),
                    st.floats(min_value=0.5, max_value=500.0,
                              allow_nan=False, allow_infinity=False),
                ),
                min_size=k,
                max_size=k,
            )
        )
        rows.append(pts)
    n = draw(st.integers(min_value=max(2 * p, 4), max_value=3000))
    caps_frac = draw(
        st.lists(st.floats(min_value=0.0, max_value=1.0),
                 min_size=p, max_size=p)
    )
    min_units = draw(st.integers(min_value=0, max_value=2))
    return _case_from_raw(rows, n, caps_frac, min_units)


# ---------------------------------------------------------------------------
# Property 1: model queries — scalar vs numpy bank vs jax bank
# ---------------------------------------------------------------------------


def _check_query_parity(case, rng):
    models = case["models"]
    p = len(models)
    bank = ModelBank.from_models(models)
    x = rng.uniform(0.0, 2e4, p)
    t = float(rng.uniform(1e-3, 100.0))
    caps = rng.uniform(0.0, 1e4, p)
    caps[rng.random(p) < 0.15] = 0.0  # zero caps -> zero allocation

    s_np, t_np = bank.speed(x), bank.time(x)
    a_np = bank.alloc_at_time(t, caps)
    with enable_x64():
        jb = _jax_bank(bank)
        s_jx = np.asarray(jb.speed(x))
        t_jx = np.asarray(jb.time(x))
        a_jx = np.asarray(jb.alloc_at_time(t, caps))

    # numpy vs jax: bit-identical on CPU, tight allclose elsewhere; NaN
    # pattern (empty rows) must agree either way
    if BIT_EXACT:
        assert np.array_equal(s_np, s_jx, equal_nan=True)
        assert np.array_equal(t_np, t_jx, equal_nan=True)
        assert np.array_equal(a_np, a_jx)
    else:
        assert np.allclose(s_np, s_jx, rtol=1e-12, equal_nan=True)
        assert np.allclose(t_np, t_jx, rtol=1e-12, equal_nan=True)
        assert np.allclose(a_np, a_jx, rtol=1e-12, atol=1e-12)

    # banks vs scalar models on non-empty rows
    for i, m in enumerate(models):
        if m.num_points == 0:
            assert np.isnan(s_np[i])
            assert a_np[i] == 0.0
            continue
        assert s_np[i] == m.speed(float(x[i]))
        assert t_np[i] == m.time(float(x[i]))
        assert a_np[i] == pytest.approx(m.alloc_at_time(t, float(caps[i])), rel=1e-10, abs=1e-10)


@pytest.mark.slow
def test_query_parity_fuzz_numpy_lane():
    rng = np.random.default_rng(101)
    for _ in range(200):
        _check_query_parity(_random_case(rng), rng)


@pytest.mark.slow
@given(case=_cases())
@settings(max_examples=200, deadline=None)
def test_query_parity_fuzz_hypothesis(case):
    _check_query_parity(case, np.random.default_rng(0))


# ---------------------------------------------------------------------------
# Property 2: partition_units — identical makespans on all three paths
# ---------------------------------------------------------------------------


def _makespan(models, d):
    return max(m.time(float(di)) for m, di in zip(models, d))


def _check_partition_parity(case):
    models = [m for m in case["models"] if m.num_points > 0]
    p = len(models)
    if p == 0:
        return
    n, min_units = case["n"], min(case["min_units"], case["n"] // max(p, 1))
    lo = max(1, min_units)
    caps = [lo + int(f * n) for f in case["caps_frac"][:p]]
    if sum(c if c < n else n for c in caps) < n:
        return  # infeasible caps are property 3's subject
    bank = ModelBank.from_models(models)

    d_scalar = partition_units(models, n, caps, min_units=min_units, vectorize=False)
    d_bank = partition_units(bank, n, caps, min_units=min_units)
    with enable_x64():
        d_jax = partition_units(_jax_bank(bank), n, caps, min_units=min_units, backend="jax")

    for d in (d_scalar, d_bank, d_jax):
        assert sum(d) == n
        assert all(min_units <= di <= ci for di, ci in zip(d, caps))
    # numpy bank vs jax bank: bit-identical allocations (CPU contract; on
    # accelerators the makespan assertion below is the binding one)
    if BIT_EXACT:
        assert d_bank == d_jax
    # all three: identical makespans (tie-breaks may differ, the metric not)
    ms = [_makespan(models, d) for d in (d_scalar, d_bank, d_jax)]
    assert max(ms) - min(ms) <= 1e-9 * max(ms)

    # fourth path: the threshold-count completion on monotone banks (auto
    # routing demotes the rest — tests/test_completion.py proves that),
    # checked against the FORCED per-unit greedy so the comparison stays
    # fast-vs-exact even though "auto" (used by d_bank above) already picks
    # the threshold path here.  Makespans must be bit-identical.
    if bank.is_monotone():
        icaps = list(_prep_unit_caps(p, n, caps, min_units))
        d_thr, _ = _partition_units_bank(
            bank, n, icaps, min_units=min_units, completion="threshold"
        )
        d_greedy, _ = _partition_units_bank(
            bank, n, icaps, min_units=min_units, completion="greedy"
        )
        with enable_x64():
            d_thr_jax = _jax_bank(bank).partition_units(
                n, caps, min_units=min_units, completion="threshold"
            )
        assert sum(d_thr) == n
        assert all(min_units <= di <= ci for di, ci in zip(d_thr, caps))
        assert _makespan(models, d_thr) == _makespan(models, d_greedy)
        if BIT_EXACT:
            assert d_thr == d_greedy == d_bank
            assert list(map(int, d_thr_jax)) == d_thr


@pytest.mark.slow
def test_partition_parity_fuzz_numpy_lane():
    rng = np.random.default_rng(202)
    for _ in range(200):
        _check_partition_parity(_random_case(rng, allow_empty=False))


@pytest.mark.slow
@given(case=_cases(allow_empty=False))
@settings(max_examples=200, deadline=None)
def test_partition_parity_fuzz_hypothesis(case):
    _check_partition_parity(case)


# ---------------------------------------------------------------------------
# Property 3: infeasible inputs raise the same ValueError on all three paths
# ---------------------------------------------------------------------------


def _check_infeasible_parity(case):
    models = [m for m in case["models"] if m.num_points > 0]
    p = len(models)
    if p == 0:
        return
    bank = ModelBank.from_models(models)
    n = case["n"]

    variants = [
        # min_units * p > n (sum of mins exceeds the total)
        dict(n=p * 2 - 1, caps=None, min_units=2),
        # some cap below min_units (the silent-shortfall regression)
        dict(n=n, caps=[0] + [n] * (p - 1), min_units=1),
        # sum(caps) < n
        dict(n=n, caps=[max(n // (2 * p) - 1, 0)] * p, min_units=0),
    ]
    for kw in variants:
        for path_kw, src in (
            (dict(vectorize=False), models),
            (dict(), bank),
            (dict(backend="jax"), bank),
        ):
            with pytest.raises(ValueError):
                with enable_x64():
                    partition_units(src, kw["n"], kw["caps"],
                                    min_units=kw["min_units"], **path_kw)


@pytest.mark.slow
def test_infeasible_parity_fuzz_numpy_lane():
    rng = np.random.default_rng(303)
    for _ in range(200):
        _check_infeasible_parity(_random_case(rng, allow_empty=False))


@pytest.mark.slow
@given(case=_cases(allow_empty=False))
@settings(max_examples=200, deadline=None)
def test_infeasible_parity_fuzz_hypothesis(case):
    _check_infeasible_parity(case)


def test_query_parity_smoke():
    rng = np.random.default_rng(111)
    for _ in range(25):
        _check_query_parity(_random_case(rng), rng)


def test_partition_parity_smoke():
    rng = np.random.default_rng(222)
    for _ in range(25):
        _check_partition_parity(_random_case(rng, allow_empty=False))


def test_infeasible_parity_smoke():
    rng = np.random.default_rng(333)
    for _ in range(10):
        _check_infeasible_parity(_random_case(rng, allow_empty=False))


def test_fold_in_parity_smoke():
    rng = np.random.default_rng(444)
    for _ in range(25):
        _check_fold_in_parity(rng)


def test_min_units_cap_shortfall_raises_on_all_paths():
    """Regression: caps[i] < min_units used to be silently absorbed by
    over-allocating the other processors; now every path refuses."""
    models = [PiecewiseLinearFPM.from_points([(10.0, 5.0), (100.0, 4.0)]) for _ in range(4)]
    bank = ModelBank.from_models(models)
    for src, kw in (
        (models, dict(vectorize=False)),
        (bank, dict()),
        (bank, dict(backend="jax")),
    ):
        with pytest.raises(ValueError, match="min_units"):
            with enable_x64():
                partition_units(src, 20, caps=[1, 20, 20, 20], min_units=2, **kw)


def test_empty_model_with_positive_cap_raises_on_bank_paths():
    models = [PiecewiseLinearFPM(), PiecewiseLinearFPM.from_points([(10.0, 5.0)])]
    bank = ModelBank.from_models(models)
    with pytest.raises(ValueError):
        partition_units(bank, 10)
    with enable_x64():
        with pytest.raises(ValueError):
            partition_units(_jax_bank(bank), 10, backend="jax")


# ---------------------------------------------------------------------------
# Property 4: fold_in == the scalar add_point update rule
# ---------------------------------------------------------------------------


def _check_fold_in_parity(rng):
    p = int(rng.integers(1, 8))
    models = [PiecewiseLinearFPM() for _ in range(p)]
    with enable_x64():
        jb = JaxModelBank.empty(p, k=2)
        for _ in range(int(rng.integers(1, 14))):
            x = np.round(rng.uniform(1, 25, p))  # small ints -> many duplicates
            s = rng.uniform(0.5, 10.0, p)
            valid = rng.random(p) > 0.25
            for i in range(p):
                if valid[i]:
                    models[i].add_point(float(x[i]), float(s[i]))
            jb = jb.fold_in(x, s, valid)
        got = jb.to_bank()
    for i in range(p):
        assert got.row(i).as_points() == models[i].as_points()


@pytest.mark.slow
def test_fold_in_parity_fuzz_numpy_lane():
    rng = np.random.default_rng(404)
    for _ in range(200):
        _check_fold_in_parity(rng)


@pytest.mark.slow
@given(seed=st.integers(min_value=0, max_value=10**6))
@settings(max_examples=200, deadline=None)
def test_fold_in_parity_fuzz_hypothesis(seed):
    _check_fold_in_parity(np.random.default_rng(seed))


# ---------------------------------------------------------------------------
# Stacked [q, p, k] bank: every column's t* bisects simultaneously
# ---------------------------------------------------------------------------


@cpu_bit_exact
def test_stacked_bank_matches_per_column():
    rng = np.random.default_rng(7)
    q, p, n = 5, 6, 400
    col_models = [
        [
            PiecewiseLinearFPM.from_points(
                sorted(zip(rng.uniform(1, 1e4, 5), rng.uniform(0.5, 500.0, 5)))
            )
            for _ in range(p)
        ]
        for _ in range(q)
    ]
    with enable_x64():
        banks = [JaxModelBank.from_models(ms) for ms in col_models]
        stacked = JaxModelBank.stack(banks)
        d_all = stacked.partition_units(n, min_units=1)
        ns = np.array([n + 37 * j for j in range(q)])
        d_var = stacked.partition_units(ns, min_units=1)
    for j in range(q):
        want = partition_units(ModelBank.from_models(col_models[j]), n, min_units=1)
        assert list(d_all[j]) == want
        want_var = partition_units(
            ModelBank.from_models(col_models[j]), int(ns[j]), min_units=1
        )
        assert list(d_var[j]) == want_var


def test_stacked_bank_rejected_by_flat_partition_api():
    """The flat List[int] API can't express [q, p] results; it must say so
    instead of crashing with an opaque conversion TypeError."""
    ms = [PiecewiseLinearFPM.from_points([(10.0, 5.0), (100.0, 4.0)])] * 3
    with enable_x64():
        stacked = JaxModelBank.stack([JaxModelBank.from_models(ms)] * 2)
        with pytest.raises(ValueError, match="stacked"):
            partition_units(stacked, 30, backend="jax")
        with pytest.raises(ValueError, match="unbatched"):
            stacked.to_bank()


def test_bank_repartition_2d_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        bank_repartition_2d([[PiecewiseLinearFPM()]], [[None]], [1], 4, backend="Jax")


@cpu_bit_exact
def test_bank_repartition_2d_matches_numpy_backend():
    rng = np.random.default_rng(11)
    p, q, M = 4, 3, 256
    specs, _ = make_hcl_time_fns(2048)
    g_batch = speed_fn_2d_batch(specs[: p * q])
    widths = [90, 80, 86]
    fpms = [[PiecewiseLinearFPM() for _ in range(q)] for _ in range(p)]
    fpm_width = [[None] * q for _ in range(p)]
    for i in range(p):
        for j in range(q):
            w = widths[j]
            for r in rng.uniform(4, M, 5):
                mb = np.full(p * q, float(r))
                nb = np.full(p * q, float(w))
                fpms[i][j].add_point(float(r), float(g_batch(mb, nb)[i * q + j]) / w)
            fpm_width[i][j] = w
    with enable_x64():
        rows_jax = bank_repartition_2d(fpms, fpm_width, widths, M, backend="jax")
    rows_np = bank_repartition_2d(fpms, fpm_width, widths, M, backend="numpy")
    assert rows_jax == rows_np
    assert all(sum(r) == M for r in rows_jax)


def test_speed_fn_2d_batch_matches_scalar():
    specs, _ = make_hcl_time_fns(2048)
    gb = speed_fn_2d_batch(specs)
    tb = time_fn_2d_batch(specs)
    P = len(specs)
    rng = np.random.default_rng(3)
    for _ in range(25):
        mb = rng.uniform(0.0, 4000.0, P)
        mb[rng.random(P) < 0.1] = 0.0
        nb = rng.uniform(1.0, 4000.0, P)
        want = [speed_fn_2d(s)(float(m), float(w)) for s, m, w in zip(specs, mb, nb)]
        np.testing.assert_allclose(gb(mb, nb), want, rtol=1e-12)
        want_t = [
            (m * w) / sv if m * w > 0 else 0.0 for m, w, sv in zip(mb, nb, want)
        ]
        np.testing.assert_allclose(tb(mb, nb), want_t, rtol=1e-12)


# ---------------------------------------------------------------------------
# End-to-end: DFPA and the BalanceController on the jax backend
# ---------------------------------------------------------------------------


@cpu_bit_exact
def test_dfpa_jax_backend_reproduces_numpy_history():
    n = 2048
    _, tb = make_hcl_time_fn_batch(n)
    p = 15

    def mk():
        return BatchedSimulatedExecutor(
            time_fn_batch=lambda r: tb(np.asarray(r, float) * n), p=p
        )

    r_np = dfpa(mk(), n, eps=0.025, min_units=1)
    with enable_x64():
        r_jx = dfpa(mk(), n, eps=0.025, min_units=1, backend="jax")
    assert r_np.d == r_jx.d
    assert r_np.iterations == r_jx.iterations
    assert [h[0] for h in r_np.history] == [h[0] for h in r_jx.history]


@cpu_bit_exact
def test_balance_controller_jax_backend_matches_numpy():
    def run(backend):
        if backend == "jax":
            with enable_x64():
                return _run(backend)
        return _run(backend)

    def _run(backend):
        ctl = BalanceController(n_units=64, num_groups=4, eps=0.05, backend=backend)
        speeds = [4.0, 4.0, 4.0, 2.0]
        trace = []
        for _ in range(6):
            times = [d / s for d, s in zip(ctl.d, speeds)]
            ctl.observe(times)
            trace.append(list(ctl.d))
        return ctl, trace

    ctl_np, trace_np = run("numpy")
    ctl_jx, trace_jx = run("jax")
    assert trace_np == trace_jx
    assert ctl_np.rebalances == ctl_jx.rebalances
    # the device snapshot agrees with the scalar models it mirrors
    with enable_x64():
        snap = ctl_jx.device_bank().to_bank()
    ref = ctl_jx.bank()
    for i in range(4):
        assert snap.row(i).as_points() == pytest.approx(ref.row(i).as_points())


def test_steady_state_carry_width_stays_bounded():
    """Regression: duplicate-x folds (a converged controller re-observing
    the same distribution every step) must not inflate the host-tracked
    count bound into endless padded-width doublings and jit recompiles."""
    with enable_x64():
        ctl = BalanceController(n_units=64, num_groups=4, eps=0.05, backend="jax")
        speeds = [4.0, 4.0, 4.0, 2.0]
        for _ in range(60):
            times = [d / s for d, s in zip(ctl.d, speeds)]
            ctl.observe(times)
        carry = ctl._carry_bank()
        true_max = int(np.asarray(carry.counts).max())
        assert int(carry.xs.shape[-1]) <= max(2 * true_max, 8)


def test_dfpa_scalar_executor_jax_backend_small():
    """Cold-start growth path: the carry's padded width doubles as rounds
    accumulate points; semantics must not change across the re-pad."""
    ex = SimulatedExecutor(
        time_fns=[lambda x: x / 100.0, lambda x: x / 40.0, lambda x: x / 10.0]
    )
    with enable_x64():
        res = dfpa(ex, 300, eps=0.02, min_units=1, backend="jax")
    assert sum(res.d) == 300
    assert res.converged
