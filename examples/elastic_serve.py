"""DFPA-balanced serving dispatch + elastic replica membership.

A fleet of heterogeneous serving replicas (nonlinear throughput vs load:
the FPM of serving).  The dispatcher's ``Scheduler`` session splits request
chunks via DFPA; a replica then joins mid-run (``join``) and the warm
session rebalances from the surviving estimates — no cold restart.  Also
runs a REAL greedy generation on the smoke model to show the engine behind
each replica.

    PYTHONPATH=src python examples/elastic_serve.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import imbalance
from repro.nn.params import init_tree
from repro.runtime.serve_loop import ReplicaDispatcher, ServeEngine
from repro.runtime.train_loop import model_spec_for

# --- 1. a real engine: prefill + greedy decode on the smoke model ---------
cfg = get_smoke_config("stablelm-12b")
params = init_tree(jax.random.PRNGKey(0), model_spec_for(cfg))
engine = ServeEngine(cfg, params, batch=2, seq_budget=48)
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
out = engine.generate(prompt, max_new=16)
print(f"engine: generated {out.shape[1]} tokens/request; sample {np.asarray(out[0][:8])}")

# --- 2. DFPA dispatch across 4 heterogeneous replicas ----------------------
rng = np.random.default_rng(0)
base = rng.uniform(2e-4, 8e-4, 5)
knee = rng.integers(20, 48, 5)


def replica_run(i, x):
    t = x * base[i]
    if x > knee[i]:
        t += (x - knee[i]) * base[i] * 4.0  # HBM-spill knee
    return t


disp = ReplicaDispatcher(replica_run, 4, eps=0.1)
res = disp.balance(96)
print(f"\n4 replicas: d={res.allocations} iters={res.iterations} imb={res.imbalance:.3f}")

# --- 3. elastic join: replica 5 arrives; warm rebalance ---------------------
sched = disp.scheduler  # the warm session autotune left behind
sched.join(1)
for _ in range(6):
    times = [replica_run(i, d) for i, d in enumerate(sched.d)]
    sched.observe(times)
times = [replica_run(i, d) for i, d in enumerate(sched.d)]
print(f"after join: d={sched.d} imb={imbalance([t for t in times if t > 0]):.3f}")
print("the newcomer was folded in from a donor estimate — no cold restart.")
