"""Roofline reporter: reads the dry-run JSONs and renders the §Roofline
table (per arch x shape, single-pod): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS, achievable-MFU bound, per-device memory, and the
what-would-move-it-down note."""

from __future__ import annotations

import glob
import io
import json
import os

PEAK = 197e12

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

_NOTES = {
    ("compute_s", "train"): "raise arithmetic intensity: fuse attention (Pallas flash kernel) and cut remat recompute via selective policies",
    ("compute_s", "prefill"): "flash-attention kernel (fused softmax) removes the quadratic-logit flops overhead",
    ("compute_s", "decode"): "batch more requests per step; absorbed/fused decode kernels",
    ("memory_s", "train"): "fuse elementwise chains (norms/gates) into matmuls; larger microbatch per device once resident allows; Pallas kernels keep working sets in VMEM",
    ("memory_s", "prefill"): "flash-attention kernel avoids writing logits to HBM — the dominant stream at 32k",
    ("memory_s", "decode"): "decode is KV-bandwidth bound by nature: quantize the cache (int8 KV) or shrink it (MLA-style latent caches)",
    ("collective_s", "train"): "overlap FSDP gathers with compute (XLA latency-hiding scheduler on TPU); cut refetch by lowering train_accum; int8 gradient compression on the DCN axis",
    ("collective_s", "prefill"): "keep heads sharded end-to-end to avoid resharding; ring-attention for the KV all-gathers",
    ("collective_s", "decode"): "seq-sharded cache psum is already minimal; co-locate sampling to avoid logit gathers",
}


def _kind(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(shape, "decode")


def roofline_table(dryrun_dir: str = "experiments/dryrun") -> str:
    recs = []
    for path in glob.glob(os.path.join(dryrun_dir, "*_single.json")):
        with open(path) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = io.StringIO()
    out.write(
        "arch,shape,status,compute_ms,memory_ms,collective_ms,dominant,"
        "useful_flops_ratio,mfu_bound,resident_GiB,fits_hbm,note\n"
    )
    for r in recs:
        if r["status"] != "ok" or "terms" not in r:
            out.write(
                f"{r['arch']},{r['shape']},{r['status']},,,,,,,,,"
                f"{r.get('reason', r.get('error', ''))[:70]}\n"
            )
            continue
        t = r["terms"]
        bound_s = max(t.values())
        mfu = r["model_flops_per_dev"] / (bound_s * PEAK) if bound_s > 0 else 0.0
        note = _NOTES.get((r["dominant"], _kind(r["shape"])), "")
        out.write(
            f"{r['arch']},{r['shape']},ok,"
            f"{t['compute_s'] * 1e3:.2f},{t['memory_s'] * 1e3:.2f},"
            f"{t['collective_s'] * 1e3:.2f},{r['dominant'].replace('_s', '')},"
            f"{r['useful_flops_ratio']:.3f},{mfu:.3f},"
            f"{r['mem']['resident_bytes'] / 2**30:.2f},{r['fits_hbm']},\"{note}\"\n"
        )
    return out.getvalue()
