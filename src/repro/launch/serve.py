"""Serving CLI: batched prefill+decode with DFPA-balanced replica dispatch.

    python -m repro.launch.serve --arch gemma2-2b --smoke --batch 4 \
        --prompt-len 32 --new-tokens 16
    python -m repro.launch.serve --arch xlstm-350m --smoke --replicas 4 \
        --chunks 64   # DFPA dispatch demo across emulated replicas
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..nn.params import init_tree
from ..runtime.serve_loop import ReplicaDispatcher, ServeEngine
from ..runtime.train_loop import model_spec_for

__all__ = ["main"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=0, help=">0: DFPA dispatch demo")
    ap.add_argument("--chunks", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encdec:
        raise SystemExit("serve CLI demonstrates decoder-only archs; see tests for enc-dec")
    params = init_tree(jax.random.PRNGKey(0), model_spec_for(cfg))
    budget = args.prompt_len + args.new_tokens
    eng = ServeEngine(cfg, params, batch=args.batch, seq_budget=budget)

    toks = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = eng.generate(toks, args.new_tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s ({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", np.asarray(out[0][:12]))

    if args.replicas > 0:
        # Heterogeneous replicas: per-chunk decode cost differs per replica
        # and bends with load (the FPM speed function of serving).
        rng = np.random.default_rng(0)
        base = rng.uniform(2e-4, 8e-4, args.replicas)
        caps = rng.integers(args.chunks // 2, args.chunks, args.replicas)

        def replica_run(i, x):
            t = x * base[i]
            if x > caps[i]:  # HBM spill: per-chunk cost grows past capacity
                t += (x - caps[i]) * base[i] * 4.0
            return t

        disp = ReplicaDispatcher(replica_run, args.replicas, eps=0.1)
        res = disp.balance(args.chunks)  # Partition, via the Scheduler facade
        print(
            f"DFPA dispatch over {args.replicas} replicas: d={res.allocations} "
            f"iters={res.iterations} imb={res.imbalance:.3f} converged={res.converged}"
        )


if __name__ == "__main__":
    main()
