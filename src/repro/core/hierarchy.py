"""Two-level hierarchical partitioning — outer solve over group aggregates,
inner per-group solves on each group's own sub-bank.

The flat partitioner is one ``O(p k)`` pass per bisection step; at p=10^4 the
stacked ``[q, p, k]`` working set falls out of CPU cache and the stacked
measurement round loses to sequential (``BENCH_fleet.json``).  The paper's
platforms are *hierarchically* heterogeneous — hosts grouped by class, groups
behind a shared interconnect — and the natural fix is the paper's own
structure:

1. **Aggregate** each group behind a composite performance model
   (``aggregate_groups`` in ``modelbank.py``): the exact
   sum-of-allocs-at-equal-time composition sampled at the union of member
   knots, a ``[g, k_g]`` bank that is monotone-time by construction.
2. **Outer solve**: the ordinary ``t*`` bisection on the group bank —
   ``O(g k_g)`` per step — then floor + take-back + the existing greedy
   tie-break over groups, so the integer group shares sum to exactly ``n``.
3. **Inner solves**: each group's share is partitioned over its members on
   the group's ``[p_g, k]`` sub-bank.  On the numpy backend this is the
   ordinary host solve per group; on the jax backend all groups run in ONE
   device program (``lax.map`` over ``[g, p_max, k]`` blocks — sequential per
   group, so each block stays cache-resident through its whole bisection);
   under ``sharding="shard_map"`` the same body runs per device over its
   local group lanes, so no single device ever materializes more than
   ``ceil(g/ndev)`` blocks of the bank (``max_shard_elems``).

Exactness tiers (asserted by ``tests/test_hierarchy.py``):

* a single group reproduces the flat solve **bit-identically** (the outer
  level degenerates to "give the one group all ``n``" and the inner solve is
  the flat kernel on the same rows);
* multiple groups reproduce the flat **makespan** to within the solver
  tolerance wherever the aggregate is exact at the solution time (between
  sampled knots the aggregate interpolates, so allocations may shift a unit
  across a boundary — never increasing the makespan beyond the interpolation
  error).

Validation raises the same ``ValueError`` messages in the same order as the
flat paths, so the ``Scheduler`` facade can route policies without changing
its error surface.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .modelbank import (
    ModelBank,
    _aggregate_one,
    _aggregate_times,
    _points_from_samples,
    group_members,
)
from .partition import (
    _partition_continuous_bank,
    _partition_units_bank,
    _prep_unit_caps,
)

try:  # telemetry is optional: the solver runs identically without repro.obs
    from ..obs.telemetry import active as _obs_active
except ImportError:  # pragma: no cover - obs layer absent
    def _obs_active():
        return None

__all__ = ["Hierarchy"]


# Compiled shard_map'd inner solvers, keyed by (device count, completion
# routing, max_steps).  Module-level so rebuilding a Hierarchy (every
# observation fold changes the banks) never retraces: jax.jit's own cache
# handles shape changes, and the mesh is built once per device count.
_SHARD_FN_CACHE: dict = {}

# Inner-solve execution routing: batched (one masked [g, ...] bisection)
# while the xs+ss block set a device touches fits comfortably in L2-ish
# cache, serial lax.map (each group's block cache-resident through its
# whole bisection) beyond that.  Bit-identical either way.
_HIER_BATCH_MAX_BYTES = 2 * 1024 * 1024

# Device aggregation materializes a [g, T, p_max, k-1] product intermediate
# (plus the [g, T, p_max] alloc cube copied back to host); route through it
# only while that stays modest.  Beyond the budget (e.g. p=10^6: several GB)
# the chunked host pass is the right tool — aggregation there runs once per
# fold and the uncapped cache serves the steady state.
_AGG_DEVICE_MAX_BYTES = 256 * 1024 * 1024


def _shard_inner_fn(ndev: int, completion_fast: bool, max_steps: int, serial: bool):
    key = (ndev, completion_fast, max_steps, serial)
    fn = _SHARD_FN_CACHE.get(key)
    if fn is None:
        from functools import partial

        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        from .modelbank_jax import _hier_inner_map

        mesh = Mesh(np.array(jax.devices()[:ndev]), ("groups",))
        spec = P("groups")
        body = partial(
            _hier_inner_map,
            rel_tol=1e-12,
            max_steps=max_steps,
            completion_fast=completion_fast,
            serial=serial,
        )
        # check_rep=False: the bisection while_loops have no replication rule
        # (jax 0.4.x); sound here because the body is collective-free — every
        # output is fully sharded along "groups", nothing is replicated.
        fn = jax.jit(
            shard_map(
                body,
                mesh=mesh,
                in_specs=(spec,) * 7,
                out_specs=(spec,) * 3,
                check_rep=False,
            )
        )
        _SHARD_FN_CACHE[key] = fn
    return fn


class Hierarchy:
    """Two-level partitioner over a ``groups[p]`` assignment.

    Build with :meth:`from_bank` (slices an existing flat bank into per-group
    sub-banks) or :meth:`from_group_banks` (the p=10^6 path: the flat
    ``[p, k]`` bank is NEVER materialized — callers hand over per-group banks
    directly and global processor indices are assigned contiguously).

    ``backend`` selects the inner solver (``"numpy"`` host loops per group,
    ``"jax"`` one ``lax.map`` device program over group blocks); ``sharding=
    "shard_map"`` (jax only) distributes the group blocks across devices.
    Instances snapshot their banks at construction — rebuild after the
    underlying models change (an observation fold), which is cheap: the jit
    caches live on module-level functions, not on the instance.
    """

    def __init__(
        self,
        sub_banks: Sequence[ModelBank],
        members: Sequence[np.ndarray],
        p: int,
        *,
        backend: str = "numpy",
        sharding: Optional[str] = None,
        max_group_knots: int = 64,
        dtype=None,
    ):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown hierarchy backend {backend!r}")
        if sharding not in (None, "shard_map"):
            raise ValueError(f"unknown sharding mode {sharding!r}")
        if sharding == "shard_map" and backend != "jax":
            raise ValueError('sharding="shard_map" requires backend="jax"')
        self.sub_banks = list(sub_banks)
        self.members = [np.asarray(m, dtype=np.int64) for m in members]
        self.p = int(p)
        self.backend = backend
        self.sharding = sharding
        self.max_group_knots = int(max_group_knots)
        self.dtype = dtype
        self._blocks = None  # device [g, p_max, k] blocks, built lazily
        self._blocks_pad = None  # shard-padded variant, keyed by ndev
        self._agg_cache: dict = {}  # caps signature -> aggregated group bank

    # -- construction --------------------------------------------------------

    @classmethod
    def from_bank(
        cls,
        bank: ModelBank,
        groups: Sequence[int],
        *,
        backend: str = "numpy",
        sharding: Optional[str] = None,
        max_group_knots: int = 64,
        dtype=None,
    ) -> "Hierarchy":
        garr = np.asarray(groups)
        if garr.ndim != 1 or garr.shape[0] != bank.p:
            raise ValueError(
                f"groups must be a length-p assignment (got shape {garr.shape} "
                f"for p={bank.p})"
            )
        _, members = group_members(groups)
        subs = [
            ModelBank(
                xs=bank.xs[idx],
                ss=bank.ss[idx],
                counts=bank.counts[idx],
                # a monotone bank has only monotone rows; a non-monotone one
                # says nothing about THIS group's rows — resolve lazily
                monotone=True if bank.monotone is True else None,
            )
            for idx in members
        ]
        return cls(
            subs,
            members,
            bank.p,
            backend=backend,
            sharding=sharding,
            max_group_knots=max_group_knots,
            dtype=dtype,
        )

    @classmethod
    def from_group_banks(
        cls,
        banks: Sequence[ModelBank],
        *,
        backend: str = "numpy",
        sharding: Optional[str] = None,
        max_group_knots: int = 64,
        dtype=None,
    ) -> "Hierarchy":
        """Build from per-group banks without ever materializing the flat
        ``[p, k]`` bank — the memory story at p=10^6, where a single flat
        float64 bank would not even allocate comfortably.  Global processor
        indices run contiguously group by group."""
        banks = list(banks)
        members: List[np.ndarray] = []
        off = 0
        for b in banks:
            members.append(np.arange(off, off + b.p, dtype=np.int64))
            off += b.p
        return cls(
            banks,
            members,
            off,
            backend=backend,
            sharding=sharding,
            max_group_knots=max_group_knots,
            dtype=dtype,
        )

    # -- shape ---------------------------------------------------------------

    @property
    def g(self) -> int:
        return len(self.sub_banks)

    def max_shard_elems(self) -> int:
        """Largest number of bank elements (xs plus ss knots) any single
        device materializes for the inner solves — the memory gate of the
        p=10^6 benchmark row.  Under ``shard_map`` each device holds only its
        ``ceil(g/ndev)`` group blocks; otherwise the one device (or host)
        holds all ``g``."""
        p_max = max((b.p for b in self.sub_banks), default=1) or 1
        k = max((int(b.xs.shape[1]) for b in self.sub_banks), default=1)
        lanes = self.g
        if self.backend == "jax" and self.sharding == "shard_map":
            import jax

            ndev = max(len(jax.devices()), 1)
            lanes = -(-self.g // ndev)
        return 2 * lanes * p_max * k

    # -- the two-level solve -------------------------------------------------

    def partition_units(
        self,
        n: int,
        caps: Optional[Sequence[int]] = None,
        *,
        min_units: int = 0,
        completion: str = "auto",
        rel_tol: float = 1e-12,
        max_steps: int = 200,
        with_t: bool = False,
    ):
        """Integer partition of ``n`` units over all ``p`` processors.

        Validation (messages and order) mirrors the flat paths exactly.
        Returns the ``[p]`` allocation list; with ``with_t=True`` returns
        ``(allocations, t_outer)`` where ``t_outer`` is the outer solve's
        equal-time point on the group aggregates.
        """
        if completion not in ("auto", "threshold", "greedy"):
            raise ValueError(f"unknown completion mode {completion!r}")
        n = int(n)
        if isinstance(caps, np.ndarray) and caps.dtype.kind in "iu":
            # vectorized mirror of _prep_unit_caps — the fleet hands the
            # per-job icaps array straight through every round, and a
            # per-element Python int() pass at p >= 10^4 would cost more
            # than the outer solve itself
            if n < 0:
                raise ValueError("n must be non-negative")
            if min_units * self.p > n:
                raise ValueError(
                    f"min_units={min_units} infeasible for n={n}, p={self.p}"
                )
            caps_arr = caps.astype(np.int64, copy=False)
            if min_units > 0:
                bad = caps_arr < min_units
                if bad.any():
                    i = int(np.argmax(bad))
                    raise ValueError(
                        f"min_units={min_units} infeasible: "
                        f"caps[{i}]={int(caps_arr[i])} < min_units"
                    )
        else:
            icaps = _prep_unit_caps(self.p, n, caps, min_units)
            caps_arr = np.asarray(icaps, dtype=np.int64)
        if self.p == 0:
            raise ValueError("no processors")
        if n == 0:
            out = [0] * self.p
            return (out, 0.0) if with_t else out
        clipped = np.minimum(caps_arr.astype(np.float64), float(n))
        if clipped.sum() < n:
            raise ValueError(f"infeasible: sum(caps)={clipped.sum()} < n={float(n)}")
        for sub, idx in zip(self.sub_banks, self.members):
            if np.any((caps_arr[idx] > 0) & (sub.counts == 0)):
                raise ValueError("empty FPM")

        tel = _obs_active()
        rec = tel is not None and tel.enabled
        if rec:
            t0 = tel.clock()
        shares, t_outer, gbank = self._outer_shares(n, caps_arr, min_units)
        if rec:
            t1 = tel.clock()
            tel.span_at("hier.outer", t0, t1, groups=self.g, n=n)

        if self.backend == "jax":
            d_full = self._inner_jax(shares, caps_arr, min_units, completion, max_steps)
        else:
            d_full = np.zeros(self.p, dtype=np.int64)
            for sub, idx, ng in zip(self.sub_banks, self.members, shares):
                if len(idx) == 0:
                    continue
                d_sub, _ = _partition_units_bank(
                    sub,
                    int(ng),
                    [int(c) for c in caps_arr[idx]],
                    min_units=min_units,
                    completion=completion,
                )
                d_full[idx] = d_sub
        if rec:
            tel.span_at("hier.inner", t1, tel.clock(),
                        groups=self.g, backend=self.backend)
        out = [int(v) for v in d_full]
        assert sum(out) == n
        return (out, float(t_outer)) if with_t else out

    def _outer_shares(
        self, n: int, caps_arr: np.ndarray, min_units: int
    ) -> Tuple[np.ndarray, float, ModelBank]:
        """Integer group shares summing to exactly ``n``: aggregate, bisect,
        floor, take back the min_units overshoot, then grant the boundary
        units between groups by the existing greedy tie-break
        ``(time(share+1), -frac_remainder, index)`` on the aggregate."""
        g = self.g
        gcaps_i = np.array(
            [caps_arr[idx].sum() for idx in self.members], dtype=np.int64
        )
        # Aggregation is the per-call tax of the two-level route; cache the
        # [g, k_g] bank on the instance.  When no member cap can bind (every
        # cap >= n, the caps=None fast path), the aggregate is computed
        # CAP-FREE so the one cached bank serves EVERY n — repeated
        # repartitions under drifting loads (the fleet serving loop) pay the
        # aggregation exactly once per fold.  Capped calls key on the exact
        # caps bytes.
        uncapped = bool(np.all(caps_arr >= n))
        key = "uncapped" if uncapped else caps_arr.tobytes()
        gbank = self._agg_cache.get(key)
        tel = _obs_active()
        if tel is not None and tel.enabled:
            tel.counter(
                "hier.agg_cache.hit" if gbank is not None
                else "hier.agg_cache.miss"
            )
        if gbank is None:
            caps_f = (
                np.full(self.p, np.inf)
                if uncapped
                else caps_arr.astype(np.float64)
            )
            gbank = ModelBank.from_point_lists(self._aggregate_pts(caps_f))
            gbank.monotone = True  # by construction: knots at sorted times
            if len(self._agg_cache) >= 8:
                self._agg_cache.clear()
            self._agg_cache[key] = gbank

        floors = np.array(
            [min_units * len(idx) for idx in self.members], dtype=np.int64
        )
        xs_list, t_outer = _partition_continuous_bank(
            gbank,
            float(n),
            [min(float(c), float(n)) for c in gcaps_i],
            rel_tol=1e-12,
            max_steps=200,
        )
        xs_g = np.asarray(xs_list, dtype=np.float64)
        shares = np.maximum(floors, np.floor(xs_g).astype(np.int64))
        shares = np.minimum(shares, gcaps_i)
        leftover = int(n - shares.sum())

        if leftover < 0:
            # min_units floors overshot: take back from the groups whose
            # aggregate per-unit time is largest, round-robin (the flat
            # take-back, at group level).
            with np.errstate(invalid="ignore"):
                per_unit = gbank.time(shares.astype(np.float64)) / np.maximum(
                    shares, 1
                )
            order = sorted(range(g), key=lambda i: per_unit[i], reverse=True)
            k = 0
            while leftover < 0:
                i = order[k % g]
                if shares[i] > floors[i]:
                    shares[i] -= 1
                    leftover += 1
                k += 1

        rem = xs_g - np.floor(xs_g)
        for _ in range(leftover):
            best_i, best_key = -1, None
            for i in range(g):
                if shares[i] + 1 > gcaps_i[i]:
                    continue
                key = (gbank.time_one(i, float(shares[i] + 1)), -float(rem[i]))
                if best_key is None or key < best_key:
                    best_i, best_key = i, key
            if best_i < 0:
                raise ValueError("caps infeasible during integer completion")
            shares[best_i] += 1
        assert int(shares.sum()) == n
        return shares, float(t_outer), gbank

    def _aggregate_pts(self, caps_f: np.ndarray) -> List[Tuple[List[float], List[float]]]:
        """Per-group aggregate knot lists, device-evaluated when cheap."""
        if self.backend == "jax":
            pts = self._aggregate_pts_device(caps_f)
            if pts is not None:
                return pts
        return [
            _aggregate_one(sub, caps_f[idx], self.max_group_knots)
            for sub, idx in zip(self.sub_banks, self.members)
        ]

    def _aggregate_pts_device(
        self, caps_f: np.ndarray
    ) -> Optional[List[Tuple[List[float], List[float]]]]:
        """Evaluate every group's member allocations in one batched
        ``[g, T, p_max]`` device program instead of g chunked numpy passes.

        The host pass materializes ~a dozen ``[T, p, k-1]`` temporaries per
        group and is memory-bandwidth bound; in the fleet steady state every
        fold widens ``k``, so by round 8 aggregation dominates the two-level
        repartition.  XLA fuses the same expression into one sweep (two
        dispatches — ``_agg_products_jit`` + ``_agg_alloc_jit`` — split so
        LLVM's FMA contraction cannot re-round the two mul-feeding-subtract
        sites).  The sample-time grid stays host-computed and the per-group
        member sum stays a host ``np.sum`` over the same axis order, so the
        aggregate bank is bit-identical to the numpy backend's.  Returns
        None — caller falls back to the chunked host loop — when blocks are
        float32 (aggregation stays float64) or the device intermediates
        would be large: at p=10^6 they reach GBs, and the once-per-fold host
        pass with 1 MB chunks is the right tool there.
        """
        ts_list = [
            _aggregate_times(sub, caps_f[idx], self.max_group_knots)
            for sub, idx in zip(self.sub_banks, self.members)
        ]
        t_max = max((int(t.size) for t in ts_list), default=0)
        if t_max == 0:
            return [([], []) for _ in ts_list]
        xs_b, ss_b, counts_b = self._ensure_blocks()
        if xs_b.dtype != np.float64:
            return None
        p_max = int(xs_b.shape[1])
        k_b = int(xs_b.shape[2])
        # the [g, T, p, k-1] t*m product is the largest device intermediate
        if self.g * t_max * p_max * max(k_b - 1, 1) * 8 > _AGG_DEVICE_MAX_BYTES:
            return None
        import jax.numpy as jnp

        from .modelbank_jax import _agg_alloc

        ts_pad = np.ones((self.g, t_max), dtype=np.float64)
        caps_pad = np.zeros((self.g, p_max), dtype=np.float64)
        for gi, (t, idx) in enumerate(zip(ts_list, self.members)):
            if t.size:
                # pad by repeating the last sample: evaluated, then sliced
                # away before the member sum
                ts_pad[gi, : t.size] = t
                ts_pad[gi, t.size :] = t[-1]
            caps_pad[gi, : len(idx)] = caps_f[idx]
        out = np.asarray(
            _agg_alloc(
                xs_b, ss_b, counts_b, jnp.asarray(caps_pad), jnp.asarray(ts_pad)
            )
        )
        pts: List[Tuple[List[float], List[float]]] = []
        for gi, (t, idx) in enumerate(zip(ts_list, self.members)):
            if t.size == 0:
                pts.append(([], []))
                continue
            xs_g = out[gi, : t.size, : len(idx)].sum(axis=1)
            pts.append(_points_from_samples(t, xs_g))
        return pts

    # -- jax inner solves ----------------------------------------------------

    def _ensure_blocks(self):
        if self._blocks is None:
            import jax.numpy as jnp

            g = self.g
            p_max = max((b.p for b in self.sub_banks), default=0) or 1
            k = max((int(b.xs.shape[1]) for b in self.sub_banks), default=1)
            xs = np.zeros((g, p_max, k), dtype=np.float64)
            ss = np.zeros_like(xs)
            counts = np.zeros((g, p_max), dtype=np.int64)
            for gi, b in enumerate(self.sub_banks):
                pg, kb = b.xs.shape
                if pg == 0:
                    continue
                xs[gi, :pg, :kb] = b.xs
                ss[gi, :pg, :kb] = b.ss
                if kb < k:
                    # width padding repeats the last column, the
                    # from_point_lists convention (masked by counts anyway)
                    xs[gi, :pg, kb:] = b.xs[:, -1:]
                    ss[gi, :pg, kb:] = b.ss[:, -1:]
                counts[gi, :pg] = b.counts
            self._blocks = (
                jnp.asarray(xs, dtype=self.dtype),
                jnp.asarray(ss, dtype=self.dtype),
                jnp.asarray(counts),
            )
        return self._blocks

    def _padded_blocks(self, ndev: int):
        """Group blocks with ``g`` padded up to a multiple of ``ndev`` by
        inert zero lanes (counts 0 — their caps/shares are zeroed by the
        caller), so shard_map's even split always applies."""
        xs, ss, counts = self._ensure_blocks()
        g = int(counts.shape[0])
        pad = (-g) % ndev
        if pad == 0:
            return xs, ss, counts, 0
        if self._blocks_pad is None or self._blocks_pad[0] != ndev:
            import jax.numpy as jnp

            zf = jnp.zeros((pad,) + tuple(xs.shape[1:]), dtype=xs.dtype)
            zc = jnp.zeros((pad,) + tuple(counts.shape[1:]), dtype=counts.dtype)
            self._blocks_pad = (
                ndev,
                jnp.concatenate([xs, zf]),
                jnp.concatenate([ss, zf]),
                jnp.concatenate([counts, zc]),
            )
        _, xs_p, ss_p, counts_p = self._blocks_pad
        return xs_p, ss_p, counts_p, pad

    def _inner_jax(
        self,
        shares: np.ndarray,
        caps_arr: np.ndarray,
        min_units: int,
        completion: str,
        max_steps: int,
    ) -> np.ndarray:
        import jax.numpy as jnp

        from .modelbank_jax import _hier_inner_jit

        g = self.g
        p_max = max((b.p for b in self.sub_banks), default=0) or 1
        caps_blk = np.zeros((g, p_max), dtype=np.int64)
        mu_blk = np.zeros((g, p_max), dtype=np.int64)  # 0 pins padded rows
        for gi, idx in enumerate(self.members):
            caps_blk[gi, : len(idx)] = caps_arr[idx]
            mu_blk[gi, : len(idx)] = min_units
        if completion == "threshold":
            fast = np.ones(g, dtype=bool)
        elif completion == "greedy":
            fast = np.zeros(g, dtype=bool)
        else:
            # per-group auto routing: an adversarial non-monotone group
            # demotes only its own inner solve (host flags, cached per sub)
            fast = np.array([b.is_monotone() for b in self.sub_banks], dtype=bool)
        cf = bool(fast.any())
        n_blk = np.asarray(shares, dtype=np.int64)

        itemsize = np.dtype(self.dtype).itemsize if self.dtype else 8
        if self.sharding == "shard_map":
            import jax

            ndev = max(len(jax.devices()), 1)
            xs, ss, counts, pad = self._padded_blocks(ndev)
            # route by the block bytes a single DEVICE touches
            local_bytes = 2 * int(xs.size) * itemsize // ndev
            serial = local_bytes > _HIER_BATCH_MAX_BYTES
            if pad:
                zrow = np.zeros((pad, p_max), dtype=np.int64)
                caps_blk = np.concatenate([caps_blk, zrow])
                mu_blk = np.concatenate([mu_blk, zrow])
                n_blk = np.concatenate([n_blk, np.zeros(pad, dtype=np.int64)])
                fast = np.concatenate([fast, np.zeros(pad, dtype=bool)])
            fn = _shard_inner_fn(ndev, cf, max_steps, serial)
            d, ok, _t = fn(
                xs,
                ss,
                counts,
                jnp.asarray(caps_blk, counts.dtype),
                jnp.asarray(n_blk),
                jnp.asarray(mu_blk, counts.dtype),
                jnp.asarray(fast),
            )
            d = np.asarray(d)[:g]
            ok = np.asarray(ok)[:g]
        else:
            xs, ss, counts = self._ensure_blocks()
            d, ok, _t = _hier_inner_jit(
                xs,
                ss,
                counts,
                jnp.asarray(caps_blk, counts.dtype),
                jnp.asarray(n_blk),
                jnp.asarray(mu_blk, counts.dtype),
                jnp.asarray(fast),
                rel_tol=1e-12,
                max_steps=max_steps,
                completion_fast=cf,
                serial=2 * int(xs.size) * itemsize > _HIER_BATCH_MAX_BYTES,
            )
            d = np.asarray(d)
            ok = np.asarray(ok)
        if not bool(np.all(ok)):
            raise ValueError("caps infeasible during integer completion")
        d_full = np.zeros(self.p, dtype=np.int64)
        for gi, idx in enumerate(self.members):
            d_full[idx] = d[gi, : len(idx)]
        return d_full
