"""Heterogeneous-cluster simulator calibrated to the paper's experiments.

The paper's speed nonlinearity has three regimes (its Fig. 3 / Fig. 5):

  * cache region   — small working sets fit L2 -> speed boost;
  * memory plateau — the CPM regime, speed ~ constant;
  * paging cliff   — footprint exceeds RAM -> speed collapses.

For the paper's 1-D matmul kernel (update of an ``n_b x n`` panel,
``x = n_b * n`` computation units) the per-processor footprint is
``8*(2*x + n^2)`` bytes (its own A/C slices + the whole of B), so the paging
threshold *in units* depends on the matrix size ``n`` — exactly why nodes
hcl06/hcl08 (256 MB) paged at n=5120 in the paper while 1 GB nodes did not.

Speeds are calibrated from the paper's measured Mflop/s list for the HCL
cluster (§3.1: {658, 667, ..., 695} for n_b=20, n=2048; 1 unit = 1 add + 1 mul
= 2 flops) and RAM/L2 sizes from Table 1.  The simulator reproduces the
paper's *phenomena* (iteration counts, cost ratios, paging-borderline
convergence); absolute seconds are the same order as the paper's tables.

TPU mapping note: this same machinery doubles as the *group-speed* simulator
for heterogeneous TPU fleets — ``make_tpu_group_time_fns`` models
mixed-generation slices where the "paging cliff" is the HBM-spill point past
a per-group microbatch count (remat/offload engaged).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = [
    "NodeSpec",
    "speed_fn_1d",
    "time_fn_1d",
    "speed_fn_1d_batch",
    "time_fn_1d_batch",
    "speed_fn_2d",
    "speed_fn_2d_batch",
    "time_fn_2d_batch",
    "HCL_SPECS",
    "make_hcl_time_fns",
    "make_hcl_time_fn_batch",
    "make_grid5000_specs",
    "make_grid5000_time_fns",
    "make_tpu_group_time_fns",
    "matmul_app_time_1d",
    "full_model_build_cost",
]

MB = 1 << 20
GB = 1 << 30


@dataclass(frozen=True)
class NodeSpec:
    """One heterogeneous node.

    ``s_mem`` — plateau (main-memory) speed in units/s (1 unit = 1 add + 1 mul);
    ``cache_boost`` — multiplier when the working set fits L2 (the paper's
    quoted Mflop/s were measured at n_b=20, n=2048 — a cache-resident working
    set — so the plateau is calibrated as measured/boost);
    ``disk_factor`` — how much slower a paged-out access is than a resident one
    (disk vs RAM); drives the thrashing collapse via a miss-fraction model;
    ``anisotropy`` — 2-D kernels: mild dependence on the panel aspect ratio.
    """

    name: str
    s_mem: float
    l2_bytes: int
    ram_bytes: int
    os_bytes: int = 48 * MB
    cache_boost: float = 1.65
    disk_factor: float = 300.0
    anisotropy: float = 0.0


def speed_fn_1d(spec: NodeSpec, n: int) -> Callable[[float], float]:
    """Ground-truth speed s(x) [units/s] for the 1-D kernel at matrix size n.

    Smooth, strictly positive, monotonically non-increasing — satisfies the
    shape restrictions of [16], so the paper's convergence proposition applies.
    """
    # Cache region: A_b/C_b rows stream; boost while 16*x <= L2.
    x_cache = max(spec.l2_bytes / 16.0, 1.0)
    # Paging threshold in units: 8*(2*x + n^2) + OS <= RAM.
    avail = spec.ram_bytes - spec.os_bytes - 8.0 * n * n
    x_page = max(avail / 16.0, 1.0)  # <=1 -> node pages from the first unit
    x_ref = spec.ram_bytes / 16.0  # working set that would fill RAM

    def s(x: float) -> float:
        if x <= 0:
            return spec.s_mem * spec.cache_boost
        # cache boost, linearly fading to 1.0 over [x_cache, 3*x_cache]
        if x <= x_cache:
            boost = spec.cache_boost
        elif x <= 3.0 * x_cache:
            w = (x - x_cache) / (2.0 * x_cache)
            boost = spec.cache_boost + w * (1.0 - spec.cache_boost)
        else:
            boost = 1.0
        base = spec.s_mem * boost
        if x > x_page:
            # Thrashing: the overflow fraction of the working set misses to
            # disk; each missed access costs disk_factor resident accesses.
            z = (x - x_page) / x_ref
            miss = z / (1.0 + z)  # in [0, 1)
            base = base / (1.0 + (spec.disk_factor - 1.0) * miss)
        return base

    return s


def time_fn_1d(spec: NodeSpec, n: int) -> Callable[[float], float]:
    s = speed_fn_1d(spec, n)
    return lambda x: (x / s(x)) if x > 0 else 0.0


def speed_fn_1d_batch(specs: Sequence[NodeSpec], n: int) -> Callable[["object"], "object"]:
    """Batched ground truth: one vector call evaluates ``s_i(x_i)`` for the
    WHOLE fleet — the simulator-side analogue of ``ModelBank`` (needed so the
    scaling benchmark and the batched executor are not bottlenecked on ``p``
    Python calls per round).  Elementwise identical to ``speed_fn_1d``.
    """
    import numpy as np

    s_mem = np.array([s.s_mem for s in specs])
    boost0 = np.array([s.cache_boost for s in specs])
    disk = np.array([s.disk_factor for s in specs])
    x_cache = np.maximum(np.array([s.l2_bytes for s in specs]) / 16.0, 1.0)
    avail = np.array([s.ram_bytes - s.os_bytes for s in specs]) - 8.0 * n * n
    x_page = np.maximum(avail / 16.0, 1.0)
    x_ref = np.array([s.ram_bytes for s in specs]) / 16.0

    def s(x):
        x = np.asarray(x, dtype=np.float64)
        w = np.clip((x - x_cache) / (2.0 * x_cache), 0.0, 1.0)
        boost = boost0 + w * (1.0 - boost0)
        boost = np.where(x <= 0, boost0, boost)
        base = s_mem * boost
        z = np.maximum(x - x_page, 0.0) / x_ref
        miss = z / (1.0 + z)
        return base / (1.0 + (disk - 1.0) * miss)

    return s


def time_fn_1d_batch(specs: Sequence[NodeSpec], n: int) -> Callable[["object"], "object"]:
    """Batched ``t_i(x_i) = x_i / s_i(x_i)`` (0 where ``x_i <= 0``)."""
    import numpy as np

    s = speed_fn_1d_batch(specs, n)

    def t(x):
        x = np.asarray(x, dtype=np.float64)
        return np.where(x > 0, x / s(x), 0.0)

    return t


def speed_fn_2d(spec: NodeSpec, b: int = 32) -> Callable[[float, float], float]:
    """2-D kernel speed g(m_b, n_b) [units/s], unit = b x b block mult-add.

    Footprint ~ 8*b^2*(m_b*n_b + m_b + n_b); mild anisotropy makes the speed
    depend on the aspect ratio (the paper's Fig. 5(b) relative-speed surface).
    """
    flops_per_unit = 2.0 * b * b * b  # one b x b block multiply-accumulate
    s_units = spec.s_mem * 2.0 / flops_per_unit * (b * b)  # rescale: keep
    # plateau speed comparable in "block units"/s given s_mem in scalar units/s.
    avail = spec.ram_bytes - spec.os_bytes
    units_page = max(avail / (24.0 * b * b), 1.0)
    units_ref = spec.ram_bytes / (24.0 * b * b)
    x_cache = max(spec.l2_bytes / (24.0 * b * b), 1.0)

    def g(mb: float, nb: float) -> float:
        u = mb * nb
        if u <= 0:
            return s_units * spec.cache_boost
        if u <= x_cache:
            boost = spec.cache_boost
        elif u <= 3.0 * x_cache:
            w = (u - x_cache) / (2.0 * x_cache)
            boost = spec.cache_boost + w * (1.0 - spec.cache_boost)
        else:
            boost = 1.0
        base = s_units * boost
        if u > units_page:
            z = (u - units_page) / units_ref
            miss = z / (1.0 + z)
            base = base / (1.0 + (spec.disk_factor - 1.0) * miss)
        if spec.anisotropy:
            aspect = nb / (mb + nb)  # in (0, 1)
            base *= 1.0 + spec.anisotropy * (aspect - 0.5)
        return base

    return g


def speed_fn_2d_batch(
    specs: Sequence[NodeSpec], b: int = 32
) -> Callable[["object", "object"], "object"]:
    """Batched 2-D ground truth: ``g_i(mb_i, nb_i)`` for the WHOLE grid in one
    vector call — the simulator-side prerequisite of the ``[q, p, k]``
    stacked-bank partitioner (a ``p x q`` grid flattens to one spec list).
    Elementwise identical to :func:`speed_fn_2d`.
    """
    import numpy as np

    flops_per_unit = 2.0 * b * b * b
    s_units = np.array([s.s_mem for s in specs]) * 2.0 / flops_per_unit * (b * b)
    boost0 = np.array([s.cache_boost for s in specs])
    disk = np.array([s.disk_factor for s in specs])
    aniso = np.array([s.anisotropy for s in specs])
    avail = np.array([s.ram_bytes - s.os_bytes for s in specs])
    units_page = np.maximum(avail / (24.0 * b * b), 1.0)
    units_ref = np.array([s.ram_bytes for s in specs]) / (24.0 * b * b)
    x_cache = np.maximum(np.array([s.l2_bytes for s in specs]) / (24.0 * b * b), 1.0)

    def g(mb, nb):
        mb = np.asarray(mb, dtype=np.float64)
        nb = np.asarray(nb, dtype=np.float64)
        u = mb * nb
        w = np.clip((u - x_cache) / (2.0 * x_cache), 0.0, 1.0)
        boost = boost0 + w * (1.0 - boost0)
        base = s_units * boost
        z = np.maximum(u - units_page, 0.0) / units_ref
        miss = z / (1.0 + z)
        base = base / (1.0 + (disk - 1.0) * miss)
        denom = np.where(mb + nb > 0.0, mb + nb, 1.0)
        aspect = nb / denom
        base = np.where(aniso != 0.0, base * (1.0 + aniso * (aspect - 0.5)), base)
        return np.where(u <= 0.0, s_units * boost0, base)

    return g


def time_fn_2d_batch(
    specs: Sequence[NodeSpec], b: int = 32
) -> Callable[["object", "object"], "object"]:
    """Batched ``t_i(mb_i, nb_i) = mb_i * nb_i / g_i(mb_i, nb_i)`` (0 where
    the block is empty)."""
    import numpy as np

    g = speed_fn_2d_batch(specs, b)

    def t(mb, nb):
        mb = np.asarray(mb, dtype=np.float64)
        nb = np.asarray(nb, dtype=np.float64)
        u = mb * nb
        return np.where(u > 0.0, u / g(mb, nb), 0.0)

    return t


# --------------------------------------------------------------------------
# Calibrated clusters
# --------------------------------------------------------------------------

# Paper §3.1 measured speeds (Mflop/s, n_b=20, n=2048) for hcl01..hcl16.
_HCL_MFLOPS = [658, 667, 648, 644, 570, 503, 583, 581, 611, 628, 567, 601, 338, 651, 554, 695]
_HCL_RAM = [1 * GB] * 4 + [256 * MB, 256 * MB, 256 * MB, 256 * MB, 1 * GB, 1 * GB,
            512 * MB, 512 * MB, 1 * GB, 1 * GB, 1 * GB, 1 * GB]
_HCL_L2 = [1 * MB] * 4 + [2 * MB, 2 * MB, 1 * MB, 1 * MB, 1 * MB, 1 * MB,
           1 * MB, 1 * MB, 256 * 1024, 1 * MB, 1 * MB, 2 * MB]

HCL_SPECS: List[NodeSpec] = [
    NodeSpec(
        name=f"hcl{i + 1:02d}",
        # measured speeds were cache-resident -> plateau = measured / boost
        s_mem=_HCL_MFLOPS[i] * 1e6 / 2.0 / 1.65,  # units/s (unit = 2 flops)
        l2_bytes=_HCL_L2[i],
        ram_bytes=_HCL_RAM[i],
        anisotropy=0.08 * ((i % 5) - 2) / 2.0,
    )
    for i in range(16)
]


def make_hcl_time_fns(
    n: int, exclude: Sequence[str] = ("hcl07",)
) -> Tuple[List[NodeSpec], List[Callable[[float], float]]]:
    """The paper's experimental setup: 15 HCL nodes (hcl07 excluded)."""
    specs = [s for s in HCL_SPECS if s.name not in set(exclude)]
    return specs, [time_fn_1d(s, n) for s in specs]


def make_hcl_time_fn_batch(
    n: int, exclude: Sequence[str] = ("hcl07",)
) -> Tuple[List[NodeSpec], Callable[["object"], "object"]]:
    """Batched counterpart of :func:`make_hcl_time_fns`: one vector-valued
    time function for the whole cluster."""
    specs = [s for s in HCL_SPECS if s.name not in set(exclude)]
    return specs, time_fn_1d_batch(specs, n)


def make_grid5000_specs(seed: int = 5000) -> List[NodeSpec]:
    """28 nodes, 14 types x 2, heterogeneity ~2.5-2.8, large RAM (no paging
    for the paper's sizes) — the paper's Grid5000 experiment (Table 4)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    specs: List[NodeSpec] = []
    # 14 types with plateau speeds spanning ~2.7x.
    type_speeds = np.geomspace(2.2e8, 6.0e8, 14)
    type_ram = [4 * GB if i % 3 else 8 * GB for i in range(14)]
    for ty in range(14):
        for rep in range(2):
            jitter = 1.0 + 0.02 * float(rng.standard_normal())
            specs.append(
                NodeSpec(
                    name=f"g5k-{ty:02d}-{rep}",
                    s_mem=float(type_speeds[ty]) * jitter,
                    l2_bytes=2 * MB,
                    ram_bytes=type_ram[ty],
                    cache_boost=1.4,
                )
            )
    return specs


def make_grid5000_time_fns(n: int) -> Tuple[List[NodeSpec], List[Callable[[float], float]]]:
    specs = make_grid5000_specs()
    return specs, [time_fn_1d(s, n) for s in specs]


def make_tpu_group_time_fns(
    group_specs: Sequence[Tuple[float, int]],
    unit_flops: float,
    *,
    spill_penalty: float = 4.0,
) -> List[Callable[[float], float]]:
    """Per-group time functions for heterogeneous TPU fleets.

    ``group_specs[i] = (effective_tflops, hbm_microbatch_capacity)``: a group
    processes one microbatch (the DFPA computation unit) in
    ``unit_flops / tflops`` seconds on the plateau; past its HBM capacity the
    per-unit cost grows (remat/offload engaged) — the TPU analogue of paging.
    """

    def make(tflops: float, cap_units: int) -> Callable[[float], float]:
        t_unit = unit_flops / (tflops * 1e12)

        def t(x: float) -> float:
            if x <= 0:
                return 0.0
            if x <= cap_units:
                return x * t_unit
            over = x - cap_units
            return cap_units * t_unit + over * t_unit * spill_penalty

        return t

    return [make(tf, cap) for tf, cap in group_specs]


# --------------------------------------------------------------------------
# Application-level cost model (for the benchmark tables)
# --------------------------------------------------------------------------

def matmul_app_time_1d(
    time_fns: Sequence[Callable[[float], float]],
    d_rows: Sequence[int],
    n: int,
    *,
    step_overhead: float = 2.0e-3,
) -> float:
    """Full 1-D matmul app time for row distribution ``d_rows`` (rows of A/C).

    The app performs ``n`` rank-1 panel updates (k = 1..n); step k updates the
    processor's whole C slice, which is exactly the benchmark kernel — so the
    per-step cost is the slowest processor's kernel time (lockstep sweep) plus
    a per-step loop overhead.  ``time_fns`` expects *units* ``x = rows * n``.
    """
    per_step = max(tf(float(r * n)) for tf, r in zip(time_fns, d_rows))
    return n * (per_step + step_overhead)


def full_model_build_cost(
    time_fns_by_n: Callable[[int], Sequence[Callable[[float], float]]],
    n_values: Sequence[int],
    nb_fracs: Sequence[float],
) -> float:
    """Cost of building FULL functional models (the paper's 1850 s):

    every processor runs the kernel over the whole (n_b, n) grid in parallel;
    rounds are lockstep, so each grid point costs the max time across nodes.
    """
    total = 0.0
    for n in n_values:
        fns = time_fns_by_n(n)
        for frac in nb_fracs:
            nb = max(int(frac * n), 1)
            total += max(fn(float(nb * n)) for fn in fns)
    return total
