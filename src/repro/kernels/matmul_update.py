"""The paper's computational kernel, TPU-native: blocked ``C += A @ B``.

Hardware adaptation (DESIGN.md §2): the 2011 kernel is a GotoBLAS-style
cache-blocked panel update tuned for L2; the TPU equivalent tiles for VMEM
and the 128x128 MXU:

  * grid (M/bm, N/bn, K/bk), K innermost — the fp32 accumulator scratch
    lives in VMEM across the K sweep (no HBM round-trips for partials);
  * blocks default to 256x256x512 — MXU-aligned (multiples of 128), working
    set (bm*bk + bk*bn + 2*bm*bn fp32) ~ 0.9 MB << 16 MB VMEM, wide enough
    to amortize HBM latency;
  * ``C`` is aliased input->output (a true += update, like the paper's).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["matmul_update_pallas"]


def _kernel(c_in_ref, a_ref, b_ref, c_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_in_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def matmul_update_pallas(
    c: jax.Array,  # (M, N)
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and c.shape == (M, N)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"shape ({M},{N},{K}) not divisible by blocks ({bm},{bn},{bk})")
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # C in
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # B
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        input_output_aliases={0: 0},
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(c, a, b)
