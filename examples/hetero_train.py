"""Heterogeneous LM training with ONLINE DFPA rebalancing + straggler
detection + an elastic group loss — the framework's production story in
miniature (real jit'd training steps; group heterogeneity emulated by
deterministic per-group slowdowns).

One ``Scheduler`` session is the whole control plane: ``observe`` folds
step times into the models and repartitions past ``eps``,
``straggler_actions`` flags and reprofiles unhealthy groups, and ``leave``
handles the elastic departure with a warm re-partition.

    PYTHONPATH=src python examples/hetero_train.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import Scheduler
from repro.data import SyntheticLMData, UnitBatcher
from repro.optim.schedule import warmup_cosine
from repro.runtime.straggler import StragglerAction, StragglerDetector
from repro.runtime.train_loop import init_train_state, make_train_step

CFG = get_smoke_config("granite-20b")
GROUPS, UNITS, STEPS = 4, 16, 14
HETERO = [1.0, 1.3, 2.0, 3.5]  # per-group slowdown factors (unknown to DFPA)

state = init_train_state(CFG, jax.random.PRNGKey(0))
sched = warmup_cosine(3e-3, 2, STEPS)
data = SyntheticLMData(CFG, batch=2, seq=32)
batcher = UnitBatcher(data, micro_batch=2)
ctrl = Scheduler(
    n_units=UNITS, num_groups=GROUPS, eps=0.15, min_units=1, smooth=1.0,
    detector=StragglerDetector(factor=1.6, patience=2, patience_hard=5),
)
step_fns = {}

print(f"groups={GROUPS} hetero={HETERO} units/step={UNITS}")
for step in range(STEPS):
    if step == 9:  # elastic event: group 3 (slowest) leaves the fleet
        ctrl.leave(3)
        HETERO = HETERO[:3]
        print(">>> elastic: group 3 left; warm-started DFPA re-partition")
    units = batcher.global_step_units(ctrl.n_units, step)
    parts = batcher.split(units, ctrl.d)
    times, loss = [], float("nan")
    for g, part in enumerate(parts):
        a = ctrl.d[g]
        if a == 0:
            times.append(0.0)
            continue
        if a not in step_fns:
            step_fns[a] = jax.jit(make_train_step(CFG, sched, accum_steps=a))
        gb = {k: jnp.asarray(v) for k, v in part.items()}
        new_state, metrics = step_fns[a](state, gb)
        times.append(a * 0.01 * HETERO[g])  # emulated wall time
        if g == 0:
            state, loss = new_state, float(metrics["loss"])
    acts = ctrl.straggler_actions(times)  # REPROFILE applied automatically
    for g, act in enumerate(acts):
        if act is not StragglerAction.NONE:
            print(f"    straggler[{g}]: {act.value}")
    changed = ctrl.observe(times)
    print(
        f"step {step:2d} loss {loss:7.4f} d={ctrl.d}"
        + ("  <- rebalanced" if changed else "")
    )
print(f"\nfinal distribution {ctrl.d}")
print("slow groups ended with fewer microbatches — the paper's partitioning,")
print("driven by training-step times instead of benchmark rounds.")
