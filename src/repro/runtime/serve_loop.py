"""Serving: prefill/decode engine + DFPA-balanced request dispatch.

Serving is the second place the paper's model fits naturally: per-replica
decode throughput is a *nonlinear* function of batch size (KV-cache
bandwidth, batch-dependent kernel efficiency, HBM spill past a batch
threshold) — a speed function s(x), unknown a priori on a heterogeneous
fleet.  ``ReplicaDispatcher`` runs DFPA over request chunks.

Serving under traffic
---------------------

At serving timescales the paper's headline claim — the cost of the optimal
distribution is orders of magnitude below the execution it optimizes — only
holds if the *online lifecycle* is cheap: the warm state must survive every
epoch.  The intended loop, per traffic epoch (see
``benchmarks/serve_trace.py`` for the full harness and
``examples/serve_trace_walkthrough.py`` for a small walkthrough):

1. ``balance_fleet(tenants)`` at tenant-set changes (admit/retire ride the
   WARM fleet session — jobs, compiled stacked programs and per-lane caches
   all persist; only a backend or replica-count change pays a fresh
   session, and even then an attached registry carries the profiles over);
2. ``fleet.rebalance(loads)`` every epoch as tenant traffic drifts — one
   stacked device program, no measurement;
3. ``fleet.straggler_actions(times)`` on the epoch's measured per-replica
   times BEFORE folding them (predictions must come from the pre-epoch
   estimates) — REPROFILE re-learns a throttled replica, QUARANTINE tells
   the caller to drop it;
4. ``fleet.observe(times)`` folds the epoch's observations into the
   stacked carry (one fold-in program).

Epoch wall-clock on a time-sliced fleet is the busiest replica's SUM across
tenants (``FleetRoundLog.wall_cost``), not any single tenant's max.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from ..core.executor import Executor, FleetRoundLog, RoundLog
from ..core.scheduler import Partition, Policy, Scheduler
from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_cache, prefill

try:  # telemetry is optional: serving runs identically without repro.obs
    from ..obs.telemetry import active as _obs_active
except ImportError:  # pragma: no cover - obs layer absent
    def _obs_active():
        return None

__all__ = ["ServeEngine", "ReplicaDispatcher"]


class ServeEngine:
    """Single-replica engine: jit'd prefill + decode with a fixed KV budget."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int, seq_budget: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.seq_budget = seq_budget
        self._prefill = jax.jit(functools.partial(prefill, cfg=cfg))
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))

    def new_cache(self):
        return init_cache(self.cfg, self.batch, self.seq_budget, self.cfg.dtype)

    def generate(
        self, tokens: jax.Array, max_new: int, *, greedy: bool = True
    ) -> jax.Array:
        """tokens: (B, S_prompt) -> (B, max_new) generated ids."""
        caches = self.new_cache()
        logits, caches = self._prefill(params=self.params, tokens=tokens, caches=caches)
        out = []
        pos = tokens.shape[1]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
        for i in range(1, max_new):
            logits, caches = self._decode(
                params=self.params, token=tok, pos=jnp.asarray(pos, jnp.int32),
                caches=caches,
            )
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out.append(tok)
            pos += 1
        return jnp.concatenate(out, axis=1)


@dataclass
class ReplicaDispatcher:
    """DFPA over request chunks across heterogeneous serving replicas.

    ``replica_run(i, x)`` must process ``x`` request chunks on replica ``i``
    and return the wall time (real engines or simulators both fit).  The
    dispatcher is an ``Executor``; :meth:`balance` drives it through the
    ``Scheduler`` facade and leaves the warm session on ``self.scheduler``
    for the online lifecycle (``observe`` / ``join`` / ``leave``).

    Fleet mode (multi-tenant serving): :meth:`balance_fleet` admits one job
    per tenant request stream into a ``FleetScheduler`` — one stacked device
    bank, one partition + one fold-in program per round for ALL tenants —
    and leaves the warm fleet session on ``self.fleet`` for the online
    lifecycle (``admit`` / ``retire`` / ``resize`` / further ``step`` s).
    Repeated ``balance_fleet`` calls REUSE that warm session (new tenants
    admitted, absent ones retired, changed ``n`` resized) so the compiled
    stacked programs and per-lane caches survive; only a backend or
    replica-count change pays a fresh session.  With a ``ProfileRegistry``
    (plus ``device_classes``) and per-tenant ``workload`` tags, tenants
    warm-start from profiles saved by earlier sessions instead of paying
    cold CPM probes.
    """

    replica_run: Callable[[int, int], float]
    num_replicas: int
    eps: float = 0.1
    # The typed serving log: single-tenant rounds append RoundLog, fleet
    # rounds append FleetRoundLog — both stamped with ``clock()`` at append
    # time (``t_wall``), so post-hoc analysis can line rounds up against
    # external events without the dispatcher having run under telemetry.
    logs: List[Union[RoundLog, FleetRoundLog]] = field(default_factory=list)
    scheduler: Optional[Scheduler] = None
    fleet: object = None  # warm FleetScheduler session (balance_fleet)
    exec_host_s: float = 0.0  # host wall spent simulating/serving in run*()
    clock: Callable[[], float] = time.monotonic  # injectable log timestamper

    @property
    def num_procs(self) -> int:
        return self.num_replicas

    def run(self, d: Sequence[int]) -> List[float]:
        t0 = time.perf_counter()
        times = [
            self.replica_run(i, int(x)) if x > 0 else 0.0 for i, x in enumerate(d)
        ]
        self.exec_host_s += time.perf_counter() - t0
        self.logs.append(
            RoundLog(list(map(int, d)), times, max(times), t_wall=self.clock())
        )
        return times

    def run_jobs(self, names: Sequence[str], D):
        """FleetExecutor protocol: one multi-tenant round — every measuring
        tenant's chunks on every replica (time-sliced per replica, so each
        (tenant, replica) cell is an independent ``replica_run`` call).

        Logs ONE :class:`FleetRoundLog` for the round, costed time-sliced:
        the round's wall-clock is the busiest replica's SUM across tenants
        (each replica serves its tenants' slices back to back), with the
        per-tenant slice times kept on the log.  One ``RoundLog`` per tenant
        at ``max(times)`` each — the previous accounting — under-reported
        the round by up to q×."""
        import numpy as np

        t0 = time.perf_counter()
        out = []
        for k, _name in enumerate(names):
            out.append(
                [
                    self.replica_run(i, int(x)) if x > 0 else 0.0
                    for i, x in enumerate(D[k])
                ]
            )
        self.exec_host_s += time.perf_counter() - t0
        T = np.asarray(out, dtype=np.float64)
        busy = T.sum(axis=0) if len(out) else np.zeros(self.num_replicas)
        self.logs.append(
            FleetRoundLog(
                names=[str(nm) for nm in names],
                D=[[int(v) for v in row] for row in D],
                times=[[float(v) for v in row] for row in T],
                proc_busy=[float(v) for v in busy],
                wall_cost=float(busy.max()) if len(out) else 0.0,
                t_wall=self.clock(),
            )
        )
        tel = _obs_active()
        if tel is not None and tel.enabled and len(out):
            # Per-replica busy windows on per-replica tracks, laid out on the
            # SIMULATED serving timeline (epochs back to back) so the trace
            # viewer shows each replica's time-sliced load per epoch.
            if not hasattr(self, "_sim_t"):
                self._sim_t = tel.clock()
            t0_sim = self._sim_t
            for i, b in enumerate(busy):
                if b > 0:
                    tel.span_at("serve.replica_busy", t0_sim, t0_sim + float(b),
                                track=f"replica:{i}", tenants=len(names))
            self._sim_t = t0_sim + float(busy.max())
        return T

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times)

    def balance(self, n_chunks: int, **kw) -> Partition:
        """Find the balanced chunk distribution for this fleet (the DFPA
        measurement loop, via the facade)."""
        if self.scheduler is None:
            self.scheduler = Scheduler(policy=Policy.DFPA, eps=self.eps)
        return self.scheduler.autotune(self, n_chunks, self.eps, **kw)

    def balance_fleet(
        self,
        tenants: Dict[str, int],
        *,
        backend: str = "jax",
        registry=None,
        device_classes: Optional[Sequence[str]] = None,
        workloads: Optional[Dict[str, str]] = None,
        reserve_knots: Optional[int] = None,
        quantize: Optional[float] = None,
        staleness_tol: Optional[float] = None,
        pipeline: bool = False,
        pipeline_depth: int = 1,
        **kw,
    ) -> Dict[str, Partition]:
        """Balance every tenant's chunk stream concurrently: ``tenants``
        maps tenant name -> its chunk count ``n``; returns tenant ->
        ``Partition``.  One ``FleetScheduler`` round serves all tenants
        (see the class docstring); extra ``kw`` become per-job ``JobSpec``
        fields (``min_units``, ``max_iter``, ...).

        Repeated calls REUSE the warm session on ``self.fleet`` whenever it
        is compatible (same backend, same replica count): absent tenants are
        retired, present ones resized to the requested ``n`` (keeping their
        learned estimates — the re-run warm-starts from a repartition), new
        ones admitted.  The compiled stacked programs and per-lane caches
        survive, so a steady-state re-balance triggers ZERO new
        compilations.  Only a backend or replica-count change pays a fresh
        session — and when a registry is attached, the old session's learned
        profiles are checkpointed into it first so the fresh session
        warm-starts instead of re-probing cold.

        ``pipeline=``/``pipeline_depth=`` pick the round lifecycle (see
        "Round lifecycle: sync vs pipelined" in ``fleet/scheduler.py``);
        toggling the mode on a warm session drains the in-flight pipeline
        first, so the switch is safe mid-tenancy."""
        from ..fleet import FleetScheduler, JobSpec

        fleet = self.fleet
        warm = (
            fleet is not None
            and getattr(fleet, "num_procs", None) == self.num_replicas
            and getattr(fleet, "backend", None) == backend
        )
        if not warm:
            if fleet is not None:
                # carry what the incompatible session learned across
                reg = registry if registry is not None else fleet.registry
                if reg is not None and fleet.device_classes is not None:
                    fleet.save_profiles(reg)
            self.fleet = fleet = FleetScheduler(
                self.num_replicas,
                backend=backend,
                registry=registry,
                device_classes=device_classes,
                alpha=0.0,
                beta=0.0,
                reserve_knots=reserve_knots,
                quantize=quantize if quantize is not None else 0.0,
                staleness_tol=staleness_tol,
                pipeline=pipeline,
                pipeline_depth=pipeline_depth,
            )
        else:
            if bool(pipeline) != fleet.pipeline or int(
                pipeline_depth
            ) != fleet.pipeline_depth:
                # Mode toggles reuse the warm session: drain first so no
                # stale carry or pre-dispatched partition crosses the switch.
                if pipeline and fleet.backend == "scalar":
                    raise ValueError(
                        'pipeline=True requires a banked backend ("numpy" or "jax")'
                    )
                if pipeline_depth not in (0, 1):
                    raise ValueError("pipeline_depth must be 0 or 1")
                fleet.drain()
                fleet.pipeline = bool(pipeline)
                fleet.pipeline_depth = int(pipeline_depth)
            if quantize is not None:
                fleet.quantize = float(quantize)
            if staleness_tol is not None:
                fleet.staleness_tol = float(staleness_tol)
            if registry is not None:
                fleet.registry = registry
            if device_classes is not None:
                if len(device_classes) != self.num_replicas:
                    raise ValueError("device_classes length != num_replicas")
                fleet.device_classes = [str(c) for c in device_classes]
        current = set(fleet.jobs)
        for name in current - set(tenants):
            fleet.retire(name)
        resize_kw = {
            k: kw[k]
            for k in ("caps", "min_units", "max_iter", "probe_budget")
            if k in kw
        }
        for name, n in tenants.items():
            if name in current:
                # unconditional: reset the loop state so run() re-converges
                # this tenant from its learned estimates (bit-identical to a
                # fresh session admitted with the same models)
                fleet.resize(name, n=int(n), eps=self.eps, **resize_kw)
            else:
                fleet.admit(
                    JobSpec(
                        name=name,
                        n=int(n),
                        eps=self.eps,
                        workload=(workloads or {}).get(name),
                        **kw,
                    )
                )
        tel = _obs_active()
        rec = tel is not None and tel.enabled
        if not rec:
            return fleet.run(self)
        # The live rebalance-vs-serve wall split: everything fleet.run spends
        # outside the dispatcher's own replica_run calls is scheduling host
        # work (partition/fold/settle).  Exported per balance so a trace of
        # a serving session shows the paper's overhead ratio evolving live
        # (the canonical end-of-run "serve.rebalance_overhead_frac" gauge is
        # set by the harness from its full-session accounting).
        t0 = time.perf_counter()
        eh0 = self.exec_host_s
        out = fleet.run(self)
        total = time.perf_counter() - t0
        serve_s = self.exec_host_s - eh0
        sched_s = max(total - serve_s, 0.0)
        tel.gauge("serve.split.serve_host_s", serve_s)
        tel.gauge("serve.split.sched_host_s", sched_s)
        if serve_s > 0:
            tel.gauge("serve.split.sched_over_serve", sched_s / serve_s)
        return out
