"""2-D heterogeneous matmul partitioning (paper §3.2), end to end.

Compares the three applications of Fig. 10 on a 4x4 processor grid —
CPM (constant models), FFMPA (pre-built full models), and DFPA
(dynamically built partial models) — all through the ``Scheduler`` facade:
the same ``partition_grid(M, N)`` call, three policies.

    PYTHONPATH=src python examples/matmul_2d_dfpa.py
"""

from repro.core import (
    HCL_SPECS,
    Policy,
    Scheduler,
    app_time_2d,
    speed_fn_2d,
)

P, Q, M, N = 4, 4, 512, 512
specs = HCL_SPECS[: P * Q]
grid = [[speed_fn_2d(specs[i * Q + j]) for j in range(Q)] for i in range(P)]

cpm = Scheduler(grid=grid, policy=Policy.CPM).partition_grid(M, N)
ff = Scheduler(grid=grid, policy=Policy.FFMPA).partition_grid(M, N, eps=0.1, max_outer=50)
df = Scheduler(grid=grid, policy=Policy.GRID2D).partition_grid(M, N, eps=0.1)

t_cpm = app_time_2d(grid, cpm, K=N) + cpm.diagnostics["bench_cost"]
t_ff = app_time_2d(grid, ff, K=N)
t_df = app_time_2d(grid, df, K=N) + df.diagnostics["bench_cost"]

print(f"grid {P}x{Q}, matrix {M}x{N} (block units)")
print(f"CPM   : {t_cpm:8.2f}s   (1 benchmark round; misestimates paging nodes)")
print(f"FFMPA : {t_ff:8.2f}s   (needs pre-built full models: expensive offline)")
print(f"DFPA  : {t_df:8.2f}s   ({df.diagnostics['total_rounds']} online rounds, "
      f"{df.diagnostics['bench_cost']:.2f}s partitioning)")
print(f"\nDFPA column widths: {df.col_widths}")
for j in range(Q):
    print(f"  column {j}: rows {df.row_heights[j]}")
print(f"\nCPM is {t_cpm / t_df:.2f}x slower than DFPA (paper Fig. 10: ~1.25x;")
print("deep-paging nodes make the gap larger on this grid).")
