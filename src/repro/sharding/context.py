"""Ambient activation-sharding context.

Model code is mesh-agnostic; the launcher activates a mesh around tracing:

    with activation_sharding(mesh):
        jax.jit(train_step).lower(...)

``maybe_constrain(x, axes)`` then pins activations to the mesh (with the
same divisibility fallbacks as parameters) — the key use is SEQUENCE-SHARDED
residuals between scanned blocks (``seq_act -> model``): the remat-stored
carry of a 60-layer scan drops 16x, which is what lets the 20B+ dense
configs fit HBM at train_4k (Megatron/Ulysses-style sequence parallelism,
expressed as an XLA sharding constraint).  Without an active mesh it is an
identity — tests and single-host runs never see it.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from .rules import LOGICAL_RULES, logical_to_pspec

__all__ = ["activation_sharding", "maybe_constrain", "current_activation_mesh"]

_ACT_MESH: ContextVar[Optional[Mesh]] = ContextVar("repro_activation_mesh", default=None)

# Activation-specific logical axes.
ACT_RULES = dict(LOGICAL_RULES)
ACT_RULES.update({
    "seq_act": ("model",),  # sequence-sharded residual stream between blocks
    "embed_act": (),
})


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh]):
    tok = _ACT_MESH.set(mesh)
    try:
        yield
    finally:
        _ACT_MESH.reset(tok)


def current_activation_mesh() -> Optional[Mesh]:
    return _ACT_MESH.get()


def maybe_constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    mesh = _ACT_MESH.get()
    if mesh is None:
        return x
    ps = logical_to_pspec(axes, mesh, x.shape, rules=ACT_RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))
