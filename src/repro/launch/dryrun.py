import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(...).compile()`` on 512 placeholder CPU devices runs
the full SPMD partitioner; sharding mismatches, compile-time OOMs and
unsupported collectives all surface here.  The compiled artifact yields the
roofline terms (EXPERIMENTS.md §Roofline):

    compute_s    = HLO flops per device / 197e12      (v5e bf16 peak)
    memory_s     = HLO bytes per device / 819e9       (HBM bandwidth)
    collective_s = collective bytes (from the partitioned HLO) / 50e9 (ICI)

Usage:
    python -m repro.launch.dryrun --arch all --shape all --mesh both \
        --out experiments/dryrun
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, ShapeSpec, get_config, shape_applicable
from ..models import encdec as ED
from ..models import transformer as T
from ..models.config import ModelConfig
from ..nn.params import ParamSpec, param_count
from ..optim import AdamWState
from ..optim.schedule import warmup_cosine
from ..runtime.train_loop import TrainState, make_train_step, model_spec_for
from ..sharding import activation_sharding, logical_to_pspec, shardings_for_axes
from ..sharding.context import ACT_RULES
from .mesh import HW, make_production_mesh

_IS_SPEC = lambda x: isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (no allocation anywhere)
# ---------------------------------------------------------------------------


def _sds(shape, dtype, mesh, axes) -> jax.ShapeDtypeStruct:
    ps = logical_to_pspec(axes, mesh, shape, rules=ACT_RULES)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, ps))


def param_sds(cfg: ModelConfig, mesh, dtype=None):
    spec = model_spec_for(cfg)

    def one(l: ParamSpec):
        return _sds(l.shape, dtype or l.dtype, mesh, l.axes)

    return jax.tree_util.tree_map(one, spec, is_leaf=_IS_SPEC)


def state_sds(cfg: ModelConfig, mesh, *, moment_dtype=None) -> TrainState:
    p = param_sds(cfg, mesh)
    m = param_sds(cfg, mesh, dtype=moment_dtype) if moment_dtype else p
    scalar = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return TrainState(
        params=p,
        opt=AdamWState(mu=m, nu=m, count=scalar),
        step=scalar,
    )


def cache_sds(cfg: ModelConfig, mesh, batch: int, seq_budget: int):
    if cfg.is_encdec:
        shapes = jax.eval_shape(
            lambda: ED.init_encdec_cache(cfg, batch, seq_budget, seq_budget, cfg.dtype)
        )
        ax_attn = {"k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
                   "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
                   "pos": ("layers", "seq")}
        axes = {
            "units": tuple(ax_attn for _ in cfg.pattern),
            "cross_kv": tuple(
                (("layers", "batch", "seq", "kv_heads", "head_dim"),) * 2
                for _ in cfg.pattern
            ),
        }
    else:
        shapes = jax.eval_shape(lambda: T.init_cache(cfg, batch, seq_budget, cfg.dtype))
        axes = T.cache_axes(cfg)

    def one(s, a):
        return _sds(s.shape, s.dtype, mesh, a)

    return jax.tree_util.tree_map(
        one, shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda b, s: _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        A = max(cfg.train_accum, 1)
        mb = B // A
        # accumulation unit dim leads when A > 1 (the DFPA unit axis)
        lead = (A,) if A > 1 else ()
        lax_ = (None,) if A > 1 else ()
        atok = lambda s: _sds(lead + (mb, s), jnp.int32, mesh, lax_ + ("batch", "seq"))
        if cfg.is_encdec:
            out["batch"] = {
                "frames": _sds(lead + (mb, S, cfg.d_model), jnp.float32, mesh,
                               lax_ + ("batch", "seq", "embed_act")),
                "tokens": atok(S),
                "labels": atok(S),
            }
        else:
            s_text = S - cfg.num_prefix_embeddings
            out["batch"] = {"tokens": atok(s_text), "labels": atok(s_text)}
            if cfg.frontend == "vision_stub":
                out["batch"]["prefix_embeds"] = _sds(
                    lead + (mb, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32,
                    mesh, lax_ + ("batch", "seq", "embed_act"),
                )
    elif shape.kind == "prefill":
        out["caches"] = cache_sds(cfg, mesh, B, S)
        if cfg.is_encdec:
            out["frames"] = _sds((B, S, cfg.d_model), jnp.float32, mesh, ("batch", "seq", "embed_act"))
            out["tokens"] = tok(B, S)
        else:
            s_text = S - cfg.num_prefix_embeddings
            out["tokens"] = tok(B, s_text)
            if cfg.frontend == "vision_stub":
                out["prefix_embeds"] = _sds(
                    (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32, mesh,
                    ("batch", "seq", "embed_act"),
                )
    else:  # decode
        out["caches"] = cache_sds(cfg, mesh, B, S)
        out["token"] = tok(B, 1)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return out


# ---------------------------------------------------------------------------
# Step functions to lower
# ---------------------------------------------------------------------------


def reduced_units(cfg: ModelConfig, units: int) -> ModelConfig:
    """Same family/widths, ``units`` pattern repetitions (prefix kept)."""
    kw = dict(num_layers=len(cfg.prefix) + units * len(cfg.pattern))
    if cfg.is_encdec:
        kw["encoder_layers"] = units * len(cfg.encoder_pattern)
    return cfg.replace(**kw)


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (fn, example_args: tuple, donate) ready for jit."""
    if shape.kind == "train":
        step = make_train_step(
            cfg, warmup_cosine(3e-4, 100, 10_000),
            accum_steps=max(cfg.train_accum, 1),
        )
        ins = input_specs(cfg, shape, mesh)
        mdt = jnp.bfloat16 if os.environ.get("REPRO_BF16_MOMENTS") else None
        return step, (state_sds(cfg, mesh, moment_dtype=mdt), ins["batch"]), (0,)

    sparams = param_sds(cfg, mesh, dtype=cfg.dtype)  # bf16 serving weights
    ins = input_specs(cfg, shape, mesh)
    if shape.kind == "prefill":
        if cfg.is_encdec:
            def fn(params, frames, tokens, caches):
                return ED.encdec_prefill(params, cfg, frames, tokens, caches)

            return fn, (sparams, ins["frames"], ins["tokens"], ins["caches"]), (3,)
        if cfg.frontend == "vision_stub":
            def fn(params, tokens, prefix_embeds, caches):
                return T.prefill(params, cfg, tokens, caches, prefix_embeds=prefix_embeds)

            return fn, (sparams, ins["tokens"], ins["prefix_embeds"], ins["caches"]), (3,)

        def fn(params, tokens, caches):
            return T.prefill(params, cfg, tokens, caches)

        return fn, (sparams, ins["tokens"], ins["caches"]), (2,)

    # decode
    if cfg.is_encdec:
        def fn(params, token, pos, caches):
            return ED.encdec_decode_step(params, cfg, token, pos, caches)
    else:
        def fn(params, token, pos, caches):
            return T.decode_step(params, cfg, token, pos, caches)

    return fn, (sparams, ins["token"], ins["pos"], ins["caches"]), (3,)


# ---------------------------------------------------------------------------
# HLO collective analysis
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes per collective type from partitioned HLO.
    ``-done`` ops are skipped (their ``-start`` was counted)."""
    stats: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op, _start = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(shape_txt)
        s = stats.setdefault(op, {"bytes": 0.0, "count": 0})
        s["bytes"] += b
        s["count"] += 1
    return stats


def slstm_flops_correction(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """The sLSTM time scan stays rolled (O(S) trips) — estimate the flops
    XLA's cost analysis misses: (trips-1) x body, body ~ recurrent einsum
    (2*B*H*hd*4hd) + ~30 elementwise ops on (B, 4d)."""
    if "slstm" not in cfg.pattern:
        return 0.0
    n_slstm = sum(1 for k in cfg.pattern if k == "slstm") * cfg.num_units
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    if S <= 1:
        return 0.0
    d = cfg.d_model
    hd = d // cfg.num_heads
    body = 2.0 * B * cfg.num_heads * hd * 4 * hd + 30.0 * B * 4 * d
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd + bwd(2x)
    return (S - 1) * body * n_slstm * mult


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: shared + top_k/E of routed)."""
    spec = model_spec_for(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(spec, is_leaf=_IS_SPEC)[0]:
        n = int(np.prod(leaf.shape))
        if "experts" in leaf.axes:
            n = int(n * cfg.top_k / max(cfg.num_experts, 1))
        total += n
    return total


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def _compile(cfg: ModelConfig, shape: ShapeSpec, mesh):
    fn, args, donate = build_step(cfg, shape, mesh)
    t0 = time.time()
    with activation_sharding(mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, round(t1 - t0, 2), round(t2 - t1, 2)


def _costs(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
    for k, v in coll.items():
        out[f"coll_{k}_bytes"] = v["bytes"]
        out[f"coll_{k}_count"] = v["count"]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, costs: bool = True) -> Dict[str, Any]:
    """One dry-run cell.

    Phase A — compile the FULL config with scan-over-layers: the required
    artifact (sharding coherence + per-device memory analysis).
    Phase B (single-pod only) — compile 1-unit and 2-unit depth variants
    with all inner scans UNROLLED, and extrapolate per-step costs affinely:
    cost(U) = a + b*U.  XLA's cost analysis counts loop bodies ONCE, so the
    full scanned artifact under-reports by ~num_units x; depth variants are
    exactly affine in U (embedding/loss/optimizer in `a`, per-unit compute,
    FSDP gathers and EP collectives in `b`).  The sLSTM time scan stays
    rolled even in phase B — corrected analytically.
    """
    cfg = get_config(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    skip = shape_applicable(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = int(np.prod(mesh.devices.shape))

        # ---- Phase A: full-config compile (the dry-run proof) -------------
        compiled, rec["lower_s"], rec["compile_s"] = _compile(cfg, shape, mesh)
        ma = compiled.memory_analysis()
        rec["mem"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.peak_memory_in_bytes),
        }
        # XLA:CPU's peak_memory only covers entry args; the honest per-device
        # residency bound is args + temps (fp32 grads, remat residuals, ...).
        resident = max(
            int(ma.peak_memory_in_bytes),
            int(ma.argument_size_in_bytes) + int(ma.temp_size_in_bytes),
        )
        rec["mem"]["resident_bytes"] = resident
        rec["fits_hbm"] = bool(resident <= HW.HBM_BYTES)
        rec["status"] = "ok"

        # ---- Phase B: affine cost extrapolation (roofline terms) ----------
        if costs and not multi_pod:
            U = cfg.num_units
            variants = {}
            for u in (1, 2):
                vcfg = reduced_units(cfg, u).replace(
                    scan_layers=False, unroll_scans=True
                )
                vc, _, _ = _compile(vcfg, shape, mesh)
                variants[u] = _costs(vc)
            keys = set(variants[1]) | set(variants[2])
            total: Dict[str, float] = {}
            for k in keys:
                c1 = variants[1].get(k, 0.0)
                c2 = variants[2].get(k, 0.0)
                b = max(c2 - c1, 0.0)
                a = max(c1 - b, 0.0)
                total[k] = a + b * U
            rec["cost_model"] = {"u1": variants[1], "u2": variants[2]}

            flops_dev = total["flops"]
            corr = slstm_flops_correction(cfg, shape) / n_dev
            if corr:
                rec["slstm_flops_correction_per_dev"] = corr
                flops_dev += corr
            bytes_dev = total["bytes"]
            coll_bytes = sum(
                v * (2.0 if k.startswith("coll_all-reduce") else 1.0)
                for k, v in total.items()
                if k.startswith("coll_") and k.endswith("_bytes")
            )
            rec["flops_per_dev"] = flops_dev
            rec["bytes_per_dev"] = bytes_dev
            rec["collectives"] = {
                k[5:-6]: {"bytes": v, "count": total.get(k[:-6] + "_count", 0)}
                for k, v in total.items()
                if k.startswith("coll_") and k.endswith("_bytes")
            }
            rec["collective_bytes"] = coll_bytes

            terms = {
                "compute_s": flops_dev / HW.PEAK_FLOPS_BF16,
                "memory_s": bytes_dev / HW.HBM_BW,
                "collective_s": coll_bytes / HW.ICI_BW,
            }
            rec["terms"] = terms
            rec["dominant"] = max(terms, key=terms.get)

            # MODEL_FLOPS: 6*N*D train, 2*N*D forward-only.
            n_active = active_param_count(cfg)
            tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
            mult = 6 if shape.kind == "train" else 2
            model_flops = mult * n_active * tokens
            rec["model_flops_total"] = float(model_flops)
            rec["model_flops_per_dev"] = float(model_flops / n_dev)
            rec["useful_flops_ratio"] = (
                float(model_flops / n_dev / flops_dev) if flops_dev else None
            )
            rec["params_total"] = param_count(model_spec_for(cfg))
            rec["params_active"] = n_active
    except Exception as e:  # noqa: BLE001 — every failure is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose output JSON already exists and is ok")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.list:
        for a in archs:
            for s in shapes:
                print(a, s)
        return 0

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                tag = f"{a}_{s}_{'multi' if mp else 'single'}"
                path0 = os.path.join(args.out, tag + ".json")
                if args.resume and os.path.exists(path0):
                    try:
                        prev = json.load(open(path0))
                        if prev.get("status") in ("ok", "skipped") and (
                            mp or prev.get("status") == "skipped" or "terms" in prev
                        ):
                            print(f"[ resume] {tag}", flush=True)
                            continue
                    except Exception:
                        pass
                rec = run_cell(a, s, mp)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = f" resident={rec['mem']['resident_bytes']/2**30:.2f}GiB fits={rec['fits_hbm']}"
                    if "terms" in rec:
                        t = rec["terms"]
                        extra += (
                            f" comp={t['compute_s']*1e3:.2f}ms"
                            f" mem={t['memory_s']*1e3:.2f}ms"
                            f" coll={t['collective_s']*1e3:.2f}ms dom={rec['dominant']}"
                        )
                elif status == "error":
                    failures += 1
                    extra = " " + rec["error"][:120]
                elif status == "skipped":
                    extra = " " + rec["reason"][:60]
                print(f"[{status:>7}] {tag}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
