"""Multi-tenant fleet scheduling: q concurrent jobs, one device program per
round, profiles that outlive the session.

Three tenants with different chunk counts and workload tags share one
heterogeneous replica fleet.  The ``FleetScheduler`` drives all of their
DFPA measurement rounds in lock-step from ONE stacked ``[q, p, k]`` device
bank — one batched repartition + one fold-in program per round, however
many tenants are admitted.  A fourth tenant is admitted mid-flight, one
retires, and the learned profiles are saved to a ``ProfileRegistry`` so a
second session warm-starts from them — the paper's "partial estimates
sufficient for a given accuracy", reused across sessions.

    PYTHONPATH=src python examples/fleet_serve.py
"""

import os
import tempfile

import numpy as np

from repro.fleet import FleetScheduler, JobSpec, ProfileRegistry
from repro.runtime.serve_loop import ReplicaDispatcher

# --- a heterogeneous replica fleet: per-replica nonlinear chunk->time -------
P = 6
CLASSES = ["a100", "a100", "h100", "h100", "l4", "l4"]
rng = np.random.default_rng(7)
base = {"a100": 4e-4, "h100": 2.2e-4, "l4": 9e-4}
knee = {"a100": 36, "h100": 64, "l4": 18}


def replica_run(i, x):
    c = CLASSES[i]
    t = x * base[c]
    if x > knee[c]:
        t += (x - knee[c]) * base[c] * 4.0  # HBM-spill knee
    return t


# --- 1. three tenants balanced concurrently through the dispatcher ----------
disp = ReplicaDispatcher(replica_run, P, eps=0.12)
results = disp.balance_fleet(
    {"chat": 96, "batch-eval": 240, "embed": 64},
    backend="jax",
    workloads={"chat": "decode", "batch-eval": "decode", "embed": "embed"},
    device_classes=CLASSES,
    min_units=1,
)
fleet = disp.fleet
for name, part in results.items():
    print(
        f"{name:>10}: d={part.allocations} iters={part.iterations} "
        f"imb={part.imbalance:.3f} converged={part.converged}"
    )
print(
    f"fleet: {fleet.rounds} rounds, {fleet.device_dispatches} device programs "
    f"(q independent loops would have paid ~{2 * 3}x per round)"
)

# --- 2. admit mid-flight / retire: lanes restack lazily ---------------------
fleet.admit(JobSpec(name="rerank", n=120, eps=0.12, min_units=1, workload="decode"))
fleet.retire("embed")  # folds its learned profile into... no registry yet
res = fleet.run(disp)
print(f"\n    rerank: d={res['rerank'].allocations} iters={res['rerank'].iterations}")

# --- 3. persist profiles; a NEW session warm-starts from them ---------------
reg = ProfileRegistry()
fleet.registry = reg
fleet.save_profiles()
path = os.path.join(tempfile.mkdtemp(), "profiles.json")
reg.save(path)
print(f"\nsaved {len(reg)} (device-class, workload) profiles -> {path}")

reg2 = ProfileRegistry.load(path)
fleet2 = FleetScheduler(
    P, backend="jax", registry=reg2, device_classes=CLASSES
)
fleet2.admit(JobSpec(name="chat-v2", n=96, eps=0.12, min_units=1, workload="decode"))
disp2 = ReplicaDispatcher(replica_run, P, eps=0.12)
res2 = fleet2.run(disp2)
cold_iters = results["chat"].iterations
print(
    f"warm-started chat-v2: d={res2['chat-v2'].allocations} "
    f"iters={res2['chat-v2'].iterations} (cold session took {cold_iters}) — "
    "the first distribution came from yesterday's estimates, not an even split."
)
