"""Two-level hierarchical partitioning: the exactness-tier battery.

The contract (``core/hierarchy.py`` module docstring):

* **single group == flat, bit-identical** — the outer level degenerates to
  "give the one group all n" and the inner solve IS the flat kernel, on
  numpy, jax, and jax+shard_map alike;
* **multi-group == flat makespan within the aggregation tolerance** — group
  aggregates are exact at sampled knots and interpolate between them, so
  allocations may shift a boundary unit but the makespan never degrades
  beyond the interpolation + integer-boundary error (asserted at 12% over
  the fuzz lanes — empirical worst over 340 random monotone cases is
  ~1.10 — for monotone banks at n >= 30 p so per-unit granularity
  does not dominate; non-monotone banks get structural checks only — their
  alloc-at-time functions JUMP, which no sampled aggregate can bound);
* **per-group completion routing** — an adversarial non-monotone group
  demotes only its OWN inner solve: auto always equals the exact greedy
  completion, and the jax block path matches the numpy per-group loop;
* **error parity** — validation raises the flat paths' messages in the flat
  paths' order, so the Scheduler facade keeps one error surface.

Fuzz lanes follow the repo convention: tier-1 smoke (25 cases) plus a
>= 200-case ``slow`` lane.  The sharded tests run under however many
devices the host exposes (CI's emulated-multi-device lane sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import numpy as np
import pytest

import jax
from jax.experimental import enable_x64

from repro.core import ModelBank, Policy, Scheduler, SpeedStore
from repro.core.hierarchy import Hierarchy
from repro.core.partition import _partition_units_bank
from repro.fleet import FleetScheduler, JobSpec

BIT_EXACT = jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Random banks and the makespan oracle
# ---------------------------------------------------------------------------


def _random_bank(rng, p, kmax=5, monotone=True):
    """Per-row random piecewise models; ``monotone=True`` draws increasing
    knot times (the threshold-count precondition), else free speeds."""
    pts = []
    for _ in range(p):
        k = int(rng.integers(1, kmax + 1))
        xs = np.unique(np.round(rng.uniform(1.0, 200.0, k), 3))
        if monotone:
            ts = np.sort(rng.uniform(0.1, 10.0, len(xs)))
            ss = xs / ts
        else:
            ss = rng.uniform(0.5, 20.0, len(xs))
        pts.append((list(xs), list(ss)))
    return ModelBank.from_point_lists(pts)


def _makespan(bank, d):
    d = np.asarray(d, dtype=np.float64)
    t = bank.time(np.maximum(d, 1.0))
    return float(np.max(np.where(d > 0, t, 0.0)))


def _random_case(rng, p_hi=40):
    p = int(rng.integers(2, p_hi))
    g = int(rng.integers(1, min(p, 8) + 1))
    groups = rng.integers(0, g, size=p).tolist()
    bank = _random_bank(rng, p, monotone=bool(rng.random() < 0.7))
    n = int(rng.integers(30 * p, 120 * p))
    min_units = int(rng.integers(0, 2))
    caps = None
    if rng.random() < 0.35:
        lo = max(1, min_units)
        caps = [lo + int(f * n) for f in rng.uniform(0.6, 1.0, p)]
    return dict(bank=bank, groups=groups, n=n, min_units=min_units, caps=caps)


def _check_hier_vs_flat(case, *, backend="numpy", sharding=None, tol=0.12):
    bank, groups = case["bank"], case["groups"]
    n, mu, caps = case["n"], case["min_units"], case["caps"]
    d_flat, _ = _partition_units_bank(
        bank, n, caps if caps is not None else [n] * bank.p, min_units=mu
    )
    h = Hierarchy.from_bank(bank, groups, backend=backend, sharding=sharding)
    d_hier = h.partition_units(n, caps, min_units=mu)

    assert sum(d_hier) == n
    icaps = caps if caps is not None else [n] * bank.p
    assert all(0 <= v <= c for v, c in zip(d_hier, icaps))
    assert all(v >= mu for v in d_hier)
    if bank.is_monotone():
        m_flat = _makespan(bank, d_flat)
        m_hier = _makespan(bank, d_hier)
        assert m_hier <= m_flat * (1.0 + tol) + 1e-12, (m_hier, m_flat)
    if len(set(groups)) == 1:
        assert d_hier == [int(v) for v in d_flat]
    return d_hier


# ---------------------------------------------------------------------------
# Tier-1: smoke fuzz + bit-identity + routing + errors
# ---------------------------------------------------------------------------


def test_hier_vs_flat_smoke_fuzz_numpy():
    rng = np.random.default_rng(201)
    for _ in range(25):
        _check_hier_vs_flat(_random_case(rng))


def test_hier_jax_matches_numpy_smoke():
    """The jax lax.map block path returns exactly the numpy per-group loop
    (bit-identical on CPU under x64)."""
    rng = np.random.default_rng(202)
    with enable_x64():
        for _ in range(6):
            case = _random_case(rng, p_hi=20)
            d_np = _check_hier_vs_flat(case, backend="numpy")
            d_jx = _check_hier_vs_flat(case, backend="jax")
            if BIT_EXACT:
                assert d_np == d_jx


def test_single_group_bit_identical_all_backends():
    """g=1 degenerates to the flat solve on every inner backend."""
    rng = np.random.default_rng(203)
    bank = _random_bank(rng, 23)
    n = 907
    d_flat, _ = _partition_units_bank(bank, n, [n] * bank.p, min_units=0)
    with enable_x64():
        for backend, sharding in [("numpy", None), ("jax", None), ("jax", "shard_map")]:
            h = Hierarchy.from_bank(bank, [0] * bank.p, backend=backend, sharding=sharding)
            d = h.partition_units(n)
            if BIT_EXACT:
                assert d == [int(v) for v in d_flat], (backend, sharding)
            else:  # pragma: no cover - accelerator hosts
                assert sum(d) == n


def test_shard_map_matches_unsharded():
    """shard_map over the host's devices returns exactly the single-program
    jax path, for group counts that do and don't divide the device count."""
    rng = np.random.default_rng(204)
    with enable_x64():
        for g in (1, 2, 5, len(jax.devices()) + 1):
            p = 6 * g
            groups = (np.arange(p) % g).tolist()
            bank = _random_bank(rng, p)
            n = int(rng.integers(p, 40 * p))
            h_jax = Hierarchy.from_bank(bank, groups, backend="jax")
            h_shd = Hierarchy.from_bank(bank, groups, backend="jax", sharding="shard_map")
            assert h_shd.partition_units(n) == h_jax.partition_units(n)


def test_shard_map_memory_gate():
    """Under shard_map no device holds more than ceil(g/ndev) group blocks —
    the p=10^6 memory story, checked structurally via max_shard_elems."""
    rng = np.random.default_rng(205)
    ndev = len(jax.devices())
    g, per = 8, 5
    banks = [_random_bank(rng, per, kmax=3) for _ in range(g)]
    h_shd = Hierarchy.from_group_banks(banks, backend="jax", sharding="shard_map")
    h_all = Hierarchy.from_group_banks(banks, backend="jax")
    k = max(int(b.xs.shape[1]) for b in banks)
    assert h_shd.max_shard_elems() == 2 * (-(-g // ndev)) * per * k
    assert h_all.max_shard_elems() == 2 * g * per * k
    if ndev > 1:
        assert h_shd.max_shard_elems() < h_all.max_shard_elems()


def test_nonmonotone_group_demotes_only_itself():
    """One group's time function DROPS past a knee (observed speed jumps:
    non-monotone).  auto must equal the exact greedy completion, and the jax
    per-group routing must match numpy — the monotone neighbours keep their
    threshold fast path without being poisoned."""
    rng = np.random.default_rng(206)
    good = _random_bank(rng, 12, monotone=True)
    # non-monotone rows: speed jumps 10x at x=50 (time drops)
    bad_pts = [([10.0, 50.0, 60.0], [s, s, 10.0 * s]) for s in rng.uniform(2.0, 8.0, 6)]
    bad = ModelBank.from_point_lists(bad_pts)
    bank = ModelBank.from_point_lists(
        [(list(b.xs[i][: b.counts[i]]), list(b.ss[i][: b.counts[i]]))
         for b in (good, bad) for i in range(b.p)]
    )
    assert bank.is_monotone() is False
    groups = [0] * good.p + [1] * bad.p
    sub_monos = [
        Hierarchy.from_bank(bank, groups).sub_banks[i].is_monotone() for i in (0, 1)
    ]
    assert sub_monos == [True, False]
    n = 1500
    with enable_x64():
        d_auto_np = Hierarchy.from_bank(bank, groups).partition_units(n)
        d_greedy_np = Hierarchy.from_bank(bank, groups).partition_units(
            n, completion="greedy"
        )
        d_auto_jx = Hierarchy.from_bank(bank, groups, backend="jax").partition_units(n)
    assert d_auto_np == d_greedy_np
    if BIT_EXACT:
        assert d_auto_jx == d_auto_np
    assert sum(d_auto_np) == n


def test_error_parity_with_flat():
    rng = np.random.default_rng(207)
    bank = _random_bank(rng, 8)
    h = Hierarchy.from_bank(bank, [0, 0, 1, 1, 2, 2, 3, 3])
    with pytest.raises(ValueError, match="unknown completion mode"):
        h.partition_units(10, completion="bogus")
    with pytest.raises(ValueError, match="n must be non-negative"):
        h.partition_units(-1)
    with pytest.raises(ValueError, match=r"infeasible: sum\(caps\)"):
        h.partition_units(100, [2] * 8)
    with pytest.raises(ValueError, match="min_units=3 infeasible"):
        h.partition_units(10, min_units=3)
    assert h.partition_units(0) == [0] * 8
    # empty FPM row with a positive cap, same message as the flat bank path
    pts = [([1.0], [1.0])] * 4
    empty = ModelBank.from_point_lists(pts)
    empty.counts = np.array([1, 1, 0, 1])
    h2 = Hierarchy.from_bank(empty, [0, 0, 1, 1])
    with pytest.raises(ValueError, match="empty FPM"):
        h2.partition_units(4)
    with pytest.raises(ValueError, match="groups must be a length-p"):
        Hierarchy.from_bank(bank, [0, 1])
    with pytest.raises(ValueError, match="unknown hierarchy backend"):
        Hierarchy.from_bank(bank, [0] * 8, backend="scalar")
    with pytest.raises(ValueError, match='requires backend="jax"'):
        Hierarchy.from_bank(bank, [0] * 8, backend="numpy", sharding="shard_map")


# ---------------------------------------------------------------------------
# Scheduler facade routing
# ---------------------------------------------------------------------------


def test_scheduler_hier_routing():
    rng = np.random.default_rng(208)
    bank = _random_bank(rng, 16)
    n = 800
    flat = Scheduler(SpeedStore.from_bank(bank)).partition(n)
    hier1 = Scheduler(
        SpeedStore.from_bank(bank), policy=Policy.HIER, groups=[0] * 16
    ).partition(n)
    assert hier1.allocations == flat.allocations
    hier4 = Scheduler(
        SpeedStore.from_bank(bank), groups=[i % 4 for i in range(16)]
    ).partition(n)
    assert sum(hier4.allocations) == n
    assert _makespan(bank, hier4.allocations) <= _makespan(bank, flat.allocations) * 1.05

    with pytest.raises(ValueError, match="policy=HIER requires a groups="):
        Scheduler(SpeedStore.from_bank(bank), policy=Policy.HIER)

    s = Scheduler(SpeedStore.from_bank(bank), groups=[i % 4 for i in range(16)])
    st = s.state_dict()
    assert st["groups"] == [i % 4 for i in range(16)]
    s2 = Scheduler.from_state(st)
    assert s2.partition(n).allocations == hier4.allocations
    # mid-flight regrouping
    s2.set_groups([0] * 16)
    assert s2.partition(n).allocations == flat.allocations
    s2.set_groups(None)
    assert s2.partition(n).allocations == flat.allocations


# ---------------------------------------------------------------------------
# FleetScheduler routing
# ---------------------------------------------------------------------------


class _FleetExec:
    """q-job wrapper over a shared per-processor batch time function."""

    def __init__(self, p, seed=3):
        r = np.random.default_rng(seed)
        self.base = r.uniform(5.0, 50.0, size=p)
        self.bend = r.uniform(50, 400, size=p)
        self.num_procs = p

    def _times(self, d):
        d = np.asarray(d, dtype=np.float64)
        s = self.base * (1.0 + 0.3 * np.minimum(d, self.bend) / self.bend)
        return np.where(d > 0, d / s, 0.0)

    def run_jobs(self, names, D):
        return np.stack([self._times(d) for d in D])


def _run_fleet(p, **kw):
    fs = FleetScheduler(p, **kw)
    fs.admit(JobSpec(name="a", n=2000, eps=0.02, max_iter=10))
    fs.admit(JobSpec(name="b", n=3333, eps=0.02, max_iter=10))
    res = fs.run(_FleetExec(p), max_rounds=16)
    return {k: (v.allocations, v.makespan) for k, v in res.items()}


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fleet_hier_single_group_matches_flat(backend):
    p = 24
    with enable_x64():
        flat = _run_fleet(p, backend=backend)
        hier = _run_fleet(p, backend=backend, groups=[0] * p)
    if BIT_EXACT:
        assert flat == hier
    else:  # pragma: no cover - accelerator hosts
        assert flat.keys() == hier.keys()


def test_fleet_hier_multigroup_converges():
    p = 24
    groups = [i % 3 for i in range(p)]
    with enable_x64():
        flat = _run_fleet(p, backend="jax")
        hier = _run_fleet(p, backend="jax", groups=groups)
    for k in flat:
        assert sum(hier[k][0]) == sum(flat[k][0])
        assert hier[k][1] <= flat[k][1] * 1.05 + 1e-9


def test_fleet_hier_validation():
    with pytest.raises(ValueError, match="hierarchical fleet requires"):
        FleetScheduler(4, backend="scalar", groups=[0] * 4)
    with pytest.raises(ValueError, match="length-p"):
        FleetScheduler(4, backend="numpy", groups=[0] * 3)
    with pytest.raises(ValueError, match='requires backend="jax"'):
        FleetScheduler(4, backend="numpy", sharding="shard_map")
    with pytest.raises(ValueError, match="unknown sharding"):
        FleetScheduler(4, backend="jax", sharding="bogus")


# ---------------------------------------------------------------------------
# Slow fuzz lanes
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_hier_vs_flat_fuzz_numpy_lane():
    rng = np.random.default_rng(210)
    for _ in range(200):
        _check_hier_vs_flat(_random_case(rng))


@pytest.mark.slow
def test_hier_fuzz_jax_lane():
    """100 fuzzed cases through the jax block path (shapes vary, so the jit
    cache churns more than the flat stacked tests — kept to 100)."""
    rng = np.random.default_rng(211)
    with enable_x64():
        for _ in range(100):
            case = _random_case(rng, p_hi=24)
            d_np = _check_hier_vs_flat(case, backend="numpy")
            d_jx = _check_hier_vs_flat(case, backend="jax")
            if BIT_EXACT:
                assert d_np == d_jx


@pytest.mark.slow
def test_hier_shard_map_fuzz_lane():
    rng = np.random.default_rng(212)
    with enable_x64():
        for _ in range(40):
            case = _random_case(rng, p_hi=24)
            d_jx = _check_hier_vs_flat(case, backend="jax")
            d_sh = _check_hier_vs_flat(case, backend="jax", sharding="shard_map")
            assert d_jx == d_sh


@pytest.mark.slow
def test_hier_p1e4_smoke():
    """p=10^4 in groups of 100: the cache-wall shape, solved hierarchically
    and checked against the flat makespan."""
    rng = np.random.default_rng(213)
    p, gsize = 10_000, 100
    bank = _random_bank(rng, p, kmax=4)
    groups = (np.arange(p) // gsize).tolist()
    case = dict(bank=bank, groups=groups, n=20 * p, min_units=0, caps=None)
    _check_hier_vs_flat(case, tol=0.05)


def test_make_fleet_bank_matches_make_fleet():
    """The vectorized benchmark bank builder (the only way to stand up the
    p=10^6 row's group banks) must produce the same fleet as the per-model
    reference generator for identical seeds."""
    import pathlib
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks.partition_scale import make_fleet, make_fleet_bank

    p = 200
    ref = ModelBank.from_models(make_fleet(p, seed=7))
    fast = make_fleet_bank(p, seed=7)
    assert fast.p == ref.p
    np.testing.assert_array_equal(fast.counts, ref.counts)
    np.testing.assert_allclose(fast.xs, ref.xs, rtol=1e-9)
    np.testing.assert_allclose(fast.ss, ref.ss, rtol=1e-9)
    assert fast.is_monotone()
    # the solve agrees too: same fleet -> same allocation
    n = 100 * p
    d_ref, _ = _partition_units_bank(ref, n, [n] * p, min_units=1)
    d_fast, _ = _partition_units_bank(fast, n, [n] * p, min_units=1)
    assert d_ref == d_fast
