"""AdamW (decoupled weight decay) with global-norm clipping — hand-rolled
(pure pytree transforms; no optax dependency in the container)."""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    mu: Any  # first moment (pytree like params)
    nu: Any  # second moment
    count: jax.Array  # () int32


def adamw_init(params, *, moment_dtype=None) -> AdamWState:
    """``moment_dtype=jnp.bfloat16`` halves optimizer-state memory — the
    lever that fits 200B+ models per chip (EXPERIMENTS §Perf cell 2); the
    update math still runs in fp32."""
    z = lambda p: jnp.zeros(p.shape, moment_dtype or p.dtype)
    return AdamWState(
        mu=jax.tree_util.tree_map(z, params),
        nu=jax.tree_util.tree_map(z, params),
        count=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.zeros(())
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        mdt = m.dtype
        m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * step).astype(p.dtype),
            m.astype(mdt),
            v.astype(mdt),
        )

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        AdamWState(
            jax.tree_util.tree_unflatten(treedef, new_m),
            jax.tree_util.tree_unflatten(treedef, new_v),
            count,
        ),
        metrics,
    )
