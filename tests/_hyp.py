"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When it
is absent, property-based tests are SKIPPED instead of killing collection for
the whole module — the deterministic tests in the same files still run.

Usage in test modules::

    from _hyp import given, settings, st   # instead of `from hypothesis import ...`
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Anything:
        """Stands in for `strategies`: any attribute/call yields another stub,
        so module-level strategy construction (`st.composite`, `st.floats(...)`)
        parses; the `given` stub then skips the test before anything runs."""

        def __call__(self, *args, **kwargs):
            return _Anything()

        def __getattr__(self, name):
            return _Anything()

    st = _Anything()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)
