"""SpeedStore: one resolved home for the fleet's speed models.

Before this module, every partitioning entry point re-derived the
scalar-vs-bank-vs-jax decision per call (``_as_bank`` / ``_as_jax_bank`` in
``partition.py``, carry plumbing in ``dfpa.py`` and ``runtime/balance.py``,
``vectorize=`` / ``backend=`` kwargs everywhere).  ``SpeedStore`` resolves the
backend **once**, at construction, and then exposes a single protocol:

  * ``speeds(x)`` / ``times(x)``      — batched model evaluation, ``[p]``;
  * ``alloc_at_time(t, caps)``        — the geometric partitioner primitive;
  * ``fold_in(x, s, valid)``          — one observation per processor (the
    paper's step-5 update), applied to the scalar estimates AND, on the jax
    backend, to the device-resident carry in the same call;
  * ``partition_units`` / ``partition_continuous`` — the partitioners of
    ``partition.py``, dispatched to the pre-resolved backend;
  * ``state_dict()`` / ``from_state`` — checkpointable estimates.

Three backends, resolved once:

  * ``"scalar"`` — per-model Python objects (``AnalyticModel`` and friends
    with no piecewise representation, or an explicitly forced baseline);
  * ``"numpy"``  — the scalar estimates are the source of truth, banked into
    a :class:`~repro.core.modelbank.ModelBank` per partition call (exactly
    the legacy behaviour, so allocations are bit-identical);
  * ``"jax"``    — a :class:`~repro.core.modelbank_jax.JaxModelBank` carry
    lives on device and is updated by ``fold_in`` (vectorized sorted insert)
    instead of being rebuilt from the scalars; partitions run under
    ``jax.jit``.

Dtype policy (serving fleets)
-----------------------------

``from_models(..., dtype=np.float32)`` (also on ``empty``/``from_state``)
builds the jax device carry in the requested float dtype instead of the
platform-native one (float64 under x64).  The decision data lives in
``BENCH_partition.json``: the ``jax_f32_*`` columns measure float32
allocation drift against the float64 reference at both serving scales —
ZERO unit drift at p=10^4 (n=10^6; also locked by
``test_float32_store_allocations_match_float64_at_p_10k``) and a worst case
of ±1 unit (2 total of n=10^7) at p=10^5 — so serving fleets can run the
cheaper dtype at sub-unit cost.  The default stays ``None`` (native dtype)
because the cross-backend parity gates are a bit-identity contract that
only float64 satisfies.  The host (scalar/numpy) paths always compute in
float64 — ``dtype`` is a device-bank policy, recorded in ``state_dict``
and round-tripped (by ``Scheduler.state_dict`` too).

Analytic sample-and-bank
------------------------

``AnalyticModel`` (FFMPA's pre-built full models, oracle time functions) has
no piecewise representation and used to force the scalar fallback.
``from_models(..., analytic_tol=..., analytic_hi=...)`` adaptively samples
such models into piecewise-linear FPMs — recursively refining the segment
whose midpoint interpolation error is worst until every segment is within
``analytic_tol`` relative error (or ``analytic_max_points`` is hit) — so
FFMPA-style baselines ride the vectorized bank paths too (ROADMAP:
analytic-model banking).  The default (``analytic_tol=None``) preserves the
exact scalar behaviour.
"""

from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .fpm import ConstantModel, PiecewiseLinearFPM, SpeedModel, imbalance
from .modelbank import ModelBank
from .partition import (
    _continuous_bank,
    _continuous_scalar,
    _partition_units_bank,
    _partition_units_scalar,
    _prep_continuous_caps,
    _prep_unit_caps,
)

try:  # telemetry is optional: the store runs identically without repro.obs
    from ..obs.telemetry import active as _obs_active
except ImportError:  # pragma: no cover - obs layer absent
    def _obs_active():
        return None

__all__ = ["SpeedStore", "sample_analytic_points"]

BACKENDS = ("scalar", "numpy", "jax")


def _warn_legacy(old: str, new: str) -> None:
    """One DeprecationWarning per legacy entry point, pointing at the facade."""
    warnings.warn(
        f"{old} is deprecated; use {new} — see core/scheduler.py and "
        "core/speedstore.py (backend resolved once at construction)",
        DeprecationWarning,
        stacklevel=3,
    )


def sample_analytic_points(
    model: SpeedModel,
    *,
    hi: float,
    lo: float = 1.0,
    tol: float = 0.01,
    max_points: int = 64,
) -> List[Tuple[float, float]]:
    """Adaptive piecewise-linear fit of ``model.speed`` on ``[lo, hi]``.

    Greedy refinement: repeatedly split the segment whose midpoint linear
    interpolation deviates most from the true speed, until every segment is
    within relative ``tol`` or ``max_points`` is reached.  The returned
    points reproduce the analytic speed to ``tol`` wherever it is locally
    smooth; kinks (paging cliffs) attract points automatically.
    """
    lo = max(float(lo), 1e-9)
    hi = float(hi)
    if hi <= lo:
        hi = lo * 2.0
    xs = [lo, hi]
    ss = [float(model.speed(lo)), float(model.speed(hi))]

    def _mid_err(k: int) -> Tuple[float, float, float]:
        xm = 0.5 * (xs[k] + xs[k + 1])
        s_true = float(model.speed(xm))
        s_lin = 0.5 * (ss[k] + ss[k + 1])
        denom = abs(s_true) if s_true != 0.0 else 1e-300
        return abs(s_lin - s_true) / denom, xm, s_true

    while len(xs) < max_points:
        worst = None
        for k in range(len(xs) - 1):
            err, xm, sm = _mid_err(k)
            if worst is None or err > worst[0]:
                worst = (err, k, xm, sm)
        if worst is None or worst[0] <= tol:
            break
        _, k, xm, sm = worst
        xs.insert(k + 1, xm)
        ss.insert(k + 1, sm)
    return [(x, max(s, 1e-300)) for x, s in zip(xs, ss)]


class SpeedStore:
    """Polymorphic model container with the backend resolved at construction.

    Do not call ``__init__`` directly — use :meth:`from_models`,
    :meth:`from_speeds`, :meth:`from_bank`, :meth:`empty`,
    :meth:`from_state`, or (for legacy adapter paths) :meth:`resolve`.
    """

    def __init__(
        self,
        models: Optional[List[SpeedModel]],
        backend: str,
        *,
        bank: Optional[ModelBank] = None,
        jbank=None,
        dtype=None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        self._models = list(models) if models is not None else None
        self.backend = backend
        self.dtype = dtype  # device-bank float dtype policy (None = native)
        # Session-local fold counter, aligned with the device carry's
        # JaxModelBank.generation tag on the jax backend (a lazy carry
        # rebuild resets the bank tag but not this counter): pipelined
        # consumers use generations to bound estimate staleness, and tests
        # assert the two advance in lock-step across folds.
        self.fold_generation = 0
        self._np_bank = bank  # wrapped ModelBank (models is None) only
        self._jbank = jbank  # device carry (jax backend); None -> lazy rebuild
        # Optional energy sub-store (same backend): energy-rate models
        # er_i(x) = x / E_i(x), so _energy.times(d) are the energies E_i(d)
        # — see core/energy.py and the "time and energy" section in
        # modelbank.py.  Attached by attach_energy / fold_energy.
        self._energy: Optional["SpeedStore"] = None

    # -- construction --------------------------------------------------------

    @classmethod
    def from_models(
        cls,
        models: Sequence[SpeedModel],
        *,
        backend: str = "auto",
        analytic_tol: Optional[float] = None,
        analytic_hi: Optional[float] = None,
        analytic_lo: float = 1.0,
        analytic_max_points: int = 64,
        dtype=None,
    ) -> "SpeedStore":
        """Build a store from scalar models, resolving the backend once.

        ``backend="auto"`` picks ``"numpy"`` when every model has a piecewise
        representation and ``"scalar"`` otherwise.  With ``analytic_tol`` set
        (and ``analytic_hi`` bounding the sampled range, typically the
        problem size ``n``), non-piecewise models are sample-and-banked so
        they can ride the vectorized backends.  ``dtype`` is the device-bank
        float dtype policy (see the module docstring); it only affects the
        jax backend's carry.
        """
        models = list(models)
        if analytic_tol is not None:
            if analytic_hi is None:
                raise ValueError("analytic_tol requires analytic_hi (sampling range)")
            banked = []
            for m in models:
                if isinstance(m, (PiecewiseLinearFPM, ConstantModel)) or hasattr(m, "as_points"):
                    banked.append(m)
                else:
                    banked.append(
                        PiecewiseLinearFPM.from_points(
                            sample_analytic_points(
                                m, hi=analytic_hi, lo=analytic_lo,
                                tol=analytic_tol, max_points=analytic_max_points,
                            )
                        )
                    )
            models = banked
        if backend == "auto":
            try:
                ModelBank.from_models(models)
            except TypeError:
                return cls(models, "scalar", dtype=dtype)
            return cls(models, "numpy", dtype=dtype)
        if backend == "scalar":
            return cls(models, "scalar", dtype=dtype)
        if backend in ("numpy", "jax"):
            try:
                ModelBank.from_models(models)
            except TypeError:
                # Mirrors the legacy per-call fallback: non-piecewise models
                # keep the scalar path even when a banked backend was asked.
                return cls(models, "scalar", dtype=dtype)
            if backend == "jax":
                return cls(
                    models, "jax", jbank=cls._initial_carry(models, dtype), dtype=dtype
                )
            return cls(models, "numpy", dtype=dtype)
        raise ValueError(f"unknown backend {backend!r}")

    @staticmethod
    def _initial_carry(models: Sequence[SpeedModel], dtype=None):
        """The DFPA device carry: built from the models when any has points,
        otherwise the empty bank (identical to the legacy dfpa/controller
        initialization)."""
        from .modelbank_jax import JaxModelBank

        if any(getattr(m, "num_points", 0) > 0 for m in models):
            return JaxModelBank.from_models(models, dtype=dtype)
        return JaxModelBank.empty(len(models), dtype=dtype)

    @classmethod
    def from_speeds(cls, speeds: Sequence[float], *, backend: str = "numpy") -> "SpeedStore":
        """CPM store: one constant-speed model per processor."""
        return cls.from_models([ConstantModel(float(s)) for s in speeds], backend=backend)

    @classmethod
    def empty(cls, p: int, *, backend: str = "numpy", dtype=None) -> "SpeedStore":
        """``p`` empty piecewise estimates (the cold-start DFPA state)."""
        models = [PiecewiseLinearFPM() for _ in range(p)]
        if backend == "jax":
            return cls(
                models, "jax", jbank=cls._initial_carry(models, dtype), dtype=dtype
            )
        if backend in ("numpy", "scalar"):
            return cls(models, backend, dtype=dtype)
        raise ValueError(f"unknown backend {backend!r}")

    @classmethod
    def from_bank(cls, bank: ModelBank) -> "SpeedStore":
        """Wrap an existing numpy bank (no scalar mirror until needed)."""
        return cls(None, "numpy", bank=bank)

    @classmethod
    def from_jax_bank(cls, jbank) -> "SpeedStore":
        """Wrap an existing device bank (no scalar mirror until needed)."""
        return cls(None, "jax", jbank=jbank)

    @classmethod
    def resolve(cls, source, *, backend: str = "numpy", vectorize: bool = True) -> "SpeedStore":
        """Adapt any legacy ``models`` argument — scalar sequence,
        ``ModelBank``, ``JaxModelBank``, or an existing store — mirroring the
        per-call dispatch the free functions used to re-derive."""
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        if isinstance(source, cls):
            return source
        if getattr(source, "is_jax", False):
            if backend == "jax" and vectorize:
                if source.xs.ndim != 2:
                    raise ValueError(
                        "stacked [q, p, k] banks don't fit the flat List[int] "
                        "contract; use JaxModelBank.partition_units / "
                        "Scheduler.repartition_grid for batched partitions"
                    )
                return cls.from_jax_bank(source)
            bank = source.to_bank()
            if not vectorize:
                return cls(bank.to_models(), "scalar")
            return cls.from_bank(bank)
        if isinstance(source, ModelBank):
            if not vectorize:
                return cls(source.to_models(), "scalar")
            if backend == "jax":
                from .modelbank_jax import JaxModelBank

                return cls(None, "jax", jbank=JaxModelBank.from_bank(source))
            return cls.from_bank(source)
        models = list(source)
        if not vectorize:
            return cls(models, "scalar")
        return cls.from_models(models, backend=backend)

    # -- shape / access ------------------------------------------------------

    @property
    def p(self) -> int:
        if self._models is not None:
            return len(self._models)
        if self._np_bank is not None:
            return self._np_bank.p
        return self._jbank.p

    def __len__(self) -> int:
        return self.p

    @property
    def models(self) -> List[SpeedModel]:
        """The live scalar estimates (materialized from the bank if the store
        was built as a pure bank wrapper)."""
        self._ensure_models()
        return self._models

    def _ensure_models(self) -> None:
        if self._models is not None:
            return
        if self._np_bank is not None:
            self._models = self._np_bank.to_models()
        else:
            self._models = self._jbank.to_bank().to_models()

    def to_models(self) -> List[SpeedModel]:
        return list(self.models)

    @property
    def num_points(self) -> List[int]:
        """Observed points per model; models without a piecewise
        representation (``AnalyticModel``, ``ConstantModel``) count as 1 —
        they are always evaluable."""
        if self._models is not None:
            return [getattr(m, "num_points", 1) for m in self._models]
        return [int(c) for c in np.asarray(self.bank().counts)]

    def bank(self) -> ModelBank:
        """Numpy-bank snapshot of the current estimates (rebuilt from the
        scalar models, exactly like the legacy per-call banking)."""
        if self._models is None:
            if self._np_bank is not None:
                return self._np_bank
            return self._jbank.to_bank()
        return ModelBank.from_models(self._models)

    def _carry(self):
        """The jax device carry; rebuilt lazily from the scalar models after
        an invalidation (straggler reprofile), exactly like the legacy
        ``BalanceController._carry_bank``."""
        if self._jbank is None:
            self._jbank = self._initial_carry(self._models, self.dtype)
        return self._jbank

    def device_bank(self, *, snapshot: bool = True):
        """``JaxModelBank`` view.  On the jax backend this is the
        incrementally maintained carry; otherwise built from the models on
        demand.  With ``snapshot=True`` the result is copied on platforms
        where ``fold_in`` donates its carry, so later folds cannot invalidate
        the caller's reference."""
        from .modelbank_jax import DONATES_CARRY, JaxModelBank

        if self.backend == "jax":
            jb = self._carry()
        elif self._np_bank is not None and self._models is None:
            jb = JaxModelBank.from_bank(self._np_bank, dtype=self.dtype)
        else:
            jb = JaxModelBank.from_models(self.models, dtype=self.dtype)
        return jb.copy() if (snapshot and DONATES_CARRY) else jb

    def drop_carry(self) -> None:
        """Invalidate the device carry (rebuilt lazily from the models)."""
        self._ensure_models()
        self._jbank = None

    # -- the model-query protocol --------------------------------------------

    def speeds(self, x) -> np.ndarray:
        """Batched ``s_i(x_i)`` as a host ``[p]`` array (NaN on empty rows)."""
        if self.backend == "jax":
            return np.asarray(self._carry().speed(np.asarray(x, dtype=np.float64)))
        if self.backend == "numpy":
            return self.bank().speed(x)
        x = np.broadcast_to(np.asarray(x, dtype=np.float64), (self.p,))
        out = np.empty(self.p, dtype=np.float64)
        for i, m in enumerate(self.models):
            if getattr(m, "num_points", 1) == 0:
                out[i] = np.nan
            else:
                out[i] = m.speed(float(x[i]))
        return out

    def times(self, x) -> np.ndarray:
        """Batched ``t_i(x_i) = x_i / s_i(x_i)`` (0 for non-positive x)."""
        if self.backend == "jax":
            return np.asarray(self._carry().time(np.asarray(x, dtype=np.float64)))
        if self.backend == "numpy":
            return self.bank().time(x)
        x = np.broadcast_to(np.asarray(x, dtype=np.float64), (self.p,))
        out = np.empty(self.p, dtype=np.float64)
        for i, m in enumerate(self.models):
            if getattr(m, "num_points", 1) == 0:
                out[i] = np.nan if x[i] > 0 else 0.0
            else:
                out[i] = m.time(float(x[i]))
        return out

    def alloc_at_time(self, t: float, caps) -> np.ndarray:
        """Batched ``max { x in [0, cap_i] : x / s_i(x) <= t }``."""
        if self.backend == "jax":
            return np.asarray(
                self._carry().alloc_at_time(t, np.asarray(caps, dtype=np.float64))
            )
        if self.backend == "numpy":
            return self.bank().alloc_at_time(t, caps)
        caps = np.broadcast_to(np.asarray(caps, dtype=np.float64), (self.p,))
        return np.asarray(
            [m.alloc_at_time(t, float(c)) for m, c in zip(self.models, caps)]
        )

    # -- observation fold-in -------------------------------------------------

    def fold_in(self, x, s, valid: Optional[Sequence[bool]] = None) -> "SpeedStore":
        """Insert one observation ``(x_i, s_i)`` per processor (the paper's
        step-5 model update) into the scalar estimates and, on the jax
        backend, into the device carry — one vectorized sorted insert instead
        of a host rebuild.  Rows with ``valid[i] == False`` are untouched.
        Mutates the store in place and returns it."""
        self._ensure_models()
        xs = [float(v) for v in np.broadcast_to(np.asarray(x, dtype=np.float64), (self.p,))]
        ss = [float(v) for v in np.broadcast_to(np.asarray(s, dtype=np.float64), (self.p,))]
        vv = (
            [bool(v) for v in np.broadcast_to(np.asarray(valid, dtype=bool), (self.p,))]
            if valid is not None
            else [True] * self.p
        )
        for i, (xi, si, ok) in enumerate(zip(xs, ss, vv)):
            if ok:
                self._models[i].add_point(xi, si)
        if self.backend == "jax":
            self._jbank = self._carry().fold_in(xs, ss, vv)
        self.fold_generation += 1
        tel = _obs_active()
        if tel is not None and tel.enabled:
            tel.counter("speedstore.fold_in")
            tel.gauge("speedstore.fold_generation", self.fold_generation)
        return self

    # -- the energy sub-store (core/energy.py) -------------------------------

    @property
    def energy(self) -> Optional["SpeedStore"]:
        """The attached energy sub-store (None until ``attach_energy`` /
        ``fold_energy``)."""
        return self._energy

    @property
    def has_energy(self) -> bool:
        return self._energy is not None

    def attach_energy(self, models: Sequence[SpeedModel]) -> "SpeedStore":
        """Attach per-processor energy-rate models (``er_i(x) = x / E_i(x)``,
        built from measured ``(x, energy)`` samples with
        ``energy.energy_model``) as a sub-store on THIS store's backend, so
        energy partitions ride the same scalar/numpy/jax path as speed.
        Returns the store."""
        models = list(models)
        if len(models) != self.p:
            raise ValueError(
                f"need {self.p} energy models (one per processor), got {len(models)}"
            )
        es = SpeedStore.from_models(models, backend=self.backend, dtype=self.dtype)
        if self.backend in ("numpy", "jax") and es.backend != self.backend:
            raise TypeError(
                "energy models need a piecewise representation to ride the "
                f"banked {self.backend!r} backend (sample-and-bank them first)"
            )
        self._energy = es
        return self

    def fold_energy(self, x, energy, valid: Optional[Sequence[bool]] = None) -> "SpeedStore":
        """Insert one measured ``(x_i, energy_i)`` observation per processor
        into the energy estimates — the energy twin of :meth:`fold_in`, with
        the rate conversion ``er = x / E`` done here.  Non-positive /
        non-finite energies (and rows with ``valid[i] == False``) are
        skipped.  Creates an empty energy sub-store on first fold."""
        if self._energy is None:
            self._energy = SpeedStore.empty(
                self.p, backend=self.backend, dtype=self.dtype
            )
        xs = np.broadcast_to(np.asarray(x, dtype=np.float64), (self.p,))
        es = np.broadcast_to(np.asarray(energy, dtype=np.float64), (self.p,))
        vv = (
            np.broadcast_to(np.asarray(valid, dtype=bool), (self.p,))
            if valid is not None
            else np.ones(self.p, dtype=bool)
        )
        ok = vv & (xs > 0.0) & (es > 0.0) & np.isfinite(es) & np.isfinite(xs)
        rates = np.where(ok, xs / np.where(es > 0.0, es, 1.0), 1.0)
        self._energy.fold_in(xs, rates, ok)
        return self

    def energy_at(self, d) -> np.ndarray:
        """Per-processor energies ``E_i(d_i)`` under the current energy
        estimates (0 for ``d_i <= 0``)."""
        if self._energy is None:
            raise ValueError(
                "no energy models attached; call attach_energy() or fold_energy()"
            )
        return self._energy.times([float(v) for v in np.broadcast_to(np.asarray(d), (self.p,))])

    def fleet_energy(self, d) -> float:
        """Total fleet energy ``sum_i E_i(d_i)`` (rows without units or
        without estimates contribute 0)."""
        e = self.energy_at(d)
        darr = np.broadcast_to(np.asarray(d, dtype=np.float64), (self.p,))
        return float(np.where((darr > 0.0) & np.isfinite(e), e, 0.0).sum())

    def pareto_front(
        self, n: int, caps=None, *, min_units: int = 0, num_points: int = 17,
        completion: str = "auto",
    ):
        """The makespan/total-energy Pareto front of integer partitions
        (``core.energy.ParetoFront``): endpoints are exactly the
        ``objective="time"`` and ``objective="energy"`` solutions, interior
        points are energy solves under time-threshold-tightened caps — one
        stacked ``[T, p, k]`` program on the jax backend, bit-identical to
        the numpy sweep."""
        if self._energy is None:
            raise ValueError(
                "no energy models attached; call attach_energy() or fold_energy()"
            )
        from .energy import pareto_front as _pareto_front

        icaps = _prep_unit_caps(self.p, n, caps, min_units)
        return _pareto_front(
            self, self._energy, int(n), icaps,
            min_units=min_units, num_points=num_points, completion=completion,
        )

    def reset_row(self, i: int, points: Sequence[Tuple[float, float]] = ()) -> None:
        """Replace processor ``i``'s estimate (straggler reprofile: keep only
        the supplied points, typically the freshest operating point).  The
        device carry is dropped and rebuilt lazily."""
        self._ensure_models()
        self._models[i] = (
            PiecewiseLinearFPM.from_points(points) if points else PiecewiseLinearFPM()
        )
        self._jbank = None

    # -- the partitioners (backend pre-resolved) ------------------------------

    def partition_continuous(
        self, n: float, caps=None, *, rel_tol: float = 1e-12, max_steps: int = 200
    ) -> Tuple[List[float], float]:
        """Continuous optimal partition (allocations, t*)."""
        p = self.p
        if p == 0:
            raise ValueError("no processors")
        if n <= 0:
            return [0.0] * p, 0.0
        if self.backend == "jax":
            caps_l = _prep_continuous_caps(p, float(n), caps)
            xs, t_star = self._carry().partition_continuous(
                float(n), caps_l, rel_tol=rel_tol, max_steps=max_steps
            )
            return [float(v) for v in xs], float(t_star)
        if self.backend == "numpy":
            return _continuous_bank(self.bank(), float(n), caps, rel_tol=rel_tol, max_steps=max_steps)
        return _continuous_scalar(self.models, float(n), caps, rel_tol=rel_tol, max_steps=max_steps)

    def partition_units(
        self, n: int, caps=None, *, min_units: int = 0, completion: str = "auto",
        objective: str = "time", energy_cap: Optional[float] = None,
    ) -> List[int]:
        """Integer partition of ``n`` units (allocations only)."""
        return self.partition(
            n, caps, min_units=min_units, completion=completion,
            objective=objective, energy_cap=energy_cap,
        )[0]

    def partition(
        self, n: int, caps=None, *, min_units: int = 0, completion: str = "auto",
        objective: str = "time", energy_cap: Optional[float] = None,
    ) -> Tuple[List[int], float]:
        """Integer partition plus the continuous solve's ``t*`` (free — the
        unit partition bisects it anyway).

        ``completion`` routes the integer completion on the banked backends
        (see the "completion modes" section in ``modelbank.py``): ``"auto"``
        — threshold-count on the *jax* backend iff the bank's monotone-time
        flag holds, the exact per-unit loop otherwise and always on the
        numpy host path (where the heap was never the bottleneck);
        ``"greedy"`` / ``"threshold"`` force a mode.  The scalar backend
        always runs its exact per-unit loop and refuses ``"threshold"``.

        ``objective`` selects what the geometric solve balances (see
        ``core/energy.py``; ``"energy"``/``"pareto"`` need energy models
        attached): ``"time"`` is the unchanged (bit-identical) default;
        ``"energy"`` runs the SAME kernel on the energy bank — the returned
        scalar is the equal-ENERGY point; ``"pareto"`` computes the
        makespan/energy front and picks the knee — or, with ``energy_cap``,
        the fastest point whose total energy fits the budget (``energy_cap``
        with any objective routes through the front; the returned scalar is
        the picked point's predicted makespan).
        """
        if completion not in ("auto", "threshold", "greedy"):
            raise ValueError(f"unknown completion mode {completion!r}")
        if objective not in ("time", "energy", "pareto"):
            raise ValueError(f"unknown objective {objective!r}")
        if (objective != "time" or energy_cap is not None) and self._energy is None:
            raise ValueError(
                f"objective={objective!r}/energy_cap need energy models; "
                "call attach_energy() or fold_energy() first"
            )
        if objective == "energy" and energy_cap is None:
            return self._energy.partition(
                n, caps, min_units=min_units, completion=completion
            )
        if objective == "pareto" or energy_cap is not None:
            front = self.pareto_front(
                n, caps, min_units=min_units, completion=completion
            )
            idx = front.pick(energy_cap)
            return [int(v) for v in front.allocations[idx]], float(front.times[idx])
        p = self.p
        icaps = _prep_unit_caps(p, n, caps, min_units)
        tel = _obs_active()
        rec = tel is not None and tel.enabled
        if rec:
            t0 = tel.clock()
        if self.backend == "jax":
            d, t_star = self._carry().partition_units(
                n, icaps, min_units=min_units, with_t=True, completion=completion
            )
            if rec:
                # the jax bisection runs its fixed-trip loop on device, so
                # there is no host iteration count to report
                tel.span_at("speedstore.partition", t0, tel.clock(),
                            n=int(n), backend="jax")
            return [int(v) for v in d], float(t_star)
        if self.backend == "numpy":
            out = _partition_units_bank(
                self.bank(), n, icaps, min_units=min_units, completion=completion
            )
        else:
            if completion == "threshold":
                raise ValueError(
                    "the scalar backend has no threshold completion; use a banked "
                    "backend or completion='auto'/'greedy'"
                )
            out = _partition_units_scalar(
                self.models, n, icaps, min_units=min_units
            )
        if rec:
            from . import partition as _partition_mod

            tel.gauge(
                "speedstore.bisection_steps",
                _partition_mod._LAST_BISECTION_STEPS,
            )
            tel.span_at("speedstore.partition", t0, tel.clock(),
                        n=int(n), backend=self.backend)
        return out

    # -- derived metrics ------------------------------------------------------

    def imbalance_estimate(self, d: Sequence[int]) -> float:
        """Predicted imbalance of distribution ``d`` under the current
        estimates (groups without points or units are ignored)."""
        pts = self.num_points
        ts = [
            float(t)
            for t, di, k in zip(self.times([float(v) for v in d]), d, pts)
            if di > 0 and k > 0 and np.isfinite(t)
        ]
        return imbalance(ts)

    # -- persistence ----------------------------------------------------------

    def state_dict(self) -> Dict:
        """Checkpointable estimates.  Raises ``TypeError`` for models with no
        piecewise representation (sample-and-bank them first)."""
        points = []
        for m in self.models:
            if not hasattr(m, "as_points"):
                if isinstance(m, ConstantModel):
                    points.append([(1.0, float(m.s))])
                    continue
                raise TypeError(
                    f"{type(m).__name__} has no piecewise representation; "
                    "build the store with analytic_tol to sample-and-bank it"
                )
            points.append([(float(x), float(s)) for x, s in m.as_points()])
        state = {
            "backend": self.backend,
            "points": points,
            "dtype": np.dtype(self.dtype).name if self.dtype is not None else None,
        }
        if self._energy is not None:
            state["energy_points"] = self._energy.state_dict()["points"]
        return state

    @classmethod
    def from_state(cls, state: Dict, *, backend: Optional[str] = None) -> "SpeedStore":
        models = [PiecewiseLinearFPM.from_points(p) for p in state["points"]]
        dtype = state.get("dtype")
        store = cls.from_models(
            models,
            backend=backend or state.get("backend", "numpy"),
            dtype=np.dtype(dtype) if dtype is not None else None,
        )
        if state.get("energy_points"):
            store.attach_energy(
                [PiecewiseLinearFPM.from_points(p) for p in state["energy_points"]]
            )
        return store
