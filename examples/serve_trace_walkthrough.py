"""Serving under traffic, in miniature: one drifting fleet, one straggler.

A small walkthrough of the serving-epoch loop the traffic-trace harness
(``benchmarks/serve_trace.py``) runs at scale, built on the trace-driven
fleet executor ``TraceExecutor2D`` — the ground-truth time function takes
the TRACE CLOCK, so speeds drift as functions of *when* a round runs:

  1. converge two tenants through ``FleetScheduler.run`` (measured rounds);
  2. per serving epoch: ``rebalance`` -> one ``run_jobs`` round at the
     current trace instant -> ``straggler_actions`` (scan BEFORE fold) ->
     ``observe`` (fold the epoch's times into the stacked carry);
  3. a replica starts a runaway decay mid-trace: watch the strike automaton
     escalate REPROFILE -> QUARANTINE on exactly that replica, then resize
     the fleet through the survivors (detector strikes remapped).

    PYTHONPATH=src python examples/serve_trace_walkthrough.py
"""

import math

import numpy as np

from repro.core.executor import TraceExecutor2D
from repro.fleet import FleetScheduler, JobSpec
from repro.runtime.straggler import StragglerAction

P = 4
DT = 2.0  # trace seconds per epoch
BASE = np.array([800.0, 700.0, 400.0, 350.0])  # chunks/s at t=0
THROTTLE_AT = 30.0  # trace seconds; replica 2 then decays x0.6 per epoch


def speeds_at(t: float) -> np.ndarray:
    """Per-replica speeds at trace time t: slow sinusoidal drift, plus the
    runaway decay on replica 2 once the throttle kicks in."""
    drift = 1.0 + 0.15 * np.sin(2.0 * math.pi * t / 240.0 + np.arange(P))
    s = BASE * drift
    if t >= THROTTLE_AT:
        s[2] *= max(0.6 ** ((t - THROTTLE_AT) / DT + 1.0), 0.05)
    return s


ex = TraceExecutor2D(
    time_fn_trace_2d=lambda X, t: X / speeds_at(t)[None, :],
    p=P,
    noise=0.01,
    rng=np.random.default_rng(0),
)

# -- 1. converge two tenants (measured rounds, one stacked program each) -----
fleet = FleetScheduler(P, backend="jax", alpha=0.0, beta=0.0,
                       reserve_knots=32, quantize=0.05)
fleet.admit(JobSpec(name="chat", n=1200, eps=0.08, min_units=1, max_iter=12))
fleet.admit(JobSpec(name="embed", n=400, eps=0.08, min_units=1, max_iter=12))
res = fleet.run(ex)
for name, part in res.items():
    print(f"converged {name:6s} d={part.allocations} "
          f"(imbalance {part.imbalance:.3f})")

# -- 2. serving epochs: rebalance -> serve -> scan -> fold -------------------
quarantined = None
for epoch in range(24):
    ex.now = epoch * DT
    ds = fleet.rebalance({"chat": None, "embed": None})
    names = list(ds)
    T = ex.run_jobs(names, [ds[nm] for nm in names])
    times = {nm: [float(v) for v in T[k]] for k, nm in enumerate(names)}
    acts = fleet.straggler_actions(times)  # predictions are pre-fold
    fleet.observe(times)
    wall = ex.logs[-1].wall_cost
    for i, act in enumerate(acts):
        if act is not StragglerAction.NONE:
            print(f"epoch {epoch:2d} (t={ex.now:5.1f}s) replica {i}: "
                  f"{act.value.upper():10s} wall {wall:.3f}s")
    if StragglerAction.QUARANTINE in acts:
        quarantined = acts.index(StragglerAction.QUARANTINE)
        break

# -- 3. drop the quarantined replica: survivors keep their estimates --------
assert quarantined == 2, "the throttled replica must be the one quarantined"
survivors = [i for i in range(P) if i != quarantined]
old = fleet
fleet = FleetScheduler(len(survivors), backend="jax", alpha=0.0, beta=0.0,
                       reserve_knots=32, quantize=0.05,
                       detector=old.detector.remap(survivors))
sub = TraceExecutor2D(
    time_fn_trace_2d=lambda X, t: X / speeds_at(t)[None, survivors],
    p=len(survivors), noise=0.01, rng=np.random.default_rng(1), now=ex.now,
)
for name, n in (("chat", 1200), ("embed", 400)):
    fleet.admit(JobSpec(name=name, n=n, eps=0.08, min_units=1, max_iter=6))
res = fleet.run(sub)
for name, part in res.items():
    print(f"resized   {name:6s} d={part.allocations} over replicas "
          f"{survivors} (imbalance {part.imbalance:.3f})")
print(f"total simulated serving: {ex.total_cost + sub.total_cost:.2f}s "
      f"across {len(ex.logs) + len(sub.logs)} fleet rounds")
