"""Shared layers: norms, RoPE, MLPs, embeddings, softcaps.

All apply-functions are pure: ``apply(params, x, ...) -> y``; spec builders
return ``ParamSpec`` trees (see ``repro.nn.params``).  A leading ``stack``
axis on every spec supports scan-over-layers stacking (added by the caller
via ``stacked()``).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.params import ParamSpec

__all__ = [
    "stacked",
    "norm_spec",
    "apply_norm",
    "mlp_spec",
    "apply_mlp",
    "embedding_spec",
    "softcap",
    "rope",
]


def stacked(spec, n: int):
    """Prepend a ``layers`` stacking dim of size ``n`` to every leaf spec."""

    def f(l: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + l.shape, ("layers",) + l.axes, l.dtype, l.init, l.scale)

    return jax.tree_util.tree_map(f, spec, is_leaf=lambda x: isinstance(x, ParamSpec))


# -- normalization -----------------------------------------------------------


def norm_spec(d: int, kind: str = "rmsnorm") -> Dict:
    spec = {"scale": ParamSpec((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        spec["bias"] = ParamSpec((d,), ("embed",), init="zeros")
    return spec


def apply_norm(params: Dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm (gemma-style: scale applied as (1 + scale) when init zeros;
        # we init scale to ones and multiply directly)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


# -- MLPs ---------------------------------------------------------------------


def mlp_spec(d: int, d_ff: int, kind: str) -> Dict:
    if kind in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d, d_ff), ("embed", "mlp")),
            "wi_up": ParamSpec((d, d_ff), ("embed", "mlp")),
            "wo": ParamSpec((d_ff, d), ("mlp", "embed")),
        }
    if kind == "gelu":
        return {
            "wi": ParamSpec((d, d_ff), ("embed", "mlp")),
            "wo": ParamSpec((d_ff, d), ("mlp", "embed")),
        }
    raise ValueError(f"unknown mlp kind {kind}")


def apply_mlp(params: Dict, x: jax.Array, kind: str) -> jax.Array:
    dtype = x.dtype
    if kind in ("swiglu", "geglu"):
        g = x @ params["wi_gate"].astype(dtype)
        u = x @ params["wi_up"].astype(dtype)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g, approximate=True)
        return (act * u) @ params["wo"].astype(dtype)
    h = jax.nn.gelu(x @ params["wi"].astype(dtype), approximate=True)
    return h @ params["wo"].astype(dtype)


# -- embeddings ---------------------------------------------------------------


def embedding_spec(vocab: int, d: int) -> Dict:
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"), init="normal", scale=1.0)}


# -- misc ---------------------------------------------------------------------


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    theta: float = 10000.0,
    fraction: float = 1.0,
) -> jax.Array:
    """Rotary embedding on the last dim of ``x``: (..., seq, heads, head_dim).

    ``positions``: (..., seq) int32.  ``fraction`` < 1 rotates only the first
    ``fraction * head_dim`` features (stablelm partial rotary).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    angles = angles[..., None, :]  # broadcast over heads: (..., seq, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = out.astype(x.dtype)
    if x_pass.shape[-1]:
        return jnp.concatenate([out, x_pass], axis=-1)
    return out
