"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local(4096)/global alternating attention, attn-logit softcap 50, final
softcap 30, GeGLU, sandwich post-norms, head_dim 256 [arXiv:2408.00118; hf].
"""

import math

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=("local", "attn"),  # alternating sliding-window / global
    window=4096,
    mlp_kind="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    rope_theta=10000.0,
    query_scale=1.0 / math.sqrt(256),
    tie_embeddings=True,
    embed_scale=math.sqrt(2304),
    train_accum=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-2b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=8,
        query_scale=1.0 / math.sqrt(16),
        embed_scale=8.0,
        xent_chunk=0,
        remat="none",
    )
