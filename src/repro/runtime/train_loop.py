"""Training step factory: mixed precision, gradient accumulation, sharding.

The DFPA integration point: a group's step processes ``A`` microbatches
(units) via an inner ``lax.scan`` — gradient accumulation length IS the
paper's per-processor allocation ``d_i``.  Different groups jit the same
program with different ``A``; shapes inside one program stay static (the
SPMD constraint, DESIGN.md §2).

Overlap note: inter-group (DCN) gradient reduction is dispatched as soon as
the local accumulation finishes while the host prepares the next step's
units (async dispatch); intra-step, XLA overlaps the FSDP all-gathers with
compute under the sharding rules.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.encdec import encdec_loss, encdec_spec
from ..models.transformer import lm_loss, lm_spec
from ..nn.params import init_tree
from ..optim import AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "init_train_state", "make_train_step", "loss_for_config"]


class TrainState(NamedTuple):
    params: Any  # fp32 master weights
    opt: AdamWState
    step: jax.Array  # () int32


def model_spec_for(cfg: ModelConfig):
    return encdec_spec(cfg) if cfg.is_encdec else lm_spec(cfg)


def loss_for_config(cfg: ModelConfig) -> Callable:
    return (lambda p, b: encdec_loss(p, cfg, b)) if cfg.is_encdec else (
        lambda p, b: lm_loss(p, cfg, b)
    )


def init_train_state(cfg: ModelConfig, key: jax.Array, *, moment_dtype=None) -> TrainState:
    params = init_tree(key, model_spec_for(cfg))
    return TrainState(
        params=params,
        opt=adamw_init(params, moment_dtype=moment_dtype),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    cfg: ModelConfig,
    lr_schedule: Callable[[jax.Array], jax.Array],
    *,
    accum_steps: int = 1,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
    b1: float = 0.9,
    b2: float = 0.95,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``accum_steps == 1``: batch leaves are (B, ...).
    ``accum_steps == A > 1``: batch leaves are (A, mb, ...) — one leading
    unit dim, scanned; gradients averaged over units.
    """
    loss_fn = loss_for_config(cfg)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params
        if accum_steps == 1:
            # accept a stacked single unit (1, mb, ...) from unit batchers
            tok = batch.get("tokens")
            if tok is not None and tok.ndim == 3 and tok.shape[0] == 1:
                batch = jax.tree_util.tree_map(lambda a: a[0], batch)
            loss, metrics, grads = grads_of(params, batch)
        else:
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, micro):
                g_acc, l_acc = acc
                loss, _, grads = grads_of(params, micro)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros(())), batch, length=accum_steps,
                unroll=cfg.unroll_scans,
            )
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {}

        lr = lr_schedule(state.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads,
            state.opt,
            params,
            lr=lr,
            b1=b1,
            b2=b2,
            weight_decay=weight_decay,
            max_grad_norm=max_grad_norm,
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
