"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352.  SwiGLU, partial rotary 25%, untied head
[hf:stabilityai/stablelm-2-12b; hf].
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=10000.0,
    rope_fraction=0.25,
    tie_embeddings=False,
    train_accum=4,
    attn_chunk_threshold=4096,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="stablelm-12b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        xent_chunk=0,
        remat="none",
    )
