"""One config dataclass covering all assigned architecture families."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # Block pattern: layer kinds cycled over the depth.  Kinds:
    #   attn   — global attention block
    #   local  — sliding-window attention block
    #   rec    — RG-LRU recurrent block (recurrentgemma)
    #   mlstm / slstm — xLSTM blocks
    pattern: Tuple[str, ...] = ("attn",)
    # Unscanned leading layers (deepseek-v2's dense first layer).
    prefix: Tuple[str, ...] = ()
    prefix_dense_ff: int = 0  # d_ff of the dense prefix layer(s)

    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu (non-gated)
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm

    # Attention options
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    window: int = 0  # sliding-window size for 'local' layers
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    query_scale: float = 0.0  # 0 -> 1/sqrt(head_dim)
    post_norms: bool = False  # gemma2 sandwich (post-attn/post-mlp norms)
    tie_embeddings: bool = True
    embed_scale: float = 1.0  # gemma multiplies embeddings by sqrt(d_model)

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # MLA (deepseek-v2)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # Recurrent blocks
    d_rnn: int = 0
    conv_width: int = 4

    # Encoder-decoder (seamless)
    encoder_layers: int = 0
    encoder_pattern: Tuple[str, ...] = ("attn",)

    # Modality frontend STUBS: input_specs() supplies precomputed embeddings.
    frontend: str = "none"  # none | vision_stub | audio_stub
    num_prefix_embeddings: int = 0  # patches prepended to the text sequence

    # Attention memory policy: full-sequence (no-cache/prefill) attention
    # switches to a scan-over-query-chunks path (flash-attention schedule in
    # pure jnp) once Sq exceeds the threshold — bounds live logits to
    # (B, q_chunk, S) instead of (B, S, S).
    attn_chunk_threshold: int = 8192
    attn_q_chunk: int = 1024

    # Loss / numerics
    zloss: float = 0.0
    logit_dtype: Any = jnp.float32
    dtype: Any = jnp.bfloat16
    # Cross-entropy computed in sequence chunks of this size (0 = unchunked);
    # bounds the live [B, chunk, V] logits buffer for 256k vocabularies.
    xent_chunk: int = 512

    # Distribution knobs (overridable per run)
    remat: str = "full"  # none | full — remat policy for scanned blocks
    scan_layers: bool = True
    # Gradient-accumulation microbatches per train step (the DFPA unit
    # count of one step).  Big configs need A > 1 to bound activation
    # transients; global batch semantics are unchanged.
    train_accum: int = 1
    # Dry-run analysis mode: unroll inner lax.scans (xent chunks, chunked
    # attention, mlstm chunks) so XLA's cost analysis counts every trip —
    # scan bodies are otherwise counted ONCE, silently under-reporting
    # flops/collectives.  Semantics identical; compile time higher.
    unroll_scans: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")
        scanned = self.num_layers - len(self.prefix)
        if self.scan_layers and scanned % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: {scanned} scanned layers not divisible by pattern {self.pattern}"
            )

    # -- derived ------------------------------------------------------------

    @property
    def num_units(self) -> int:
        return (self.num_layers - len(self.prefix)) // len(self.pattern)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if decode-state size is O(1) in context length — the archs
        that run the long_500k shape."""
        quad = any(k in ("attn",) for k in self.pattern + self.prefix + (self.encoder_pattern if self.is_encdec else ()))
        return not quad

    def replace(self, **kw) -> "ModelConfig":
        return replace(self, **kw)
