"""Pipelined fleet rounds: a straggler lane keeps measuring while a
converged lane rebalances immediately.

``FleetScheduler(pipeline=True)`` restructures the round loop over
double-buffered fold-in carries (see "Round lifecycle: sync vs pipelined"
in ``fleet/scheduler.py``): round r's observations fold into the newest
carry while round r+1's stacked repartition is pre-dispatched against the
previous one — a SPECULATIVE read, consumed only when it advances every
job's trajectory (validated against the per-job seen sets), so a
deterministic replay stays bit-identical to the sync fleet while a live
serving fleet overlaps its device programs with host work.

Part 1 shows the mechanics on a mixed fleet: a ``straggler`` tenant still
deep in its DFPA measurement rounds shares the carry with a ``steady``
tenant that converged long ago and only rebalances.  The steady lane's
rebalance partitions against the previous fold generation — it never
waits on the straggler's in-flight fold — and the counters show which
speculative reads were consumed and which fell back to the fresh carry
(the fallback is what keeps the trajectory at the sync fixed point).

Part 2 shows where the overlap pays on the clock: a fully-converged
serving fleet whose epochs are ``rebalance()`` + ``observe(times)``.  The
sync epoch serializes fold -> partition; the pipelined epoch reads the
double-buffered carry and fetches the partition ``observe`` pre-dispatched
while the previous fold was still in flight (the same regime
``benchmarks/fleet_scale.py`` gates with its ``pipeline_*`` columns).

    PYTHONPATH=src python examples/fleet_pipeline_walkthrough.py
"""

import time

import numpy as np

from repro.core import BatchedSimulatedExecutor2D, PiecewiseLinearFPM
from repro.fleet import FleetScheduler, JobSpec


def make_fleet_truth(q, p, seed):
    """Per-(job, replica) plateau/knee ground truth + 6-point warm banks."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-4, 5e-4, (q, p))
    knee = rng.uniform(30.0, 120.0, (q, p))

    def time_fn(X):  # X[q, p] -> T[q, p]
        return X * base * (1.0 + np.where(X > knee, 3.0 * (X - knee) / knee, 0.0))

    def learned(j):
        models = []
        for i in range(p):
            xs = np.geomspace(4.0, 8.0 * knee[j, i], 6)
            ts = xs * base[j, i] * (
                1.0
                + np.where(xs > knee[j, i], 3.0 * (xs - knee[j, i]) / knee[j, i], 0.0)
            )
            models.append(PiecewiseLinearFPM.from_points(list(zip(xs, xs / ts))))
        return models

    return time_fn, learned, base, knee


# --- Part 1: straggler lane overlapping a converged lane's rebalance --------
P = 8
time_fn, learned, base, knee = make_fleet_truth(2, P, seed=42)

fleet = FleetScheduler(P, backend="jax", pipeline=True, pipeline_depth=1)
fleet.admit(JobSpec(name="steady", n=400, eps=0.1, min_units=1), models=learned(0))
fleet.admit(JobSpec(name="straggler", n=640, eps=0.01, min_units=1, max_iter=10))
ex = BatchedSimulatedExecutor2D(
    time_fn_batch_2d=time_fn, p=P, q=2, job_names=["steady", "straggler"]
)

print("Part 1 — mixed fleet, pipeline_depth=1:")
for epoch in range(8):
    fleet.step(ex)  # the straggler's DFPA measurement round
    # the converged lane's serving cycle: its load drifts, its rebalance
    # reads the PREVIOUS fold generation — no wait on the in-flight fold
    ds = fleet.rebalance({"steady": 400 + epoch})
    x = np.asarray(ds["steady"], dtype=np.float64)
    t = x * base[0] * (1.0 + np.where(x > knee[0], 3.0 * (x - knee[0]) / knee[0], 0.0))
    fleet.observe({"steady": [float(v) for v in t]})
strag = fleet.snapshot("straggler")
print(
    f"  straggler: iterations={strag.iterations} imbalance={strag.imbalance:.4f}"
    f"  |  steady kept serving every epoch"
)
print(
    f"  speculative stale reads consumed: {fleet.stale_reads}, "
    f"misses (fell back to the fresh carry): {fleet.speculative_misses}, "
    f"pre-dispatched partitions: {fleet.predispatches}"
)
print(
    "  a consumed read overlapped the straggler's fold; a miss means the\n"
    "  stale estimates taught that lane nothing new, so the round paid the\n"
    "  same fresh partition sync would have — never more.\n"
)

# --- Part 2: the steady-state serving win (every tenant converged) ----------
Q = 8
time_fn, learned, base, knee = make_fleet_truth(Q, 64, seed=7)
names = [f"tenant-{j}" for j in range(Q)]


def serve_epochs(pipeline):
    fl = FleetScheduler(64, backend="jax", pipeline=pipeline, pipeline_depth=1)
    for j in range(Q):
        fl.admit(
            JobSpec(name=names[j], n=6400 + 7 * j, eps=1e-12, min_units=1),
            models=learned(j),
        )
    walls = []
    for epoch in range(12):
        t0 = time.perf_counter()
        ds = fl.rebalance()  # one stacked partition for all tenants
        obs = {}
        for j, nm in enumerate(names):
            x = np.asarray(ds[nm], dtype=np.float64)
            t = x * base[j] * (
                1.0 + np.where(x > knee[j], 3.0 * (x - knee[j]) / knee[j], 0.0)
            )
            obs[nm] = [float(v) for v in t]
        fl.observe(obs)  # one stacked fold (+ pre-dispatch when pipelined)
        walls.append(time.perf_counter() - t0)
    return fl, walls[3:]  # skip compile epochs


print("Part 2 — steady-state serving epochs (rebalance + observe), q=8 p=64:")
fl_sync, w_sync = serve_epochs(False)
fl_pipe, w_pipe = serve_epochs(True)
ms, mp = np.median(w_sync) * 1e3, np.median(w_pipe) * 1e3
print(f"      sync: {ms:7.2f} ms/epoch  (fold -> partition serialized)")
print(
    f" pipelined: {mp:7.2f} ms/epoch  ({ms / mp:.2f}x — "
    f"{fl_pipe.stale_reads} stale reads, "
    f"{fl_pipe.predispatches} pre-dispatched partitions)"
)
