from .pipeline import SyntheticLMData, UnitBatcher

__all__ = ["SyntheticLMData", "UnitBatcher"]
