"""Logical-axis -> mesh-axis sharding rules (MaxText-style) with
divisibility fallback.

Parameters/activations carry *logical* axis names (``repro.nn.ParamSpec``);
this module maps them onto the physical mesh:

  * ``batch``  -> ("pod", "data")   — data parallelism across pods & slices;
  * ``embed``  -> ("data",)         — FSDP / ZeRO-3 parameter sharding;
  * ``heads/kv_heads/mlp/vocab/experts/rnn`` -> ("model",) — tensor/expert
    parallelism;
  * everything else replicated.

Fallbacks keep every (arch x mesh) cell lowerable instead of failing:
  1. a mesh axis already used by an earlier dim of the same tensor is
     skipped (e.g. MoE ``wi: (experts, embed, mlp)`` — ``experts`` takes
     ``model``, so ``mlp`` replicates);
  2. a mesh axis whose size does not divide the dim is dropped (granite's
     kv=1 MQA replicates KV heads instead of failing on model=16).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..nn.params import ParamSpec, axes_tree

__all__ = [
    "LOGICAL_RULES",
    "logical_to_pspec",
    "batch_pspec",
    "shardings_for_axes",
    "shardings_for_spec",
]

LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "rnn": ("model",),
    "seq": (),  # sequence parallelism is opt-in via override rules
    "seq_kv": ("model",),  # KV-cache sequence sharding (MLA / MQA decode)
    "lora": (),
    "head_dim": (),
    "layers": (),
    "stack": (),
    "conv": (),
    "null": (),
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_pspec(
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    shape: Optional[Sequence[int]] = None,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> P:
    """Map logical axes -> PartitionSpec under ``mesh`` with fallbacks."""
    rules = rules or LOGICAL_RULES
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    out = []
    for i, name in enumerate(axes):
        entry: Tuple[str, ...] = ()
        if name is not None and name != "null":
            entry = tuple(a for a in rules.get(name, ()) if a in sizes)
        # fallback 1: drop already-used mesh axes
        entry = tuple(a for a in entry if a not in used)
        # fallback 2: divisibility — drop trailing axes until they divide
        if shape is not None and entry:
            dim = shape[i]
            while entry:
                prod = 1
                for a in entry:
                    prod *= sizes[a]
                if dim % prod == 0:
                    break
                entry = entry[:-1]
        used.update(entry)
        if len(entry) == 0:
            out.append(None)
        elif len(entry) == 1:
            out.append(entry[0])
        else:
            out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def batch_pspec(mesh: Mesh, batch: Optional[int] = None) -> P:
    """PartitionSpec for a leading-batch tensor under ``mesh``."""
    return logical_to_pspec(("batch",), mesh, (batch,) if batch else None)


def shardings_for_axes(axes_tree_, mesh: Mesh, shapes_tree=None, rules=None):
    """Tree of logical-axes tuples -> tree of NamedShardings."""

    def one(axes, shape=None):
        return NamedSharding(mesh, logical_to_pspec(axes, mesh, shape, rules))

    if shapes_tree is None:
        return jax.tree_util.tree_map(
            one, axes_tree_, is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x
            )
        )
    return jax.tree_util.tree_map(
        lambda a, s: one(a, s.shape if hasattr(s, "shape") else s),
        axes_tree_,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


def shardings_for_spec(spec_tree, mesh: Mesh, rules=None):
    """ParamSpec tree -> NamedSharding tree (shape-aware fallback)."""

    def one(l: ParamSpec):
        return NamedSharding(mesh, logical_to_pspec(l.axes, mesh, l.shape, rules))

    return jax.tree_util.tree_map(one, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
