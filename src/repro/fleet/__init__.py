"""Fleet: multi-tenant scheduling over one heterogeneous platform.

The layer above the single-job ``Scheduler`` facade: a
:class:`FleetScheduler` admits many concurrent jobs (:class:`JobSpec` each),
keeps ONE stacked ``[q, p, k]`` device bank as a donated carry, and runs
every admitted job's DFPA measurement round in one device program per fleet
round — one stacked repartition, one batched measurement
(:class:`~repro.core.executor.FleetExecutor`), one stacked fold-in.  Results
are bit-identical to q independent ``Scheduler.autotune`` loops.

:class:`ProfileRegistry` persists the partial speed-function estimates
across sessions, keyed by (device class, workload tag), so admitted jobs
warm-start from prior measurements instead of cold probes.
"""

from .registry import ProfileRegistry
from .scheduler import FleetScheduler, JobSpec

__all__ = ["FleetScheduler", "JobSpec", "ProfileRegistry"]
