"""Substrate: sharding rules, data pipeline, optimizer, compression,
checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data import SyntheticLMData, UnitBatcher
from repro.nn.params import ParamSpec, axes_tree, init_tree, param_count
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_bf16,
    compress_int8_ef,
    decompress_int8,
    warmup_cosine,
)
from repro.sharding import logical_to_pspec


# ---------------------------------------------------------------------------
# Sharding rules (pure functions of mesh metadata — use a tiny local mesh)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    # single-device "mesh" cannot exercise the rules; fake via axis sizes
    # by reshaping the one device is impossible -> use mesh of shape (1, 1)
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


class _FakeMesh:
    """Rules only read axis_names and device shape — fake a 16x16 mesh."""

    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)

    shape = {"data": 16, "model": 16}


def test_rules_basic():
    m = _FakeMesh()
    assert logical_to_pspec(("embed", "heads", "head_dim"), m, (64, 32, 16)) == P("data", "model")
    assert logical_to_pspec(("batch",), m, (256,)) == P("data")


def test_rules_conflict_resolution():
    m = _FakeMesh()
    # experts take model; mlp can't reuse it
    ps = logical_to_pspec(("experts", "embed", "mlp"), m, (32, 64, 128))
    assert ps == P("model", "data")


def test_rules_divisibility_fallback():
    m = _FakeMesh()
    # kv_heads=1 can't shard 16 ways -> replicated
    ps = logical_to_pspec(("embed", "kv_heads", "head_dim"), m, (64, 1, 16))
    assert ps == P("data")
    # odd dim drops the axis
    ps = logical_to_pspec(("embed",), m, (65,))
    assert ps == P()


def test_param_spec_validation():
    with pytest.raises(ValueError):
        ParamSpec((4, 4), ("embed",))  # rank mismatch


def test_init_tree_deterministic():
    spec = {"a": ParamSpec((4, 8), ("embed", "mlp")), "b": {"c": ParamSpec((8,), ("mlp",), init="ones")}}
    t1 = init_tree(jax.random.PRNGKey(1), spec)
    t2 = init_tree(jax.random.PRNGKey(1), spec)
    for x, y in zip(jax.tree_util.tree_leaves(t1), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert param_count(spec) == 4 * 8 + 8


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = get_smoke_config("granite-20b")
    d1 = SyntheticLMData(cfg, batch=2, seq=16, seed=3)
    b0, b1 = d1.next(), d1.next()
    state = d1.state_dict()
    b2 = d1.next()
    d2 = SyntheticLMData(cfg, batch=2, seq=16, seed=3)
    d2.load_state_dict(state)
    b2r = d2.next()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_smoke_config("granite-20b")
    b = SyntheticLMData(cfg, batch=2, seq=16).next()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_unit_batcher_split_matches_distribution():
    cfg = get_smoke_config("granite-20b")
    data = SyntheticLMData(cfg, batch=2, seq=8)
    batcher = UnitBatcher(data, micro_batch=2)
    units = batcher.global_step_units(10, step=0)
    assert units["tokens"].shape == (10, 2, 8)
    parts = batcher.split(units, [3, 5, 2])
    assert [p["tokens"].shape[0] for p in parts] == [3, 5, 2]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), units["tokens"]
    )


def test_unit_batcher_steps_disjoint():
    cfg = get_smoke_config("granite-20b")
    data = SyntheticLMData(cfg, batch=2, seq=8)
    batcher = UnitBatcher(data, micro_batch=2)
    u0 = batcher.global_step_units(4, step=0)
    u1 = batcher.global_step_units(4, step=1)
    assert not np.array_equal(u0["tokens"], u1["tokens"])
    # step replay is deterministic
    u0r = batcher.global_step_units(4, step=0)
    np.testing.assert_array_equal(u0["tokens"], u0r["tokens"])


# ---------------------------------------------------------------------------
# Optimizer + schedules + compression
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for i in range(300):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(
            g, state, params, lr=jnp.float32(0.1), weight_decay=0.0
        )
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.ones((3,)) * 4.0}  # norm ~ 6.93
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(48.0))
    total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100, floor=0.1)
    assert float(s(0)) == pytest.approx(0.1)
    assert float(s(9)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(s(55)) < float(s(20))


def test_compress_bf16_roundtrip():
    g = {"w": jnp.array([1.0, 2.5, -3.25])}
    c = compress_bf16(g)
    assert c["w"].dtype == jnp.bfloat16


@given(
    vals=st.lists(st.floats(-10, 10, allow_nan=False), min_size=4, max_size=32)
)
@settings(max_examples=50, deadline=None)
def test_int8_error_feedback_unbiased_over_time(vals):
    """Repeated compression of the same gradient with error feedback: the
    ACCUMULATED decompressed sum approaches the accumulated true sum."""
    g = {"w": jnp.array(vals, jnp.float32)}
    err = {"w": jnp.zeros_like(g["w"])}
    acc = jnp.zeros_like(g["w"])
    T = 20
    for _ in range(T):
        q, s, err = compress_int8_ef(g, err)
        acc = acc + decompress_int8(q, s)["w"]
    scale = float(jnp.max(jnp.abs(g["w"]))) + 1e-6
    drift = float(jnp.max(jnp.abs(acc / T - g["w"]))) / scale
    assert drift < 0.02


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.array(7, jnp.int32),
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        save_checkpoint(d, 7, t)
        like = jax.tree_util.tree_map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
        back, man = load_checkpoint(d, like)
        assert man["step"] == 7
        np.testing.assert_array_equal(np.asarray(back["params"]["w"]), np.asarray(t["params"]["w"]))


def test_checkpoint_latest_pointer_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in [1, 2, 3]:
            mgr.save_async(s, _tree())
            mgr.wait()
        assert mgr.latest_step() == 3
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2  # retention


def test_checkpoint_missing_key_fails_loud():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros(3)})
        like = {"a": jax.ShapeDtypeStruct((3,), jnp.float32), "b": jax.ShapeDtypeStruct((2,), jnp.float32)}
        with pytest.raises(KeyError):
            load_checkpoint(d, like)


def test_checkpoint_atomic_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, _tree())
        assert not [x for x in os.listdir(d) if x.startswith("tmp.")]


def test_checkpoint_dtype_cast_on_restore():
    """Elastic/precision restore: checkpoint fp32 -> restore as bf16."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"w": jnp.ones((4,), jnp.float32)})
        like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
        back, _ = load_checkpoint(d, like)
        assert back["w"].dtype == jnp.bfloat16


def test_adamw_bf16_moments_still_converges():
    """bf16 optimizer moments (the 200B+ memory lever) keep convergence."""
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert state.mu["w"].dtype == jnp.bfloat16
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(
            g, state, params, lr=jnp.float32(0.1), weight_decay=0.0
        )
    assert state.mu["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1
