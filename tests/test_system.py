"""End-to-end behaviour: the paper's system running as a whole.

1. Heterogeneous multi-group training with online DFPA rebalancing
   (simulated group speeds, real jit'd steps) — the self-adaptable
   application of the paper, in miniature.
2. Serving dispatch balanced by DFPA across heterogeneous replicas.
3. Checkpoint/restore of model + balance state (self-adaptation survives
   restarts — including an elastic group change).
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import SimulatedExecutor, dfpa, imbalance
from repro.data import SyntheticLMData, UnitBatcher
from repro.optim.schedule import warmup_cosine
from repro.runtime.balance import BalanceController
from repro.runtime.elastic import elastic_rebalance
from repro.runtime.serve_loop import ReplicaDispatcher, ServeEngine
from repro.runtime.train_loop import init_train_state, make_train_step, model_spec_for
from repro.nn.params import init_tree

KEY = jax.random.PRNGKey(0)


def test_hetero_training_rebalances_and_learns():
    """4 heterogeneous groups; DFPA shifts units toward fast groups while
    the model trains (loss decreases)."""
    cfg = get_smoke_config("granite-moe-1b-a400m")
    state = init_train_state(cfg, KEY)
    sched = warmup_cosine(3e-3, 2, 40)
    n_units, groups = 16, 4
    hetero = [1.0, 1.0, 2.0, 4.0]  # last group 4x slower
    data = SyntheticLMData(cfg, batch=2, seq=16)
    batcher = UnitBatcher(data, micro_batch=2)
    ctrl = BalanceController(n_units=n_units, num_groups=groups, eps=0.2, smooth=1.0)
    step_fns = {}
    losses = []
    for i in range(10):
        units = batcher.global_step_units(n_units, i)
        parts = batcher.split(units, ctrl.d)
        times = []
        for g, part in enumerate(parts):
            a = ctrl.d[g]
            if a == 0:
                times.append(0.0)
                continue
            if a not in step_fns:
                step_fns[a] = jax.jit(make_train_step(cfg, sched, accum_steps=a))
            gb = {k: jnp.asarray(v) for k, v in part.items()}
            new_state, m = step_fns[a](state, gb)
            # emulated heterogeneity: deterministic per-unit cost
            times.append(a * 0.01 * hetero[g])
            if g == 0:
                keep_state, loss = new_state, float(m["loss"])
        state = keep_state
        losses.append(loss)
        ctrl.observe(times)
    # fast groups got more units than the 4x-slow group
    assert ctrl.d[3] < ctrl.d[0]
    t_final = [d * 0.01 * h for d, h in zip(ctrl.d, hetero)]
    assert imbalance(t_final) <= 0.6
    assert losses[-1] < losses[0]


def test_serving_dispatch_balances():
    rng = np.random.default_rng(1)
    base = rng.uniform(1e-4, 5e-4, 4)

    def replica_run(i, x):
        t = x * base[i]
        if x > 24:
            t += (x - 24) * base[i] * 5.0  # spill knee
        return t

    disp = ReplicaDispatcher(replica_run, 4, eps=0.1)
    res = disp.balance(64)
    assert sum(res.d) == 64
    assert res.imbalance <= 0.1 or not res.converged
    times = [replica_run(i, d) for i, d in enumerate(res.d)]
    even = max(replica_run(i, 16) for i in range(4))
    assert max(times) <= even  # never worse than the even split


def test_generate_deterministic_greedy():
    cfg = get_smoke_config("xlstm-350m")
    params = init_tree(KEY, model_spec_for(cfg))
    eng = ServeEngine(cfg, params, batch=2, seq_budget=24)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out1 = eng.generate(toks, 8)
    out2 = eng.generate(toks, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 8)


def test_full_state_checkpoint_with_balance_and_elastic_restart():
    cfg = get_smoke_config("gemma2-2b")
    state = init_train_state(cfg, KEY)
    ctrl = BalanceController(n_units=12, num_groups=3, eps=0.1, smooth=1.0)
    ctrl.observe([1.0, 2.0, 3.0])
    data = SyntheticLMData(cfg, batch=2, seq=16)
    data.next()

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(
            d, 1, {"train": state},
            extra={"balance": ctrl.state_dict(), "data": data.state_dict()},
        )
        like = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), {"train": state}
        )
        restored, man = load_checkpoint(d, like)
        # model state identical
        for a, b in zip(
            jax.tree_util.tree_leaves(restored["train"].params),
            jax.tree_util.tree_leaves(state.params),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # balance state: warm restart + elastic change (drop group 0)
        ctrl2 = BalanceController.from_state(man["extra"]["balance"], eps=0.1)
        assert ctrl2.d == ctrl.d
        ctrl3 = elastic_rebalance(ctrl2, surviving=[1, 2])
        assert sum(ctrl3.d) == 12
        # data pipeline resumes at the right index
        assert man["extra"]["data"]["next_index"] == 1


def test_dfpa_paper_narrative_end_to_end():
    """The quickstart story: unknown 4-processor cluster, balanced in a few
    rounds at a tiny fraction of the work."""
    fns = [
        lambda x: x / 100.0,
        lambda x: x / 250.0,
        lambda x: x / 60.0 if x < 500 else x / 60.0 * (1 + (x - 500) / 200.0),
        lambda x: x / 180.0,
    ]
    ex = SimulatedExecutor(time_fns=fns)
    res = dfpa(ex, 2000, eps=0.1, min_units=1)
    assert res.converged
    assert res.imbalance <= 0.1
    assert res.iterations <= 12
