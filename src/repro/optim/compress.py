"""Gradient compression for the inter-group (DCN) all-reduce.

Groups in the DFPA training runtime synchronize gradients over the slow
cross-pod fabric once per global step; compression cuts those bytes:

  * ``compress_bf16`` — 2x: cast fp32 grads to bf16 for the wire;
  * ``compress_int8_ef`` — 4x: per-tensor absmax int8 quantization with
    ERROR FEEDBACK: the quantization residual is carried into the next
    step's gradient, making the compression unbiased over time (Seide et
    al. / DGC-style).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_bf16", "compress_int8_ef", "decompress_int8"]


def compress_bf16(grads):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads)


def compress_int8_ef(grads, error: Any) -> Tuple[Any, Any, Any]:
    """Returns (q_int8_tree, scales_tree, new_error_tree).

    ``error`` is the carried residual pytree (zeros at step 0).
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        return q, scale, new_e

    qs, scales, errs = [], [], []
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    for g, e in zip(flat_g, flat_e):
        q, s, ne = one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    unf = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
    return unf(qs), unf(scales), unf(errs)


def decompress_int8(q_tree, scales_tree):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales_tree
    )
