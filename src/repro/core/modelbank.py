"""Vectorized bank of piecewise-linear FPMs — the batched model core.

The paper's headline requirement is that the cost of computing an optimal
distribution must be *orders of magnitude* below the application time for
self-adaptability to pay off.  The scalar path (``fpm.PiecewiseLinearFPM`` +
``partition.partition_units``) evaluates ``alloc_at_time`` one processor at a
time in Python, so every bisection step on ``t*`` costs a ``p``-long Python
loop over per-model segment scans — fine for the paper's 15-node HCL cluster,
hopeless for fleets of thousands of device groups.

``ModelBank`` stores all ``p`` models as padded 2-D arrays:

  * ``xs[p, k_max]`` — sorted observed problem sizes, right-padded by
    repeating each row's last point (padding segments have zero length and
    are masked out);
  * ``ss[p, k_max]`` — the speeds at those points, padded the same way;
  * ``counts[p]``    — number of valid points per row (0 = empty model).

and evaluates the three model queries for the WHOLE bank in single numpy
passes:

  * ``speed(x)`` / ``time(x)`` — batched piecewise-linear interpolation with
    constant extension outside the observed range (identical semantics to
    ``PiecewiseLinearFPM.speed``/``time`` elementwise);
  * ``alloc_at_time(t, caps) -> [p]`` — the closed-form per-segment
    feasibility test ``x (1 - t m) <= t (s0 - m x0)`` evaluated for every
    segment of every processor at once.

The bank is the inner loop of the vectorized partitioners in
``partition.py``: one bisection step on ``t*`` becomes one ``total_alloc``
array op instead of ``p`` Python calls.  The scalar ``SpeedModel`` protocol
survives as a thin adapter (``row()`` / ``to_models()``), so existing call
sites keep working unchanged.

Three backends, one semantics
-----------------------------

Three implementations of the same partitioning algorithm coexist, and
``tests/test_modelbank_jax.py`` fuzz-locks them together:

* **scalar** (``fpm.py`` + the ``_scalar`` helpers in ``partition.py``) —
  one Python object per processor.  Selected automatically when a model has
  no piecewise representation (``AnalyticModel``: FFMPA baselines, oracle
  partitions over raw time functions) or explicitly with
  ``vectorize=False``.  This is the semantics reference: both banked paths
  mirror its closed-form per-segment feasibility test expression for
  expression.
* **numpy bank** (this module; the default, ``backend="numpy"``) — padded
  ``[p, k]`` arrays on the host, one numpy pass per bisection step, lazy-heap
  integer completion.  The right path for host-side control loops at any
  fleet size; no accelerator or warm-up required.
* **jax bank** (``modelbank_jax.py``, ``backend="jax"``) — the same padded
  layout as device arrays, the whole ``t*`` search and greedy completion
  under ``jax.jit`` (fixed-iteration ``lax.fori_loop`` bisection,
  masked-argmin completion), plus ``fold_in`` so DFPA and the
  ``BalanceController`` keep the bank as a device-resident carry across
  rounds.  Pick it when repartitioning must compose with a jitted training
  step or run at high frequency: after the one-time compile a repartition
  costs microseconds.  With x64 enabled its element-wise float ops are
  IEEE-identical to numpy's, and allocations match the numpy bank
  bit-for-bit; in float32 they may differ by a unit.

All three raise the same ``ValueError`` s on infeasible inputs (``sum(caps)
< n``, ``min_units * p > n``, any ``cap < min_units``, empty models with
positive caps), and all three produce allocations that sum exactly to ``n``
with identical makespans (tie-breaks may place a leftover unit differently
only between the scalar and banked continuous solvers' float paths).

**The two-level (hierarchical) path** composes the same three backends one
level up.  Given a ``groups[p]`` assignment, :func:`aggregate_groups` builds
one *group-level* speed function per group — the pointwise
sum-of-speeds-at-equal-time composition ``X_G(t) = sum_{i in G}
alloc_i(t, cap_i)``, sampled at the union of the members' knot times (plus
their cap-crossing times), so the aggregate is exact at every sampled knot
and piecewise-interpolated between them.  The aggregate is monotone-time BY
CONSTRUCTION (its knots are sampled at sorted times, so the segment
inequality ``x0 s1 <= x1 s0`` reduces to ``t0 <= t1``), which means the
threshold-count completion is always exact at the group level regardless of
the members' shapes.  ``core/hierarchy.py`` then solves the outer ``t*``
bisection over the ``[g, k_g]`` group bank — O(g k_g) instead of O(p k) —
and scatters each group's integer share to an inner per-group partition on
the group's own ``[p_g, k]`` sub-bank: per-group host solves on numpy,
one ``lax.map`` program over cache-resident ``[g, p_max, k]`` blocks on
jax (``_hier_inner_jit``), and the same body ``shard_map``'d across devices
under ``sharding="shard_map"`` so no device touches more than its
``ceil(g/ndev)`` blocks.  One group reproduces the flat path bit-identically
(the outer trivially assigns it all ``n`` units and the inner IS the flat
kernel); multiple groups agree with the flat makespan to within the
interpolation error of the aggregate (fuzz-locked in
``tests/test_hierarchy.py``).

Time and energy, one bank layout
--------------------------------

The bi-objective extension (``core/energy.py``; ROADMAP direction 4) does
not add a fourth backend — it adds a SECOND bank in the same layout.  An
optional ``energy`` sub-bank (``es[p, k]``, attached with
:meth:`ModelBank.with_energy` / built by ``SpeedStore.attach_energy``)
stores per-processor *energy-rate* functions ``er_i(x) = x / E_i(x)``, so
``energy.time(x)`` IS the energy ``E_i(x)`` and every mechanism above —
padded layout, fold-in, stacking, monotone flags, the jitted ``t*``
bisection and threshold-count completion — serves the energy objective
verbatim.  ``energy_at(d)`` / ``fleet_energy(d)`` evaluate per-processor
and total energies of a distribution; ``objective="energy"`` partitions
run the SAME geometric kernel on the energy sub-bank (balancing
per-processor energies), and the makespan/energy Pareto front is a batched
sweep of time-threshold bisections — tightened caps
``min(cap_i, floor(alloc_time_i(t)))`` feeding stacked ``[T, p, k]``
energy solves (``energy.pareto_front``), numpy/jax bit-identical under the
same fuzz-parity regime as speed (``tests/test_energy.py``).

The fleet layer stacks the jax backend one level higher: q concurrent
jobs' banks live in ONE ``[q, p, k]`` ``JaxModelBank`` owned by
``repro.fleet.FleetScheduler`` (per-job ``n``/caps/``min_units`` and
per-lane completion routing ride the batch dims), so a whole fleet's
measurement round — or a ``Scheduler.partition_grid`` outer round, whose
per-column inner loops run through the same driver — is one device
program.  The stacked carry is derived state: the per-job scalar estimates
stay the source of truth, and the stack is rebuilt lazily when jobs come
and go.  ``repro.fleet.ProfileRegistry`` persists those estimates across
sessions keyed by ``(device_class, workload_tag)``.

Completion modes and the monotonicity contract
----------------------------------------------

The integer completion (placing the ``n - sum(floor(x_i))`` leftover units
after the continuous solve) has two implementations on the banked backends:

* **per-unit greedy** (``completion="greedy"``) — the semantics reference:
  each leftover unit goes to the processor minimizing
  ``(time(d_i + 1), -frac_remainder, index)``; a lazy heap on the numpy
  bank, a masked lexicographic-argmin ``while_loop`` on the jax bank.
  Exact for ANY speed estimate, but sequential: ~``p/2`` iterations.
* **threshold-count** (``completion="threshold"``) — for *monotone-time*
  banks only: when every row's per-unit time ``x / s_i(x)`` is nondecreasing
  in ``x``, the greedy processes unit increments in globally sorted
  ``(time, -rem, index)`` order, so the optimal completion collapses to one
  more bisection — count units under a candidate time threshold ``t`` via
  ``floor(alloc_at_time(t))`` (clamped to ``[d_i, cap_i]``), bisect ``t``
  until ``count(lo) < leftover <= count(hi)``, bulk-grant everything
  counted at ``lo``, and resolve only the handful of boundary-tied units
  with the exact greedy.  One ``O(p k)`` pass per bisection step instead of
  one ``O(p)`` argmin per unit — this is what makes ``p = 10^5`` fleets
  repartition in milliseconds, and because the boundary remainder runs
  through the *same* greedy, makespans (and in practice allocations) are
  bit-identical to the per-unit path.
* **auto** (the default) — backend-aware: on the *jitted* backends it picks
  threshold-count iff the bank's ``monotone`` flag holds (per-unit greedy
  otherwise), because the per-unit ``while_loop``'s serial dispatch was the
  p=10^4..10^5 bottleneck there; on the *numpy host* path it always keeps
  the lazy heap — the heap was never the host bottleneck, and the threshold
  pass costs ~one extra continuous solve (``bank_threshold_s`` vs
  ``bank_s`` in ``BENCH_partition.json`` records the tradeoff).  The flag
  is a host-side ``O(p k)`` check recorded lazily on the bank: time is
  nondecreasing on a linear segment iff its knot times are ordered
  (``x0 * s1 <= x1 * s0``), so a row is monotone iff its knots are sorted,
  its speeds positive and finite, and every consecutive knot pair satisfies
  that inequality.  Adversarial (non-monotone) banks — speed spikes,
  duplicate-``x`` rows whose replacing speed jumps up — are provably
  demoted to the exact per-unit loop (``tests/test_completion.py``); on a
  stacked ``[q, p, k]`` bank the routing is *per column*
  (``JaxModelBank.monotone_lanes``), so an adversarial column demotes only
  itself.  Forcing ``completion="threshold"`` on a non-monotone bank is a
  benchmark-only override with no exactness guarantee.

The scalar backend always runs its per-unit loop (asking it for
``"threshold"`` raises ``ValueError``).

Migration: free functions → Scheduler
-------------------------------------

The backend used to be chosen per call (``vectorize=`` / ``backend=``
kwargs, re-derived by ``_as_bank``-style dispatch helpers at every entry
point).  It is now chosen ONCE, at ``SpeedStore`` construction, and the
lifecycle lives on the ``Scheduler`` facade (``core/scheduler.py``).  The
old entry points still work but emit ``DeprecationWarning`` and delegate:

======================================================  =====================================================
legacy                                                  facade
======================================================  =====================================================
``partition_units(models, n, backend="jax")``           ``SpeedStore.from_models(models, backend="jax")``
                                                        ``    .partition_units(n)``
``partition_units(models, n, vectorize=False)``         ``SpeedStore.from_models(models, backend="scalar")``
``partition_continuous(models, n)``                     ``store.partition_continuous(n)``
(per-unit greedy completion, always)                    ``store.partition_units(n, completion=...)``
                                                        (``"auto"`` routes monotone banks to the
                                                        threshold-count completion)
(float64 device bank, always)                           ``SpeedStore.from_models(models, backend="jax",``
                                                        ``    dtype=np.float32)``
``cpm_partition(speeds, n)``                            ``Scheduler.from_speeds(speeds).partition(n)``
``dfpa(executor, n, eps, ...)``                         ``Scheduler().autotune(executor, n, eps, ...)``
``dfpa_partition_2d(grid, M, N, eps)``                  ``Scheduler(grid=grid, policy=Policy.GRID2D)``
                                                        ``    .partition_grid(M, N, eps=eps)``
``cpm_partition_2d`` / ``ffmpa_partition_2d``           same, with ``policy=Policy.CPM`` / ``Policy.FFMPA``
``bank_repartition_2d(fpms, widths, M)``                ``Scheduler(...).repartition_grid(...)``
``BalanceController(...).observe(times)``               ``Scheduler(n_units=..., num_groups=...)``
                                                        ``    .observe(times)``
``elastic_rebalance(ctrl, surviving, joined)``          ``sched.resize(...)`` / ``sched.join()`` /
                                                        ``sched.leave(g)``
``StragglerDetector`` wiring + ``det.reprofile``        ``sched.straggler_actions(times)`` (auto-reprofiles)
``ctrl.state_dict()`` (lost backend/smooth)             ``sched.state_dict()`` (full config round-trips)
(no energy objective)                                   ``sched.partition(n, objective="time"|"energy"``
                                                        ``    |"pareto", energy_cap=...)`` after
                                                        ``sched.attach_energy(energy_models)``
======================================================  =====================================================

Results are a typed ``Partition`` (allocations, ``t_star``, makespan,
imbalance, convergence, per-group diagnostics) instead of bare lists /
``DFPAResult`` / ``Grid2DResult``.  ``AnalyticModel`` consumers that want
the banked paths can sample-and-bank via
``SpeedStore.from_models(..., analytic_tol=..., analytic_hi=n)``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .fpm import ConstantModel, PiecewiseLinearFPM

__all__ = ["ModelBank", "aggregate_groups", "group_members"]

ArrayLike = Union[float, Sequence[float], np.ndarray]


def _monotone_check(xs: np.ndarray, ss: np.ndarray, counts: np.ndarray) -> bool:
    """Host-side monotone-time check over a padded bank (see
    :meth:`ModelBank.is_monotone`); one numpy pass, shared with the jax
    bank's host snapshot path."""
    k = xs.shape[-1]
    pts = np.arange(k) < counts[..., None]
    ok_pts = (xs > 0.0) & np.isfinite(xs) & (ss > 0.0) & np.isfinite(ss)
    if np.any(pts & ~ok_pts):
        return False
    if k >= 2:
        x0, x1 = xs[..., :-1], xs[..., 1:]
        s0, s1 = ss[..., :-1], ss[..., 1:]
        seg = np.arange(k - 1) < (counts - 1)[..., None]
        ok_seg = (x1 >= x0) & (x0 * s1 <= x1 * s0)
        if np.any(seg & ~ok_seg):
            return False
    return True


@dataclass
class ModelBank:
    """All ``p`` piecewise-linear FPMs as padded arrays (see module docstring)."""

    xs: np.ndarray  # [p, k_max] float64, row-sorted, padding repeats last point
    ss: np.ndarray  # [p, k_max] float64, padded the same way
    counts: np.ndarray  # [p] int64, number of valid points per row
    # Host-side monotone-time flag (None = unknown, computed lazily by
    # is_monotone()); routes the threshold-count integer completion.
    monotone: Optional[bool] = None
    # Optional energy sub-bank (same layout; ss holds energy RATES x/E(x),
    # so energy.time(x) == E(x)) — see the "time and energy" docstring
    # section and core/energy.py.
    energy: Optional["ModelBank"] = None

    # -- construction --------------------------------------------------------

    @classmethod
    def from_point_lists(
        cls, points: Sequence[Tuple[Sequence[float], Sequence[float]]]
    ) -> "ModelBank":
        """Build from per-processor ``(xs_i, ss_i)`` sorted point lists."""
        p = len(points)
        counts = np.array([len(px) for px, _ in points], dtype=np.int64)
        k_max = max(int(counts.max(initial=1)), 1)
        xs = np.zeros((p, k_max), dtype=np.float64)
        ss = np.zeros((p, k_max), dtype=np.float64)
        for i, (px, ps) in enumerate(points):
            c = len(px)
            if c == 0:
                continue
            xs[i, :c] = px
            ss[i, :c] = ps
            xs[i, c:] = px[-1]  # zero-length padding segments, masked later
            ss[i, c:] = ps[-1]
        return cls(xs=xs, ss=ss, counts=counts)

    @classmethod
    def from_models(cls, models: Sequence[object]) -> "ModelBank":
        """Adapt a sequence of scalar models into a bank.

        Accepts ``PiecewiseLinearFPM``, ``ConstantModel`` (becomes the
        single-point model ``{(1, s)}``, whose constant extension reproduces
        it exactly), and anything exposing ``as_points()``.  Raises
        ``TypeError`` for models with no piecewise representation (e.g.
        ``AnalyticModel``) — callers fall back to the scalar path.
        """
        pts: List[Tuple[List[float], List[float]]] = []
        for m in models:
            if isinstance(m, PiecewiseLinearFPM):
                pts.append((list(m.xs), list(m.ss)))
            elif isinstance(m, ConstantModel):
                pts.append(([1.0], [float(m.s)]))
            elif hasattr(m, "as_points"):
                pp = m.as_points()
                pts.append(([float(x) for x, _ in pp], [float(s) for _, s in pp]))
            else:
                raise TypeError(
                    f"{type(m).__name__} has no piecewise representation; "
                    "use the scalar partition path"
                )
        return cls.from_point_lists(pts)

    # -- shape ---------------------------------------------------------------

    @property
    def p(self) -> int:
        return self.xs.shape[0]

    def __len__(self) -> int:
        return self.p

    @property
    def num_points(self) -> np.ndarray:
        return self.counts

    # -- monotonicity (threshold-count completion routing) -------------------

    def is_monotone(self) -> bool:
        """True iff every row's time ``x / s_i(x)`` is nondecreasing on
        ``[0, inf)`` — the contract under which the threshold-count integer
        completion is exact (see the module docstring).

        On a linear segment ``s(x) = s0 + m (x - x0)`` the time derivative
        has the constant sign of ``s0 x1 - s1 x0``, so the whole row is
        monotone iff its knots are sorted, its speeds positive and finite,
        and the knot times ``x/s`` are nondecreasing (``x0 s1 <= x1 s0``).
        The constant extensions outside the observed range are always
        increasing.  Rows with non-positive / non-finite points (possible
        only in hand-built banks) demote the bank conservatively.  Computed
        once per bank, ``O(p k)``, and cached on the ``monotone`` field.
        """
        if self.monotone is None:
            self.monotone = _monotone_check(self.xs, self.ss, self.counts)
        return self.monotone

    # -- batched evaluation --------------------------------------------------

    def _edges(self):
        idx = np.arange(self.p)
        last = np.maximum(self.counts - 1, 0)
        return self.xs[idx, 0], self.ss[idx, 0], self.xs[idx, last], self.ss[idx, last]

    def speed(self, x: ArrayLike) -> np.ndarray:
        """Batched ``s_i(x_i)``; ``x`` is a scalar or a ``[p]`` vector.

        Empty rows evaluate to NaN (the scalar model raises there).
        """
        x = np.broadcast_to(np.asarray(x, dtype=np.float64), (self.p,))
        first_x, first_s, last_x, last_s = self._edges()
        # k = bisect_right(xs, x) - 1, batched; padding repeats last_x so it
        # never out-counts an interior x.
        k = np.sum(self.xs <= x[:, None], axis=1) - 1
        k = np.clip(k, 0, np.maximum(self.counts - 2, 0))
        idx = np.arange(self.p)
        kp1 = np.minimum(k + 1, self.xs.shape[1] - 1)
        x0, x1 = self.xs[idx, k], self.xs[idx, kp1]
        s0, s1 = self.ss[idx, k], self.ss[idx, kp1]
        denom = np.where(x1 > x0, x1 - x0, 1.0)
        w = (x - x0) / denom
        interior = s0 + w * (s1 - s0)
        s = np.where(x <= first_x, first_s, np.where(x >= last_x, last_s, interior))
        return np.where(self.counts > 0, s, np.nan)

    def time(self, x: ArrayLike) -> np.ndarray:
        """Batched ``t_i(x_i) = x_i / s_i(x_i)`` (0 for non-positive ``x``)."""
        x = np.broadcast_to(np.asarray(x, dtype=np.float64), (self.p,))
        with np.errstate(divide="ignore", invalid="ignore"):
            t = x / self.speed(x)
        return np.where(x > 0.0, t, 0.0)

    def alloc_at_time(self, t: float, caps: ArrayLike) -> np.ndarray:
        """Batched ``max { x in [0, cap_i] : x / s_i(x) <= t }`` -> ``[p]``.

        One numpy pass over every segment of every processor — the closed-form
        linear-inequality test of ``PiecewiseLinearFPM.alloc_at_time``,
        elementwise identical to the scalar implementation.
        """
        caps = np.broadcast_to(np.asarray(caps, dtype=np.float64), (self.p,))
        if t <= 0.0:
            return np.zeros(self.p, dtype=np.float64)
        first_x, first_s, last_x, last_s = self._edges()

        # Region [0, x_1]: constant speed ss[:, 0].
        best = np.minimum(t * first_s, np.minimum(first_x, caps))

        # Interior segments, all at once: s(x) = s0 + m (x - x0) on [x0, x1];
        # x <= t s(x)  <=>  x (1 - t m) <= t (s0 - m x0).
        k_max = self.xs.shape[1]
        if k_max >= 2:
            x0, x1 = self.xs[:, :-1], self.xs[:, 1:]
            s0, s1 = self.ss[:, :-1], self.ss[:, 1:]
            seg = np.arange(k_max - 1)[None, :]
            valid = (
                (seg < (self.counts - 1)[:, None])
                & (x0 < caps[:, None])
                & (x1 > x0)
            )
            x1c = np.minimum(x1, caps[:, None])
            denom = np.where(x1 > x0, x1 - x0, 1.0)
            m = (s1 - s0) / denom
            a = 1.0 - t * m
            b = t * (s0 - m * x0)
            with np.errstate(divide="ignore", invalid="ignore"):
                ub = b / np.where(a != 0.0, a, 1.0)
            cand = np.where(
                a > 0.0,
                np.where(ub >= x0, np.minimum(ub, x1c), 0.0),
                np.where(
                    a == 0.0,
                    np.where(b >= 0.0, x1c, 0.0),
                    np.where(x1c >= ub, x1c, 0.0),
                ),
            )
            cand = np.where(valid, cand, 0.0)
            best = np.maximum(best, cand.max(axis=1))

        # Region [x_m, cap]: constant speed ss[:, count-1].
        ub_r = t * last_s
        right = (caps > last_x) & (ub_r >= last_x) & (self.counts > 0)
        best = np.maximum(best, np.where(right, np.minimum(ub_r, caps), 0.0))

        return np.where((caps > 0.0) & (self.counts > 0), best, 0.0)

    def total_alloc(self, t: float, caps: ArrayLike) -> float:
        """``sum_i alloc_i(t)`` — one bisection step of the partitioner."""
        return float(self.alloc_at_time(t, caps).sum())

    # -- scalar access (greedy completion, adapters) -------------------------

    def speed_one(self, i: int, x: float) -> float:
        """Scalar ``s_i(x)`` for one row (used by the greedy unit completion)."""
        c = int(self.counts[i])
        if c == 0:
            raise ValueError("empty FPM row")
        xs, ss = self.xs[i], self.ss[i]
        if x <= xs[0]:
            return float(ss[0])
        if x >= xs[c - 1]:
            return float(ss[c - 1])
        k = int(np.searchsorted(xs[:c], x, side="right")) - 1
        w = (x - xs[k]) / (xs[k + 1] - xs[k])
        return float(ss[k] + w * (ss[k + 1] - ss[k]))

    def time_one(self, i: int, x: float) -> float:
        if x <= 0.0:
            return 0.0
        return x / self.speed_one(i, x)

    # -- the energy sub-bank (core/energy.py) --------------------------------

    def with_energy(self, energy: "ModelBank") -> "ModelBank":
        """Attach an energy sub-bank (same ``p``; ``ss`` holds energy rates
        ``x / E(x)``) — returns a new bank sharing this bank's arrays."""
        if energy.p != self.p:
            raise ValueError(
                f"energy bank has {energy.p} rows but speed bank has {self.p}"
            )
        return ModelBank(
            xs=self.xs, ss=self.ss, counts=self.counts,
            monotone=self.monotone, energy=energy,
        )

    def energy_at(self, d: ArrayLike) -> np.ndarray:
        """Per-processor energies ``E_i(d_i)`` of a distribution (0 for
        ``d_i <= 0``, NaN on empty energy rows with units)."""
        if self.energy is None:
            raise ValueError("no energy sub-bank attached (use with_energy)")
        return self.energy.time(d)

    def fleet_energy(self, d: ArrayLike) -> float:
        """Total fleet energy ``sum_i E_i(d_i)`` of a distribution."""
        return float(self.energy_at(d).sum())

    # -- transformations -----------------------------------------------------

    def scaled(self, speed_scale: ArrayLike) -> "ModelBank":
        """New bank with every row's speeds multiplied by ``speed_scale[i]``
        (the 2-D partitioner's column-width rescaling, batched).  A uniform
        positive per-row scale preserves time-monotonicity, so the cached
        flag carries over; any other scale resets it to unknown.  The energy
        sub-bank (problem-size semantics unchanged by a speed rescale)
        carries through untouched."""
        scale = np.broadcast_to(np.asarray(speed_scale, dtype=np.float64), (self.p,))
        return ModelBank(
            xs=self.xs.copy(),
            ss=self.ss * scale[:, None],
            counts=self.counts.copy(),
            monotone=self.monotone if bool(np.all(scale > 0.0)) else None,
            energy=self.energy,
        )

    # -- adapters back to the scalar protocol --------------------------------

    def row(self, i: int) -> PiecewiseLinearFPM:
        """Scalar ``SpeedModel`` view of one processor."""
        c = int(self.counts[i])
        return PiecewiseLinearFPM(xs=list(self.xs[i, :c]), ss=list(self.ss[i, :c]))

    def to_models(self) -> List[PiecewiseLinearFPM]:
        return [self.row(i) for i in range(self.p)]


# ---------------------------------------------------------------------------
# Group aggregation — the two-level partitioning path (core/hierarchy.py)
# ---------------------------------------------------------------------------


def _alloc_at_times(bank: ModelBank, ts: np.ndarray, caps: np.ndarray) -> np.ndarray:
    """``alloc_at_time`` for a whole VECTOR of candidate times at once:
    returns ``[T, p]``.  Expression-for-expression the scalar
    :meth:`ModelBank.alloc_at_time` with a leading time axis (same shape
    discipline as the jax ``_alloc_at_time``'s batched ``t``), so each row
    is bitwise what the scalar call would produce — the group aggregation
    samples K knots in three numpy passes instead of K."""
    ts = np.asarray(ts, dtype=np.float64)[:, None]  # [T, 1]
    caps2 = np.broadcast_to(np.asarray(caps, dtype=np.float64), (bank.p,))[None, :]
    first_x, first_s, last_x, last_s = bank._edges()

    best = np.minimum(ts * first_s[None, :], np.minimum(first_x[None, :], caps2))

    k_max = bank.xs.shape[1]
    if k_max >= 2:
        x0, x1 = bank.xs[None, :, :-1], bank.xs[None, :, 1:]
        s0, s1 = bank.ss[None, :, :-1], bank.ss[None, :, 1:]
        seg = np.arange(k_max - 1)[None, None, :]
        valid = (
            (seg < (bank.counts - 1)[None, :, None])
            & (x0 < caps2[..., None])
            & (x1 > x0)
        )
        x1c = np.minimum(x1, caps2[..., None])
        denom = np.where(x1 > x0, x1 - x0, 1.0)
        m = (s1 - s0) / denom
        tseg = ts[..., None]  # [T, 1, 1]
        a = 1.0 - tseg * m
        b = tseg * (s0 - m * x0)
        with np.errstate(divide="ignore", invalid="ignore"):
            ub = b / np.where(a != 0.0, a, 1.0)
        cand = np.where(
            a > 0.0,
            np.where(ub >= x0, np.minimum(ub, x1c), 0.0),
            np.where(
                a == 0.0,
                np.where(b >= 0.0, x1c, 0.0),
                np.where(x1c >= ub, x1c, 0.0),
            ),
        )
        cand = np.where(valid, cand, 0.0)
        best = np.maximum(best, cand.max(axis=-1))

    ub_r = ts * last_s[None, :]
    right = (caps2 > last_x[None, :]) & (ub_r >= last_x[None, :]) & (bank.counts > 0)[None, :]
    best = np.maximum(best, np.where(right, np.minimum(ub_r, caps2), 0.0))

    best = np.where((caps2 > 0.0) & (bank.counts > 0)[None, :], best, 0.0)
    return np.where(ts > 0.0, best, 0.0)


def group_members(groups: Sequence[int]) -> Tuple[List[int], List[np.ndarray]]:
    """Normalize a ``groups[p]`` assignment: returns the sorted unique group
    ids and, per group, the member processor indices in ascending order (the
    order the hierarchical scatter preserves)."""
    garr = np.asarray(groups)
    if garr.ndim != 1:
        raise ValueError("groups must be a 1-D per-processor assignment")
    gids = sorted(set(int(v) for v in garr))
    members = [np.flatnonzero(garr == g) for g in gids]
    return gids, members


def _aggregate_one(
    sub: ModelBank, caps: np.ndarray, max_knots: int
) -> Tuple[List[float], List[float]]:
    """One group's aggregate knots.

    The aggregate problem-size-at-time function is ``X(t) = sum_i
    alloc_i(t, cap_i)`` — exactly what one bisection step of the outer
    partitioner needs.  Knots are sampled at the union of the members'
    observed knot times ``x_ij / s_ij`` plus each member's cap-crossing time
    ``time_i(cap_i)`` (where its alloc saturates), so the aggregate is exact
    at every time any member's behaviour changes slope; between knots the
    bank's linear-in-speed interpolation approximates the true piecewise-
    rational composition.  Member caps are baked in (NOT the job size ``n``:
    the same aggregate serves any ``n``, and allocations above ``n`` cannot
    occur at the solution).  Sampling at sorted times makes the result
    monotone-time by construction: ``x0 s1 <= x1 s0`` with ``s = x/t``
    reduces to ``t0 <= t1``.

    Two refinements keep the interpolation honest between knot times:

    * a member's alloc can JUMP at a knot time — within a segment the
      implied time ``x / s(x)`` is a monotone hyperbola piece (``s``
      linear), so when it runs *decreasing* the whole segment becomes
      feasible the moment ``t`` reaches the far knot's time: a step, never
      an interior extremum.  A sample exactly at the knot time lands on TOP
      of that step; sampling each kept time again just below
      (``t (1 - 1e-9)``) pins the step's bottom, so the aggregate brackets
      the jump instead of interpolating across it;
    * between WIDELY separated knot times the sum of hyperbola/linear
      member pieces bends far from the bank's linear-in-speed
      interpolation, so a geometric fill of sample times spans the whole
      knot range — the gap ratio is bounded regardless of how the members'
      knots cluster.

    The knot budget splits ``max_knots`` as: up to 1/4 exact knot times,
    1/4 geometric fill, then the below-jump brackets double the kept set.
    """
    ts = _aggregate_times(sub, caps, max_knots)
    if ts.size == 0:
        return [], []
    caps_f = caps.astype(np.float64)
    k = sub.xs.shape[1]
    # _alloc_at_times materializes ~a dozen [T_chunk, p, k-1] temporaries;
    # chunk the time axis so each slab stays ~1 MB and the whole working set
    # L2-resident — the pass is memory-bandwidth bound, and cache blocking
    # here measures ~1.8x at fleet group shapes (p_g=1000, k~17) while also
    # keeping p ~ 10^5 member groups allocatable at p=10^6.  Chunk
    # boundaries cannot change any element's arithmetic, so the result is
    # bitwise independent of the chunk size.
    t_chunk = max(1, int(131_072 // max(sub.p * max(k, 1), 1)))
    xs_g = np.concatenate(
        [
            _alloc_at_times(sub, ts[i : i + t_chunk], caps_f).sum(axis=1)
            for i in range(0, ts.size, t_chunk)
        ]
    )
    return _points_from_samples(ts, xs_g)


def _aggregate_times(sub: ModelBank, caps: np.ndarray, max_knots: int) -> np.ndarray:
    """Sample times for one group's aggregate (see :func:`_aggregate_one`).

    Factored out of :func:`_aggregate_one` so the jax hierarchy backend can
    compute the sample grid on host (cheap, O(p k) with small constants)
    while evaluating the member allocations at those times on device.
    Returns a sorted, strictly positive, possibly empty float array.
    """
    k = sub.xs.shape[1]
    valid = (np.arange(k)[None, :] < sub.counts[:, None]) & (caps[:, None] > 0)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_pts = np.where(sub.ss > 0, sub.xs / sub.ss, np.nan)
    ts = t_pts[valid]
    active = (caps > 0) & (sub.counts > 0)
    if np.any(active):
        cap_t = sub.time(np.where(active, caps, 1.0))
        ts = np.concatenate([ts, cap_t[active]])
    ts = np.unique(ts[np.isfinite(ts) & (ts > 0)])
    if ts.size == 0:
        return ts
    quota = max(max_knots // 4, 2)
    if ts.size > quota:
        pick = np.unique(np.round(np.linspace(0, ts.size - 1, quota)).astype(int))
        ts = ts[pick]
    # Jump brackets FIRST, on the knot/cap-derived times only: member alloc
    # functions can step exactly AT a knot time, never between knots, so the
    # geometric fill below (curvature sampling in wide gaps) needs no
    # brackets — skipping them keeps the sampled grid (and the group bank's
    # knot count) ~25% smaller for the same accuracy.
    ts = np.unique(np.concatenate([ts, ts * (1.0 - 1e-9)]))
    if ts[-1] > ts[0]:
        ts = np.unique(np.concatenate([ts, np.geomspace(ts[0], ts[-1], quota)]))
    return ts


def _points_from_samples(
    ts: np.ndarray, xs_g: np.ndarray
) -> Tuple[List[float], List[float]]:
    """Turn sampled ``(time, aggregate size)`` pairs into bank knot lists."""
    keep = xs_g > 0
    # equal-X plateaus (all members capped): keep the FIRST (earliest-time,
    # fastest) occurrence — the true aggregate reaches that size then.
    keep &= np.concatenate([[True], np.diff(xs_g) > 0])
    ts, xs_g = ts[keep], xs_g[keep]
    return list(xs_g), list(xs_g / ts)


def aggregate_groups(
    bank: ModelBank,
    groups: Sequence[int],
    caps: Sequence[float],
    *,
    max_group_knots: int = 64,
) -> Tuple[ModelBank, np.ndarray, List[np.ndarray]]:
    """Build the ``[g, k_g]`` group-level bank for a ``groups[p]`` assignment.

    Returns ``(group_bank, group_caps, members)``: one aggregate row per
    group (see :func:`_aggregate_one`; ``max_group_knots`` bounds each row's
    knot count, keeping the outer solve O(g k_g)), the summed member caps,
    and the per-group member indices.  The group bank's ``monotone`` flag is
    set — true by construction — so the outer integer completion may always
    take the threshold-count bulk grant.  Groups with no capacity get an
    empty row and cap 0 (the outer solver allocates them nothing).
    """
    caps_arr = np.broadcast_to(np.asarray(caps, dtype=np.float64), (bank.p,))
    gids, members = group_members(groups)
    pts: List[Tuple[List[float], List[float]]] = []
    gcaps = np.zeros(len(gids), dtype=np.float64)
    for gi, idx in enumerate(members):
        sub = ModelBank(
            xs=bank.xs[idx], ss=bank.ss[idx], counts=bank.counts[idx]
        )
        gcaps[gi] = caps_arr[idx].sum()
        pts.append(_aggregate_one(sub, caps_arr[idx], max_group_knots))
    gbank = ModelBank.from_point_lists(pts)
    gbank.monotone = True  # by construction: knots sampled at sorted times
    return gbank, gcaps, members
