"""Pallas TPU kernels for the compute hot-spots + jnp oracles.

  * ``matmul_update``   — the paper's computational kernel (C += A.B panel
    update), adapted from the 2011 CPU cache-blocking design to TPU:
    MXU-aligned tiles, fp32 VMEM accumulator, K-innermost grid;
  * ``flash_attention`` — online-softmax attention (causal / sliding-window /
    logit-softcap / GQA) for the training & prefill paths;
  * ``rglru``           — chunked linear recurrence for RG-LRU (recurrentgemma).

Each kernel ships ``ref.py``-style oracles (pure jnp) and jit'd ``ops``
wrappers that pick interpret mode automatically off-TPU.
"""

from .ops import flash_attention, matmul_update, rglru_scan

__all__ = ["matmul_update", "flash_attention", "rglru_scan"]
