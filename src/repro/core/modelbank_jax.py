"""JAX-jitted ModelBank: the on-device third backend of the partitioner.

``JaxModelBank`` holds the same padded ``xs[p, k]`` / ``ss[p, k]`` /
``counts[p]`` layout as the numpy :class:`~repro.core.modelbank.ModelBank`,
as ``jnp`` arrays, and evaluates the same three model queries as pure array
ops.  The ``t*`` search of the geometric partitioner runs entirely on device:

  * exponential bracketing as a ``lax.while_loop`` (masked per batch element,
    so a stacked ``[q, p, k]`` bank bisects every column's ``t*``
    simultaneously);
  * bisection as a fixed-iteration ``lax.fori_loop`` carrying ``(lo, hi,
    done)`` — the ``done`` flag reproduces the numpy path's early-exit
    semantics exactly, so the two backends take bit-identical branch
    sequences;
  * the greedy integer completion as a masked lexicographic-argmin pass
    (smallest ``(time(d+1), -frac_remainder, index)``) instead of a Python
    heap — one ``O(p)`` argmin per leftover unit, with only the winning
    row's key recomputed, mirroring the lazy-heap refresh;
  * for monotone-time banks (the host-tracked ``monotone`` flag, see the
    "completion modes" section in ``modelbank.py``) the completion instead
    collapses into ONE more fixed-iteration bisection — count units under a
    time threshold via ``floor(alloc_at_time)``, bulk-grant below it, and
    run the argmin loop only for the boundary-tied remainder.  That removes
    the ~p/2 sequential ``while_loop`` iterations that tied the numpy heap
    at p=10^4 and is what lets p=10^5 fleets repartition in milliseconds
    (``benchmarks/partition_scale.py`` completion columns).

Every formula mirrors the numpy implementation expression-for-expression;
with float64 enabled (``jax.config.update("jax_enable_x64", True)`` or the
``jax.experimental.enable_x64`` context) the element-wise ops are IEEE-double
identical to numpy, so allocations match the numpy bank bit-for-bit (the
acceptance gate of ``benchmarks/partition_scale.py --backend jax``).  Without
x64 the math runs in float32 and allocations may differ by a unit — fine for
steering, not for the parity tests.

Dtype plumbing is explicit throughout: the bank's array dtype (float64 under
x64, float32 otherwise) flows into every constant and scalar operand, so no
silent upcasts/downcasts occur inside ``jit``.

``fold_in`` is the vectorized sorted insert that lets DFPA and the
``BalanceController`` keep the bank as a *device-resident carry* across
rounds — one ``[p]``-wide masked shift per round instead of rebuilding the
padded arrays from ``p`` scalar models (the ROADMAP's observation fold-in
item).  The carry buffers are donated to the update where the backend
supports donation (no-op on CPU).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .modelbank import ModelBank

__all__ = ["JaxModelBank", "enable_compilation_cache", "fetch_partition"]


def enable_compilation_cache(path: str) -> None:
    """Point jax's persistent compilation cache at ``path`` so a restarted
    session (or a cold CI runner) reuses compiled partition/fold kernels
    instead of re-tracing them — the Scheduler/FleetScheduler
    ``compilation_cache_dir=`` knob.  Idempotent; safe to call per session."""
    from jax.experimental.compilation_cache import compilation_cache as cc

    cc.set_cache_dir(str(path))
    try:
        # Our kernels compile in ~1-3s each; cache them all, not just the
        # ones above jax's default write threshold.
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:  # pragma: no cover - knob name varies across versions
        pass

# Buffer donation is a no-op (and warns) on CPU; donate the fold-in carry
# only where the platform actually reuses the buffers.  When donation is on,
# fold_in invalidates the previous bank's buffers — holders of snapshots
# (e.g. BalanceController.device_bank callers) must copy() first;
# DONATES_CARRY tells them whether that matters on this platform.
_DONATE = (0, 1, 2) if jax.default_backend() != "cpu" else ()
DONATES_CARRY = bool(_DONATE)


# ---------------------------------------------------------------------------
# Batched model queries (leading batch dims allowed: [..., p, k])
# ---------------------------------------------------------------------------


def _edges(xs, ss, counts):
    last = jnp.maximum(counts - 1, 0)
    last_x = jnp.take_along_axis(xs, last[..., None], axis=-1)[..., 0]
    last_s = jnp.take_along_axis(ss, last[..., None], axis=-1)[..., 0]
    return xs[..., 0], ss[..., 0], last_x, last_s


def _speed(xs, ss, counts, x):
    """Mirror of ``ModelBank.speed`` (NaN on empty rows)."""
    first_x, first_s, last_x, last_s = _edges(xs, ss, counts)
    k = jnp.sum(xs <= x[..., None], axis=-1) - 1
    k = jnp.clip(k, 0, jnp.maximum(counts - 2, 0))
    kp1 = jnp.minimum(k + 1, xs.shape[-1] - 1)
    x0 = jnp.take_along_axis(xs, k[..., None], axis=-1)[..., 0]
    x1 = jnp.take_along_axis(xs, kp1[..., None], axis=-1)[..., 0]
    s0 = jnp.take_along_axis(ss, k[..., None], axis=-1)[..., 0]
    s1 = jnp.take_along_axis(ss, kp1[..., None], axis=-1)[..., 0]
    one = jnp.asarray(1.0, xs.dtype)
    denom = jnp.where(x1 > x0, x1 - x0, one)
    w = (x - x0) / denom
    interior = s0 + w * (s1 - s0)
    s = jnp.where(x <= first_x, first_s, jnp.where(x >= last_x, last_s, interior))
    return jnp.where(counts > 0, s, jnp.asarray(jnp.nan, xs.dtype))


def _time(xs, ss, counts, x):
    zero = jnp.asarray(0.0, xs.dtype)
    return jnp.where(x > zero, x / _speed(xs, ss, counts, x), zero)


def _alloc_at_time(xs, ss, counts, t, caps):
    """Mirror of ``ModelBank.alloc_at_time``; ``t`` has the batch shape
    (scalar for a single bank, ``[q]`` for a stacked one)."""
    dt = xs.dtype
    zero, one = jnp.asarray(0.0, dt), jnp.asarray(1.0, dt)
    t = jnp.asarray(t, dt)
    tb = t[..., None]  # broadcast against [..., p]
    first_x, first_s, last_x, last_s = _edges(xs, ss, counts)

    # Region [0, x_1]: constant speed ss[..., 0].
    best = jnp.minimum(tb * first_s, jnp.minimum(first_x, caps))

    # Interior segments, all at once (static branch on the padded width).
    k_max = xs.shape[-1]
    if k_max >= 2:
        x0, x1 = xs[..., :-1], xs[..., 1:]
        s0, s1 = ss[..., :-1], ss[..., 1:]
        seg = jnp.arange(k_max - 1)
        valid = (
            (seg < (counts - 1)[..., None])
            & (x0 < caps[..., None])
            & (x1 > x0)
        )
        x1c = jnp.minimum(x1, caps[..., None])
        denom = jnp.where(x1 > x0, x1 - x0, one)
        m = (s1 - s0) / denom
        tseg = tb[..., None]  # against [..., p, k-1]
        a = one - tseg * m
        b = tseg * (s0 - m * x0)
        ub = b / jnp.where(a != zero, a, one)
        cand = jnp.where(
            a > zero,
            jnp.where(ub >= x0, jnp.minimum(ub, x1c), zero),
            jnp.where(
                a == zero,
                jnp.where(b >= zero, x1c, zero),
                jnp.where(x1c >= ub, x1c, zero),
            ),
        )
        cand = jnp.where(valid, cand, zero)
        best = jnp.maximum(best, cand.max(axis=-1))

    # Region [x_m, cap]: constant speed at the last observed point.
    ub_r = tb * last_s
    right = (caps > last_x) & (ub_r >= last_x) & (counts > 0)
    best = jnp.maximum(best, jnp.where(right, jnp.minimum(ub_r, caps), zero))

    best = jnp.where((caps > zero) & (counts > 0), best, zero)
    return jnp.where(tb > zero, best, zero)


def _total_alloc(xs, ss, counts, t, caps):
    return _alloc_at_time(xs, ss, counts, t, caps).sum(axis=-1)


@jax.jit
def _agg_products_jit(xs, ss, ts):
    """Segment-slope products ``t*m`` and ``m*x0`` for the aggregation
    kernel, compiled as a SEPARATE executable from ``_agg_alloc_jit`` on
    purpose: within one executable LLVM contracts ``1 - t*m`` and
    ``s0 - m*x0`` into FMAs (observed on XLA:CPU; ``optimization_barrier``
    does not survive the LLVM lowering), which rounds differently from
    numpy's two-op sequence and breaks the numpy/jax aggregate-bank
    bit-parity.  Materializing the products as one executable's OUTPUTS
    forces the standalone rounding — the consumer then only subtracts,
    and contraction cannot cross compiled-executable boundaries."""
    one = jnp.asarray(1.0, xs.dtype)
    x0, x1 = xs[..., :-1], xs[..., 1:]
    s0, s1 = ss[..., :-1], ss[..., 1:]
    denom = jnp.where(x1 > x0, x1 - x0, one)
    m = (s1 - s0) / denom
    tm = ts[..., None, None] * m[:, None]  # [g, T, p, k-1]
    mx0 = m * x0  # [g, p, k-1]
    return tm, mx0


@jax.jit
def _agg_alloc_jit(xs, ss, counts, caps, ts, tm, mx0):
    """Member allocations at per-group sample times — the device half of
    group aggregation: ``[g, p, k]`` bank blocks evaluated at ``[g, T]``
    times give ``[g, T, p]`` member allocs.  Open-codes ``_alloc_at_time``
    with a broadcast time lane, taking the two FMA-contractable products
    precomputed (see ``_agg_products_jit``), so every remaining op is a
    single correctly-rounded IEEE op and the result is bitwise the host
    ``_alloc_at_times`` pass.  The per-group member SUM happens back on
    host to keep the reduction order — and the aggregate bank —
    bit-identical to the numpy backend."""
    dt = xs.dtype
    zero, one = jnp.asarray(0.0, dt), jnp.asarray(1.0, dt)
    xsb, ssb, cb, capb = xs[:, None], ss[:, None], counts[:, None], caps[:, None]
    tb = jnp.asarray(ts, dt)[..., None]  # [g, T, 1] against [g, 1, p]
    first_x, first_s, last_x, last_s = _edges(xsb, ssb, cb)

    best = jnp.minimum(tb * first_s, jnp.minimum(first_x, capb))

    k_max = xs.shape[-1]
    if k_max >= 2:
        x0, x1 = xsb[..., :-1], xsb[..., 1:]
        s0 = ssb[..., :-1]
        seg = jnp.arange(k_max - 1)
        valid = (
            (seg < (cb - 1)[..., None])
            & (x0 < capb[..., None])
            & (x1 > x0)
        )
        x1c = jnp.minimum(x1, capb[..., None])
        tseg = tb[..., None]  # [g, T, 1, 1] against [g, 1, p, k-1]
        a = one - tm
        b = tseg * (s0 - mx0[:, None])
        ub = b / jnp.where(a != zero, a, one)
        cand = jnp.where(
            a > zero,
            jnp.where(ub >= x0, jnp.minimum(ub, x1c), zero),
            jnp.where(
                a == zero,
                jnp.where(b >= zero, x1c, zero),
                jnp.where(x1c >= ub, x1c, zero),
            ),
        )
        cand = jnp.where(valid, cand, zero)
        best = jnp.maximum(best, cand.max(axis=-1))

    ub_r = tb * last_s
    right = (capb > last_x) & (ub_r >= last_x) & (cb > 0)
    best = jnp.maximum(best, jnp.where(right, jnp.minimum(ub_r, capb), zero))

    best = jnp.where((capb > zero) & (cb > 0), best, zero)
    return jnp.where(tb > zero, best, zero)


def _agg_alloc(xs, ss, counts, caps, ts):
    """Two-dispatch device aggregation evaluation (see the two jits)."""
    tm, mx0 = _agg_products_jit(xs, ss, ts)
    return _agg_alloc_jit(xs, ss, counts, caps, ts, tm, mx0)


@jax.jit
def _monotone_lanes_jit(xs, ss, counts):
    """Device mirror of ``modelbank._monotone_check`` (same expressions),
    reduced per *lane*: one bool per leading batch element (a scalar for a
    plain ``[p, k]`` bank, ``[q]`` for a stacked one).  A lane is monotone
    iff every row's time is nondecreasing — knots sorted, speeds positive
    and finite, knot times ordered (``x0 s1 <= x1 s0``)."""
    k = xs.shape[-1]
    zero = jnp.asarray(0.0, xs.dtype)
    pts = jnp.arange(k) < counts[..., None]
    ok_pts = (xs > zero) & jnp.isfinite(xs) & (ss > zero) & jnp.isfinite(ss)
    ok = ~jnp.any(pts & ~ok_pts, axis=(-2, -1))
    if k >= 2:
        x0, x1 = xs[..., :-1], xs[..., 1:]
        s0, s1 = ss[..., :-1], ss[..., 1:]
        seg = jnp.arange(k - 1) < (counts - 1)[..., None]
        ok_seg = (x1 >= x0) & (x0 * s1 <= x1 * s0)
        ok &= ~jnp.any(seg & ~ok_seg, axis=(-2, -1))
    return ok


# ---------------------------------------------------------------------------
# t* search: masked doubling + fixed-iteration bisection
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("max_steps",))
def _partition_continuous_jit(xs, ss, counts, caps, n, rel_tol, max_steps):
    dt = xs.dtype
    zero = jnp.asarray(0.0, dt)
    n = jnp.asarray(n, dt)
    rel_tol = jnp.asarray(rel_tol, dt)
    active = caps > zero

    # Exponential search for an upper bound on t* (per batch element).
    t_init = _time(xs, ss, counts, jnp.minimum(jnp.asarray(1.0, dt), caps))
    hi = jnp.maximum(
        zero, jnp.where(active, t_init, -jnp.inf).max(axis=-1)
    )
    hi = jnp.maximum(hi, jnp.asarray(1e-9, dt))

    def _need(hi):
        return _total_alloc(xs, ss, counts, hi, caps) < n

    def dbl_cond(carry):
        hi, i = carry
        return jnp.any(_need(hi)) & (i < 200)

    def dbl_body(carry):
        hi, i = carry
        hi = jnp.where(_need(hi), hi * 2.0, hi)
        return hi, i + 1

    hi, _ = lax.while_loop(dbl_cond, dbl_body, (hi, jnp.asarray(0, jnp.int32)))

    # Bisection with early exit replicated via `done` (set AFTER the update,
    # exactly like the numpy loop's break).  A while_loop, not a fori_loop:
    # once every lane's `done` freezes its values, further iterations are
    # provable no-ops, and rel_tol=1e-12 converges in ~45 steps — running
    # all 200 made the p=10^4..10^5 (and stacked [q, p, k]) partitions
    # ~4x more expensive for bit-identical results.
    # Lanes with n <= 0 start done: their convergence test (hi - lo <=
    # rel_tol * hi with lo pinned at 0) could never fire, so without this
    # they would spin all max_steps for an answer the excess rescale below
    # zeroes out regardless.  Allocations are identical either way; only
    # such lanes' (unused) t_star differs.  The hierarchical inner solve
    # batches empty-share/padded group lanes through here.
    lo = jnp.zeros_like(hi)
    done = jnp.broadcast_to(n <= zero, hi.shape)

    def bis_cond(carry):
        _, _, done, i = carry
        return (~jnp.all(done)) & (i < max_steps)

    def bis_body(carry):
        lo, hi, done, i = carry
        mid = 0.5 * (lo + hi)
        ge = _total_alloc(xs, ss, counts, mid, caps) >= n
        hi2 = jnp.where(~done & ge, mid, hi)
        lo2 = jnp.where(~done & ~ge, mid, lo)
        done2 = done | (hi2 - lo2 <= rel_tol * hi2)
        return lo2, hi2, done2, i + 1

    lo, hi, done, _ = lax.while_loop(
        bis_cond, bis_body, (lo, hi, done, jnp.asarray(0, jnp.int32))
    )
    t_star = hi

    alloc = _alloc_at_time(xs, ss, counts, t_star, caps)
    total = alloc.sum(axis=-1)
    excess = total - n
    scaled = alloc - (excess[..., None] * (alloc / total[..., None]))
    alloc = jnp.where(((total > zero) & (excess > zero))[..., None], scaled, alloc)
    return alloc, t_star


# ---------------------------------------------------------------------------
# Integer partition: floor + masked take-back + completion (threshold-count
# bulk grant for monotone banks, masked-argmin greedy for the remainder)
# ---------------------------------------------------------------------------


def _threshold_prefill(
    xs, ss, counts, caps_i, d0, leftover, t_star, rel_tol, max_steps, fast_mask
):
    """Batched threshold-count bulk completion (monotone-time banks).

    Expression-for-expression mirror of ``partition._threshold_prefill_bank``:
    bisect a time threshold ``t`` on ``count(t) = sum(clip(floor(alloc(t)),
    d0, caps)) - sum(d0)`` with the strict bracket ``count(lo) < leftover <=
    count(hi)`` (masked doubling bracket from ``t*``, after-update early
    exit), bulk-grant everything counted at ``lo``, and hand the >=1
    boundary-tied remainder to the exact greedy.  Leading batch dims are the
    stacked ``[q, p, k]`` bank's columns; lanes with no leftover — or lanes
    routed to the exact per-unit loop by ``fast_mask`` (per-column completion
    routing: a non-monotone column demotes only itself, in the same device
    program) — pass through untouched.
    """
    dt = xs.dtype
    it = d0.dtype
    caps_f = caps_i.astype(dt)
    base_total = d0.sum(axis=-1)
    active = (leftover > 0) & fast_mask

    def count(t):
        a = _alloc_at_time(xs, ss, counts, t, caps_f)
        g = jnp.clip(jnp.floor(a).astype(it), d0, caps_i)
        return g.sum(axis=-1) - base_total, g

    hi = jnp.maximum(t_star, jnp.asarray(1e-9, dt))

    def _need(hi):
        c, _ = count(hi)
        return active & (c < leftover)

    def dbl_cond(carry):
        hi, i = carry
        return jnp.any(_need(hi)) & (i < 200)

    def dbl_body(carry):
        hi, i = carry
        hi = jnp.where(_need(hi), hi * 2.0, hi)
        return hi, i + 1

    hi, _ = lax.while_loop(dbl_cond, dbl_body, (hi, jnp.asarray(0, jnp.int32)))

    # Same early-exit while_loop as the continuous bisection: inactive (or
    # converged) lanes freeze, and the loop stops when all have.
    lo = jnp.zeros_like(hi)
    done = ~active

    def bis_cond(carry):
        _, _, done, i = carry
        return (~jnp.all(done)) & (i < max_steps)

    def bis_body(carry):
        lo, hi, done, i = carry
        mid = 0.5 * (lo + hi)
        c, _ = count(mid)
        ge = c >= leftover
        hi2 = jnp.where(~done & ge, mid, hi)
        lo2 = jnp.where(~done & ~ge, mid, lo)
        done2 = done | (hi2 - lo2 <= rel_tol * hi2)
        return lo2, hi2, done2, i + 1

    lo, hi, done, _ = lax.while_loop(
        bis_cond, bis_body, (lo, hi, done, jnp.asarray(0, jnp.int32))
    )
    c_lo, g_lo = count(lo)
    d = jnp.where(active[..., None], g_lo, d0)
    leftover2 = jnp.where(active, leftover - c_lo, leftover)
    return d, leftover2


def _complete_greedy_one(xs, ss, counts, caps_i, d, rem, leftover):
    """Greedy completion for ONE bank (no leading batch dims; vmapped by the
    caller for stacked banks).

    Repeated masked lexicographic argmin over ``(time(d+1), -rem, index)`` —
    identical tie-breaking to the numpy lazy heap.  The key vector is carried
    and only the winner's entry is rewritten (a scatter, mirroring the heap's
    single-entry refresh), so one leftover unit costs a handful of ``O(p)``
    reduction passes instead of full-array rebuilds.
    """
    dt = xs.dtype
    it = d.dtype
    key0 = jnp.where((d + 1) <= caps_i, _time(xs, ss, counts, (d + 1).astype(dt)), jnp.inf)

    def cond(carry):
        _, leftover, _, _ = carry
        return leftover > 0

    def body(carry):
        d, leftover, key, ok = carry
        i0 = jnp.argmin(key)  # first index of the minimum
        m1 = key[i0]
        feasible = jnp.isfinite(m1)

        def tie_break(_):
            # >1 processor shares the exact minimal time: the heap orders
            # them by (-rem, index) — largest fractional remainder wins.
            tie = key == m1
            r = jnp.where(tie, rem, -jnp.inf)
            return jnp.argmax(tie & (r == r.max()))

        i = lax.cond(jnp.sum(key == m1) > 1, tie_break, lambda _: i0, None)
        take = feasible.astype(it)
        d2 = d.at[i].add(take)
        x_new = (d2[i] + 1).astype(dt)
        t_new = _time(xs[i], ss[i], counts[i], x_new)
        new_key = jnp.where((d2[i] + 1) <= caps_i[i], t_new, jnp.inf)
        key2 = key.at[i].set(jnp.where(feasible, new_key, key[i]))
        leftover2 = jnp.where(feasible, leftover - 1, 0)
        return d2, leftover2, key2, ok & feasible

    d, _, _, ok = lax.while_loop(
        cond, body, (d, leftover, key0, jnp.asarray(True))
    )
    return d, ok


def _partition_units_impl(
    xs, ss, counts, caps_i, n, min_units, rel_tol, max_steps, fast_mask,
    completion_fast=False,
):
    # `n` and `fast_mask` carry the batch shape (scalars for a plain bank,
    # [q] for a stacked one); `min_units` carries the ROW shape ``[..., p]``
    # (the public API broadcasts its per-lane floors; the hierarchical inner
    # solve passes genuinely per-row floors so padded member rows pin at 0)
    # — per-column unit counts, floors and completion routing all ride the
    # same device program.  This plain impl is also called per group block
    # inside ``_hier_inner_map``'s ``lax.map`` (and under ``shard_map``), so
    # it must stay jit-free; ``_partition_units_jit`` below is the jitted
    # entry point with identical semantics.
    dt = xs.dtype
    it = caps_i.dtype
    n_f = jnp.asarray(n, dt)
    caps_f = jnp.minimum(caps_i.astype(dt), n_f[..., None])  # continuous clip
    alloc, t_star = _partition_continuous_jit(xs, ss, counts, caps_f, n_f, rel_tol, max_steps)

    d = jnp.maximum(min_units, jnp.floor(alloc).astype(it))
    d = jnp.minimum(d, caps_i)
    leftover = jnp.asarray(n, it) - d.sum(axis=-1)
    p = xs.shape[-2]
    idx = jnp.arange(p)

    # -- take-back (min_units overshoot): largest per-unit time first,
    #    round-robin — the stable descending order of the numpy path.
    per_unit = _time(xs, ss, counts, d.astype(dt)) / jnp.maximum(d, 1)
    order = jnp.argsort(-per_unit, axis=-1, stable=True)

    def tb_cond(carry):
        _, leftover, _ = carry
        return jnp.any(leftover < 0)

    def tb_body(carry):
        d, leftover, kk = carry
        i = jnp.take_along_axis(order, (kk % p)[..., None], axis=-1)[..., 0]
        d_i = jnp.take_along_axis(d, i[..., None], axis=-1)[..., 0]
        mu_i = jnp.take_along_axis(min_units, i[..., None], axis=-1)[..., 0]
        take = (leftover < 0) & (d_i > mu_i)
        d = d - ((idx == i[..., None]) & take[..., None]).astype(it)
        return d, leftover + take.astype(it), kk + 1

    kk0 = jnp.zeros(leftover.shape, it)
    d, leftover, _ = lax.while_loop(tb_cond, tb_body, (d, leftover, kk0))

    # -- threshold-count bulk grant (static branch: skipped entirely when no
    #    lane is monotone) — collapses all but the boundary-tied units into
    #    one more bisection; fast_mask routes it per lane.
    rem = alloc - jnp.floor(alloc)
    if completion_fast:
        d, leftover = _threshold_prefill(
            xs, ss, counts, caps_i, d, leftover, t_star, rel_tol, max_steps,
            fast_mask,
        )

    # -- greedy completion (see _complete_greedy_one); stacked banks flatten
    #    their leading dims and vmap, so every column completes in the same
    #    device program (lanes mask out as their leftovers hit zero).
    batch = xs.shape[:-2]
    if batch:
        b = int(np.prod(batch))
        p_dim, k_dim = xs.shape[-2], xs.shape[-1]
        d, ok = jax.vmap(_complete_greedy_one)(
            xs.reshape(b, p_dim, k_dim),
            ss.reshape(b, p_dim, k_dim),
            counts.reshape(b, p_dim),
            caps_i.reshape(b, p_dim),
            d.reshape(b, p_dim),
            rem.reshape(b, p_dim),
            leftover.reshape(b),
        )
        d = d.reshape(*batch, p_dim)
        ok = ok.reshape(batch)
    else:
        d, ok = _complete_greedy_one(xs, ss, counts, caps_i, d, rem, leftover)
    return d, ok, t_star


_partition_units_jit = partial(
    jax.jit, static_argnames=("max_steps", "completion_fast")
)(_partition_units_impl)


# ---------------------------------------------------------------------------
# Hierarchical inner solves: one device program over [g, p_max, k] group
# blocks, with SIZE-ROUTED execution.  When the whole block set fits in
# cache the groups run BATCHED (one [g, ...] bisection — every loop update
# is already masked per lane, so results are bit-identical to solo runs);
# when it does not, lax.map runs the groups SEQUENTIALLY so each group's
# [p_g, k] block stays cache-resident through its whole t* bisection — the
# cache-blocking that recovers the p >= 10^4 stacked regression.  Either
# way the program compiles once and dispatches once.  Under shard_map the
# same body runs per device over its local group lanes (no collectives:
# every group's solve is independent), so no single device ever touches
# more than its ceil(g/ndev) blocks of the bank.
# ---------------------------------------------------------------------------


def _hier_inner_map(
    xs, ss, counts, caps_i, n, min_units, fast_mask, *,
    rel_tol, max_steps, completion_fast, serial=True,
):
    """Per-group integer partitions: ``xs``/``ss`` are ``[g, p_max, k]``
    (members right-padded with caps=0 / min_units=0 rows), ``n`` ``[g]`` the
    outer solve's group shares, ``min_units`` ``[g, p_max]``, ``fast_mask``
    ``[g]`` the per-group completion routing.  ``serial`` picks lax.map
    (cache-blocked, for block sets larger than cache) over the batched
    solve (one masked bisection, for cache-resident block sets) — the two
    return BIT-IDENTICAL allocations, see the routing note above.  Returns
    ``(d [g, p_max], ok [g], t_star [g])``."""
    if not serial:
        return _partition_units_impl(
            xs, ss, counts, caps_i, n, min_units,
            jnp.asarray(rel_tol, xs.dtype), max_steps, fast_mask,
            completion_fast=completion_fast,
        )

    def body(args):
        xs_g, ss_g, counts_g, caps_g, n_g, mu_g, fm_g = args
        return _partition_units_impl(
            xs_g, ss_g, counts_g, caps_g, n_g, mu_g,
            jnp.asarray(rel_tol, xs_g.dtype), max_steps, fm_g,
            completion_fast=completion_fast,
        )

    return lax.map(body, (xs, ss, counts, caps_i, n, min_units, fast_mask))


_hier_inner_jit = partial(
    jax.jit, static_argnames=("rel_tol", "max_steps", "completion_fast", "serial")
)(_hier_inner_map)


def _fold_in_impl(xs, ss, counts, x, s, valid):
    """Vectorized sorted insert of one ``(x_i, s_i)`` observation per row.

    Exactly ``PiecewiseLinearFPM.add_point`` semantics, for all rows at once:
    replace the speed on an exact duplicate ``x``, otherwise shift-insert at
    the bisect position and re-pad with the row's (possibly new) last point.
    Rows with ``valid[i] == False`` are untouched.
    """
    k = xs.shape[-1]
    j = jnp.arange(k)
    in_prefix = j < counts[..., None]
    dup = in_prefix & (xs == x[..., None])
    has_dup = jnp.any(dup, axis=-1)
    do_replace = valid & has_dup
    do_insert = valid & ~has_dup

    ss = jnp.where(dup & do_replace[..., None], s[..., None], ss)

    pos = jnp.sum(in_prefix & (xs < x[..., None]), axis=-1)
    jm1 = jnp.maximum(j - 1, 0)
    xs_prev, ss_prev = xs[..., jm1], ss[..., jm1]
    at = j == pos[..., None]
    before = j < pos[..., None]
    xs_ins = jnp.where(before, xs, jnp.where(at, x[..., None], xs_prev))
    ss_ins = jnp.where(before, ss, jnp.where(at, s[..., None], ss_prev))
    new_counts = counts + do_insert.astype(counts.dtype)
    last = jnp.maximum(new_counts - 1, 0)
    last_x = jnp.take_along_axis(xs_ins, last[..., None], axis=-1)
    last_s = jnp.take_along_axis(ss_ins, last[..., None], axis=-1)
    pad = j >= new_counts[..., None]
    xs_ins = jnp.where(pad, last_x, xs_ins)
    ss_ins = jnp.where(pad, last_s, ss_ins)

    ins = do_insert[..., None]
    return (
        jnp.where(ins, xs_ins, xs),
        jnp.where(ins, ss_ins, ss),
        new_counts,
    )


_fold_in_jit = partial(jax.jit, donate_argnums=_DONATE)(_fold_in_impl)
# Non-donating twin: double-buffered callers (the fleet's pipelined rounds)
# fold into a NEW carry while the previous generation's buffers stay valid,
# so an in-flight repartition can keep reading them.  On CPU (no donation)
# the two behave identically; keeping separate jit caches means a pipelined
# fleet never perturbs the donating path's recompile accounting.
_fold_in_nodonate_jit = jax.jit(_fold_in_impl)


# ---------------------------------------------------------------------------
# The bank
# ---------------------------------------------------------------------------


@dataclass
class JaxModelBank:
    """Device-resident padded FPM bank; accepts leading batch dims
    (``[p, k]`` for one fleet, ``[q, p, k]`` for a stacked 2-D grid).

    ``max_count`` (host-side upper bound on ``counts.max()``) and
    ``empty_rows`` (host-side ``counts == 0`` mirror) keep the hot paths —
    fold-in growth checks and per-repartition feasibility validation — free
    of blocking device->host syncs; ``None`` means unknown (computed and
    cached on first use).
    """

    xs: jnp.ndarray
    ss: jnp.ndarray
    counts: jnp.ndarray
    max_count: Optional[int] = None
    empty_rows: Optional[np.ndarray] = None
    # Host-side monotone-time flag (None = unknown; resolved by is_monotone()
    # — from the numpy bank's host check at construction, or by one tiny
    # jitted reduction + scalar sync after a device-side fold_in).  Routes
    # the threshold-count completion.
    monotone: Optional[bool] = None
    # Per-lane mirror for stacked [q, p, k] banks (None = unknown; resolved
    # by monotone_lanes()): routes the completion per column, so one
    # adversarial column demotes only itself while the rest keep the
    # threshold-count bulk grant — in the same device program.
    monotone_cols: Optional[np.ndarray] = None
    # Optional energy sub-bank (same layout; ss holds energy RATES x/E(x),
    # so energy.time(x) == E(x)) — see the "time and energy" section in
    # modelbank.py and core/energy.py.
    energy: Optional["JaxModelBank"] = None
    # Fold-in generation tag (host int): construction paths start at 0 and
    # every ``fold_in`` returns a bank one generation newer.  Double-buffered
    # consumers (the fleet's pipelined rounds) use the tag to bound how
    # stale a carry a repartition may read — never more than
    # ``pipeline_depth`` fold generations behind the newest.
    generation: int = 0

    is_jax = True  # duck-type marker for the partition.py dispatcher

    # -- construction --------------------------------------------------------

    @classmethod
    def from_bank(cls, bank: ModelBank, dtype=None) -> "JaxModelBank":
        """Device copy of a numpy bank.  ``dtype`` overrides the float dtype
        of the model arrays (the ``SpeedStore`` dtype policy — e.g.
        ``np.float32`` for a cheaper serving-fleet bank); the default keeps
        the platform-native dtype (float64 under x64)."""
        return cls(
            xs=jnp.asarray(bank.xs, dtype=dtype),
            ss=jnp.asarray(bank.ss, dtype=dtype),
            counts=jnp.asarray(bank.counts),
            max_count=int(bank.counts.max(initial=0)),
            empty_rows=np.asarray(bank.counts) == 0,
            # resolve on the host while the arrays are still numpy — one
            # O(p k) pass, so stacked/2-D paths never pay a device check
            monotone=bank.is_monotone(),
            energy=(
                cls.from_bank(bank.energy, dtype=dtype)
                if bank.energy is not None
                else None
            ),
        )

    @classmethod
    def from_models(cls, models: Sequence[object], dtype=None) -> "JaxModelBank":
        """Adapt scalar models (``TypeError`` for non-piecewise ones —
        callers fall back to the host paths)."""
        return cls.from_bank(ModelBank.from_models(models), dtype=dtype)

    @classmethod
    def empty(cls, p: int, k: int = 8, dtype=None) -> "JaxModelBank":
        """A bank of ``p`` empty rows (the cold-start DFPA carry)."""
        return cls(
            xs=jnp.zeros((p, k), dtype=dtype),
            ss=jnp.zeros((p, k), dtype=dtype),
            counts=jnp.zeros((p,), dtype=jax.dtypes.canonicalize_dtype(np.int64)),
            max_count=0,
            empty_rows=np.ones((p,), dtype=bool),
            monotone=True,  # vacuous: no observed points yet
        )

    @classmethod
    def stack(
        cls, banks: Sequence["JaxModelBank"], min_k: Optional[int] = None
    ) -> "JaxModelBank":
        """Stack ``q`` same-``p`` banks into one ``[q, p, k]`` bank so every
        column's ``t*`` bisects simultaneously (the 2-D partitioner).

        ``min_k`` reserves padded knot capacity up front: a serving fleet
        that restacks with a fixed ``min_k`` keeps the carry's shapes — and
        therefore its compiled programs — identical across sessions, and
        ``fold_in`` never pays a growth recompile until a row actually
        exceeds the reservation."""
        k = max(int(b.xs.shape[-1]) for b in banks)
        if min_k is not None:
            k = max(k, int(min_k))
        padded = [b._padded_to(k) for b in banks]
        flags = [b.monotone for b in banks]
        energy = (
            cls.stack([b.energy for b in banks], min_k=min_k)
            if banks and all(b.energy is not None for b in banks)
            else None
        )
        return cls(
            energy=energy,
            xs=jnp.stack([px for px, _ in padded]),
            ss=jnp.stack([ps for _, ps in padded]),
            counts=jnp.stack([b.counts for b in banks]),
            max_count=max(b._max_count_bound() for b in banks),
            empty_rows=np.stack([b._empty_rows_host() for b in banks]),
            # All columns known-monotone -> stacked fast path; any known
            # violation demotes its own column (per-lane routing); unknowns
            # resolve lazily on first partition.
            monotone=(
                True if all(f is True for f in flags)
                else False if any(f is False for f in flags)
                else None
            ),
            monotone_cols=(
                np.asarray(flags, dtype=bool)
                if all(f is not None for f in flags)
                else None
            ),
        )

    def _padded_to(self, k: int):
        extra = k - int(self.xs.shape[-1])
        if extra <= 0:
            return self.xs, self.ss
        # padding repeats the last column (== the row's last point, or the
        # zeros of an empty row) — same convention as from_point_lists.
        # Done on the host: the source width varies bank to bank, and device
        # repeat/concatenate would compile a fresh (k_src -> k) program for
        # every width seen; a [p, k] pad is host-trivial and jnp.asarray is
        # a transfer, not a trace.
        xs = np.asarray(self.xs)
        ss = np.asarray(self.ss)
        return (
            jnp.asarray(np.concatenate([xs, np.repeat(xs[..., -1:], extra, axis=-1)], axis=-1)),
            jnp.asarray(np.concatenate([ss, np.repeat(ss[..., -1:], extra, axis=-1)], axis=-1)),
        )

    def to_bank(self) -> ModelBank:
        """Host snapshot as the numpy :class:`ModelBank` (single bank only)."""
        if self.xs.ndim != 2:
            raise ValueError("to_bank() requires an unbatched [p, k] bank")
        return ModelBank(
            xs=np.asarray(self.xs, dtype=np.float64),
            ss=np.asarray(self.ss, dtype=np.float64),
            counts=np.asarray(self.counts, dtype=np.int64),
            monotone=self.monotone,
            energy=self.energy.to_bank() if self.energy is not None else None,
        )

    # -- shape ---------------------------------------------------------------

    @property
    def p(self) -> int:
        return int(self.xs.shape[-2])

    def __len__(self) -> int:
        return self.p

    @property
    def dtype(self):
        return self.xs.dtype

    # -- batched evaluation (device) -----------------------------------------

    def speed(self, x) -> jnp.ndarray:
        x = jnp.broadcast_to(jnp.asarray(x, self.dtype), self.counts.shape)
        return _speed(self.xs, self.ss, self.counts, x)

    def time(self, x) -> jnp.ndarray:
        x = jnp.broadcast_to(jnp.asarray(x, self.dtype), self.counts.shape)
        return _time(self.xs, self.ss, self.counts, x)

    def alloc_at_time(self, t, caps) -> jnp.ndarray:
        caps = jnp.broadcast_to(jnp.asarray(caps, self.dtype), self.counts.shape)
        return _alloc_at_time(self.xs, self.ss, self.counts, t, caps)

    def total_alloc(self, t, caps) -> jnp.ndarray:
        return self.alloc_at_time(t, caps).sum(axis=-1)

    def scaled(self, speed_scale) -> "JaxModelBank":
        """New bank with every row's speeds scaled (2-D column-width rescale).

        Where ``fold_in`` donates its carry the shared ``xs``/``counts``
        buffers are copied, so folding either bank cannot invalidate the
        other; on CPU they alias harmlessly.
        """
        scale_host = np.asarray(speed_scale, dtype=np.float64)
        scale = jnp.broadcast_to(jnp.asarray(speed_scale, self.dtype), self.counts.shape)
        xs = jnp.array(self.xs) if DONATES_CARRY else self.xs
        counts = jnp.array(self.counts) if DONATES_CARRY else self.counts
        positive = bool(np.all(scale_host > 0.0))
        return JaxModelBank(
            xs=xs, ss=self.ss * scale[..., None], counts=counts,
            max_count=self.max_count, empty_rows=self.empty_rows,
            # positive per-row scaling preserves time-monotonicity
            monotone=self.monotone if positive else None,
            monotone_cols=self.monotone_cols if positive else None,
            generation=self.generation,
            energy=self.energy,  # problem-size semantics unchanged
        )

    def copy(self) -> "JaxModelBank":
        """Deep copy of the device buffers.  Needed by holders of a snapshot
        on platforms where ``fold_in`` donates its carry (``DONATES_CARRY``):
        the original buffers are invalidated by the next fold."""
        return JaxModelBank(
            xs=jnp.array(self.xs), ss=jnp.array(self.ss),
            counts=jnp.array(self.counts), max_count=self.max_count,
            empty_rows=self.empty_rows, monotone=self.monotone,
            monotone_cols=self.monotone_cols, generation=self.generation,
            energy=self.energy.copy() if self.energy is not None else None,
        )

    # -- the energy sub-bank (core/energy.py) --------------------------------

    def with_energy(self, energy: "JaxModelBank") -> "JaxModelBank":
        """Attach an energy sub-bank (same shape; ``ss`` holds energy rates
        ``x / E(x)``) — returns a new bank sharing this bank's buffers."""
        if energy.counts.shape != self.counts.shape:
            raise ValueError(
                f"energy bank shape {energy.counts.shape} != speed bank "
                f"shape {self.counts.shape}"
            )
        return JaxModelBank(
            xs=self.xs, ss=self.ss, counts=self.counts,
            max_count=self.max_count, empty_rows=self.empty_rows,
            monotone=self.monotone, monotone_cols=self.monotone_cols,
            generation=self.generation, energy=energy,
        )

    def energy_at(self, d) -> jnp.ndarray:
        """Per-processor energies ``E_i(d_i)`` of a distribution (0 for
        ``d_i <= 0``, NaN on empty energy rows with units)."""
        if self.energy is None:
            raise ValueError("no energy sub-bank attached (use with_energy)")
        return self.energy.time(d)

    def fleet_energy(self, d) -> float:
        """Total fleet energy ``sum_i E_i(d_i)`` of a distribution (host
        scalar; one reduction + sync)."""
        return float(self.energy_at(d).sum())

    def _max_count_bound(self) -> int:
        """Host-side upper bound on ``counts.max()`` (syncs once if unknown,
        then stays host-tracked)."""
        if self.max_count is None:
            self.max_count = int(np.asarray(self.counts).max(initial=0))
        return self.max_count

    def _empty_rows_host(self) -> np.ndarray:
        """Host-side ``counts == 0`` mirror (syncs once if unknown, then
        maintained by ``fold_in`` without further transfers)."""
        if self.empty_rows is None:
            self.empty_rows = np.asarray(self.counts) == 0
        return self.empty_rows

    def is_monotone(self) -> bool:
        """Host bool of the bank's monotone-time flag (the threshold-count
        completion's routing contract — see ``ModelBank.is_monotone``).

        Construction paths inherit the numpy bank's host check for free;
        after a device-side ``fold_in`` the flag is unknown and resolving it
        costs one ``O(p k)`` jitted reduction plus a scalar device->host
        sync — paid at most once per fold/partition cycle, i.e. amortized
        into the repartition the observation was folded in for."""
        if self.monotone is None:
            if self.monotone_cols is not None:
                self.monotone = bool(np.all(self.monotone_cols))
            else:
                self.monotone = bool(
                    np.all(_monotone_lanes_jit(self.xs, self.ss, self.counts))
                )
        return self.monotone

    def monotone_lanes(self) -> np.ndarray:
        """Per-lane host mirror of :meth:`is_monotone` — one bool per
        leading batch element (shape ``[q]`` for a stacked bank, ``()`` for
        a plain one).  ``completion="auto"`` on a stacked bank routes the
        threshold-count completion through this, so a single non-monotone
        column demotes only its own lane to the exact per-unit loop while
        every other column keeps the bulk grant (one device program either
        way).  Same lazy-resolution contract as the scalar flag."""
        shape = self.counts.shape[:-1]
        if self.monotone_cols is None:
            if self.monotone is True:
                # the scalar flag is the AND of the lanes, so only True
                # determines them all; False means *some* lane violates.
                self.monotone_cols = np.ones(shape, dtype=bool)
            else:
                self.monotone_cols = np.asarray(
                    _monotone_lanes_jit(self.xs, self.ss, self.counts)
                ).reshape(shape)
        return self.monotone_cols

    # -- the jitted partitioners --------------------------------------------

    def _check_feasible(self, caps: np.ndarray, n) -> None:
        if np.any((caps > 0.0) & self._empty_rows_host()):
            raise ValueError("empty FPM")

    def partition_continuous(
        self, n, caps=None, *, rel_tol: float = 1e-12, max_steps: int = 200
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Continuous optimal partition on device; ``n`` may be batched for a
        stacked bank.  Returns ``(allocations, t_star)`` as jnp arrays."""
        shape = self.counts.shape
        # Caps are validated host-side first, then uploaded ONCE — the hot
        # repartition path never reads device memory back.
        if caps is not None:
            caps_host = np.broadcast_to(np.asarray(caps, dtype=np.float64), shape)
        else:
            caps_host = np.broadcast_to(
                np.asarray(n, dtype=np.float64)[..., None], shape
            )
        self._check_feasible(caps_host, n)
        return _partition_continuous_jit(
            self.xs, self.ss, self.counts,
            jnp.asarray(caps_host, self.dtype),
            jnp.asarray(n, self.dtype),
            jnp.asarray(rel_tol, self.dtype),
            max_steps,
        )

    def partition_units(
        self, n, caps=None, *, min_units=0, max_steps: int = 200,
        with_t: bool = False, completion: str = "auto",
        completion_lanes=None, defer: bool = False,
    ) -> np.ndarray:
        """Integer partition on device; host-side feasibility checks raise
        the same ``ValueError`` s as the scalar and numpy-bank paths.

        ``n`` is a scalar (or ``[q]`` for a stacked bank, partitioning every
        column simultaneously); ``min_units`` may likewise be per-column on a
        stacked bank.  Returns the host ``int`` allocation array; with
        ``with_t=True`` returns ``(allocations, t_star)`` — the inner
        continuous solve's equal-time point, at zero extra device work.

        ``completion`` routes the integer completion (see the "completion
        modes" section in ``modelbank.py``): ``"auto"`` uses the
        threshold-count bulk grant iff the bank is monotone-time (one extra
        jitted bisection instead of ~p/2 sequential argmin iterations —
        the p=10^5 millisecond-repartition path), ``"greedy"`` forces the
        exact per-unit loop, ``"threshold"`` forces the bulk grant
        (benchmark-only on non-monotone banks).  On a stacked bank ``"auto"``
        routes *per column* (``monotone_lanes``), so an adversarial column
        demotes only itself; ``completion_lanes`` (a ``[q]`` bool mask, used
        by the fleet scheduler) overrides the routing explicitly — True
        lanes take the bulk grant, False lanes the exact loop — keeping
        mixed-mode fleets in one device program.

        ``defer=True`` dispatches the device program and returns WITHOUT
        blocking: the result is a ``(d, ok)`` pair of device arrays (JAX
        async dispatch keeps computing in the background) to be materialized
        later with :func:`fetch_partition` — which performs the same
        integer-completion feasibility raise this call would have.  The
        pipelined fleet round uses this to overlap next round's repartition
        with the in-flight fold and the host-side bookkeeping between them.
        """
        if completion not in ("auto", "threshold", "greedy"):
            raise ValueError(f"unknown completion mode {completion!r}")
        shape = self.counts.shape
        p = shape[-1]
        if completion_lanes is not None:
            lanes_host = np.array(
                np.broadcast_to(np.asarray(completion_lanes, dtype=bool), shape[:-1])
            )
        elif completion == "threshold":
            lanes_host = np.ones(shape[:-1], dtype=bool)
        elif completion == "greedy":
            lanes_host = np.zeros(shape[:-1], dtype=bool)
        elif self.counts.ndim >= 2:
            lanes_host = self.monotone_lanes()  # per-column auto routing
        else:
            lanes_host = np.full(shape[:-1], self.is_monotone(), dtype=bool)
        fast = bool(np.any(lanes_host))
        n_host = np.broadcast_to(np.asarray(n), shape[:-1])
        if np.any(n_host < 0):
            raise ValueError("n must be non-negative")
        mu_host = np.broadcast_to(np.asarray(min_units, dtype=np.int64), shape[:-1])
        if np.any(mu_host * p > n_host):
            i = int(np.argmax(np.reshape(mu_host * p > n_host, (-1,))))
            raise ValueError(
                f"min_units={int(np.reshape(mu_host, (-1,))[i])} infeasible for "
                f"n={int(np.reshape(n_host, (-1,))[i])}, p={p}"
            )
        idtype = self.counts.dtype
        # Host-side caps first (validation below), one device upload after —
        # no blocking device->host round-trips on the repartition hot path.
        if caps is None:
            caps_host = np.broadcast_to(
                np.asarray(n_host, dtype=np.int64)[..., None], shape
            )
        else:
            caps_host = np.broadcast_to(np.asarray(caps, dtype=np.int64), shape)
        under = (caps_host < mu_host[..., None]) & (mu_host[..., None] > 0)
        if np.any(under):
            i = int(np.argmax(np.reshape(under, (-1,))))
            raise ValueError(
                f"min_units={int(np.reshape(mu_host, (-1,))[i // p])} "
                f"infeasible: cap {int(caps_host.reshape(-1)[i])} < min_units"
            )
        clipped = np.minimum(caps_host.astype(np.float64), n_host[..., None].astype(np.float64))
        short = clipped.sum(axis=-1) < n_host
        if np.any(short):
            i = int(np.argmax(np.reshape(short, (-1,))))
            raise ValueError(
                f"infeasible: sum(caps)={float(clipped.reshape(-1, p)[i].sum())} "
                f"< n={float(np.reshape(n_host, (-1,))[i])}"
            )
        self._check_feasible(caps_host.astype(np.float64), n)
        # min_units broadcast to row shape [..., p]: the kernel takes per-row
        # floors (uniform here; genuinely per-row on the hierarchical path).
        d, ok, t_star = _partition_units_jit(
            self.xs, self.ss, self.counts,
            jnp.asarray(caps_host, idtype),
            jnp.asarray(n_host),
            jnp.asarray(np.broadcast_to(mu_host[..., None], shape), idtype),
            jnp.asarray(1e-12, self.dtype),
            max_steps,
            jnp.asarray(lanes_host),
            completion_fast=fast,
        )
        if defer:
            return (d, ok, t_star) if with_t else (d, ok)
        if not bool(np.all(np.asarray(ok))):
            raise ValueError("caps infeasible during integer completion")
        if with_t:
            return np.asarray(d), np.asarray(t_star)
        return np.asarray(d)

    # -- device-resident observation fold-in ---------------------------------

    def fold_in(self, x, s, valid=None, *, donate: bool = True) -> "JaxModelBank":
        """Insert one observation ``(x_i, s_i)`` per row (vectorized sorted
        insert; duplicate ``x`` replaces the speed).  Returns the updated
        bank; the old buffers are donated where the platform supports it.
        Grows the padded width (by doubling) when any row is full.

        ``donate=False`` routes through a non-donating twin of the fold
        kernel so THIS bank's buffers stay valid after the call — the
        double-buffer contract pipelined fleet rounds rely on (the previous
        generation keeps serving an in-flight repartition while the new one
        folds).  The returned bank is tagged one :attr:`generation` newer
        either way."""
        x = jnp.broadcast_to(jnp.asarray(x, self.dtype), self.counts.shape)
        s = jnp.broadcast_to(jnp.asarray(s, self.dtype), self.counts.shape)
        # valid is host data in every caller (DFPA / BalanceController build
        # Python lists); mirror it on the host so empty_rows stays host-
        # tracked, then upload.
        if valid is None:
            valid_host = np.ones(self.counts.shape, dtype=bool)
        else:
            valid_host = np.broadcast_to(np.asarray(valid, bool), self.counts.shape)
        valid = jnp.asarray(valid_host)
        xs, ss = self.xs, self.ss
        k = int(xs.shape[-1])
        bound = self._max_count_bound()
        if bound >= k:
            # The host-tracked bound overcounts duplicate-x folds (they
            # replace a speed without growing counts), so before paying for
            # a width doubling — new shape, new jit traces — resync the true
            # maximum (a [p]-int transfer, at most once per k folds).  A
            # steady-state carry re-observing the same distribution keeps
            # its width (and its compiled kernels) forever.
            bound = int(np.asarray(self.counts).max(initial=0))
            self.max_count = bound
            if bound >= k:
                k = max(2 * k, 1)
                xs, ss = self._padded_to(k)
        kernel = _fold_in_jit if donate else _fold_in_nodonate_jit
        nxs, nss, ncounts = kernel(xs, ss, self.counts, x, s, valid)
        return JaxModelBank(
            xs=nxs, ss=nss, counts=ncounts, max_count=min(bound + 1, k),
            generation=self.generation + 1,
            empty_rows=self._empty_rows_host() & ~valid_host,
            # The inserted points can create OR (duplicate-x replace) remove
            # a monotonicity violation; the flag is re-resolved lazily by
            # is_monotone() on the next partition (one device reduction).
            monotone=None,
            # Speed observations don't touch the energy sub-bank; fold
            # energy observations into it directly (it is a bank).
            energy=self.energy,
        )


def fetch_partition(deferred) -> np.ndarray:
    """Materialize a ``partition_units(..., defer=True)`` result: blocks on
    the in-flight device program, runs the integer-completion feasibility
    check the eager call would have run, and returns the host allocation
    array (plus ``t_star`` when the deferred call used ``with_t=True``)."""
    d, ok = deferred[0], deferred[1]
    d_host = np.asarray(d)
    if not bool(np.all(np.asarray(ok))):
        raise ValueError("caps infeasible during integer completion")
    if len(deferred) == 3:
        return d_host, np.asarray(deferred[2])
    return d_host
