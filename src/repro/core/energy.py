"""Bi-objective time/energy partitioning: Pareto fronts over FPM banks.

The source paper's FPMs model only speed; the same group's follow-up
(*Bi-objective Optimisation of Data-parallel Applications on Heterogeneous
Platforms for Performance and Energy*, Khaleghzadeh et al.) extends the
framework with per-processor *energy* functions of problem size.  This
module adds that second objective on top of the existing bank machinery —
deliberately reusing the partition kernels rather than growing new ones:

* **Energy banks are speed banks.**  An energy model is stored as an
  *energy-rate* function ``er_i(x) = x / E_i(x)`` (units per joule) in a
  second :class:`~repro.core.modelbank.ModelBank` /
  ``JaxModelBank`` with the identical padded ``[p, k]`` layout, so
  ``energy_bank.time(x) == E_i(x)`` and every existing kernel — fold-in,
  stacking, monotone flags, the jitted ``t*`` bisection, the
  threshold-count completion — applies verbatim.  Build rate models from
  measured ``(x, energy)`` samples with :func:`energy_model`.

* **The energy objective is the same geometric solve.**
  ``objective="energy"`` runs the equal-point bisection on the energy bank:
  it balances the *per-processor* energies (min-max energy), exactly as the
  time objective balances per-processor times.  The *fleet* (total) energy
  ``sum_i E_i(d_i)`` is what a power cap constrains; the front below
  reports totals, and dominated sweep points are filtered, so the reported
  front is always a valid (time, total-energy) trade-off curve.

* **The Pareto front is a batched sweep of time-threshold bisections.**
  Between the two pure solutions (time-optimal and energy-optimal), each
  front candidate fixes a makespan threshold ``t`` and solves the
  *energy-balanced partition subject to finishing by ~t*: per-processor
  caps are tightened to ``min(cap_i, floor(alloc_time_i(t)))`` — the PR 4
  count-under-threshold expression — and the energy bank is partitioned
  under those caps.  On the jax backend all interior thresholds solve as
  ONE stacked ``[T, p, k]`` program (the fleet's stacked-lane machinery);
  on numpy they run through the same host kernel per threshold.  The
  thresholds, tightened caps, and all front metrics are computed host-side
  in float64 from the scalar estimates, so numpy and jax produce
  bit-identical fronts (the stacked-lane == independent-solve parity is
  the fleet contract, fuzz-locked in ``tests/test_energy.py``).

The endpoints of the front are the pure solutions **by construction** —
index 0 is exactly ``objective="time"``'s partition and index -1 exactly
``objective="energy"``'s (the CI gate in ``benchmarks/energy_pareto.py``).
In the degenerate case where the energy-balanced solve does not reduce
total energy below the time-optimal point's, the front collapses to the
single time-optimal point (there is no trade-off to expose).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .fpm import PiecewiseLinearFPM
from .modelbank import ModelBank, _alloc_at_times
from .partition import _partition_units_bank, _partition_units_scalar

__all__ = [
    "ParetoFront",
    "pareto_front",
    "capped_energy_partition",
    "energy_model",
]


def energy_model(points: Sequence[Tuple[float, float]]) -> PiecewiseLinearFPM:
    """Build an energy-rate FPM from measured ``(x, energy)`` samples.

    The returned model stores ``er(x) = x / E(x)``, so banking it and
    calling ``time(x)`` returns the energy ``E(x)`` — the representation
    trick that lets the whole speed-bank stack serve energy unchanged.
    Energies must be positive; sizes must be positive.
    """
    pts = []
    for x, e in points:
        x, e = float(x), float(e)
        if x <= 0.0 or e <= 0.0:
            raise ValueError(f"energy samples need x > 0 and energy > 0 (got {(x, e)})")
        pts.append((x, x / e))
    return PiecewiseLinearFPM.from_points(pts)


@dataclass
class ParetoFront:
    """A makespan/total-energy trade-off curve of integer partitions.

    ``times`` is strictly increasing and ``energies`` strictly decreasing
    (both float64; dominated sweep points are filtered at construction), so
    ``allocations[0]`` is the pure time-optimal partition and
    ``allocations[-1]`` the pure energy-balanced one.  A single-point front
    means the two objectives agree (no trade-off).
    """

    times: np.ndarray        # [F] predicted makespans, strictly increasing
    energies: np.ndarray     # [F] predicted total fleet energies, strictly decreasing
    allocations: np.ndarray  # [F, p] int64 partitions

    def __len__(self) -> int:
        return int(self.times.shape[0])

    def knee(self) -> int:
        """Index of the knee point: the front point closest (in the
        normalized (time, energy) square) to the utopia corner — the
        default pick when no energy budget is given."""
        f = len(self)
        if f <= 2:
            return 0
        t, e = self.times, self.energies
        tn = (t - t[0]) / (t[-1] - t[0]) if t[-1] > t[0] else np.zeros(f)
        en = (e - e[-1]) / (e[0] - e[-1]) if e[0] > e[-1] else np.zeros(f)
        return int(np.argmin(tn + en))

    def pick(self, energy_cap: Optional[float] = None) -> int:
        """Select a front index: with ``energy_cap`` the *fastest* point
        whose total energy fits the budget; without, the :meth:`knee`.
        An unattainable cap (below the front's minimum energy) returns the
        minimum-energy endpoint — best effort, still over budget; callers
        enforcing a hard budget must check ``energies[idx]``."""
        if energy_cap is None:
            return self.knee()
        cap = float(energy_cap)
        feasible = np.flatnonzero(self.energies <= cap)
        if feasible.size == 0:
            return len(self) - 1
        return int(feasible[0])  # times ascend: first feasible is fastest

    def as_dict(self) -> dict:
        """JSON-friendly view (benchmarks/energy_pareto.py payloads)."""
        return {
            "times": [float(v) for v in self.times],
            "energies": [float(v) for v in self.energies],
            "allocations": [[int(v) for v in row] for row in self.allocations],
        }


def _active_max(vals: np.ndarray, d: Sequence[int]) -> float:
    """Max over processors with units (the makespan/peak convention used by
    Scheduler._flat_result: zero-allocation rows are ignored)."""
    out = [float(v) for v, di in zip(vals, d) if di > 0 and np.isfinite(v)]
    return max(out) if out else 0.0


def _total(vals: np.ndarray, d: Sequence[int]) -> float:
    """Total over processors with units (left-to-right float64 sum — the
    fixed reduction order that keeps numpy/jax front metrics bit-identical)."""
    out = 0.0
    for v, di in zip(vals, d):
        if di > 0 and np.isfinite(v):
            out += float(v)
    return out


def _stacked_energy_partition(jbank, caps_t, n, min_units, completion):
    """All interior thresholds' energy solves as ONE stacked [T, p, k]
    program: the energy bank broadcast along the threshold axis, per-lane
    caps carrying the tightened time caps — exactly the fleet scheduler's
    stacked-lane shape, so the compiled kernel is shared with it."""
    import jax.numpy as jnp

    from .modelbank_jax import JaxModelBank

    T, p = caps_t.shape
    k = int(jbank.xs.shape[-1])
    flag = jbank.is_monotone()
    stacked = JaxModelBank(
        xs=jnp.broadcast_to(jbank.xs, (T, p, k)),
        ss=jnp.broadcast_to(jbank.ss, (T, p, k)),
        counts=jnp.broadcast_to(jbank.counts, (T, p)),
        max_count=jbank._max_count_bound(),
        empty_rows=np.broadcast_to(jbank._empty_rows_host(), (T, p)),
        monotone=flag,
        monotone_cols=np.full((T,), flag, dtype=bool),
    )
    d = stacked.partition_units(
        np.full(T, int(n), dtype=np.int64),
        caps_t,
        min_units=np.full(T, int(min_units), dtype=np.int64),
        completion=completion,
    )
    return [[int(v) for v in row] for row in d]


def pareto_front(
    store,
    energy,
    n: int,
    icaps: Sequence[int],
    *,
    min_units: int = 0,
    num_points: int = 17,
    completion: str = "auto",
) -> ParetoFront:
    """Compute the makespan/total-energy Pareto front (see module docstring).

    ``store`` / ``energy`` are SpeedStore-protocol objects over the same
    ``p`` processors and backend: ``store`` holds the speed models,
    ``energy`` the energy-rate models.  ``icaps`` must already be prepared
    per-processor integer caps (``_prep_unit_caps`` output).  ``num_points``
    bounds the sweep size (endpoints + up to ``num_points - 2`` interior
    thresholds, geometrically spaced between the pure solutions' makespans);
    dominated candidates are filtered, so the front may be smaller.
    """
    p = store.p
    icaps_arr = np.asarray(icaps, dtype=np.int64)
    scalar = store.backend == "scalar"
    if scalar:
        times_of = lambda d: store.times([float(v) for v in d])
        etimes_of = lambda d: energy.times([float(v) for v in d])
    else:
        sbank, ebank = store.bank(), energy.bank()
        times_of = lambda d: sbank.time([float(v) for v in d])
        etimes_of = lambda d: ebank.time([float(v) for v in d])

    # Endpoints: the pure solutions, via the store's own partition dispatch
    # (bit-identical to objective="time"/"energy" by construction).
    d_time, _ = store.partition(n, list(icaps_arr), min_units=min_units, completion=completion)
    d_energy, _ = energy.partition(n, list(icaps_arr), min_units=min_units, completion=completion)
    d_time_arr = np.asarray(d_time, dtype=np.int64)
    t_lo = _active_max(times_of(d_time), d_time)
    e_lo = _total(etimes_of(d_time), d_time)
    t_hi = _active_max(times_of(d_energy), d_energy)
    e_hi = _total(etimes_of(d_energy), d_energy)

    def _front(points):
        times, energies, allocs = zip(*points)
        return ParetoFront(
            times=np.asarray(times, dtype=np.float64),
            energies=np.asarray(energies, dtype=np.float64),
            allocations=np.asarray(allocs, dtype=np.int64),
        )

    # Degenerate: no trade-off to expose (identical partitions, a zero-work
    # job, or an energy solve that does not beat the time point on total
    # energy) — the front is the single time-optimal point.
    if (
        t_lo <= 0.0
        or list(d_time) == list(d_energy)
        or not (e_hi < e_lo and t_hi > t_lo)
    ):
        return _front([(t_lo, e_lo, [int(v) for v in d_time])])

    # Interior thresholds: geometric in (t_lo, t_hi), host float64 — the
    # SAME grid on every backend, so caps_t (and thus the solves) agree
    # bit-for-bit between numpy and jax.
    m = max(int(num_points), 2)
    ts = np.geomspace(t_lo, t_hi, m)[1:-1] if m > 2 else np.empty(0)

    interior: List[Tuple[float, float, List[int]]] = []
    if ts.size:
        if scalar:
            allocs = np.stack(
                [
                    np.asarray(
                        [
                            mdl.alloc_at_time(float(t), float(c))
                            for mdl, c in zip(store.models, icaps_arr)
                        ]
                    )
                    for t in ts
                ]
            )
        else:
            allocs = _alloc_at_times(sbank, ts, icaps_arr.astype(np.float64))
        # Tighten caps to the threshold; the elementwise max with the
        # time-optimal partition guarantees feasibility (sum >= n, caps >=
        # min_units) against float flooring at the t_lo boundary.
        caps_t = np.maximum(
            np.minimum(icaps_arr[None, :], np.floor(allocs).astype(np.int64)),
            d_time_arr[None, :],
        )
        if scalar:
            sols = [
                _partition_units_scalar(
                    energy.models, int(n), [int(v) for v in row], min_units=min_units
                )[0]
                for row in caps_t
            ]
        elif store.backend == "numpy":
            sols = [
                _partition_units_bank(
                    ebank, int(n), row, min_units=min_units, completion=completion
                )[0]
                for row in caps_t
            ]
        else:
            sols = _stacked_energy_partition(
                energy._carry(), caps_t, n, min_units, completion
            )
        for d in sols:
            interior.append(
                (_active_max(times_of(d), d), _total(etimes_of(d), d), [int(v) for v in d])
            )

    # Dominance filter: ascending time, strictly descending energy; interior
    # points colliding with (or dominated by) either endpoint drop out, so
    # both endpoints survive verbatim.
    interior.sort(key=lambda r: (r[0], r[1]))
    kept: List[Tuple[float, float, List[int]]] = [(t_lo, e_lo, [int(v) for v in d_time])]
    for t, e, d in interior:
        if t <= kept[-1][0] or e >= kept[-1][1]:
            continue
        if e <= e_hi or t >= t_hi:
            continue
        kept.append((t, e, d))
    kept.append((t_hi, e_hi, [int(v) for v in d_energy]))
    return _front(kept)


def capped_energy_partition(
    bank: ModelBank,
    ebank: ModelBank,
    n: int,
    icaps: Sequence[int],
    t_threshold: float,
    *,
    floor_d: Optional[Sequence[int]] = None,
    min_units: int = 0,
    completion: str = "auto",
) -> Optional[List[int]]:
    """One energy-balanced partition subject to makespan <= ``t_threshold``.

    The fleet power-cap primitive (host numpy — serving fleets bisect a
    common threshold multiplier over a handful of jobs, so the host kernel
    is the right cost class): tighten each cap to
    ``min(cap_i, floor(alloc_time_i(t)))``, optionally floor at ``floor_d``
    (pass the time-optimal partition to guarantee feasibility for any
    ``t >= makespan(floor_d)``), then partition the energy bank under the
    tightened caps.  Returns ``None`` when the threshold is infeasible
    (``sum(caps_t) < n``).
    """
    icaps_arr = np.asarray(icaps, dtype=np.int64)
    allocs = _alloc_at_times(
        bank, np.asarray([float(t_threshold)]), icaps_arr.astype(np.float64)
    )[0]
    caps_t = np.minimum(icaps_arr, np.floor(allocs).astype(np.int64))
    if floor_d is not None:
        caps_t = np.maximum(caps_t, np.asarray(floor_d, dtype=np.int64))
    if int(caps_t.sum()) < int(n):
        return None
    if min_units > 0 and np.any(caps_t < min_units):
        return None
    d, _ = _partition_units_bank(
        ebank, int(n), caps_t, min_units=int(min_units), completion=completion
    )
    return [int(v) for v in d]
