"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160e top-6, MLA kv_lora=512, 2 shared experts
[arXiv:2405.04434; hf].

Notes: the assignment's d_ff=1536 is the routed-expert intermediate size;
the first layer is dense with intermediate 12288 (per the HF config).
MLA: q_lora 1536, kv_lora 512, rope_head 64, nope_head 128, v_head 128.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,  # nope 128 + rope 64 (scoring dim)
    d_ff=1536,  # routed expert intermediate
    vocab_size=102400,
    pattern=("attn",),
    prefix=("attn",),  # dense first layer
    prefix_dense_ff=12288,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    mlp_kind="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
    train_accum=8,
    attn_chunk_threshold=4096,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=24,
        d_ff=64,
        prefix_dense_ff=128,
        vocab_size=512,
        q_lora_rank=32,
        kv_lora_rank=16,
        rope_head_dim=8,
        nope_head_dim=16,
        v_head_dim=16,
        num_experts=8,
        num_shared_experts=1,
        top_k=2,
        d_ff_expert=64,
        xent_chunk=0,
        remat="none",
    )
