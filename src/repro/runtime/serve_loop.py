"""Serving: prefill/decode engine + DFPA-balanced request dispatch.

Serving is the second place the paper's model fits naturally: per-replica
decode throughput is a *nonlinear* function of batch size (KV-cache
bandwidth, batch-dependent kernel efficiency, HBM spill past a batch
threshold) — a speed function s(x), unknown a priori on a heterogeneous
fleet.  ``ReplicaDispatcher`` runs DFPA over request chunks.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.executor import Executor, RoundLog
from ..core.scheduler import Partition, Policy, Scheduler
from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_cache, prefill

__all__ = ["ServeEngine", "ReplicaDispatcher"]


class ServeEngine:
    """Single-replica engine: jit'd prefill + decode with a fixed KV budget."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int, seq_budget: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.seq_budget = seq_budget
        self._prefill = jax.jit(functools.partial(prefill, cfg=cfg))
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))

    def new_cache(self):
        return init_cache(self.cfg, self.batch, self.seq_budget, self.cfg.dtype)

    def generate(
        self, tokens: jax.Array, max_new: int, *, greedy: bool = True
    ) -> jax.Array:
        """tokens: (B, S_prompt) -> (B, max_new) generated ids."""
        caches = self.new_cache()
        logits, caches = self._prefill(params=self.params, tokens=tokens, caches=caches)
        out = []
        pos = tokens.shape[1]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
        for i in range(1, max_new):
            logits, caches = self._decode(
                params=self.params, token=tok, pos=jnp.asarray(pos, jnp.int32),
                caches=caches,
            )
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out.append(tok)
            pos += 1
        return jnp.concatenate(out, axis=1)


@dataclass
class ReplicaDispatcher:
    """DFPA over request chunks across heterogeneous serving replicas.

    ``replica_run(i, x)`` must process ``x`` request chunks on replica ``i``
    and return the wall time (real engines or simulators both fit).  The
    dispatcher is an ``Executor``; :meth:`balance` drives it through the
    ``Scheduler`` facade and leaves the warm session on ``self.scheduler``
    for the online lifecycle (``observe`` / ``join`` / ``leave``).
    """

    replica_run: Callable[[int, int], float]
    num_replicas: int
    eps: float = 0.1
    logs: List[RoundLog] = field(default_factory=list)
    scheduler: Optional[Scheduler] = None

    @property
    def num_procs(self) -> int:
        return self.num_replicas

    def run(self, d: Sequence[int]) -> List[float]:
        times = [
            self.replica_run(i, int(x)) if x > 0 else 0.0 for i, x in enumerate(d)
        ]
        self.logs.append(RoundLog(list(map(int, d)), times, max(times)))
        return times

    def round_cost(self, times: Sequence[float]) -> float:
        return max(times)

    def balance(self, n_chunks: int, **kw) -> Partition:
        """Find the balanced chunk distribution for this fleet (the DFPA
        measurement loop, via the facade)."""
        if self.scheduler is None:
            self.scheduler = Scheduler(policy=Policy.DFPA, eps=self.eps)
        return self.scheduler.autotune(self, n_chunks, self.eps, **kw)
