"""Deprecation shims: every legacy entry point still works AND warns.

Each shim must (a) emit ``DeprecationWarning`` pointing at the facade, and
(b) produce results identical to calling the facade directly — they are thin
delegations, not parallel implementations.  Runs in CI under
``-W error::DeprecationWarning`` (``pytest.deprecated_call`` records the
warning before the filter can raise), which simultaneously proves the
*facade* paths underneath never touch the shimmed API themselves.
"""

import warnings

import numpy as np
import pytest

from repro.core import (
    AnalyticModel,
    HCL_SPECS,
    Policy,
    Scheduler,
    SimulatedExecutor,
    SpeedStore,
    speed_fn_2d,
)
from repro.core.fpm import PiecewiseLinearFPM


def _models(p=4, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(p):
        xs = np.sort(rng.uniform(1.0, 1e3, 4))
        ss = rng.uniform(1.0, 50.0, 4)
        out.append(PiecewiseLinearFPM.from_points(list(zip(xs, ss))))
    return out


def test_partition_units_shim_warns_and_delegates():
    from repro.core import partition_units

    models = _models()
    with pytest.deprecated_call(match="partition_units"):
        d = partition_units(models, 100, min_units=1)
    assert d == SpeedStore.from_models(models).partition_units(100, min_units=1)


def test_partition_continuous_shim_warns_and_delegates():
    from repro.core import partition_continuous

    models = _models()
    with pytest.deprecated_call(match="partition_continuous"):
        xs, t = partition_continuous(models, 100.0)
    xs2, t2 = SpeedStore.from_models(models).partition_continuous(100.0)
    assert xs == xs2 and t == t2


def test_cpm_partition_shim_warns_and_delegates():
    from repro.core import cpm_partition

    with pytest.deprecated_call(match="cpm_partition"):
        d = cpm_partition([1.0, 2.0, 3.0], 60)
    assert d == Scheduler.from_speeds([1.0, 2.0, 3.0]).partition(60).allocations


def test_dfpa_shim_warns_and_delegates():
    from repro.core import dfpa

    fns = [lambda x: x / 10.0, lambda x: x / 20.0, lambda x: x / 5.0]
    with pytest.deprecated_call(match="dfpa"):
        res = dfpa(SimulatedExecutor(time_fns=list(fns)), 300, 0.05, min_units=1)
    part = Scheduler().autotune(
        SimulatedExecutor(time_fns=list(fns)), 300, 0.05, min_units=1
    )
    assert res.d == part.allocations
    assert res.iterations == part.iterations
    assert res.history == part.diagnostics["history"]
    assert res.points_per_proc == [m.num_points for m in part.diagnostics["models"]]


def test_grid_shims_warn_and_delegate():
    from repro.core import cpm_partition_2d, dfpa_partition_2d, ffmpa_partition_2d

    p, q, M, N = 2, 2, 64, 64
    specs = HCL_SPECS[: p * q]
    grid = [[speed_fn_2d(specs[i * q + j]) for j in range(q)] for i in range(p)]

    with pytest.deprecated_call(match="dfpa_partition_2d"):
        df = dfpa_partition_2d(grid, M, N, eps=0.1)
    part = Scheduler(grid=grid, policy=Policy.GRID2D).partition_grid(M, N, eps=0.1)
    assert df.row_heights == part.row_heights
    assert df.col_widths == part.col_widths

    with pytest.deprecated_call(match="cpm_partition_2d"):
        cpm, cost = cpm_partition_2d(grid, M, N)
    cpm_part = Scheduler(grid=grid, policy=Policy.CPM).partition_grid(M, N)
    assert cpm.row_heights == cpm_part.row_heights
    assert cost == pytest.approx(cpm_part.diagnostics["bench_cost"])

    with pytest.deprecated_call(match="ffmpa_partition_2d"):
        ff = ffmpa_partition_2d(grid, M, N, eps=0.1)
    ff_part = Scheduler(grid=grid, policy=Policy.FFMPA).partition_grid(
        M, N, eps=0.1, max_outer=50
    )
    assert ff.row_heights == ff_part.row_heights


def test_bank_repartition_2d_shim_warns_and_delegates():
    from repro.core import bank_repartition_2d

    p, q, M = 3, 2, 60
    rng = np.random.default_rng(2)
    widths = [20, 22]
    fpms = [[PiecewiseLinearFPM() for _ in range(q)] for _ in range(p)]
    fpm_width = [[None] * q for _ in range(p)]
    for i in range(p):
        for j in range(q):
            for r in rng.uniform(2, M, 3):
                fpms[i][j].add_point(float(r), float(rng.uniform(1.0, 9.0)))
            fpm_width[i][j] = widths[j]
    with pytest.deprecated_call(match="bank_repartition_2d"):
        rows = bank_repartition_2d(fpms, fpm_width, widths, M)
    want = Scheduler(policy=Policy.GRID2D).repartition_grid(fpms, fpm_width, widths, M)
    assert rows == want


def test_balance_controller_shims_warn():
    from repro.runtime.balance import BalanceController

    ctrl = BalanceController(n_units=32, num_groups=2, eps=0.05, smooth=1.0)
    with pytest.deprecated_call(match="observe"):
        ctrl.observe([2.0, 1.0])
    with pytest.deprecated_call(match="bank"):
        bank = ctrl.bank()
    assert bank.p == 2
    with pytest.deprecated_call(match="device_bank"):
        jb = ctrl.device_bank()
    assert jb.p == 2


def test_elastic_rebalance_shim_warns_and_delegates():
    from repro.runtime.balance import BalanceController
    from repro.runtime.elastic import elastic_rebalance

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ctrl = BalanceController(n_units=60, num_groups=3, eps=0.05, smooth=1.0)
        for _ in range(8):
            times = [d / s if d > 0 else 0.0 for d, s in zip(ctrl.d, [1.0, 2.0, 3.0])]
            ctrl.observe(times)
    with pytest.deprecated_call(match="elastic_rebalance"):
        new = elastic_rebalance(ctrl, surviving=[0, 1], joined=1)
    assert new.num_groups == 3
    assert sum(new.d) == 60
    # same semantics as the facade's resize
    want = ctrl._sched.resize([0, 1], joined=1, caps=None)
    assert new.d == want.d


def test_legacy_flat_call_sites_are_shim_free_inside_facade():
    """The facade itself must not route through the shims: a full lifecycle
    raises nothing under error-filtered DeprecationWarning."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sched = Scheduler(n_units=64, num_groups=4, eps=0.05, min_units=1, smooth=1.0)
        for _ in range(10):
            times = [d / s if d > 0 else 0.0 for d, s in zip(sched.d, [1, 2, 3, 2])]
            sched.observe(times)
        sched.straggler_actions([t or 0.0 for t in sched.store.times(sched.d)])
        sched.leave(3)
        sched.join(1)
        sched.repartition()
        Scheduler.from_state(sched.state_dict())
        ffmpa = Scheduler.from_models(
            [AnalyticModel(lambda x: x / 7.0)] * 3, policy=Policy.FFMPA
        )
        ffmpa.partition(30, min_units=1)
