"""Pallas kernels vs pure-jnp oracles.

Two lanes share the same case lists and check bodies:

* default — ``interpret=True`` shape/dtype sweeps, runs everywhere (CPU CI);
* ``-m compiled`` — the same sweeps with ``interpret=False``, exercising the
  real Mosaic-compiled path.  Skipped automatically when no accelerator
  backend is present; CI runs it as a non-blocking job so a real-TPU runner
  lights it up without any test changes (first step of the ROADMAP's
  real-TPU lane item).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul_update import matmul_update_pallas
from repro.kernels.rglru import rglru_scan_pallas

KEY = jax.random.PRNGKey(0)

# The compiled lane needs a real accelerator: interpret=False on the CPU
# backend would try (and fail) to lower Mosaic for TPU.
compiled = pytest.mark.compiled
needs_accelerator = pytest.mark.skipif(
    jax.default_backend() == "cpu",
    reason="compiled pallas lane requires a non-CPU jax backend",
)


def _rand(key, shape, dtype, scale=1.0):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# matmul_update — the paper's kernel, TPU-native
# ---------------------------------------------------------------------------

MATMUL_DTYPES = [(jnp.float32, 2e-4), (jnp.bfloat16, 5e-2)]
MATMUL_SHAPES = [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 384, 128, 256, 128),
    (512, 256, 1024, 256, 256, 512),
    (128, 1024, 256, 64, 512, 256),
]


def _check_matmul_update(M, N, K, bm, bn, bk, dtype, atol, *, interpret):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = _rand(k1, (M, K), dtype)
    b = _rand(k2, (K, N), dtype)
    c = _rand(k3, (M, N), dtype)
    out = matmul_update_pallas(c, a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    want = ref.matmul_update_ref(c, a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=atol * np.sqrt(K), rtol=2e-2,
    )


@pytest.mark.parametrize("dtype,atol", MATMUL_DTYPES)
@pytest.mark.parametrize("M,N,K,bm,bn,bk", MATMUL_SHAPES)
def test_matmul_update_sweep(M, N, K, bm, bn, bk, dtype, atol):
    _check_matmul_update(M, N, K, bm, bn, bk, dtype, atol, interpret=True)


@compiled
@needs_accelerator
@pytest.mark.parametrize("dtype,atol", MATMUL_DTYPES)
@pytest.mark.parametrize("M,N,K,bm,bn,bk", MATMUL_SHAPES)
def test_matmul_update_sweep_compiled(M, N, K, bm, bn, bk, dtype, atol):
    _check_matmul_update(M, N, K, bm, bn, bk, dtype, atol, interpret=False)


def test_matmul_update_rejects_indivisible():
    a = jnp.zeros((100, 128))
    b = jnp.zeros((128, 128))
    c = jnp.zeros((100, 128))
    with pytest.raises(ValueError):
        matmul_update_pallas(c, a, b, bm=64, bn=64, bk=64, interpret=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_DTYPES = [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)]
FLASH_CASES = [
    (1, 2, 2, 128, 128, 64, dict(causal=True)),
    (2, 4, 2, 128, 128, 64, dict(causal=True)),  # GQA
    (2, 4, 1, 128, 128, 32, dict(causal=True)),  # MQA
    (1, 2, 2, 128, 128, 64, dict(causal=True, window=32)),  # sliding window
    (1, 2, 2, 128, 128, 64, dict(causal=True, softcap=30.0)),  # gemma softcap
    (1, 2, 2, 128, 128, 64, dict(causal=False)),  # encoder
    (1, 2, 2, 64, 256, 64, dict(causal=True)),  # right-aligned queries
]


def _check_flash_attention(B, H, Kv, Sq, Sk, D, kwargs, dtype, tol, *, interpret):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, H, Sq, D), dtype, 0.3)
    k = _rand(k2, (B, Kv, Sk, D), dtype, 0.3)
    v = _rand(k3, (B, Kv, Sk, D), dtype)
    out = flash_attention_pallas(q, k, v, bq=64, bk=64, interpret=interpret, **kwargs)
    want = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("dtype,tol", FLASH_DTYPES)
@pytest.mark.parametrize("B,H,Kv,Sq,Sk,D,kwargs", FLASH_CASES)
def test_flash_attention_sweep(B, H, Kv, Sq, Sk, D, kwargs, dtype, tol):
    _check_flash_attention(B, H, Kv, Sq, Sk, D, kwargs, dtype, tol, interpret=True)


@compiled
@needs_accelerator
@pytest.mark.parametrize("dtype,tol", FLASH_DTYPES)
@pytest.mark.parametrize("B,H,Kv,Sq,Sk,D,kwargs", FLASH_CASES)
def test_flash_attention_sweep_compiled(B, H, Kv, Sq, Sk, D, kwargs, dtype, tol):
    _check_flash_attention(B, H, Kv, Sq, Sk, D, kwargs, dtype, tol, interpret=False)


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's _sdpa oracle (GQA layout adapter)."""
    from repro.models.attention import _sdpa
    from repro.models.attention import _causal_mask

    B, S, H, Kv, D = 2, 128, 4, 2, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, S, H, D), jnp.float32, 0.3)
    k = _rand(k2, (B, S, Kv, D), jnp.float32, 0.3)
    v = _rand(k3, (B, S, Kv, D), jnp.float32)
    want = _sdpa(q, k, v, _causal_mask(S, S, 0), scale=D**-0.5, cap=0.0)
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, bq=64, bk=64, interpret=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU chunked recurrence
# ---------------------------------------------------------------------------

RGLRU_CASES = [
    (1, 128, 128, 64, 128),
    (2, 256, 512, 128, 256),
    (3, 512, 256, 256, 128),
]


def _check_rglru_scan(B, S, D, bs, bd, *, interpret):
    k1, k2 = jax.random.split(KEY)
    log_a = -jax.nn.softplus(jax.random.normal(k1, (B, S, D)))
    b = 0.1 * jax.random.normal(k2, (B, S, D))
    out = rglru_scan_pallas(log_a, b, bs=bs, bd=bd, interpret=interpret)
    want = ref.rglru_scan_ref(log_a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,S,D,bs,bd", RGLRU_CASES)
def test_rglru_scan_sweep(B, S, D, bs, bd):
    _check_rglru_scan(B, S, D, bs, bd, interpret=True)


@compiled
@needs_accelerator
@pytest.mark.parametrize("B,S,D,bs,bd", RGLRU_CASES)
def test_rglru_scan_sweep_compiled(B, S, D, bs, bd):
    _check_rglru_scan(B, S, D, bs, bd, interpret=False)


def test_rglru_matches_model_block_scan():
    """Kernel recurrence == the associative-scan used inside the model."""
    from repro.models.recurrent import _rglru_scan

    B, S, D = 2, 256, 128
    k1, k2 = jax.random.split(KEY)
    log_a = -jax.nn.softplus(jax.random.normal(k1, (B, S, D)))
    b = 0.1 * jax.random.normal(k2, (B, S, D))
    want = _rglru_scan(log_a, b, None)
    got = rglru_scan_pallas(log_a, b, bs=128, bd=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_ops_dispatch_to_ref_on_cpu():
    from repro.kernels import flash_attention, matmul_update, rglru_scan

    a = jnp.ones((8, 8))
    assert np.allclose(matmul_update(jnp.zeros((8, 8)), a, a), 8.0)
    q = jnp.ones((1, 1, 8, 4)) * 0.1
    out = flash_attention(q, q, q, causal=True)
    assert out.shape == (1, 1, 8, 4)
    la = jnp.zeros((1, 8, 4)) - 1.0
    assert rglru_scan(la, jnp.ones((1, 8, 4))).shape == (1, 8, 4)
