"""Data-partitioning algorithms over performance models.

Implements the building blocks the paper composes:

* ``partition_continuous`` — the geometric algorithm of [16] (Lastovetsky &
  Reddy, IJHPCA 2007): the optimal allocations ``x_i`` lie on a straight line
  through the origin of the (size, speed) plane, i.e. all processors finish at
  the same time ``t* = x_i / s_i(x_i)``.  We find the smallest ``t`` such that
  ``sum_i alloc_i(t) >= n`` by bisection; ``alloc_i(t) = max{x <= cap_i :
  x/s_i(x) <= t}`` is supplied by the model (monotone in ``t`` by construction,
  so bisection is exact regardless of the shape of the speed estimate).

* ``partition_units`` — the integer version used by DFPA: continuous solution,
  floor, then a greedy min-makespan completion (each leftover unit goes to the
  processor whose completion time after receiving it is smallest).  This is the
  "distribution of computation units" the paper's step 3 sends out.

* ``cpm_partition`` — the conventional constant-performance-model distribution
  (speed constants, proportional allocation), the paper's baseline.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

from .fpm import ConstantModel, SpeedModel

__all__ = [
    "partition_continuous",
    "partition_units",
    "cpm_partition",
]


def _total_alloc(models: Sequence[SpeedModel], t: float, caps: Sequence[float]) -> float:
    return sum(m.alloc_at_time(t, c) for m, c in zip(models, caps))


def partition_continuous(
    models: Sequence[SpeedModel],
    n: float,
    caps: Optional[Sequence[float]] = None,
    *,
    rel_tol: float = 1e-12,
    max_steps: int = 200,
) -> Tuple[List[float], float]:
    """Continuous optimal partition of ``n`` units across ``models``.

    Returns ``(allocations, t_star)``.  ``caps`` bounds per-processor
    allocation (memory limits); infeasible caps raise ``ValueError``.
    """
    p = len(models)
    if p == 0:
        raise ValueError("no processors")
    if n <= 0:
        return [0.0] * p, 0.0
    caps = list(caps) if caps is not None else [float(n)] * p
    caps = [min(float(c), float(n)) for c in caps]
    if sum(caps) < n:
        raise ValueError(f"infeasible: sum(caps)={sum(caps)} < n={n}")

    # Exponential search for an upper bound on t*.
    hi = max(m.time(min(1.0, c)) for m, c in zip(models, caps) if c > 0)
    hi = max(hi, 1e-9)
    for _ in range(200):
        if _total_alloc(models, hi, caps) >= n:
            break
        hi *= 2.0
    else:  # pragma: no cover - guarded by the feasibility check above
        raise RuntimeError("could not bracket t*")
    lo = 0.0
    # Bisection: invariant total(lo) < n <= total(hi).
    for _ in range(max_steps):
        mid = 0.5 * (lo + hi)
        if _total_alloc(models, mid, caps) >= n:
            hi = mid
        else:
            lo = mid
        if hi - lo <= rel_tol * hi:
            break
    t_star = hi
    xs = [m.alloc_at_time(t_star, c) for m, c in zip(models, caps)]
    total = sum(xs)
    if total > 0:
        # alloc_at_time(t_star) may slightly overshoot n; rescale the excess
        # proportionally so the continuous solution sums exactly to n.
        excess = total - n
        if excess > 0:
            xs = [x - excess * (x / total) for x in xs]
    return xs, t_star


def partition_units(
    models: Sequence[SpeedModel],
    n: int,
    caps: Optional[Sequence[int]] = None,
    *,
    min_units: int = 0,
) -> List[int]:
    """Integer partition of ``n`` equal computation units.

    Continuous solution -> floor -> greedy min-makespan completion.  With
    ``min_units > 0`` every processor receives at least that many units
    (the paper's matrix apps keep every processor participating).
    """
    p = len(models)
    if n < 0:
        raise ValueError("n must be non-negative")
    if min_units * p > n:
        raise ValueError(f"min_units={min_units} infeasible for n={n}, p={p}")
    icaps = [int(c) for c in caps] if caps is not None else [n] * p
    fcaps = [float(c) for c in icaps]
    xs, _ = partition_continuous(models, float(n), fcaps)
    d = [max(min_units, int(math.floor(x))) for x in xs]
    d = [min(di, ci) for di, ci in zip(d, icaps)]
    leftover = n - sum(d)
    if leftover < 0:
        # min_units pushed us over n: take units back from the processors whose
        # per-unit time is largest (removing from the slowest hurts least).
        order = sorted(range(p), key=lambda i: models[i].time(d[i]) / max(d[i], 1), reverse=True)
        k = 0
        while leftover < 0:
            i = order[k % p]
            if d[i] > min_units:
                d[i] -= 1
                leftover += 1
            k += 1
    # Greedy completion: each leftover unit to the processor minimizing the
    # resulting completion time (ties -> larger fractional remainder).
    rem = [x - math.floor(x) for x in xs]
    for _ in range(leftover):
        best_i, best_key = -1, None
        for i in range(p):
            if d[i] + 1 > icaps[i]:
                continue
            key = (models[i].time(d[i] + 1), -rem[i])
            if best_key is None or key < best_key:
                best_i, best_key = i, key
        if best_i < 0:
            raise ValueError("caps infeasible during integer completion")
        d[best_i] += 1
    assert sum(d) == n
    return d


def cpm_partition(speeds: Sequence[float], n: int, caps: Optional[Sequence[int]] = None) -> List[int]:
    """Conventional CPM distribution: proportional to constant speeds."""
    models = [ConstantModel(s) for s in speeds]
    return partition_units(models, n, caps)
