"""Elastic scaling: group join/leave -> warm-started DFPA re-partition.

.. deprecated::
    Elastic membership now lives on the facade —
    :meth:`repro.core.scheduler.Scheduler.join` /
    :meth:`~repro.core.scheduler.Scheduler.leave` /
    :meth:`~repro.core.scheduler.Scheduler.resize` — which keep the
    survivors' FPM points (the paper's §3.2 trick of reusing all previous
    benchmark results), seed joiners from the fastest survivor's estimate,
    and re-partition immediately.  :func:`elastic_rebalance` remains as a
    thin shim delegating to ``Scheduler.resize``.
"""

from __future__ import annotations

from typing import Optional, Sequence

from ..core.speedstore import _warn_legacy
from .balance import BalanceController

__all__ = ["elastic_rebalance"]


def elastic_rebalance(
    controller: BalanceController,
    surviving: Sequence[int],
    joined: int = 0,
    *,
    caps: Optional[Sequence[int]] = None,
) -> BalanceController:
    """Build a controller for the new group set.

    ``surviving`` — indices (into the old controller) still alive;
    ``joined``    — number of new groups appended after the survivors.

    .. deprecated:: use ``Scheduler.resize`` (or the in-place
       ``Scheduler.join`` / ``Scheduler.leave``).
    """
    _warn_legacy("elastic_rebalance()", "Scheduler.resize()/join()/leave()")
    sched = controller._sched if isinstance(controller, BalanceController) else controller
    return BalanceController._wrap(sched.resize(surviving, joined=joined, caps=caps))
