"""Chrome-trace / Perfetto JSON export of a telemetry recording.

The Trace Event Format (the ``chrome://tracing`` / Perfetto JSON dialect)
renders named duration events on per-thread tracks — exactly the view that
makes the fleet pipeline *visible*: the scheduler track shows
``fleet.partition`` / ``fleet.fold`` / ``fleet.predispatch`` spans, the
per-replica tracks show each replica's busy windows, and PR 9's overlap (a
pre-dispatched partition running while the previous fold is in flight)
shows up as overlapping spans instead of a number in a counter.

Mapping:

* span events  -> ``"ph": "X"`` complete events (``ts``/``dur`` in µs);
* counters and gauges -> ``"ph": "C"`` counter events (charted as stacked
  area tracks by the viewers);
* point events -> ``"ph": "i"`` instant events;
* tracks       -> synthetic ``tid`` s, named via ``thread_name`` metadata —
  a span's ``track`` attr (e.g. ``"replica:3"``) picks its row; everything
  else lands on the ``"scheduler"`` track.

The written file is a superset of the format: alongside ``traceEvents`` it
carries a ``repro`` block (counter totals, gauge levels) which the viewers
ignore but ``python -m repro.obs.report`` reads back.  Timestamps are
whatever clock the :class:`~repro.obs.telemetry.Telemetry` was built with,
scaled to microseconds.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .telemetry import Telemetry

__all__ = ["to_chrome_trace", "export_chrome_trace"]

_SCHEDULER_TRACK = "scheduler"


def to_chrome_trace(tel: Telemetry) -> Dict[str, Any]:
    """Build the trace dict (see module docstring) from a recording."""
    tracks: Dict[str, int] = {_SCHEDULER_TRACK: 0}
    trace_events: List[Dict[str, Any]] = []

    def tid(track: str) -> int:
        t = tracks.get(track)
        if t is None:
            t = tracks[track] = len(tracks)
        return t

    for e in tel.events:
        track = e.attrs.get("track", _SCHEDULER_TRACK) if e.attrs else _SCHEDULER_TRACK
        args = {k: v for k, v in (e.attrs or {}).items() if k != "track"}
        if e.kind == "span":
            trace_events.append({
                "name": e.name,
                "cat": e.name.split(".", 1)[0],
                "ph": "X",
                "ts": e.t0 * 1e6,
                "dur": max((e.t1 - e.t0) * 1e6, 0.0),
                "pid": 0,
                "tid": tid(track),
                "args": args,
            })
        elif e.kind in ("counter", "gauge"):
            trace_events.append({
                "name": e.name,
                "ph": "C",
                "ts": e.t0 * 1e6,
                "pid": 0,
                "args": {e.kind: e.value, **args},
            })
        else:
            trace_events.append({
                "name": e.name,
                "cat": e.name.split(".", 1)[0],
                "ph": "i",
                "s": "g",
                "ts": e.t0 * 1e6,
                "pid": 0,
                "tid": tid(track),
                "args": args,
            })
    for track, t in tracks.items():
        trace_events.append({
            "name": "thread_name",
            "ph": "M",
            "pid": 0,
            "tid": t,
            "args": {"name": track},
        })
    return {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "repro": {
            "counters": dict(tel.counters),
            "gauges": dict(tel.gauges),
        },
    }


def export_chrome_trace(tel: Telemetry, path: str) -> Dict[str, Any]:
    """Write the trace JSON to ``path``; returns the written dict."""
    trace = to_chrome_trace(tel)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def span_count(trace: Dict[str, Any], name: Optional[str] = None) -> int:
    """Number of duration spans in an exported trace (validation helper)."""
    return sum(
        1
        for ev in trace.get("traceEvents", [])
        if ev.get("ph") == "X" and (name is None or ev.get("name") == name)
    )
