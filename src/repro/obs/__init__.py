"""Observability for the scheduler stack: spans, counters, gauges, traces.

Observing the scheduler
=======================

The paper's central empirical claim is an observability claim: the cost of
distributing the computation (partial FPM estimation + repartitioning) is
orders of magnitude below the execution it optimizes.  This package is the
substrate that lets you *watch* that claim hold on a live session instead
of trusting one benchmark's printed fraction.

Everything is off by default — the process-global sink is a no-op and every
instrumentation site in the stack guards itself with a cheap ``enabled``
check, so an uninstrumented run is bit-identical and unmeasurably close in
wall-clock (the ``obs_overhead`` gate in ``BENCH_fleet.json`` holds the
ENABLED cost under 2% of a fleet round too).  Turn it on by installing a
sink::

    from repro import obs

    tel = obs.Telemetry()           # unbounded recording sink
    obs.install(tel)                # process-global: all layers now report
    ...  # run Scheduler / FleetScheduler / ReplicaDispatcher work
    obs.uninstall()                 # back to the no-op

    # scoped form
    with obs.use(obs.Telemetry()) as tel:
        fleet.step(executor)

What gets recorded (the instrumented layers):

* ``Scheduler`` — ``scheduler.partition`` / ``scheduler.autotune`` spans
  (iterations, convergence), ``scheduler.observe`` counters,
  ``scheduler.reprofile`` events;
* ``SpeedStore`` — ``speedstore.fold_in`` counters, the
  ``speedstore.fold_generation`` gauge, ``speedstore.partition`` spans with
  the host bisection's iteration count;
* ``FleetScheduler`` — the round lifecycle as spans (``fleet.round`` with
  nested ``fleet.partition`` / ``fleet.measure`` / ``fleet.fold``,
  ``fleet.rebalance``, ``fleet.observe``), restack/predispatch counters,
  speculation hit/miss/stale-read counters, the power-cap theta gauge,
  lane-bucket recompile counters (jit ``_cache_size()`` deltas), and every
  :meth:`~repro.fleet.scheduler.FleetScheduler.stats` field as a
  ``fleet.*`` gauge each round;
* ``Hierarchy`` — aggregation-cache hit/miss counters and outer/inner
  solve spans;
* ``StragglerDetector`` — ``straggler.strike`` events carrying the
  (predicted, observed, ratio) evidence and ``straggler.verdict`` events
  for REPROFILE/QUARANTINE;
* ``ReplicaDispatcher`` — per-epoch replica busy spans on per-replica
  tracks plus the live rebalance-vs-serve wall split (the paper's overhead
  ratio as a gauge);
* ``ProfileRegistry`` — every ``warnings.warn`` (missing/unreadable/
  malformed registry, staleness demotions) mirrored as a structured
  ``registry.warning`` event, so cold-start causes show up in traces.

Artifacts:

* :func:`~repro.obs.chrometrace.export_chrome_trace` writes a
  Chrome-trace/Perfetto JSON (open in ``chrome://tracing`` or
  https://ui.perfetto.dev) — fleet rounds as named spans on per-replica +
  scheduler tracks, so the PR 9 pipeline overlap is visible.  Wired as
  ``--trace out.json`` on ``benchmarks/serve_trace.py`` and
  ``benchmarks/fleet_scale.py``.
* :class:`~repro.obs.flightrec.FlightRecorder` — a ring-bounded sink plus
  estimate snapshots, dumped to JSON on QUARANTINE or gate failure for
  post-incident forensics without a rerun.
* ``python -m repro.obs.report trace.json`` — the paper-style summary
  table (overhead fraction, dispatches/round, compiles, speculation rates,
  reaction times) from either artifact.

See ``examples/obs_walkthrough.py`` for an end-to-end tour.
"""

from .chrometrace import export_chrome_trace, to_chrome_trace
from .flightrec import FlightRecorder
from .telemetry import (
    NOOP,
    Event,
    NoopTelemetry,
    Telemetry,
    active,
    install,
    uninstall,
    use,
)

def __getattr__(name):
    # Lazy: ``python -m repro.obs.report`` executes report as __main__, and
    # an eager package-level import of the same module would make runpy warn
    # about the double life.
    if name == "MetricsSnapshot":
        from .report import MetricsSnapshot

        return MetricsSnapshot
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Event",
    "Telemetry",
    "NoopTelemetry",
    "NOOP",
    "active",
    "install",
    "uninstall",
    "use",
    "FlightRecorder",
    "MetricsSnapshot",
    "export_chrome_trace",
    "to_chrome_trace",
]
