from .context import activation_sharding, current_activation_mesh, maybe_constrain
from .rules import (
    LOGICAL_RULES,
    batch_pspec,
    logical_to_pspec,
    shardings_for_axes,
    shardings_for_spec,
)

__all__ = [
    "LOGICAL_RULES",
    "activation_sharding",
    "batch_pspec",
    "current_activation_mesh",
    "logical_to_pspec",
    "maybe_constrain",
    "shardings_for_axes",
    "shardings_for_spec",
]
