"""Round-semantics conformance suite for the pipelined fleet driver.

The contract this file locks (see "Round lifecycle: sync vs pipelined" in
``fleet/scheduler.py``):

* ``pipeline=False`` (the default) stays bit-identical to the pre-pipeline
  sync rounds — re-proven here against q independent autotune sessions on
  top of ``test_fleet.py``'s existing lanes.
* ``pipeline=True, pipeline_depth=0`` reads only the newest carry and is
  bit-identical to sync (the pre-dispatch machinery must be a pure no-op
  semantically).
* ``pipeline=True, pipeline_depth=1`` may partition against estimates one
  fold generation old — never more — and converges to the SAME fixed point
  as sync within <= 2 extra rounds on every fuzz case.
* Every interleaving of fold-vs-partition completion order (forced through
  the deterministic ``fold_ready_hook`` seam) reaches that same fixed
  point; the all-fold-first schedule is bit-identical to sync.
* Mid-flight ``admit``/``retire``/``resize`` and mid-round ``state_dict``
  round-trips preserve those guarantees (the pipeline drains or discards
  its pre-dispatched work, never serves it across a membership change).

Fuzz lanes follow the repo convention: numpy-rng lanes >= 200 cases under
the ``slow`` marker with small tier-1 smokes.
"""

import itertools
import json

import numpy as np
import pytest

from jax.experimental import enable_x64

from repro.core import (
    BatchedSimulatedExecutor2D,
    DelayedBatchedExecutor,
    SpeedStore,
)
from repro.fleet import FleetScheduler, JobSpec

from test_fleet import (
    BIT_EXACT,
    _batch_fn,
    _check_fleet_parity,
    _energy_fixtures,
    _knee_params,
    _random_fleet_case,
)


# ---------------------------------------------------------------------------
# Case builders / runners
# ---------------------------------------------------------------------------


def _converging_case(rng):
    """Like ``_random_fleet_case`` but guaranteed head-room to converge:
    moderate eps, generous max_iter, no caps — the bounded-staleness lane
    asserts BOTH modes reach the eps test, so probe-exhaustion cut-offs
    (which freeze a lagged pipeline allocation by design) are excluded."""
    p = int(rng.integers(2, 7))
    q = int(rng.integers(1, 5))
    base, knee = _knee_params(rng, q, p)
    jobs = [
        dict(
            n=int(rng.integers(max(2 * p, 8), 60 * p)),
            eps=float(rng.uniform(0.06, 0.25)),
            caps=None,
            min_units=1,
            max_iter=24,
        )
        for _ in range(q)
    ]
    return dict(p=p, q=q, base=base, knee=knee, jobs=jobs)


def _mk_fleet(case, backend, **kw):
    fleet = FleetScheduler(case["p"], backend=backend, **kw)
    for j, spec in enumerate(case["jobs"]):
        fleet.admit(
            JobSpec(
                name=str(j),
                n=spec["n"],
                eps=spec["eps"],
                caps=spec["caps"],
                min_units=spec["min_units"],
                max_iter=spec["max_iter"],
            )
        )
    return fleet


def _mk_ex(case):
    return BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(case["base"], case["knee"]),
        p=case["p"],
        q=case["q"],
        job_names=[str(j) for j in range(case["q"])],
    )


def _run_case(case, backend, **kw):
    fleet = _mk_fleet(case, backend, **kw)
    return fleet, fleet.run(_mk_ex(case))


def _assert_fleet_equal(fa, ra, fb, rb, q):
    """Full bit-identity between two fleet sessions over the same case."""
    for j in range(q):
        name = str(j)
        a, b = ra[name], rb[name]
        assert a.allocations == b.allocations
        assert a.times == b.times
        assert a.iterations == b.iterations
        assert a.converged == b.converged
        assert a.imbalance == b.imbalance
        assert a.diagnostics["history"] == b.diagnostics["history"]
        assert fa.bench_cost(name) == fb.bench_cost(name)
        assert [m.as_points() for m in fa.models(name)] == [
            m.as_points() for m in fb.models(name)
        ]


def _check_depth0_identity(case, backend):
    fs, rs = _run_case(case, backend)
    fp, rp = _run_case(case, backend, pipeline=True, pipeline_depth=0)
    _assert_fleet_equal(fs, rs, fp, rp, case["q"])
    assert fp.stale_reads == 0  # depth 0 never reads the stale generation


def _check_bounded_staleness(case, backend):
    """The depth-1 conformance bound: same fixed point as sync within <= 2
    extra rounds.  On a deterministic replay the seen-set validation makes
    every speculation miss, so the trajectory is bit-identical (0 extra) —
    asserted in full; the speculative machinery must actually have run."""
    fs, rs = _run_case(case, backend)
    fp, rp = _run_case(case, backend, pipeline=True, pipeline_depth=1)
    for j in range(case["q"]):
        name = str(j)
        assert rp[name].allocations == rs[name].allocations
        assert rp[name].converged == rs[name].converged
        assert sum(rp[name].allocations) == case["jobs"][j]["n"]
    _assert_fleet_equal(fs, rs, fp, rp, case["q"])
    assert fp.rounds <= fs.rounds + 2
    if fp.rounds >= 4:  # long enough for the stale generation to exist
        assert fp.stale_reads + fp.speculative_misses > 0


# ---------------------------------------------------------------------------
# Construction contract
# ---------------------------------------------------------------------------


def test_pipeline_validation():
    with pytest.raises(ValueError, match="banked backend"):
        FleetScheduler(4, backend="scalar", pipeline=True)
    with pytest.raises(ValueError, match="pipeline_depth"):
        FleetScheduler(4, backend="numpy", pipeline=True, pipeline_depth=2)
    for backend in ("numpy", "jax"):
        fl = FleetScheduler(4, backend=backend, pipeline=True)
        assert fl.pipeline and fl.pipeline_depth == 1


# ---------------------------------------------------------------------------
# depth 0 == sync, bit for bit
# ---------------------------------------------------------------------------


def test_depth0_bit_identical_to_sync_jax_smoke():
    rng = np.random.default_rng(900)
    with enable_x64():
        for _ in range(4):
            _check_depth0_identity(_random_fleet_case(rng), "jax")


def test_depth0_bit_identical_to_sync_numpy_smoke():
    rng = np.random.default_rng(901)
    for _ in range(5):
        _check_depth0_identity(_random_fleet_case(rng), "numpy")


@pytest.mark.slow
def test_depth0_bit_identity_fuzz_numpy_lane():
    rng = np.random.default_rng(902)
    for _ in range(200):
        _check_depth0_identity(_random_fleet_case(rng), "numpy")


@pytest.mark.slow
def test_depth0_bit_identity_fuzz_jax_lane():
    rng = np.random.default_rng(903)
    with enable_x64():
        for _ in range(200):
            _check_depth0_identity(_random_fleet_case(rng), "jax")


@pytest.mark.slow
def test_sync_default_bit_identity_fuzz_lane():
    """The default-mode guarantee, re-proven from this suite's seeds: a
    post-refactor sync fleet still matches q independent autotune loops."""
    rng = np.random.default_rng(904)
    for _ in range(200):
        _check_fleet_parity(_random_fleet_case(rng), "numpy")


# ---------------------------------------------------------------------------
# depth 1: bounded staleness, same fixed point, <= 2 extra rounds
# ---------------------------------------------------------------------------


def test_bounded_staleness_jax_smoke():
    rng = np.random.default_rng(910)
    with enable_x64():
        for _ in range(4):
            _check_bounded_staleness(_converging_case(rng), "jax")


def test_bounded_staleness_numpy_smoke():
    rng = np.random.default_rng(911)
    for _ in range(5):
        _check_bounded_staleness(_converging_case(rng), "numpy")


@pytest.mark.slow
def test_bounded_staleness_fuzz_numpy_lane():
    rng = np.random.default_rng(912)
    for _ in range(200):
        _check_bounded_staleness(_converging_case(rng), "numpy")


@pytest.mark.slow
def test_bounded_staleness_fuzz_jax_lane():
    rng = np.random.default_rng(913)
    with enable_x64():
        for _ in range(200):
            _check_bounded_staleness(_converging_case(rng), "jax")


def test_staleness_bound_never_exceeds_one_generation():
    """The carry a pipelined repartition may read is never more than ONE
    fold generation behind the newest — checked after every round via the
    generation tags the carries carry — and the speculative machinery
    (stale dispatch + validation) actually ran."""
    rng = np.random.default_rng(914)
    case = _converging_case(rng)
    fleet = _mk_fleet(case, "jax", pipeline=True, pipeline_depth=1)
    ex = _mk_ex(case)
    with enable_x64():
        for _ in range(10):
            if not fleet.active_jobs:
                break
            fleet.step(ex)
            if fleet._stacked_stale is not None:
                gap = fleet._stacked.generation - fleet._stacked_stale.generation
                assert 0 <= gap <= 1
    assert fleet.stale_reads + fleet.speculative_misses > 0
    assert fleet.predispatches > 0  # overlapped partitions were dispatched


def test_speedstore_fold_generation_counter():
    store = SpeedStore.empty(3, backend="numpy")
    assert store.fold_generation == 0
    store.fold_in([4, 5, 6], [0.1, 0.2, 0.3])
    assert store.fold_generation == 1
    store.fold_in([8, 9, 10], [0.2, 0.3, 0.4])
    assert store.fold_generation == 2


# ---------------------------------------------------------------------------
# Genuine stale acceptance: the rounds where speculation actually wins
# ---------------------------------------------------------------------------


def test_serving_rebalance_cycle_accepts_stale_read():
    """The steady-state serving epoch (observe -> rebalance, estimates
    preloaded, nothing measured through the seen set) is where depth-1
    speculation pays: the rebalance after a fold consumes the overlapped
    stale partition instead of waiting on the in-flight fold, lagging it
    by exactly one generation; a drained fresh rebalance then matches the
    sync fleet's post-fold answer bit-for-bit."""
    p = 5

    def build(pipeline):
        kw = dict(pipeline=True, pipeline_depth=1) if pipeline else {}
        fl = FleetScheduler(p, backend="jax", **kw)
        for j, n in enumerate((300, 500)):
            sm, _ = _energy_fixtures(p, seed=20 + j)
            fl.admit(JobSpec(str(j), n), models=sm)
        return fl

    epoch = {"0": [0.2 * (i + 1) for i in range(p)]}
    with enable_x64():
        sync, pipe = build(False), build(True)
        assert sync.rebalance() == pipe.rebalance()  # no stale generation yet
        for fl in (sync, pipe):
            fl.observe(epoch)
        ds_sync = sync.rebalance()
        ds_pipe = pipe.rebalance()
        assert pipe.stale_reads == 1  # the overlapped partition was consumed
        for nm, d in ds_pipe.items():
            assert sum(d) == pipe._jobs[nm].spec.n
        # the stale read lags the fold by one generation; draining and
        # re-reading fresh reconverges onto the sync answer exactly
        pipe.drain()
        assert pipe.rebalance() == ds_sync


def test_resize_after_convergence_accepts_stale_and_reconverges():
    """A fleet-wide resize clears every seen set, so the next round's
    speculative partition is consumable (novel n, novel distributions):
    the re-run converges from one-generation-old estimates within eps."""
    rng = np.random.default_rng(915)
    p, q = 4, 2
    base, knee = _knee_params(rng, q, p)
    ex = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(base, knee),
        p=p,
        q=q,
        job_names=[str(j) for j in range(q)],
    )
    with enable_x64():
        fleet = FleetScheduler(p, backend="jax", pipeline=True, pipeline_depth=1)
        for j in range(q):
            fleet.admit(
                JobSpec(name=str(j), n=60 + 40 * j, eps=0.15, min_units=1,
                        max_iter=20)
            )
        fleet.run(ex)
        fleet.resize("0", n=77)
        fleet.resize("1", n=131)
        pre = fleet.stale_reads
        res = fleet.run(ex)
    assert fleet.stale_reads > pre  # the resized round speculated and won
    assert sum(res["0"].allocations) == 77
    assert sum(res["1"].allocations) == 131
    assert res["0"].converged and res["1"].converged


# ---------------------------------------------------------------------------
# Deterministic interleaving enumeration (the fake-async seam)
# ---------------------------------------------------------------------------


def test_every_fold_vs_partition_interleaving_reaches_sync_fixed_point():
    """``fold_ready_hook`` forces the completion order per round: True
    means "the fold finished before the partition dispatched" (fresh read),
    False leaves the pipeline free to speculate on the stale generation.
    Every schedule in {fold-first, partition-first}^R must produce sync's
    results bit-for-bit within <= 2 extra rounds — the seen-set validation
    makes the completion order unobservable in the allocations."""
    rng = np.random.default_rng(920)
    case = _converging_case(rng)
    R = 4
    with enable_x64():
        fs, rs = _run_case(case, "jax")
        for schedule in itertools.product([False, True], repeat=R):
            fleet = _mk_fleet(case, "jax", pipeline=True, pipeline_depth=1)
            fleet.fold_ready_hook = lambda s=schedule: s[min(fleet.rounds, R - 1)]
            rp = fleet.run(_mk_ex(case))
            _assert_fleet_equal(fs, rs, fleet, rp, case["q"])
            assert fleet.rounds <= fs.rounds + 2, schedule
            if all(schedule):
                # every round read fresh -> no speculation at all
                assert fleet.stale_reads == 0 and fleet.speculative_misses == 0


# ---------------------------------------------------------------------------
# Mid-flight admit / retire / resize under the pipeline
# ---------------------------------------------------------------------------


def _membership_script(fleet, ex, specs):
    """Shared mid-flight script: staggered admits, a retire, a resize."""
    fleet.admit(specs[0])
    fleet.step(ex)
    fleet.step(ex)
    fleet.admit(specs[1])  # restack: the pipeline must drain/discard
    fleet.step(ex)
    fleet.admit(specs[2])
    fleet.step(ex)
    retired = fleet.retire("1")
    fleet.resize("0", n=specs[0].n + 17)
    results = fleet.run(ex)
    return retired, results


def test_pipeline_depth0_membership_changes_bit_identical_to_sync():
    rng = np.random.default_rng(930)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)
    specs = [
        JobSpec(name=str(j), n=40 + 30 * j, eps=0.05, min_units=1, max_iter=8)
        for j in range(q)
    ]

    def mk_ex():
        return BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee),
            p=p,
            q=q,
            job_names=[str(j) for j in range(q)],
        )

    with enable_x64():
        sync = FleetScheduler(p, backend="jax")
        ret_s, res_s = _membership_script(sync, mk_ex(), specs)
        pipe = FleetScheduler(p, backend="jax", pipeline=True, pipeline_depth=0)
        ret_p, res_p = _membership_script(pipe, mk_ex(), specs)
    assert ret_p.allocations == ret_s.allocations
    assert ret_p.diagnostics["history"] == ret_s.diagnostics["history"]
    for name in ("0", "2"):
        assert res_p[name].allocations == res_s[name].allocations
        assert res_p[name].times == res_s[name].times
        assert (
            res_p[name].diagnostics["history"]
            == res_s[name].diagnostics["history"]
        )
        assert pipe.bench_cost(name) == sync.bench_cost(name)


def test_pipeline_depth1_membership_changes_prefix_parity():
    """Depth 1 with mid-flight membership churn: the deterministic replay
    stays bit-identical to sync (every speculation misses its seen-set
    validation), the retired job's history is a bounded prefix of the rounds
    it ran, survivors reach correct sums, and the pre-dispatched partition
    is never served across a restack (its fingerprint covers the
    membership)."""
    rng = np.random.default_rng(931)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)
    specs = [
        JobSpec(name=str(j), n=40 + 30 * j, eps=0.1, min_units=1, max_iter=20)
        for j in range(q)
    ]

    def mk_ex():
        return BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee),
            p=p,
            q=q,
            job_names=[str(j) for j in range(q)],
        )

    with enable_x64():
        sync = FleetScheduler(p, backend="jax")
        ret_s, res_s = _membership_script(sync, mk_ex(), specs)
        fleet = FleetScheduler(p, backend="jax", pipeline=True, pipeline_depth=1)
        retired, results = _membership_script(fleet, mk_ex(), specs)
    assert 0 < len(retired.diagnostics["history"]) <= 4
    assert retired.diagnostics["history"] == ret_s.diagnostics["history"]
    assert sum(results["0"].allocations) == specs[0].n + 17
    assert sum(results["2"].allocations) == specs[2].n
    for name in ("0", "2"):
        assert results[name].allocations == res_s[name].allocations
        assert results[name].converged == res_s[name].converged
        assert (
            results[name].diagnostics["history"]
            == res_s[name].diagnostics["history"]
        )
    # deterministic replay: every speculation misses, none consumed
    assert fleet.stale_reads == 0
    assert fleet.speculative_misses > 0
    assert fleet.predispatches > 0


# ---------------------------------------------------------------------------
# state_dict round-trip while a round is in flight (satellite 2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "backend,pipeline", [("jax", False), ("jax", True), ("numpy", True)]
)
def test_state_dict_roundtrip_mid_flight(backend, pipeline):
    """Checkpointing a fleet with work in flight (pending fold carry, a
    pre-dispatched partition) must drain the pipeline and serialize a state
    whose restore continues bit-identically to the donor."""
    rng = np.random.default_rng(940)
    case = _converging_case(rng)
    kw = dict(pipeline=True, pipeline_depth=1) if pipeline else {}
    with enable_x64():
        donor = _mk_fleet(case, backend, **kw)
        ex = _mk_ex(case)
        for _ in range(3):
            donor.step(ex)
        if pipeline and backend == "jax":
            assert donor._predispatched is not None  # genuinely mid-pipeline
        state = json.loads(json.dumps(donor.state_dict()))  # JSON-safe
        assert state["config"]["pipeline"] == bool(pipeline)
        restored = FleetScheduler.from_state(state)
        res_a = donor.run(ex)
        res_b = restored.run(_mk_ex(case))
    for j in range(case["q"]):
        name = str(j)
        assert res_a[name].allocations == res_b[name].allocations
        assert (
            res_a[name].diagnostics["history"]
            == res_b[name].diagnostics["history"]
        )
        assert res_a[name].converged == res_b[name].converged


def test_state_dict_drains_pipeline():
    rng = np.random.default_rng(941)
    case = _converging_case(rng)
    with enable_x64():
        fleet = _mk_fleet(case, "jax", pipeline=True, pipeline_depth=1)
        ex = _mk_ex(case)
        fleet.step(ex)
        fleet.step(ex)
        fleet.state_dict()
    assert fleet._predispatched is None
    assert fleet._stacked_stale is None


# ---------------------------------------------------------------------------
# quantize= x lane_buckets=True composed (satellite 3)
# ---------------------------------------------------------------------------


def test_quantize_lane_buckets_composed_parity_and_dummy_lane_noop():
    """PR 7's quantized folds and PR 8's padded lane buckets compose: the
    bucketed fleet is bit-identical to the unbucketed one on the quantized
    knot grid, and the masked dummy lane's carry rows (the single-knot
    padding sentinel) stay EXACTLY untouched through every quantized fold —
    a fold that perturbed them would shift the shared knot grid and break
    the bucket's zero-recompile guarantee."""
    rng = np.random.default_rng(950)
    p, q = 4, 3  # q=3 pads to 4: one dummy lane in every program

    base, knee = _knee_params(rng, q, p)

    def run(buckets):
        fleet = FleetScheduler(
            p, backend="jax", quantize=0.05, lane_buckets=buckets
        )
        for j in range(q):
            fleet.admit(
                JobSpec(
                    name=str(j), n=50 + 30 * j, eps=0.05, min_units=1, max_iter=6
                )
            )
        snap = None
        if buckets:
            stacked = fleet._ensure_stack()
            snap = (
                np.asarray(stacked.counts)[q:].copy(),
                np.asarray(stacked.xs)[q:].copy(),
                np.asarray(stacked.ss)[q:].copy(),
            )
        ex = BatchedSimulatedExecutor2D(
            time_fn_batch_2d=_batch_fn(base, knee),
            p=p,
            q=q,
            job_names=[str(j) for j in range(q)],
        )
        results = fleet.run(ex)
        return fleet, results, snap

    with enable_x64():
        fa, ra, _ = run(False)
        fb, rb, snap = run(True)
        # padded stack: 4 lanes for 3 jobs, the 4th masked out
        assert int(fb._stacked.counts.shape[0]) == 4
        dummy_counts = np.asarray(fb._stacked.counts)[q:]
        dummy_xs = np.asarray(fb._stacked.xs)[q:]
        dummy_ss = np.asarray(fb._stacked.ss)[q:]
    # every quantized fold left the dummy rows bit-identical to the
    # padding sentinel captured before any measurement was folded in: same
    # knot counts, same valid knots.  (Folds may GROW the shared padded
    # knot-capacity axis — the pad replicates the last knot — so only the
    # valid prefix is comparable across the run.)
    assert np.array_equal(dummy_counts, snap[0])
    kv = int(snap[0].max())  # sentinel width: one knot per processor
    assert np.array_equal(dummy_xs[..., :kv], snap[1][..., :kv])
    assert np.array_equal(dummy_ss[..., :kv], snap[2][..., :kv])
    for j in range(q):
        name = str(j)
        assert ra[name].allocations == rb[name].allocations
        assert ra[name].diagnostics["history"] == rb[name].diagnostics["history"]
        assert [m.as_points() for m in fa.models(name)] == [
            m.as_points() for m in fb.models(name)
        ]


def test_quantize_lane_buckets_pipeline_composed():
    """All three compose: quantized folds + padded buckets + depth-0
    pipeline stay bit-identical to the plain quantized sync fleet."""
    rng = np.random.default_rng(951)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)
    case = dict(
        p=p,
        q=q,
        base=base,
        knee=knee,
        jobs=[
            dict(n=50 + 30 * j, eps=0.05, caps=None, min_units=1, max_iter=6)
            for j in range(q)
        ],
    )
    with enable_x64():
        fs, rs = _run_case(case, "jax", quantize=0.05)
        fp, rp = _run_case(
            case,
            "jax",
            quantize=0.05,
            lane_buckets=True,
            pipeline=True,
            pipeline_depth=0,
        )
    for j in range(q):
        name = str(j)
        assert rs[name].allocations == rp[name].allocations
        assert rs[name].diagnostics["history"] == rp[name].diagnostics["history"]


# ---------------------------------------------------------------------------
# Power cap + hierarchy routes under the pipeline
# ---------------------------------------------------------------------------


def test_pipeline_power_cap_reads_consistent_generation():
    """A power cap forces every priced repartition onto the newest carry
    (``_apply_power_cap`` prices time and energy against ONE generation):
    the capped pipeline fleet matches the capped sync fleet bit-for-bit
    and never counts a stale read."""
    p = 5

    def build(pipeline):
        kw = dict(pipeline=True, pipeline_depth=1) if pipeline else {}
        fl = FleetScheduler(p, backend="jax", power_cap=50.0, **kw)
        for j, n in enumerate((300, 500)):
            sm, em = _energy_fixtures(p, seed=10 + j)
            fl.admit(JobSpec(str(j), n), models=sm, energy_models=em)
        return fl

    with enable_x64():
        sync, pipe = build(False), build(True)
        assert sync.rebalance() == pipe.rebalance()
        for fl in (sync, pipe):
            fl.observe({"0": [0.1 * (i + 1) for i in range(p)]})
        assert sync.rebalance() == pipe.rebalance()
    assert pipe.stale_reads == 0


def test_pipeline_hier_route_depth0_matches_sync():
    class _FleetExec:
        def __init__(self, p, seed=3):
            r = np.random.default_rng(seed)
            self.base = r.uniform(5.0, 50.0, size=p)
            self.bend = r.uniform(50, 400, size=p)
            self.num_procs = p

        def run_jobs(self, names, D):
            D = np.asarray(D, dtype=np.float64)
            s = self.base * (1.0 + 0.3 * np.minimum(D, self.bend) / self.bend)
            return np.where(D > 0, D / s, 0.0)

    p = 8
    groups = [i % 2 for i in range(p)]

    def run(**kw):
        fs = FleetScheduler(p, backend="jax", groups=groups, **kw)
        fs.admit(JobSpec(name="a", n=500, eps=0.05, max_iter=8))
        fs.admit(JobSpec(name="b", n=700, eps=0.05, max_iter=8))
        res = fs.run(_FleetExec(p), max_rounds=10)
        return fs, {k: (v.allocations, v.diagnostics["history"]) for k, v in res.items()}

    with enable_x64():
        _, sync = run()
        _, d0 = run(pipeline=True, pipeline_depth=0)
        _, d1 = run(pipeline=True, pipeline_depth=1)
    if BIT_EXACT:
        assert sync == d0
        assert sync == d1  # deterministic replay: every speculation misses
    for k in sync:
        assert sum(d1[k][0]) == sum(sync[k][0])


# ---------------------------------------------------------------------------
# DelayedBatchedExecutor (satellite 1): the reproducible async double
# ---------------------------------------------------------------------------


def test_delayed_executor_preserves_times_and_fleet_parity():
    rng = np.random.default_rng(960)
    case = _converging_case(rng)
    lat = {str(j): 0.5 * j for j in range(case["q"])}
    with enable_x64():
        fs, rs = _run_case(case, "jax", pipeline=True, pipeline_depth=1)
        fleet = _mk_fleet(case, "jax", pipeline=True, pipeline_depth=1)
        wrapped = DelayedBatchedExecutor(inner=_mk_ex(case), lane_latency=lat, seed=7)
        rw = fleet.run(wrapped)
    for j in range(case["q"]):
        name = str(j)
        assert rw[name].allocations == rs[name].allocations
        assert rw[name].times == rs[name].times
        assert (
            rw[name].diagnostics["history"] == rs[name].diagnostics["history"]
        )
    assert len(wrapped.completions) > 0
    assert wrapped.clock > 0.0


def test_delayed_executor_seeded_reproducibility_and_straggler_order():
    rng = np.random.default_rng(961)
    p, q = 4, 3
    base, knee = _knee_params(rng, q, p)

    def mk(seed, lat):
        return DelayedBatchedExecutor(
            inner=BatchedSimulatedExecutor2D(
                time_fn_batch_2d=_batch_fn(base, knee),
                p=p,
                q=q,
                job_names=[str(j) for j in range(q)],
            ),
            lane_latency=lat,
            seed=seed,
        )

    D = [[10, 12, 8, 5]] * q
    names = [str(j) for j in range(q)]

    # same seed -> identical completion logs, different latency -> the
    # straggler ("1") completes last while times stay bit-equal to bare
    straggler = {"0": 0.0, "1": 10.0, "2": 0.0}
    a, b = mk(0, straggler), mk(0, straggler)
    Ta = np.asarray(a.run_jobs(names, D))
    Tb = np.asarray(b.run_jobs(names, D))
    bare = BatchedSimulatedExecutor2D(
        time_fn_batch_2d=_batch_fn(base, knee),
        p=p,
        q=q,
        job_names=names,
    )
    assert np.array_equal(Ta, np.asarray(bare.run_jobs(names, D)))
    assert a.completions == b.completions
    assert a.completions[-1][1] == "1"  # straggler observed last
    assert a.clock == a.completions[-1][0]

    # equal latencies: the seeded permutation still fixes a reproducible
    # tie-break order
    c, d = mk(5, None), mk(5, None)
    c.run_jobs(names, [[3, 3, 3, 3]] * q)
    d.run_jobs(names, [[3, 3, 3, 3]] * q)
    assert c.completions == d.completions


# ---------------------------------------------------------------------------
# ReplicaDispatcher.balance_fleet threading
# ---------------------------------------------------------------------------


def test_balance_fleet_pipeline_threading_and_warm_toggle():
    from repro.runtime.serve_loop import ReplicaDispatcher

    base = [4e-4, 2e-4, 8e-4, 3e-4]

    def replica_run(i, x):
        t = x * base[i]
        if x > 30:
            t += (x - 30) * base[i] * 3.0
        return t

    tenants = {"chat": 48, "embed": 96}
    with enable_x64():
        sync = ReplicaDispatcher(replica_run, 4, eps=0.15)
        res_s = sync.balance_fleet(tenants, backend="jax", min_units=1)
        disp = ReplicaDispatcher(replica_run, 4, eps=0.15)
        res_p = disp.balance_fleet(
            tenants, backend="jax", min_units=1, pipeline=True, pipeline_depth=0
        )
        assert disp.fleet.pipeline and disp.fleet.pipeline_depth == 0
        for nm in tenants:
            assert res_p[nm].allocations == res_s[nm].allocations
            assert (
                res_p[nm].diagnostics["history"]
                == res_s[nm].diagnostics["history"]
            )
        # warm toggle back to sync drains the pipeline in place
        res_off = disp.balance_fleet(tenants, backend="jax", min_units=1)
        assert disp.fleet.pipeline is False
        assert disp.fleet._predispatched is None
        assert disp.fleet._stacked_stale is None
        for nm in tenants:
            assert sum(res_off[nm].allocations) == tenants[nm]
        # depth 1 keeps serving the warm session too
        res_d1 = disp.balance_fleet(
            tenants, backend="jax", min_units=1, pipeline=True, pipeline_depth=1
        )
        for nm in tenants:
            assert sum(res_d1[nm].allocations) == tenants[nm]
