"""Serving-path tests over the traffic-trace harness (benchmarks/serve_trace).

Locks the three serving-path behaviors this harness was built to expose:
trace determinism under a fixed seed, straggler reaction on the RIGHT
replica before AND after a fleet resize, and balance_fleet warm-session
reuse (bit-identical to a fresh session admitted with the same models, with
zero new compilations).
"""

import sys
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))

import serve_trace as st  # noqa: E402

from repro.fleet import ProfileRegistry  # noqa: E402
from repro.runtime.serve_loop import ReplicaDispatcher  # noqa: E402
from repro.runtime.straggler import StragglerAction  # noqa: E402


# ---------------------------------------------------------------------------
# trace determinism
# ---------------------------------------------------------------------------


def test_trace_deterministic_under_fixed_seed():
    cfg = st.QUICK
    t1, t2 = st.build_trace(cfg), st.build_trace(cfg)
    assert t1 == t2
    assert st.build_trace(replace(cfg, seed=cfg.seed + 1)) != t1


def test_trace_has_flash_crowd_and_admit_segments():
    cfg = st.QUICK
    trace = st.build_trace(cfg)
    assert len(trace) == cfg.epochs
    name, f0, f1, mult = cfg.flash
    inside = np.mean([trace[e][name] for e in range(f0, f1)])
    outside = np.mean(
        [trace[e][name] for e in range(cfg.epochs) if not f0 <= e < f1]
    )
    assert inside > 1.5 * outside  # the flash crowd is visible in the trace
    aname, _, a0, a1 = cfg.admit
    assert all(aname in trace[e] for e in range(a0, a1))
    assert all(aname not in trace[e] for e in range(a0))


def test_world_speeds_deterministic():
    cfg = st.QUICK
    w1 = st.world_with_joiner(cfg, st.build_world(cfg))
    w2 = st.world_with_joiner(cfg, st.build_world(cfg))
    rids = [r.rid for r in w1.replicas]
    for e in (0, 10, cfg.straggler[1] + 3):
        assert np.array_equal(w1.speeds(rids, e), w2.speeds(rids, e))


# ---------------------------------------------------------------------------
# straggler reaction: right replica, before AND after a resize
# ---------------------------------------------------------------------------


def _serve_epoch(disp, tenants, speeds):
    """One steady serving epoch: rebalance -> simulate -> scan -> fold."""
    fleet = disp.fleet
    ds = fleet.rebalance(dict(tenants))
    times = {
        name: [d / s if d > 0 else 0.0 for d, s in zip(dvec, speeds)]
        for name, dvec in ds.items()
    }
    acts = fleet.straggler_actions(times)  # scan BEFORE fold
    fleet.observe(times)
    return acts


def _decay_until(disp, tenants, base_speeds, lane, action, max_epochs=10):
    """Throttle ``lane`` with a runaway x0.5/epoch decay until ``action``
    fires; returns (epochs_taken, set of OTHER lanes any action fired on)."""
    others = set()
    for k in range(max_epochs):
        speeds = list(base_speeds)
        speeds[lane] = base_speeds[lane] * 0.5 ** (k + 1)
        acts = _serve_epoch(disp, tenants, speeds)
        for i, a in enumerate(acts):
            if a is not StragglerAction.NONE and i != lane:
                others.add(i)
        if acts[lane] is action:
            return k + 1, others
    return None, others


def test_straggler_reaction_right_replica_before_and_after_resize():
    tenants = {"t": 400}
    speeds = [8.0, 8.0, 4.0, 4.0]
    disp = ReplicaDispatcher(
        replica_run=lambda i, x: 0.0, num_replicas=4, eps=0.08
    )
    disp.replica_run = lambda i, x: x / speeds[i]
    disp.balance_fleet(
        tenants, reserve_knots=16, quantize=0.05, min_units=1, max_iter=12
    )
    for _ in range(3):  # healthy steady epochs: no strikes anywhere
        acts = _serve_epoch(disp, tenants, speeds)
        assert all(a is StragglerAction.NONE for a in acts)

    # BEFORE resize: runaway decay on replica 2 -> REPROFILE on replica 2,
    # within the detector's patience, and on NO other replica
    n, others = _decay_until(
        disp, tenants, speeds, lane=2, action=StragglerAction.REPROFILE
    )
    assert n is not None and n <= disp.fleet.detector.patience
    assert others == set()

    # resize: replica 2 leaves (quarantine path) -> fresh 3-replica session;
    # strikes must follow the survivors (detector remap)
    old_fleet = disp.fleet
    survivors = [0, 1, 3]
    speeds3 = [speeds[i] for i in survivors]
    disp.num_replicas = 3
    disp.replica_run = lambda i, x: x / speeds3[i]
    disp.balance_fleet(
        tenants, reserve_knots=16, quantize=0.05, min_units=1, max_iter=12
    )
    assert disp.fleet is not old_fleet  # replica-count change -> fresh
    disp.fleet.detector = old_fleet.detector.remap(survivors)

    # AFTER resize: decay the replica formerly at index 3 (now index 2) ->
    # the reaction must land on the SHIFTED index, nowhere else
    n, others = _decay_until(
        disp, tenants, speeds3, lane=2, action=StragglerAction.REPROFILE
    )
    assert n is not None and n <= disp.fleet.detector.patience
    assert others == set()


# ---------------------------------------------------------------------------
# balance_fleet warm reuse: bit-identical, zero new compilations
# ---------------------------------------------------------------------------


def test_balance_fleet_warm_reuse_parity_and_no_recompile():
    import repro.core.modelbank_jax as mbj

    speeds = [4.0, 2.0, 1.0]
    tenants = {"a": 300, "b": 120}
    # distinct per-replica classes and per-tenant workloads: registry
    # profiles stay per-(replica, tenant), so a fresh session warm-starts
    # from EXACTLY the models the warm session resumes from
    kw = dict(
        device_classes=["c0", "c1", "c2"],
        workloads={"a": "wa", "b": "wb"},
        reserve_knots=16,
        quantize=0.05,
        min_units=1,
        max_iter=10,
    )
    disp = ReplicaDispatcher(
        replica_run=lambda i, x: x / speeds[i], num_replicas=3, eps=0.08
    )
    disp.balance_fleet(tenants, registry=ProfileRegistry(), **kw)

    fleet0 = disp.fleet
    caches0 = (
        mbj._partition_units_jit._cache_size(),
        mbj._fold_in_jit._cache_size(),
    )
    restacks0 = fleet0.restacks
    res_warm = disp.balance_fleet(tenants, registry=ProfileRegistry(), **kw)

    # warm session reused: same object, no restack, ZERO new compilations
    assert disp.fleet is fleet0
    assert fleet0.restacks == restacks0
    assert mbj._partition_units_jit._cache_size() == caches0[0]
    assert mbj._fold_in_jit._cache_size() == caches0[1]

    # bit-identical to a fresh session admitted with the same models
    # (checkpointed through the registry)
    reg = ProfileRegistry()
    disp.fleet.save_profiles(reg)
    disp2 = ReplicaDispatcher(
        replica_run=lambda i, x: x / speeds[i], num_replicas=3, eps=0.08
    )
    res_fresh = disp2.balance_fleet(tenants, registry=reg, **kw)
    assert disp2.fleet is not fleet0
    for name in tenants:
        assert res_warm[name].allocations == res_fresh[name].allocations


def test_balance_fleet_admit_retire_rides_warm_session():
    speeds = [4.0, 2.0, 1.0]
    disp = ReplicaDispatcher(
        replica_run=lambda i, x: x / speeds[i], num_replicas=3, eps=0.08
    )
    disp.balance_fleet({"a": 300}, reserve_knots=16, min_units=1, max_iter=10)
    fleet0 = disp.fleet
    # admit a new tenant + retire nothing: same session
    res = disp.balance_fleet(
        {"a": 300, "b": 120}, reserve_knots=16, min_units=1, max_iter=10
    )
    assert disp.fleet is fleet0
    assert set(res) == {"a", "b"}
    assert set(fleet0.jobs) == {"a", "b"}
    # retire one: still the same session
    disp.balance_fleet({"b": 120}, reserve_knots=16, min_units=1, max_iter=10)
    assert disp.fleet is fleet0
    assert set(fleet0.jobs) == {"b"}
    # a replica-count change is the ONLY fresh-session trigger here
    disp.num_replicas = 2
    disp.replica_run = lambda i, x: x / speeds[i]
    disp.balance_fleet({"b": 120}, reserve_knots=16, min_units=1, max_iter=10)
    assert disp.fleet is not fleet0
