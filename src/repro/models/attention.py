"""Attention: GQA/MQA (global, sliding-window, cross) and MLA (deepseek-v2).

Cache convention (per attention layer):
  * GQA:  {"k": (B, S_buf, Kv, hd), "v": (B, S_buf, Kv, hd),
           "pos": (S_buf,) int32 absolute positions, -1 = empty}
  * MLA:  {"ckv": (B, S_buf, kv_lora), "kr": (B, S_buf, rope_hd),
           "pos": (S_buf,)}

``S_buf = min(seq_budget, window)`` for local layers (ring buffer), else the
full sequence budget.  Decode writes at ``index % S_buf``; masks are derived
from the stored absolute positions, so ring wraparound is handled uniformly.

Flash-attention Pallas kernels (``repro.kernels.flash_attention``) are the
TPU perf path for the training/prefill full-sequence case; the jnp path here
is the oracle and the portable/dry-run path (toggled via ``use_kernel``).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.params import ParamSpec
from ..sharding.context import maybe_constrain
from .config import ModelConfig
from .layers import rope, softcap

__all__ = [
    "attn_spec",
    "mla_spec",
    "apply_attn",
    "apply_mla",
    "init_attn_cache",
    "init_mla_cache",
]

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def attn_spec(cfg: ModelConfig, *, cross: bool = False) -> Dict:
    d, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, Kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, Kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed")),
    }


def mla_spec(cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.num_heads
    nope, rhd, vhd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wdq": ParamSpec((d, qr), ("embed", "lora")),
        "q_norm": {"scale": ParamSpec((qr,), ("lora",), init="ones")},
        "wuq": ParamSpec((qr, H, nope + rhd), ("lora", "heads", "head_dim")),
        "wdkv": ParamSpec((d, kvr), ("embed", "lora")),
        "kv_norm": {"scale": ParamSpec((kvr,), ("lora",), init="ones")},
        "wuk": ParamSpec((kvr, H, nope), ("lora", "heads", "head_dim")),
        "wuv": ParamSpec((kvr, H, vhd), ("lora", "heads", "head_dim")),
        "wkr": ParamSpec((d, rhd), ("embed", "head_dim")),
        "wo": ParamSpec((H, vhd, d), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _buf_len(cfg: ModelConfig, kind: str, seq_budget: int) -> int:
    if kind == "local" and cfg.window > 0:
        return min(seq_budget, cfg.window)
    return seq_budget


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, seq_budget: int, dtype) -> Dict:
    S = _buf_len(cfg, kind, seq_budget)
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, S, Kv, hd), dtype),
        "v": jnp.zeros((batch, S, Kv, hd), dtype),
        "pos": jnp.full((S,), -1, jnp.int32),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, seq_budget: int, dtype) -> Dict:
    return {
        "ckv": jnp.zeros((batch, seq_budget, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, seq_budget, cfg.rope_head_dim), dtype),
        "pos": jnp.full((seq_budget,), -1, jnp.int32),
    }


def attn_cache_axes(cfg: ModelConfig, kind: str) -> Dict:
    """Logical sharding axes for the GQA cache.

    Global-attention caches shard the SEQUENCE over the model axis
    ('seq_kv'): batch-only sharding leaves a 32k-context cache replicated
    across tensor ranks whenever kv_heads < |model| (kv=8 archs measured
    50+ GiB/device at decode_32k).  The kv_heads dim then falls back to
    replicated via the conflict rule; decode attention pays one small psum
    of (B, H, 1) partial scores instead.  Sliding-window caches are small
    — keep them batch-sharded only."""
    seq_ax = "seq_kv" if kind != "local" else "seq"
    return {
        "k": ("batch", seq_ax, "kv_heads", "head_dim"),
        "v": ("batch", seq_ax, "kv_heads", "head_dim"),
        "pos": ("seq",),
    }


def mla_cache_axes(cfg: ModelConfig) -> Dict:
    """MLA caches are shared across heads (no head dim to shard) -> shard
    the sequence over the model axis."""
    return {
        "ckv": ("batch", "seq_kv", "lora"),
        "kr": ("batch", "seq_kv", "head_dim"),
        "pos": ("seq",),
    }


# ---------------------------------------------------------------------------
# Core attention math (jnp oracle path)
# ---------------------------------------------------------------------------


def _sdpa(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Kv, hd)
    v: jax.Array,  # (B, Sk, Kv, hd)
    mask: Optional[jax.Array],  # (Sq, Sk) or (B, Sq, Sk) additive-bool
    *,
    scale: float,
    cap: float,
) -> jax.Array:
    """GQA handled by broadcasting KV to H heads (XLA fuses the repeat into
    the matmuls).  A (Kv, G) reshape-grouping instead FRAGMENTS the head
    sharding whenever Kv doesn't divide the model axis — the partitioner
    then thrashes involuntary reshards of the fp32 logits (measured 32 GiB
    of all-gathers per layer on kv=8 x mesh 16)."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    if mask is not None:
        m = mask if mask.ndim == 2 else mask[:, None]
        logits = jnp.where(m, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", w, v)


def _causal_mask(Sq: int, Sk: int, window: int, q_offset: int = 0) -> jax.Array:
    """(Sq, Sk) mask: key j visible to query i iff j <= i (+offset) and within
    the sliding window when ``window > 0``."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def _sdpa_chunked(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Kv, hd)
    v: jax.Array,
    *,
    scale: float,
    cap: float,
    causal: bool,
    window: int,
    q_chunk: int,
    unroll: bool = False,
) -> jax.Array:
    """Flash-attention schedule in pure jnp: scan over query chunks so the
    live logits buffer is (B, q_chunk, Sk) — the long-prefill memory path
    (the Pallas kernel is the on-TPU twin of this loop)."""
    B, Sq, H, hd = q.shape
    L = q_chunk
    if Sq % L != 0:
        return _sdpa(
            q, k, v,
            _causal_mask(Sq, k.shape[1], window) if causal else None,
            scale=scale, cap=cap,
        )
    nc = Sq // L
    qc = q.reshape(B, nc, L, H, hd).transpose(1, 0, 2, 3, 4)
    offsets = jnp.arange(nc) * L

    # checkpoint the chunk body: without it the backward stores every
    # chunk's fp32 softmax residuals simultaneously — i.e. the full
    # (B, H, Sq, Sk) logits the chunking was supposed to avoid.
    @jax.checkpoint
    def body(_, inp):
        qi, off = inp
        if causal:
            mask = _causal_mask(L, k.shape[1], window, q_offset=off)
        else:
            mask = None
        return None, _sdpa(qi, k, v, mask, scale=scale, cap=cap)

    _, out = jax.lax.scan(body, None, (qc, offsets), unroll=unroll)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------


def apply_attn(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (S,) absolute positions of x
    *,
    kind: str,  # attn | local
    causal: bool = True,
    cache: Optional[Dict] = None,
    decode: bool = False,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (output, updated_cache).

    Modes:
      * train:            cache=None, decode=False — full-sequence causal.
      * prefill:          cache given (zeroed), decode=False — fills the cache.
      * decode:           cache given, decode=True, S == 1.
      * cross-attention:  cross_kv=(k, v) precomputed from encoder output.
    """
    dtype = x.dtype
    B, S, _ = x.shape
    window = cfg.window if kind == "local" else 0
    scale = cfg.query_scale if cfg.query_scale > 0 else 1.0 / math.sqrt(cfg.head_dim)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if cross_kv is not None:
        k, v = cross_kv
        q = maybe_constrain(q, ("batch", "seq", "heads", "head_dim"))
        if S >= cfg.attn_chunk_threshold:
            out = _sdpa_chunked(
                q, k, v, scale=scale, cap=cfg.attn_softcap, causal=False,
                window=0, q_chunk=cfg.attn_q_chunk, unroll=cfg.unroll_scans,
            )
        else:
            out = _sdpa(q, k, v, None, scale=scale, cap=cfg.attn_softcap)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype)), cache

    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    # Ulysses-style transition: the residual stream is sequence-sharded over
    # the model axis; attention wants HEADS sharded and the sequence whole —
    # without this the (B, H, Sq, Sk) fp32 logits materialize with ALL heads
    # per device (measured 8 GiB per buffer on deepseek's 128 heads).
    q = maybe_constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = maybe_constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = maybe_constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    q = rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

    def full_attn(q, k, v):
        if S >= cfg.attn_chunk_threshold:
            return _sdpa_chunked(
                q, k, v, scale=scale, cap=cfg.attn_softcap,
                causal=causal, window=window, q_chunk=cfg.attn_q_chunk,
                unroll=cfg.unroll_scans,
            )
        mask = _causal_mask(S, S, window) if causal else None
        return _sdpa(q, k, v, mask, scale=scale, cap=cfg.attn_softcap)

    if cache is None:
        out = full_attn(q, k, v)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype)), None

    S_buf = cache["k"].shape[1]
    if not decode:
        # Prefill: attend over the in-flight sequence, then store the last
        # S_buf positions into the (ring) buffer.  When the prompt exactly
        # fills the buffer (the standard prefill) the ring layout is the
        # identity — write directly, no scatter (keeps the seq-sharded cache
        # path collective-free).
        out = full_attn(q, k, v)
        keep = min(S, S_buf)
        if S == S_buf:
            new_cache = {"k": k, "v": v, "pos": positions}
        else:
            slot = positions[-keep:] % S_buf
            new_cache = {
                "k": cache["k"].at[:, slot].set(k[:, -keep:]),
                "v": cache["v"].at[:, slot].set(v[:, -keep:]),
                "pos": cache["pos"].at[slot].set(positions[-keep:]),
            }
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype)), new_cache

    # Decode: S == 1, write at position % S_buf, attend over the buffer.
    pos = positions[0]
    slot = pos % S_buf
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions, slot, axis=0)
    valid = (cpos >= 0) & (cpos <= pos)
    if window > 0:
        valid &= cpos > pos - window
    out = _sdpa(q, ck, cv, valid[None, :], scale=scale, cap=cfg.attn_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# MLA apply (deepseek-v2)
# ---------------------------------------------------------------------------


def _mla_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def apply_mla(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[Dict] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head Latent Attention.

    Train/prefill: decompress the latent KV (oracle-simple, matmul-heavy —
    this is what the FPM sees as its computational kernel).  Decode: the
    *absorbed* form — attention runs entirely in the compressed space
    (scores ~ MQA with head_dim kv_lora+rope), never materializing per-head
    K/V for the 32k cache.
    """
    dtype = x.dtype
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rhd, vhd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(nope + rhd)

    cq = _mla_norm(params["q_norm"]["scale"], x @ params["wdq"].astype(dtype))
    q = jnp.einsum("bsq,qhk->bshk", cq, params["wuq"].astype(dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, theta=cfg.rope_theta)

    ckv = _mla_norm(params["kv_norm"]["scale"], x @ params["wdkv"].astype(dtype))
    kr = rope((x @ params["wkr"].astype(dtype))[:, :, None, :], positions, theta=cfg.rope_theta)[:, :, 0]

    if not decode:
        k_nope = jnp.einsum("bsc,chk->bshk", ckv, params["wuk"].astype(dtype))
        v = jnp.einsum("bsc,chk->bshk", ckv, params["wuv"].astype(dtype))
        # Fold the decoupled-RoPE scores into a standard attention by
        # concatenating features: q_eff/k_eff have head_dim nope+rhd.
        q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_eff = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], k_nope.shape[:3] + (rhd,))],
            axis=-1,
        )
        # Head-sharded attention (see apply_attn): 128 MLA heads must not
        # ride the sequence-sharded layout into the fp32 logits.
        q_eff = maybe_constrain(q_eff, ("batch", "seq", "heads", "head_dim"))
        k_eff = maybe_constrain(k_eff, ("batch", "seq", "heads", "head_dim"))
        v = maybe_constrain(v, ("batch", "seq", "heads", "head_dim"))
        if S >= cfg.attn_chunk_threshold:
            out = _sdpa_chunked(
                q_eff, k_eff, v, scale=scale, cap=0.0, causal=True, window=0,
                q_chunk=cfg.attn_q_chunk, unroll=cfg.unroll_scans,
            )
        else:
            out = _sdpa(q_eff, k_eff, v, _causal_mask(S, S, 0), scale=scale, cap=0.0)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
        new_cache = None
        if cache is not None:
            S_buf = cache["ckv"].shape[1]
            if S == S_buf:  # standard prefill: direct write, no scatter
                new_cache = {"ckv": ckv, "kr": kr, "pos": positions}
            else:
                keep = min(S, S_buf)
                slot = positions[-keep:] % S_buf
                new_cache = {
                    "ckv": cache["ckv"].at[:, slot].set(ckv[:, -keep:]),
                    "kr": cache["kr"].at[:, slot].set(kr[:, -keep:]),
                    "pos": cache["pos"].at[slot].set(positions[-keep:]),
                }
        return y, new_cache

    # Absorbed decode (S == 1).
    assert cache is not None
    pos = positions[0]
    S_buf = cache["ckv"].shape[1]
    slot = pos % S_buf
    cckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, slot, axis=1)
    ckr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions, slot, axis=0)
    valid = (cpos >= 0) & (cpos <= pos)

    # q_nope absorbed through W_uk:  (B,1,H,nope) x (kv_lora,H,nope) -> (B,1,H,kv_lora)
    q_abs = jnp.einsum("bqhk,chk->bqhc", q_nope, params["wuk"].astype(dtype))
    logits = (
        jnp.einsum("bqhc,bsc->bhqs", q_abs, cckv)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, ckr)
    ).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, -1).astype(dtype)
    ctx = jnp.einsum("bhqs,bsc->bqhc", w, cckv)  # compressed context
    out = jnp.einsum("bqhc,chk->bqhk", ctx, params["wuv"].astype(dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, {"ckv": cckv, "kr": ckr, "pos": cpos}
