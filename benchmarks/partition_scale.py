"""Fleet-scale partition latency: scalar vs numpy ModelBank vs jitted jax bank.

The paper's self-adaptability requirement is that computing an optimal
distribution costs orders of magnitude less than the application it balances.
This benchmark measures that cost directly for all three partition paths on
synthetic heterogeneous fleets of p ∈ {10, 100, 1000, 10000} processor
groups (HCL-like piecewise-linear FPMs, ~6 observed points each):

  * scalar — the seed implementation (``vectorize=False``): every bisection
    step on ``t*`` is a p-long Python loop over per-model segment scans;
  * bank   — the ``ModelBank`` path: one numpy pass per bisection step;
  * jax    — the ``JaxModelBank`` path: the whole t* search + integer
    completion under ``jax.jit``.  Two numbers matter: the one-time compile
    cost, and the steady-state repartition latency afterwards — the
    compile-once/repartition-many number the paper's self-adaptability
    argument actually depends on (repartitioning happens every imbalance
    event; compilation happens once per fleet shape).

The jax sweep runs with x64 enabled and asserts its allocations are
BIT-IDENTICAL to the numpy bank at every swept p (exit code 1 otherwise —
CI runs the quick sweep, so parity is enforced on every PR).

Results are written to ``BENCH_partition.json``.

    PYTHONPATH=src python benchmarks/partition_scale.py \
        [--quick] [--backend numpy|jax|both] [--out FILE]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ModelBank, PiecewiseLinearFPM, partition_units


def make_fleet(p: int, seed: int = 0):
    """p heterogeneous piecewise-linear FPMs: plateau speed spanning ~3x,
    cache boost at small x, paging-style decay past a per-proc knee."""
    rng = np.random.default_rng(seed)
    plateau = rng.uniform(1.0, 3.0, p) * 1e6
    knee = rng.uniform(2e3, 2e4, p)
    models = []
    for i in range(p):
        xs = np.geomspace(16.0, 8.0 * knee[i], 6)
        ss = np.where(
            xs <= knee[i],
            plateau[i] * (1.0 + 0.4 * np.exp(-xs / 500.0)),
            plateau[i] / (1.0 + 2.0 * (xs - knee[i]) / knee[i]),
        )
        models.append(PiecewiseLinearFPM.from_points(list(zip(xs, ss))))
    return models


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_sweep(ps, repeats: int, backend: str, units_per_proc: int = 100,
              scalar_cutoff: int = 10**9):
    if backend in ("jax", "both"):
        import jax

        # Bit-identical-to-numpy is the acceptance gate; that needs doubles.
        jax.config.update("jax_enable_x64", True)
        from repro.core import JaxModelBank

    rows = []
    for p in ps:
        models = make_fleet(p, seed=p)
        bank = ModelBank.from_models(models)
        n = units_per_proc * p

        t_bank = best_of(lambda: partition_units(bank, n, min_units=1), repeats)
        d_bank = partition_units(bank, n, min_units=1)

        row = {"p": p, "n": n, "bank_s": t_bank}
        if backend in ("numpy", "both") and p <= scalar_cutoff:
            t_scalar = best_of(
                lambda: partition_units(models, n, min_units=1, vectorize=False), repeats
            )
            d_scalar = partition_units(models, n, min_units=1, vectorize=False)
            row["scalar_s"] = t_scalar
            row["speedup"] = t_scalar / t_bank
            row["max_unit_diff"] = int(max(abs(a - b) for a, b in zip(d_scalar, d_bank)))
        if backend in ("jax", "both"):
            jbank = JaxModelBank.from_bank(bank)

            def jax_partition():
                return partition_units(jbank, n, min_units=1, backend="jax")

            t0 = time.perf_counter()
            d_jax = jax_partition()  # traces + compiles for this fleet shape
            t_compile = time.perf_counter() - t0
            t_jax = best_of(jax_partition, max(repeats, 2))  # post-compile
            row["jax_compile_s"] = t_compile
            row["jax_steady_s"] = t_jax
            row["jax_vs_bank_speedup"] = t_bank / t_jax
            row["jax_max_unit_diff"] = int(
                max(abs(a - b) for a, b in zip(d_jax, d_bank))
            )
        rows.append(row)
        msg = f"p={p:6d}  bank={t_bank * 1e3:9.3f} ms"
        if "scalar_s" in row:
            msg += (
                f"  scalar={row['scalar_s'] * 1e3:10.3f} ms"
                f"  speedup={row['speedup']:8.1f}x"
                f"  max|Δd|={row['max_unit_diff']}"
            )
        if "jax_steady_s" in row:
            msg += (
                f"  jax={row['jax_steady_s'] * 1e3:9.3f} ms"
                f" (compile {row['jax_compile_s']:6.2f} s)"
                f"  jax_max|Δd|={row['jax_max_unit_diff']}"
            )
        print(msg, flush=True)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sweep for CI smoke")
    ap.add_argument("--backend", choices=["numpy", "jax", "both"], default="both")
    ap.add_argument("--out", default="BENCH_partition.json")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    if args.quick:
        ps, repeats, cutoff = [10, 100], args.repeats or 2, 10**9
    else:
        ps, repeats, cutoff = [10, 100, 1000, 10000], args.repeats or 3, 10**9

    rows = run_sweep(ps, repeats, args.backend, scalar_cutoff=cutoff)
    payload = {
        "benchmark": "partition_scale",
        "description": (
            "partition_units latency: seed scalar path vs numpy ModelBank "
            "vs jitted JaxModelBank (x64; steady-state = post-compile)"
        ),
        "units_per_proc": 100,
        "repeats": repeats,
        "backend": args.backend,
        "sweep": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"-> {args.out}")

    rc = 0
    checked = [r for r in rows if "speedup" in r]
    big = [r for r in checked if r["p"] >= 1000]
    if big and min(r["speedup"] for r in big) < 10.0:
        print("WARNING: <10x speedup at p>=1000")
        rc = 1
    if any(r["max_unit_diff"] > 1 for r in checked):
        print("WARNING: scalar/bank paths disagree by >1 unit")
        rc = 1
    jaxed = [r for r in rows if "jax_max_unit_diff" in r]
    if jaxed:
        import jax

        if jax.default_backend() == "cpu":
            # Bit-identity is a CPU contract (same FPU, same reduction
            # order); on accelerators a 1-ulp sum difference may move one
            # boundary unit, so there only >1-unit drift is a failure.
            if any(r["jax_max_unit_diff"] != 0 for r in jaxed):
                print("FAIL: jax allocations not bit-identical to the numpy bank")
                rc = 1
        elif any(r["jax_max_unit_diff"] > 1 for r in jaxed):
            print("FAIL: jax allocations differ from the numpy bank by >1 unit")
            rc = 1
    # Hard gate at the paper-scale fleet (p=1000): steady-state jitted
    # repartition must not lose to the numpy bank.  Larger p is reported but
    # informational — at p=10^4 the sequential completion loop's per-
    # iteration overhead on CPU XLA still roughly ties the numpy heap
    # (ROADMAP: threshold-count batched completion).
    slow = [r for r in jaxed if r["p"] == 1000 and r["jax_steady_s"] > r["bank_s"]]
    if slow:
        print("FAIL: jax steady-state slower than numpy bank at p=1000")
        rc = 1
    for r in jaxed:
        if r["p"] > 1000 and r["jax_steady_s"] > r["bank_s"]:
            print(f"note: jax steady-state behind numpy bank at p={r['p']} "
                  f"({r['jax_steady_s']*1e3:.0f} ms vs {r['bank_s']*1e3:.0f} ms)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
