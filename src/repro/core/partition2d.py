"""Nested 2-D partitioning (paper §3.2) + CPM / FFMPA baselines.

The 2-D heterogeneous matmul distributes an ``M x N`` block matrix over a
``p x q`` processor grid: column widths ``n_j`` (outer) and per-column row
heights ``m_ij`` (inner).  The paper's DFPA-based algorithm:

  1. start even: ``n_j = N/q``, ``m_ij = M/p``;
  2. for each column j IN PARALLEL, run DFPA on the column's rows (this
     *estimates a 1-D projection of the 2-D FPM* at width ``n_j``);
  3. if the global imbalance <= eps -> done; else set
     ``n_j ∝ sum_i s_ij(m_ij, n_j)`` (column width proportional to the
     column's speed sum) and goto 2.

Implementation includes the paper's cost optimizations (§3.2 last page):
  * reuse all previous benchmark points (rescaled to the new column width);
  * skip re-partitioning a column whose width changed by < ``width_tol``;
  * warm-start each inner DFPA from the previous iteration's row heights.

``backend="jax"`` forwards to the inner DFPA loops (their re-partitions run
jitted on device), and :func:`bank_repartition_2d` exposes the fully batched
variant: all ``q`` columns' model banks stacked into one ``[q, p, k]`` tensor
whose ``t*`` bisections run *simultaneously* in a single jitted call — the
device-side refresh used when widths move but no new benchmarks are wanted
(simulator counterparts: ``speed_fn_2d_batch`` / ``time_fn_2d_batch``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from .dfpa import dfpa
from .executor import SimulatedExecutor
from .fpm import AnalyticModel, PiecewiseLinearFPM, imbalance
from .modelbank import ModelBank
from .partition import cpm_partition, partition_units

__all__ = [
    "Grid2DResult",
    "bank_repartition_2d",
    "dfpa_partition_2d",
    "cpm_partition_2d",
    "ffmpa_partition_2d",
    "app_time_2d",
]

SpeedFn2D = Callable[[float, float], float]  # g(m_b, n_b) -> units/s


@dataclass
class Grid2DResult:
    col_widths: List[int]  # n_j, len q
    row_heights: List[List[int]]  # m[j][i], q x p
    outer_iterations: int
    total_rounds: int  # total DFPA parallel rounds across all columns
    bench_cost: float  # wall-clock spent benchmarking (parallel-round model)
    converged: bool
    imbalance: float
    times: List[List[float]] = field(default_factory=list)  # t[j][i]


def _col_times(
    grid: Sequence[Sequence[SpeedFn2D]], j: int, widths: Sequence[int], rows: Sequence[int]
) -> List[float]:
    w = widths[j]
    return [
        (r * w) / grid[i][j](float(r), float(w)) if r > 0 else 0.0
        for i, r in enumerate(rows)
    ]


def _flat_imbalance(times: List[List[float]]) -> float:
    # imbalance() ignores zero-allocation entries itself.
    return imbalance([t for col in times for t in col])


def bank_repartition_2d(
    fpms: Sequence[Sequence[PiecewiseLinearFPM]],
    fpm_width: Sequence[Sequence[Optional[int]]],
    widths: Sequence[int],
    M: int,
    *,
    min_units: int = 1,
    backend: str = "numpy",
) -> List[List[int]]:
    """Re-partition EVERY column's rows from the surviving FPM estimates in
    one call — no new benchmarks.

    ``fpms[i][j]`` / ``fpm_width[i][j]`` are the per-(row, column) estimates
    and the widths they were observed at (the state ``dfpa_partition_2d``
    maintains); each column's bank is rescaled to its current width (speed in
    row units ~ 1/width) and, on the jax backend, all ``q`` banks are stacked
    into one ``[q, p, k]`` tensor whose ``t*`` bisections run simultaneously
    in a single jitted device call.  ``backend="numpy"`` loops the columns on
    the host (same allocations).  Returns ``rows[j][i]``.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    p, q = len(fpms), len(widths)
    for i in range(p):
        for j in range(q):
            if fpm_width[i][j] is None or fpms[i][j].num_points == 0:
                raise ValueError(f"no FPM estimate for processor ({i}, {j})")
    col_banks = []
    for j in range(q):
        bank = ModelBank.from_models([fpms[i][j] for i in range(p)])
        scale = [fpm_width[i][j] / widths[j] for i in range(p)]
        col_banks.append(bank.scaled(scale))
    if backend == "jax":
        from .modelbank_jax import JaxModelBank

        stacked = JaxModelBank.stack([JaxModelBank.from_bank(b) for b in col_banks])
        d = stacked.partition_units(M, min_units=min_units)
        return [[int(v) for v in row] for row in d]
    return [partition_units(b, M, min_units=min_units) for b in col_banks]


def dfpa_partition_2d(
    grid: Sequence[Sequence[SpeedFn2D]],
    M: int,
    N: int,
    eps: float,
    *,
    max_outer: int = 40,
    inner_max_iter: int = 15,
    width_tol: float = 0.02,
    min_units: int = 1,
    backend: str = "numpy",
) -> Grid2DResult:
    """DFPA-based nested 2-D partitioning over ground-truth speeds ``grid``.

    ``grid[i][j]`` is the speed function of processor (i, j) of a p x q grid.
    """
    p, q = len(grid), len(grid[0])
    widths = [N // q + (1 if j < N % q else 0) for j in range(q)]
    rows: List[Optional[List[int]]] = [None] * q  # warm-start rows per column
    # FPM estimates per (i, j), in ROW units at the width they were observed;
    # reused across widths by rescaling rows/s by (old_w / new_w).
    fpms: List[List[PiecewiseLinearFPM]] = [[PiecewiseLinearFPM() for _ in range(q)] for _ in range(p)]
    fpm_width: List[List[Optional[int]]] = [[None] * q for _ in range(p)]

    total_rounds = 0
    bench_cost = 0.0
    times: List[List[float]] = [[0.0] * p for _ in range(q)]
    prev_widths: Optional[List[int]] = None
    best: Optional[Grid2DResult] = None

    for outer in range(1, max_outer + 1):
        col_round_costs = [0.0] * q
        for j in range(q):
            w = widths[j]
            if (
                prev_widths is not None
                and rows[j] is not None
                and w == prev_widths[j]
            ):
                # Paper's optimization: width unchanged -> keep the column's
                # partition; no re-benchmark needed.
                times[j] = _col_times(grid, j, widths, rows[j])
                continue
            # Rescale surviving FPM points to the new width (g ~ const in w):
            # one batched speed-scale over the column's model bank.
            warm = None
            if all(
                fpm_width[i][j] is not None and fpms[i][j].num_points > 0
                for i in range(p)
            ):
                col_bank = ModelBank.from_models([fpms[i][j] for i in range(p)])
                scale = [fpm_width[i][j] / w for i in range(p)]
                warm = col_bank.scaled(scale).to_models()
            ex = SimulatedExecutor(
                time_fns=[
                    (lambda i_: lambda r: (r * w) / grid[i_][j](float(r), float(w)) if r > 0 else 0.0)(i)
                    for i in range(p)
                ]
            )
            res = dfpa(
                ex,
                M,
                eps,
                max_iter=inner_max_iter,
                min_units=min_units,
                backend=backend,
                warm_models=warm,
                warm_start_d=rows[j] if rows[j] is not None else None,
                # Probe fixed points only on the COLD first partition of a
                # column; warm refinements rely on the outer width update
                # for fresh information — unbounded probing churned 2256
                # rounds / 76% cost at M=N=768.
                probe_budget=p if warm is None else 0,
            )
            rows[j] = list(res.d)
            times[j] = list(res.times)
            for i in range(p):
                fpms[i][j] = res.models[i]
                fpm_width[i][j] = w
            total_rounds += res.iterations
            col_round_costs[j] = ex.total_cost
        # Columns run their inner DFPA in parallel -> cost = slowest column.
        bench_cost += max(col_round_costs) if col_round_costs else 0.0

        imb = _flat_imbalance(times)
        snap = Grid2DResult(
            list(widths), [list(r) for r in rows], outer, total_rounds,
            bench_cost, imb <= eps, imb, [list(t) for t in times],
        )
        if best is None or imb < best.imbalance:
            best = snap
        if imb <= eps:
            return snap

        # Outer step (ii): columns' widths ∝ column speed sums (damped).
        # Paper's freeze optimization: revert sub-tolerance width changes
        # (skipping their columns' re-benchmark next round) and hand the
        # residual to the columns that did move.
        prev_widths = list(widths)
        widths = _rebalance_widths(widths, times, rows, N)
        moved = [j for j in range(q) if abs(widths[j] - prev_widths[j]) > width_tol * prev_widths[j]]
        if moved and len(moved) < q:
            for j in range(q):
                if j not in moved:
                    widths[j] = prev_widths[j]
            diff = N - sum(widths)
            k = 0
            while diff != 0:
                j = moved[k % len(moved)]
                step = 1 if diff > 0 else -1
                if widths[j] + step >= 1:
                    widths[j] += step
                    diff -= step
                k += 1
        elif not moved:
            widths = list(prev_widths)

    best = Grid2DResult(
        best.col_widths, best.row_heights, max_outer, total_rounds,
        bench_cost, best.converged, best.imbalance, best.times,
    )
    return best


def cpm_partition_2d(
    grid: Sequence[Sequence[SpeedFn2D]], M: int, N: int
) -> Tuple[Grid2DResult, float]:
    """The conventional baseline: ONE benchmark round at the even distribution
    gives each processor a speed constant; rows/columns split proportionally.
    Returns (result, bench_cost)."""
    p, q = len(grid), len(grid[0])
    w0, r0 = N // q, M // p
    speeds = [[grid[i][j](float(r0), float(w0)) for j in range(q)] for i in range(p)]
    bench_cost = max(
        (r0 * w0) / speeds[i][j] for i in range(p) for j in range(q)
    )
    col_speed = [sum(speeds[i][j] for i in range(p)) for j in range(q)]
    widths = cpm_partition(col_speed, N)
    rows = [cpm_partition([speeds[i][j] for i in range(p)], M) for j in range(q)]
    times = [
        _col_times(grid, j, widths, rows[j]) for j in range(q)
    ]
    res = Grid2DResult(widths, rows, 1, 1, bench_cost, True, _flat_imbalance(times), times)
    return res, bench_cost


def _rebalance_widths(widths: List[int], times: List[List[float]], rows, N: int, *, damp: float = 0.5) -> List[int]:
    """Outer step (ii): widths ∝ column speed sums, RELAXED by ``damp`` —
    the undamped update oscillates when speeds bend with the allocation
    (paging/nonlinear regions)."""
    q = len(widths)
    col_speed = []
    for j in range(q):
        s = sum(
            (rows[j][i] * widths[j]) / times[j][i]
            for i in range(len(rows[j]))
            if times[j][i] > 0
        )
        col_speed.append(s)
    tot = sum(col_speed)
    target = [N * s / tot for s in col_speed]
    blended = [
        (1.0 - damp) * w + damp * t for w, t in zip(widths, target)
    ]
    new_widths = [max(int(round(b)), 1) for b in blended]
    diff = N - sum(new_widths)
    order = sorted(range(q), key=lambda j: blended[j] - new_widths[j], reverse=(diff > 0))
    k = 0
    while diff != 0:
        j = order[k % q]
        step = 1 if diff > 0 else -1
        if new_widths[j] + step >= 1:
            new_widths[j] += step
            diff -= step
        k += 1
    return new_widths


def ffmpa_partition_2d(
    grid: Sequence[Sequence[SpeedFn2D]],
    M: int,
    N: int,
    eps: float,
    *,
    max_outer: int = 50,
) -> Grid2DResult:
    """FFMPA baseline [18]: the FULL models are given (pre-built), so the
    nested iteration runs entirely on the host with zero benchmark cost.
    Rows are partitioned directly in ROW units (one row of width w = one
    unit), avoiding unit->row rounding distortion.  The analytic full models
    have no piecewise representation, so this baseline exercises the scalar
    partition path (``partition_units`` falls back automatically)."""
    p, q = len(grid), len(grid[0])
    widths = [N // q + (1 if j < N % q else 0) for j in range(q)]
    rows: List[List[int]] = [[M // p] * p for _ in range(q)]
    times: List[List[float]] = [[0.0] * p for _ in range(q)]
    best = None
    for outer in range(1, max_outer + 1):
        for j in range(q):
            w = widths[j]
            models = [
                AnalyticModel(
                    (lambda i_: lambda r: (r * w) / grid[i_][j](float(r), float(w)) if r > 0 else 0.0)(i)
                )
                for i in range(p)
            ]
            rows[j] = partition_units(models, M, min_units=1)
            times[j] = _col_times(grid, j, widths, rows[j])
        imb = _flat_imbalance(times)
        if best is None or imb < best.imbalance:
            best = Grid2DResult(list(widths), [list(r) for r in rows], outer, 0, 0.0, imb <= eps, imb, [list(t) for t in times])
        if imb <= eps:
            return best
        new_widths = _rebalance_widths(widths, times, rows, N)
        if new_widths == widths:
            return best
        widths = new_widths
    return best


def app_time_2d(
    grid: Sequence[Sequence[SpeedFn2D]],
    result: Grid2DResult,
    K: int,
    *,
    bcast_overhead: float = 1.0e-3,
) -> float:
    """Full 2-D matmul app time: K pivot steps, each costing the slowest
    processor's panel update + broadcast overhead (paper Fig. 7(a))."""
    step = 0.0
    for j, w in enumerate(result.col_widths):
        for i, r in enumerate(result.row_heights[j]):
            if r > 0:
                step = max(step, (r * w) / grid[i][j](float(r), float(w)))
    return K * (step + bcast_overhead)
