"""Two-level hierarchical partitioning: host classes as groups, sharded
inner solves, and a mid-flight regroup.

A heterogeneous platform is rarely flat: hosts come in CLASSES (a rack of
a100 nodes, a rack of h100 nodes, a drawer of l4 cards), and the flat
``[p, k]`` bank stops fitting in cache long before p=10^6.  The two-level
path mirrors the platform:

1. each group is AGGREGATED behind one composite performance model (the
   exact sum-of-allocs-at-equal-time composition, ``aggregate_groups``);
2. the outer ``t*`` bisection runs on the tiny ``[g, k_g]`` group bank;
3. each group's integer share is partitioned over its members on the
   group's own cache-resident ``[p_g, k]`` sub-bank — on the jax backend
   all groups in ONE device program, and under ``sharding="shard_map"``
   spread across devices so no device materializes more than
   ``ceil(g/ndev)`` blocks.

This walkthrough builds a 3-class platform, partitions it flat and
hierarchically, shows the single-group degeneration (bit-identical to
flat), runs the sharded inner path, and regroups MID-FLIGHT with
``Scheduler.set_groups`` after a host class is split in two.

    PYTHONPATH=src python examples/hierarchy_walkthrough.py

For the multi-device inner solve, emulate devices on CPU first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/hierarchy_walkthrough.py
"""

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import ModelBank, Scheduler, SpeedStore
from repro.core.hierarchy import Hierarchy

# --- 1. a 3-class platform: per-class speed curves, per-host jitter ---------
rng = np.random.default_rng(0)
CLASS_SPECS = {  # name -> (hosts, base speed, saturation knee)
    "a100": (8, 40.0, 600.0),
    "h100": (6, 90.0, 900.0),
    "l4": (10, 12.0, 200.0),
}
names, groups, pts = [], [], []
for gid, (cls_, (hosts, base, knee)) in enumerate(CLASS_SPECS.items()):
    for h in range(hosts):
        jitter = rng.uniform(0.9, 1.1)
        xs = np.array([knee / 8, knee / 2, knee, 4 * knee])
        # speed rises toward the knee, then saturates: a classic FPM shape
        ss = base * jitter * np.array([0.7, 0.95, 1.0, 0.8])
        names.append(f"{cls_}-{h}")
        groups.append(gid)
        pts.append((list(xs), list(ss)))
bank = ModelBank.from_point_lists(pts)
p, n = bank.p, 12_000
print(f"platform: p={p} hosts in {len(CLASS_SPECS)} classes, n={n} units")

# --- 2. flat vs hierarchical ------------------------------------------------
flat = Scheduler(SpeedStore.from_bank(bank)).partition(n)
hier = Scheduler(SpeedStore.from_bank(bank), groups=groups).partition(n)


def makespan(d):
    d = np.asarray(d, dtype=np.float64)
    return float(np.max(np.where(d > 0, bank.time(np.maximum(d, 1.0)), 0.0)))


per_class = {
    cls_: sum(hier.allocations[i] for i in range(p) if names[i].startswith(cls_))
    for cls_ in CLASS_SPECS
}
print(f"flat makespan {makespan(flat.allocations):.4f}  "
      f"hier makespan {makespan(hier.allocations):.4f}")
print(f"hier class shares: {per_class} (sum {sum(hier.allocations)})")

# --- 3. exactness tier 1: one group degenerates to the flat solve -----------
one = Scheduler(SpeedStore.from_bank(bank), groups=[0] * p).partition(n)
print(f"single group == flat, bit-identical: {one.allocations == flat.allocations}")

# --- 4. the sharded inner path ---------------------------------------------
ndev = len(jax.devices())
h_shard = Hierarchy.from_bank(bank, groups, backend="jax", sharding="shard_map")
h_plain = Hierarchy.from_bank(bank, groups, backend="jax")
d_shard = h_shard.partition_units(n)
print(f"shard_map over {ndev} device(s) == one-program jax: "
      f"{d_shard == h_plain.partition_units(n)}")
print(f"per-device bank elements: {h_shard.max_shard_elems()} sharded "
      f"vs {h_plain.max_shard_elems()} unsharded")

# --- 5. mid-flight regroup: the l4 drawer is split across two PDUs ----------
sched = Scheduler(SpeedStore.from_bank(bank), groups=groups)
sched.partition(n)
regrouped = [
    (3 if g == 2 and i % 2 else g) for i, g in enumerate(groups)
]
sched.set_groups(regrouped)  # no rebuild of the store, just new routing
after = sched.partition(n)
print(f"after regroup (4 groups): makespan {makespan(after.allocations):.4f}, "
      f"sum {sum(after.allocations)}")
