"""The paper's contribution: FPMs, the geometric partitioner of [16], DFPA,
the nested 2-D variant, and the calibrated heterogeneous-cluster simulator."""

from .dfpa import DFPAResult, dfpa
from .executor import CallableExecutor, Executor, RoundLog, SimulatedExecutor
from .fpm import AnalyticModel, ConstantModel, PiecewiseLinearFPM, SpeedModel, imbalance
from .partition import cpm_partition, partition_continuous, partition_units
from .partition2d import (
    Grid2DResult,
    app_time_2d,
    cpm_partition_2d,
    dfpa_partition_2d,
    ffmpa_partition_2d,
)
from .simulator import (
    HCL_SPECS,
    NodeSpec,
    full_model_build_cost,
    make_grid5000_specs,
    make_grid5000_time_fns,
    make_hcl_time_fns,
    make_tpu_group_time_fns,
    matmul_app_time_1d,
    speed_fn_1d,
    speed_fn_2d,
    time_fn_1d,
)

__all__ = [
    "AnalyticModel",
    "CallableExecutor",
    "ConstantModel",
    "DFPAResult",
    "Executor",
    "Grid2DResult",
    "HCL_SPECS",
    "NodeSpec",
    "PiecewiseLinearFPM",
    "RoundLog",
    "SimulatedExecutor",
    "SpeedModel",
    "app_time_2d",
    "cpm_partition",
    "cpm_partition_2d",
    "dfpa",
    "dfpa_partition_2d",
    "ffmpa_partition_2d",
    "full_model_build_cost",
    "imbalance",
    "make_grid5000_specs",
    "make_grid5000_time_fns",
    "make_hcl_time_fns",
    "make_tpu_group_time_fns",
    "matmul_app_time_1d",
    "partition_continuous",
    "partition_units",
    "speed_fn_1d",
    "speed_fn_2d",
    "time_fn_1d",
]
