"""Traffic-trace serving harness: adaptive DFPA fleet vs static vs oracle.

The paper's headline claim — the cost of the optimal distribution is orders
of magnitude below the execution it optimizes — restated at serving
timescales: a synthetic request-arrival trace (Poisson base rate + diurnal
modulation + a flash-crowd segment, seeded/deterministic) drives
``ReplicaDispatcher.balance_fleet`` over a simulated heterogeneous replica
fleet with drifting speed functions, one injected runaway straggler
(throttled mid-trace; REPROFILE→QUARANTINE must fire on the RIGHT replica),
mid-trace tenant admit/retire, and (full mode) replica join/leave.

Three arms serve the IDENTICAL trace:

  * **adaptive** — the repo's serving loop: ``balance_fleet`` warm sessions
    at membership changes (one measured round each), and per steady epoch
    ``fleet.rebalance(loads)`` → simulate → ``fleet.straggler_actions`` →
    ``fleet.observe`` (scan BEFORE fold: strike predictions come from the
    pre-epoch estimates).  A QUARANTINE removes the replica (fresh session,
    profiles carried via the registry, detector remapped through the
    survivors).
  * **static** — each replica's share fixed proportional to its DEPLOY-TIME
    speed (measured once, never updated): correct at t=0, wrong under
    drift, catastrophic under the runaway straggler it can't drop.
  * **oracle** — proportional to the TRUE drifted speeds every epoch (the
    unachievable lower bound: no measurement, no lag).

Serving model: per epoch, each replica serves its tenants' slices back to
back (time-sliced — ``FleetRoundLog``'s accounting), so replica ``i``'s
busy time is the SUM across tenants of ``d_k[i] / speed_i(t)`` and the
``j``-th of its ``c_i`` chunks completes at ``busy_i * j / c_i``.  Reported
per arm: p50/p99 request latency, goodput (fraction of chunks inside the
SLO), drift-segment goodput, mean epoch wall.  Adaptive also reports
rebalance reaction times (trace time from the drift / straggler onset to
the first visible response) and rebalance overhead — scheduler host seconds
(balance_fleet walls minus time spent inside ``replica_run``, plus
rebalance/scan/observe walls) as a fraction of total SIMULATED serving
seconds.

Acceptance gates (exit 1):
  (a) adaptive goodput >= static goodput on the drifting-speed segment, and
      adaptive p99 latency < static p99 over the whole trace;
  (b) straggler reaction: REPROFILE fires on the throttled replica within
      ``REACTION_BOUND_EPOCHS`` epochs of onset, QUARANTINE fires on that
      same replica and on no other; a REPROFILE on a healthy replica counts
      as a misfire unless it lands within ``REACTION_BOUND_EPOCHS`` epochs
      of a fresh-from-registry session (there the detector is EXPECTED to
      clear stale merged class profiles — reported as ``grace_reprofiles``);
  (c) warm-session no-recompile: a repeated ``balance_fleet`` call reuses
      ``self.fleet`` (identity), performs zero restacks and zero new jit
      compilations (``_cache_size`` deltas on the stacked partition and
      fold-in programs);
  (d) rebalance overhead <= 1% of total trace serving time.

Results are written to ``BENCH_serve.json``.

``--trace out.json`` additionally runs the adaptive arm under a
``repro.obs.FlightRecorder`` and writes a Chrome-trace/Perfetto JSON of the
whole serving session (fleet rounds, rebalance/observe spans, straggler
strike/verdict events, the canonical ``serve.rebalance_overhead_frac``
gauge).  On a QUARANTINE verdict — or on any gate failure — the recorder
dumps ``out.json.flightrec.json`` naming the incident replica and the
strike evidence that convicted it.  The written trace is validated (parses,
>= 1 fleet span per epoch, overhead gauge == the harness fraction); a
validation failure exits 1 like any gate.

    PYTHONPATH=src python benchmarks/serve_trace.py [--quick] [--out FILE]
        [--trace TRACE]
"""

from __future__ import annotations

import argparse
import json
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core.executor import FleetRoundLog
from repro.fleet import ProfileRegistry
from repro.runtime.serve_loop import ReplicaDispatcher
from repro.runtime.straggler import StragglerAction

REACTION_BOUND_EPOCHS = 6  # gate (b): REPROFILE within this many epochs
OVERHEAD_BOUND = 0.01  # gate (d): scheduler host s / simulated serving s
RESERVE_KNOTS = 64  # fixed [q, p, k] carry shapes -> precompilable
QUANTIZE = 0.05  # fold-grid pitch (all folds): bounded knot set per replica
STALENESS_TOL = 0.5  # drop a registry class profile this far off on round 1


# ---------------------------------------------------------------------------
# world: heterogeneous replicas with drifting speed functions
# ---------------------------------------------------------------------------


@dataclass
class Replica:
    rid: int
    cls: str
    base: float  # chunks/second at amplitude midpoint
    phase: float  # drift sinusoid phase


@dataclass
class World:
    """Ground truth the arms are measured against.  Speeds drift as
    per-replica sinusoids; one replica takes a step drift (the gate
    segment) and one a runaway decay (the straggler)."""

    replicas: List[Replica]
    drift_amp: float
    drift_period: float  # epochs
    drift_step: Tuple[int, int, int, float]  # rid, start, end, multiplier
    straggler: Tuple[int, int, float, float]  # rid, onset, decay/epoch, floor

    def speed(self, rid: int, epoch: int) -> float:
        r = next(rep for rep in self.replicas if rep.rid == rid)
        s = r.base * (
            1.0
            + self.drift_amp
            * math.sin(2.0 * math.pi * epoch / self.drift_period + r.phase)
        )
        sr, s0, s1, mult = self.drift_step
        if rid == sr and s0 <= epoch < s1:
            s *= mult
        gr, onset, decay, floor = self.straggler
        if rid == gr and epoch >= onset:
            s *= max(decay ** (epoch - onset + 1), floor)
        return max(s, 1e-9)

    def speeds(self, rids: Sequence[int], epoch: int) -> np.ndarray:
        return np.asarray([self.speed(r, epoch) for r in rids], dtype=np.float64)


# ---------------------------------------------------------------------------
# trace: seeded arrivals + scripted membership events
# ---------------------------------------------------------------------------


@dataclass
class TraceConfig:
    epochs: int
    dt: float  # seconds of trace time per epoch
    seed: int
    replicas: List[Tuple[str, float]]  # (device class, base speed)
    drift_amp: float
    drift_period: float
    drift_step: Tuple[int, int, int, float]
    straggler: Tuple[int, int, float, float]
    tenants: Dict[str, float]  # name -> mean arrivals/epoch
    diurnal_amp: float
    diurnal_period: float  # epochs
    flash: Tuple[str, int, int, float]  # tenant, start, end, multiplier
    admit: Optional[Tuple[str, float, int, int]]  # name, rate, at, retire_at
    join: Optional[Tuple[str, float, int]] = None  # class, speed, at epoch
    leave: Optional[Tuple[int, int]] = None  # rid, at epoch
    slo_factor: float = 1.4  # SLO = factor * mean-load epoch wall at t=0


QUICK = TraceConfig(
    epochs=60,
    dt=2.0,
    seed=7,
    replicas=[("fast", 800.0), ("fast", 780.0), ("mid", 400.0),
              ("mid", 390.0), ("slow", 200.0)],
    drift_amp=0.2,
    drift_period=50.0,
    drift_step=(0, 12, 32, 0.55),
    straggler=(3, 46, 0.55, 0.05),
    tenants={"chat": 1500.0, "embed": 600.0},
    diurnal_amp=0.3,
    diurnal_period=40.0,
    flash=("chat", 36, 44, 2.5),
    admit=("burst", 300.0, 18, 30),
)

FULL = TraceConfig(
    epochs=240,
    dt=2.0,
    seed=17,
    replicas=[("fast", 800.0), ("fast", 780.0), ("mid", 400.0),
              ("mid", 390.0), ("slow", 200.0), ("slow", 195.0)],
    drift_amp=0.25,
    drift_period=100.0,
    drift_step=(1, 40, 80, 0.55),
    straggler=(3, 120, 0.55, 0.05),
    tenants={"chat": 1500.0, "embed": 600.0},
    diurnal_amp=0.35,
    diurnal_period=96.0,
    flash=("chat", 90, 110, 2.5),
    admit=("burst", 350.0, 60, 140),
    join=("mid", 410.0, 160),
    leave=(5, 200),
)


def build_world(cfg: TraceConfig) -> World:
    reps = [
        Replica(rid=i, cls=c, base=s, phase=0.61803 * (i + 1) * 2.0 * math.pi)
        for i, (c, s) in enumerate(cfg.replicas)
    ]
    return World(
        replicas=reps,
        drift_amp=cfg.drift_amp,
        drift_period=cfg.drift_period,
        drift_step=cfg.drift_step,
        straggler=cfg.straggler,
    )


def build_trace(cfg: TraceConfig) -> List[Dict[str, int]]:
    """Per-epoch per-tenant arrival counts — Poisson base rate x diurnal
    modulation x flash-crowd multiplier, fully determined by ``cfg.seed``."""
    rng = np.random.default_rng(cfg.seed)
    sched: List[Dict[str, int]] = []
    names = list(cfg.tenants)
    if cfg.admit is not None:
        names.append(cfg.admit[0])
    pmax = len(cfg.replicas) + 2
    for e in range(cfg.epochs):
        row: Dict[str, int] = {}
        for j, name in enumerate(names):
            if cfg.admit is not None and name == cfg.admit[0]:
                if not (cfg.admit[2] <= e < cfg.admit[3]):
                    continue
                rate = cfg.admit[1]
            else:
                rate = cfg.tenants[name]
            rate *= 1.0 + cfg.diurnal_amp * math.sin(
                2.0 * math.pi * e / cfg.diurnal_period + 1.7 * j
            )
            fname, f0, f1, fmult = cfg.flash
            if name == fname and f0 <= e < f1:
                rate *= fmult
            row[name] = max(int(rng.poisson(max(rate, 1.0))), pmax)
        sched.append(row)
    return sched


# ---------------------------------------------------------------------------
# serving-model helpers
# ---------------------------------------------------------------------------


def prop_split(n: int, w: np.ndarray) -> np.ndarray:
    """Largest-remainder integer split of ``n`` proportional to ``w``."""
    f = n * w / w.sum()
    d = np.floor(f).astype(np.int64)
    rem = int(n - d.sum())
    if rem > 0:
        order = np.argsort(-(f - d))
        d[order[:rem]] += 1
    return d


@dataclass
class ArmStats:
    """Latency/goodput accumulator (per-replica uniform completion ramp)."""

    slo_s: float
    drift_window: Tuple[int, int]
    lat_chunks: List[np.ndarray] = field(default_factory=list)
    good = 0
    total = 0
    seg_good = 0
    seg_total = 0
    epoch_walls: List[float] = field(default_factory=list)

    def record(self, epoch: int, counts: np.ndarray, busy: np.ndarray) -> None:
        in_seg = self.drift_window[0] <= epoch < self.drift_window[1]
        for c, b in zip(counts.astype(int), busy):
            if c <= 0:
                continue
            lat = b * np.arange(1, c + 1, dtype=np.float64) / c
            self.lat_chunks.append(lat)
            g = int((lat <= self.slo_s).sum())
            self.good += g
            self.total += c
            if in_seg:
                self.seg_good += g
                self.seg_total += c
        self.epoch_walls.append(float(busy.max()) if len(busy) else 0.0)

    def summary(self) -> Dict[str, float]:
        lat = np.concatenate(self.lat_chunks) if self.lat_chunks else np.zeros(1)
        return {
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "goodput": self.good / max(self.total, 1),
            "goodput_drift_segment": self.seg_good / max(self.seg_total, 1),
            "mean_epoch_wall_s": float(np.mean(self.epoch_walls)),
            "serving_sim_s": float(np.sum(self.epoch_walls)),
            "chunks_served": int(self.total),
        }


def active_rids(cfg: TraceConfig, epoch: int, quarantined: set) -> List[int]:
    """Scripted membership (join/leave) minus adaptive quarantines."""
    rids = [i for i in range(len(cfg.replicas))]
    if cfg.join is not None and epoch >= cfg.join[2]:
        rids.append(len(cfg.replicas))  # the joiner gets the next id
    if cfg.leave is not None and epoch >= cfg.leave[1]:
        rids = [r for r in rids if r != cfg.leave[0]]
    return [r for r in rids if r not in quarantined]


def world_with_joiner(cfg: TraceConfig, world: World) -> World:
    if cfg.join is not None:
        cls, speed, _at = cfg.join
        world.replicas.append(
            Replica(
                rid=len(cfg.replicas), cls=cls, base=speed,
                phase=0.61803 * (len(cfg.replicas) + 1) * 2.0 * math.pi,
            )
        )
    return world


def slo_seconds(cfg: TraceConfig) -> float:
    cap0 = sum(s for _, s in cfg.replicas)
    mean_arrivals = sum(cfg.tenants.values())
    return cfg.slo_factor * mean_arrivals / cap0


def prewarm_fleet_shapes(cfg: TraceConfig) -> None:
    """Precompile the stacked programs for every (q, p) the scripted trace
    can produce.  With ``reserve_knots`` the carry's shapes are fully
    predictable ([q, p, RESERVE_KNOTS]), so a serving deployment compiles
    them once at startup — the standard serving warm-up — and membership
    changes mid-trace never pay a jit trace."""
    from repro.core.fpm import PiecewiseLinearFPM
    from repro.core.modelbank_jax import JaxModelBank

    p0 = len(cfg.replicas)
    qs = {len(cfg.tenants)}
    if cfg.admit is not None:
        qs.add(len(cfg.tenants) + 1)
    ps = {p0, p0 - 1}
    if cfg.join is not None:
        ps.add(p0 + 1)
    for q in sorted(qs):
        for p in sorted(ps):
            banks = [
                JaxModelBank.from_models(
                    [PiecewiseLinearFPM.from_points([(8.0, 1.0), (16.0, 1.0)])
                     for _ in range(p)]
                )
                for _ in range(q)
            ]
            st = JaxModelBank.stack(banks, min_k=RESERVE_KNOTS)
            n = np.full(q, 4 * p, dtype=np.int64)
            caps = np.full((q, p), 4 * p, dtype=np.int64)
            mu = np.ones(q, dtype=np.int64)
            st.monotone_lanes()
            for lanes in (np.ones(q, dtype=bool), np.zeros(q, dtype=bool)):
                st.partition_units(n, caps, min_units=mu, completion_lanes=lanes)
            st.fold_in(
                np.full((q, p), 8.0), np.ones((q, p)), np.ones((q, p), dtype=bool)
            )


# ---------------------------------------------------------------------------
# the three arms
# ---------------------------------------------------------------------------


def run_reference_arm(cfg: TraceConfig, world: World, trace, *, oracle: bool):
    """static (deploy-time speeds, frozen) or oracle (true drifted speeds)."""
    stats = ArmStats(slo_s=slo_seconds(cfg), drift_window=cfg.drift_step[1:3])
    deploy_speed: Dict[int, float] = {}
    for e in range(cfg.epochs):
        rids = active_rids(cfg, e, quarantined=set())
        for r in rids:
            deploy_speed.setdefault(r, world.speed(r, e))  # measured on join
        true = world.speeds(rids, e)
        w = true if oracle else np.asarray([deploy_speed[r] for r in rids])
        counts = np.zeros(len(rids), dtype=np.int64)
        busy = np.zeros(len(rids), dtype=np.float64)
        for name, n in trace[e].items():
            d = prop_split(n, w)
            counts += d
            busy += np.where(d > 0, d / true, 0.0)
        stats.record(e, counts, busy)
    return stats.summary()


def run_adaptive_arm(cfg: TraceConfig, world: World, trace,
                     flight_path: Optional[str] = None):
    """The repo's serving loop, end to end (see module docstring).

    With ``flight_path`` set (and a ``FlightRecorder`` installed as the
    active telemetry sink), per-epoch estimate snapshots feed the recorder
    and a QUARANTINE verdict dumps the incident file immediately."""
    from repro import obs

    flight = obs.active() if flight_path is not None else None
    if not isinstance(flight, obs.FlightRecorder):
        flight = None
    stats = ArmStats(slo_s=slo_seconds(cfg), drift_window=cfg.drift_step[1:3])
    noise_rng = np.random.default_rng(cfg.seed + 1)
    registry = ProfileRegistry()
    quarantined: set = set()
    events: List[Dict[str, object]] = []
    sched_host = 0.0

    state = {"epoch": 0, "rids": active_rids(cfg, 0, quarantined)}

    def replica_run(i: int, x: int) -> float:
        rid = state["rids"][i]
        t = x / world.speed(rid, state["epoch"])
        return float(t * (1.0 + 0.02 * noise_rng.standard_normal()))

    disp = ReplicaDispatcher(
        replica_run=replica_run, num_replicas=len(state["rids"]), eps=0.08
    )

    def classes() -> List[str]:
        by_id = {r.rid: r.cls for r in world.replicas}
        return [by_id[r] for r in state["rids"]]

    def call_balance(tenants: Dict[str, int], max_iter: int) -> float:
        """One balance_fleet call; returns scheduler host seconds (the call
        wall minus the time spent inside replica_run — i.e. serving)."""
        t0 = time.perf_counter()
        e0 = disp.exec_host_s
        disp.balance_fleet(
            tenants,
            registry=registry,
            device_classes=classes(),
            workloads={name: "serve" for name in tenants},
            reserve_knots=RESERVE_KNOTS,
            quantize=QUANTIZE,
            staleness_tol=STALENESS_TOL,
            min_units=1,
            max_iter=max_iter,
        )
        return (time.perf_counter() - t0) - (disp.exec_host_s - e0)

    # -- setup (reported, excluded from the per-epoch overhead metric):
    #    precompile the predictable fleet shapes, then converge the tenants
    t_setup = time.perf_counter()
    prewarm_fleet_shapes(cfg)
    sched_setup = call_balance(trace[0], max_iter=12)
    setup_wall = time.perf_counter() - t_setup

    # -- gate (c): repeated warm call — identity, no restack, no compile ----
    import repro.core.modelbank_jax as mbj

    fleet0 = disp.fleet
    caches0 = (mbj._partition_units_jit._cache_size(), mbj._fold_in_jit._cache_size())
    restacks0 = fleet0.restacks
    call_balance(trace[0], max_iter=12)
    warm_gate = {
        "session_reused": disp.fleet is fleet0,
        "new_restacks": disp.fleet.restacks - restacks0,
        "new_partition_compiles": mbj._partition_units_jit._cache_size() - caches0[0],
        "new_fold_compiles": mbj._fold_in_jit._cache_size() - caches0[1],
    }
    warm_gate["ok"] = bool(
        warm_gate["session_reused"]
        and warm_gate["new_restacks"] == 0
        and warm_gate["new_partition_compiles"] == 0
        and warm_gate["new_fold_compiles"] == 0
    )

    straggler_rid = cfg.straggler[0]
    drift_rid = cfg.drift_step[0]
    share_pre_drift = None
    reaction: Dict[str, Optional[float]] = {
        "reprofile_epoch": None, "quarantine_epoch": None, "drift_epoch": None,
    }
    wrong_replica_events = 0
    # one self-healing REPROFILE shortly after a fresh-from-registry session
    # is the detector doing its job (clearing a stale merged class profile);
    # the same action in steady state is a misfire and counts as wrong
    grace_reprofiles = 0
    last_fresh_epoch = -10**9
    prev_tenants = set(trace[0])

    for e in range(cfg.epochs):
        state["epoch"] = e
        rids = active_rids(cfg, e, quarantined)
        tenants = dict(trace[e])
        membership = rids != state["rids"] or set(tenants) != prev_tenants
        prev_tenants = set(tenants)

        if membership:
            p_changed = len(rids) != len(state["rids"])
            old_fleet, old_rids = disp.fleet, state["rids"]
            state["rids"] = rids
            disp.num_replicas = len(rids)
            # one measured round IS this epoch's serving (no separate
            # rebalance/observe; the straggler scan pauses for the epoch)
            sched_host += call_balance(tenants, max_iter=1)
            if p_changed:
                last_fresh_epoch = e
            if p_changed and old_fleet is not None:
                # fresh session: strikes follow the survivors (remap — the
                # resize bugfix exercised at fleet scope)
                det = getattr(old_fleet, "detector", None)
                if det is not None:
                    surviving = [
                        j for j, r in enumerate(old_rids) if r in rids
                    ]
                    joined = len(rids) - len(surviving)
                    disp.fleet.detector = det.remap(surviving, joined)
            log = disp.logs[-1]
            assert isinstance(log, FleetRoundLog)
            counts = np.asarray(log.D, dtype=np.int64).sum(axis=0)
            busy = np.asarray(log.proc_busy, dtype=np.float64)
            stats.record(e, counts, busy)
            events.append({"epoch": e, "event": "membership",
                           "replicas": list(rids), "tenants": sorted(tenants)})
            continue

        fleet = disp.fleet
        t0 = time.perf_counter()
        ds = fleet.rebalance({name: int(n) for name, n in tenants.items()})
        sched_host += time.perf_counter() - t0

        true = world.speeds(rids, e)
        times: Dict[str, List[float]] = {}
        counts = np.zeros(len(rids), dtype=np.int64)
        busy = np.zeros(len(rids), dtype=np.float64)
        for name, d in ds.items():
            d = np.asarray(d, dtype=np.int64)
            t = np.where(d > 0, d / true, 0.0)
            t *= 1.0 + 0.02 * noise_rng.standard_normal(len(rids))
            t = np.where(d > 0, np.maximum(t, 1e-12), 0.0)
            times[name] = [float(v) for v in t]
            counts += d
            busy += t
        stats.record(e, counts, busy)
        if flight is not None:
            flight.snapshot(f"epoch:{e}", {
                "replicas": [int(r) for r in rids],
                "busy_s": [float(b) for b in busy],
                "allocations": {nm: [int(v) for v in d]
                                for nm, d in ds.items()},
            })

        t0 = time.perf_counter()
        acts = fleet.straggler_actions(times)  # pre-fold predictions
        fleet.observe(times)  # folds on the fleet's construction-time grid
        sched_host += time.perf_counter() - t0

        for i, act in enumerate(acts):
            if act is StragglerAction.NONE:
                continue
            rid = rids[i]
            events.append({"epoch": e, "event": act.value, "replica": rid})
            if act is StragglerAction.REPROFILE:
                if rid == straggler_rid:
                    if reaction["reprofile_epoch"] is None and e >= cfg.straggler[1]:
                        reaction["reprofile_epoch"] = e
                elif rid == drift_rid:
                    pass  # drift step legitimately reprofiles, never quarantines
                elif e - last_fresh_epoch <= REACTION_BOUND_EPOCHS:
                    grace_reprofiles += 1  # clearing a stale warm profile
                else:
                    wrong_replica_events += 1
            if act is StragglerAction.QUARANTINE:
                if flight is not None:
                    det = getattr(fleet, "detector", None)
                    rows = [r for r in (det.history if det else []) if r[0] == i]
                    flight.dump(
                        flight_path,
                        reason="quarantine",
                        context={
                            "replica": int(rid),
                            "epoch": int(e),
                            "strike_evidence": [
                                {"d_units": int(du), "predicted": float(pr),
                                 "observed": float(ob), "ratio": float(ra)}
                                for _, du, pr, ob, ra in rows[-5:]
                            ],
                        },
                    )
                if rid == straggler_rid:
                    if reaction["quarantine_epoch"] is None:
                        reaction["quarantine_epoch"] = e
                    quarantined.add(rid)
                else:
                    wrong_replica_events += 1

        # drift reaction: share on the stepped replica visibly drops
        if drift_rid in rids:
            i = rids.index(drift_rid)
            share = sum(d[i] for d in ds.values()) / max(sum(tenants.values()), 1)
            if e == cfg.drift_step[1] - 1:
                share_pre_drift = share
            if (
                reaction["drift_epoch"] is None
                and share_pre_drift is not None
                and e >= cfg.drift_step[1]
                and share < 0.8 * share_pre_drift
            ):
                reaction["drift_epoch"] = e

    out = stats.summary()
    dt = cfg.dt
    out.update({
        "setup_wall_s": setup_wall,
        "setup_sched_host_s": sched_setup,
        "sched_host_s": sched_host,
        "rebalance_overhead_frac": sched_host / max(out["serving_sim_s"], 1e-12),
        "straggler_replica": straggler_rid,
        "straggler_onset_epoch": cfg.straggler[1],
        "reprofile_reaction_s": (
            (reaction["reprofile_epoch"] - cfg.straggler[1] + 1) * dt
            if reaction["reprofile_epoch"] is not None else None
        ),
        "quarantine_reaction_s": (
            (reaction["quarantine_epoch"] - cfg.straggler[1] + 1) * dt
            if reaction["quarantine_epoch"] is not None else None
        ),
        "drift_reaction_s": (
            (reaction["drift_epoch"] - cfg.drift_step[1] + 1) * dt
            if reaction["drift_epoch"] is not None else None
        ),
        "quarantined_replica": (
            next(iter(quarantined)) if quarantined else None
        ),
        "wrong_replica_events": wrong_replica_events,
        "grace_reprofiles": grace_reprofiles,
        "warm_no_recompile": warm_gate,
        "events": events,
    })
    return out


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: short trace, gates only")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--trace", default=None, metavar="TRACE",
                    help="write a Chrome-trace JSON of the adaptive arm "
                         "(+ TRACE.flightrec.json on incidents)")
    args = ap.parse_args(argv)

    # benchmark-process only (NOT at import: the test suite imports this
    # module, and flipping x64 mid-process would change every other test)
    jax.config.update("jax_enable_x64", True)

    cfg = QUICK if args.quick else FULL
    world = world_with_joiner(cfg, build_world(cfg))
    trace = build_trace(cfg)

    print(f"trace: {cfg.epochs} epochs x {cfg.dt}s, "
          f"{len(cfg.replicas)} replicas, seed={cfg.seed}", flush=True)
    static = run_reference_arm(cfg, world, trace, oracle=False)
    oracle = run_reference_arm(cfg, world, trace, oracle=True)
    tel = None
    flight_path = None
    if args.trace:
        from repro import obs

        flight_path = args.trace + ".flightrec.json"
        # ring big enough to hold the whole session (the recorder bound
        # matters for hours-long deployments, not a bounded benchmark)
        tel = obs.FlightRecorder(capacity=200_000, snapshot_capacity=64)
        obs.install(tel)
    try:
        adaptive = run_adaptive_arm(cfg, world, trace, flight_path=flight_path)
    finally:
        if tel is not None:
            from repro import obs

            obs.uninstall()

    for name, row in (("static", static), ("oracle", oracle),
                      ("adaptive", adaptive)):
        print(f"{name:9s} p50 {row['latency_p50_s']:.3f}s "
              f"p99 {row['latency_p99_s']:.3f}s goodput {row['goodput']:.3f} "
              f"(drift seg {row['goodput_drift_segment']:.3f})", flush=True)
    print(f"adaptive  reaction: reprofile {adaptive['reprofile_reaction_s']}s, "
          f"quarantine {adaptive['quarantine_reaction_s']}s "
          f"(replica {adaptive['quarantined_replica']}, "
          f"wrong-replica events {adaptive['wrong_replica_events']}, "
          f"grace reprofiles {adaptive['grace_reprofiles']}), "
          f"drift {adaptive['drift_reaction_s']}s", flush=True)
    print(f"adaptive  overhead: {adaptive['sched_host_s']:.3f}s host / "
          f"{adaptive['serving_sim_s']:.1f}s served "
          f"= {adaptive['rebalance_overhead_frac']:.4%} "
          f"(setup {adaptive['setup_sched_host_s']:.3f}s excluded)", flush=True)

    rc = 0
    g = adaptive
    if g["goodput_drift_segment"] < static["goodput_drift_segment"]:
        print("FAIL(a): adaptive drift-segment goodput "
              f"{g['goodput_drift_segment']:.3f} < static "
              f"{static['goodput_drift_segment']:.3f}")
        rc = 1
    if g["latency_p99_s"] >= static["latency_p99_s"]:
        print(f"FAIL(a): adaptive p99 {g['latency_p99_s']:.3f}s >= "
              f"static {static['latency_p99_s']:.3f}s")
        rc = 1
    bound_s = REACTION_BOUND_EPOCHS * cfg.dt
    if g["reprofile_reaction_s"] is None or g["reprofile_reaction_s"] > bound_s:
        print(f"FAIL(b): straggler REPROFILE reaction "
              f"{g['reprofile_reaction_s']} not within {bound_s}s")
        rc = 1
    if g["quarantined_replica"] != g["straggler_replica"]:
        print(f"FAIL(b): quarantined replica {g['quarantined_replica']} != "
              f"throttled replica {g['straggler_replica']}")
        rc = 1
    if g["wrong_replica_events"]:
        print(f"FAIL(b): {g['wrong_replica_events']} straggler actions fired "
              "on healthy replicas")
        rc = 1
    if not g["warm_no_recompile"]["ok"]:
        print(f"FAIL(c): warm balance_fleet recompiled: "
              f"{g['warm_no_recompile']}")
        rc = 1
    if g["rebalance_overhead_frac"] > OVERHEAD_BOUND:
        print(f"FAIL(d): rebalance overhead "
              f"{g['rebalance_overhead_frac']:.4%} > {OVERHEAD_BOUND:.0%}")
        rc = 1

    if tel is not None:
        from repro.obs.chrometrace import export_chrome_trace

        # The canonical overhead gauge is the harness's own full-session
        # fraction (the paper's headline figure); the dispatcher's live
        # "serve.split.*" gauges are the per-balance view of the same split.
        tel.gauge("serve.rebalance_overhead_frac",
                  float(adaptive["rebalance_overhead_frac"]))
        if adaptive["reprofile_reaction_s"] is not None:
            tel.gauge("serve.reaction_epochs",
                      float(adaptive["reprofile_reaction_s"]) / cfg.dt)
        export_chrome_trace(tel, args.trace)
        with open(args.trace) as f:
            parsed = json.load(f)  # must round-trip as valid JSON
        fleet_spans = sum(
            1 for ev in parsed.get("traceEvents", [])
            if ev.get("ph") == "X" and ev.get("cat") == "fleet"
        )
        gauge = parsed.get("repro", {}).get("gauges", {}).get(
            "serve.rebalance_overhead_frac"
        )
        print(f"trace: {len(parsed.get('traceEvents', []))} events, "
              f"{fleet_spans} fleet spans over {cfg.epochs} epochs "
              f"-> {args.trace}", flush=True)
        if fleet_spans < cfg.epochs:
            print(f"FAIL(trace): {fleet_spans} fleet spans < "
                  f"{cfg.epochs} epochs (expected >= 1 per round)")
            rc = 1
        if gauge is None or abs(
            gauge - adaptive["rebalance_overhead_frac"]
        ) > 1e-12:
            print(f"FAIL(trace): trace overhead gauge {gauge!r} != harness "
                  f"fraction {adaptive['rebalance_overhead_frac']!r}")
            rc = 1
        if rc != 0:
            tel.dump(flight_path, reason="gate-failure",
                     context={"gates_ok": False})
            print(f"-> {flight_path} (gate failure)")

    if rc == 0:
        print("all gates OK")

    payload = {
        "benchmark": "serve_trace",
        "description": (
            "traffic-trace serving harness: seeded Poisson+diurnal+flash "
            "arrivals drive ReplicaDispatcher.balance_fleet warm sessions "
            "over a drifting heterogeneous replica fleet with a runaway "
            "straggler (REPROFILE->QUARANTINE on the right replica), tenant "
            "admit/retire and replica join/leave; adaptive vs static "
            "(deploy-time speeds) vs oracle (true drifted speeds); latency "
            "model = time-sliced per-replica busy sums (FleetRoundLog), "
            "chunk j of c on a replica completes at busy*j/c; overhead = "
            "scheduler host seconds / simulated serving seconds"
        ),
        "mode": "quick" if args.quick else "full",
        "config": {
            "epochs": cfg.epochs, "dt_s": cfg.dt, "seed": cfg.seed,
            "replicas": [{"rid": i, "class": c, "base_speed": s}
                         for i, (c, s) in enumerate(cfg.replicas)],
            "tenants": cfg.tenants, "slo_s": slo_seconds(cfg),
            "drift_step": cfg.drift_step, "straggler": cfg.straggler,
            "flash": cfg.flash, "admit": cfg.admit,
            "join": cfg.join, "leave": cfg.leave,
            "reaction_bound_s": bound_s,
        },
        "arms": {"static": static, "oracle": oracle, "adaptive": adaptive},
        "gates_ok": rc == 0,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"-> {args.out}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
