"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    pattern=("attn",),
    num_experts=32,
    num_shared_experts=0,
    top_k=8,
    d_ff_expert=512,
    mlp_kind="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=512,
        num_experts=4,
        top_k=2,
        d_ff_expert=64,
        xent_chunk=0,
        remat="none",
    )
