"""Threshold-count completion: the adversarial-bank battery + fuzz parity.

The threshold-count integer completion (see the "completion modes" section
in ``core/modelbank.py``) is exact ONLY on monotone-time banks — per-unit
time ``x / s(x)`` nondecreasing in ``x`` — where the per-unit greedy
provably processes unit increments in globally sorted ``(time, -rem, index)``
order.  This suite locks the two safety properties that make routing it by
default safe:

  * **demotion** — adversarial banks (speed spikes, duplicate-``x`` rows
    whose replacing speed jumps up, non-positive hand-built points) are
    detected by the host-side monotonicity check and provably fall back to
    the exact per-unit loop (monkeypatched-kernel proofs below, on both
    banked backends);
  * **parity** — on monotone banks the threshold-count completion produces
    makespans (and, on the CPU x64 contract, allocations) bit-identical to
    the per-unit heap/argmin completion across the numpy bank, the jitted
    jax bank, and the stacked ``[q, p, k]`` 2-D path, and both modes raise
    identical ``ValueError`` s on infeasible inputs.

Fuzz lanes follow the repo convention: a hypothesis lane through the
optional ``tests/_hyp.py`` shim plus an always-on numpy-rng lane, >= 200
cases each, both driving the same ``_check_*`` functions; the heavy lanes
carry the ``slow`` marker (tier-1 runs the 25-case smoke versions).
"""

import numpy as np
import pytest

from _hyp import given, settings, st

import jax
from jax.experimental import enable_x64

from repro.core import PiecewiseLinearFPM, Scheduler, SpeedStore
from repro.core.modelbank import ModelBank
from repro.core.modelbank_jax import JaxModelBank
from repro.core.partition import (
    _partition_units_bank,
    _prep_unit_caps,
    _threshold_prefill_bank,
)
from repro.core import modelbank_jax as mbj
from repro.core import partition as partition_mod

BIT_EXACT = jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Bank generators
# ---------------------------------------------------------------------------


def _bank(rows) -> ModelBank:
    """Rows of ``[(x, s), ...]`` point lists -> a padded bank."""
    return ModelBank.from_point_lists(
        [([x for x, _ in r], [s for _, s in r]) for r in rows]
    )


def _monotone_rows(rng, p, k_max=7):
    """Random monotone-time rows, two flavours: nonincreasing speed, and
    increasing-speed-but-ordered-knot-times (s may rise as long as x/s does
    not fall) — the subtle class the flag must still accept."""
    rows = []
    for _ in range(p):
        k = int(rng.integers(1, k_max))
        xs = np.unique(rng.uniform(1.0, 1e4, k))
        if rng.random() < 0.5:
            ss = np.sort(rng.uniform(0.5, 500.0, len(xs)))[::-1]
        else:
            ts = np.sort(rng.uniform(0.1, 50.0, len(xs)))
            ss = xs / ts
        rows.append(list(zip(xs.tolist(), ss.tolist())))
    return rows


def _spike_models(p=4):
    """Speed spike at large x: time DIPS (10/5=2 -> 20/50=0.4) — the
    canonical adversarial bank the flag must demote."""
    return [
        PiecewiseLinearFPM.from_points([(10.0, 5.0), (20.0, 50.0)])
        for _ in range(p)
    ]


def _makespan(bank: ModelBank, d) -> float:
    return float(np.max(bank.time(np.asarray(d, dtype=np.float64))))


# ---------------------------------------------------------------------------
# The monotonicity flag: classification
# ---------------------------------------------------------------------------


def test_flag_accepts_monotone_classes():
    rng = np.random.default_rng(0)
    for _ in range(50):
        bank = _bank(_monotone_rows(rng, 6))
        assert bank.is_monotone()
    # constant models (single point) are trivially monotone
    assert ModelBank.from_point_lists([([1.0], [5.0])] * 3).is_monotone()
    # empty rows are vacuously monotone
    assert ModelBank.from_point_lists([([], [])]).is_monotone()


def test_flag_rejects_speed_spike():
    bank = ModelBank.from_models(_spike_models())
    assert not bank.is_monotone()


def test_flag_duplicate_x_rows():
    # hand-built duplicate-x pair: speed jumping UP at the same x makes time
    # jump DOWN -> non-monotone; jumping down keeps time nondecreasing.
    assert not ModelBank.from_point_lists([([10.0, 10.0], [5.0, 9.0])]).is_monotone()
    assert ModelBank.from_point_lists([([10.0, 10.0], [9.0, 5.0])]).is_monotone()


def test_flag_rejects_nonpositive_and_nonfinite_points():
    assert not ModelBank.from_point_lists([([10.0, 20.0], [5.0, 0.0])]).is_monotone()
    assert not ModelBank.from_point_lists([([10.0, 20.0], [5.0, -1.0])]).is_monotone()
    assert not ModelBank.from_point_lists([([0.0, 20.0], [5.0, 4.0])]).is_monotone()
    assert not ModelBank.from_point_lists(
        [([10.0, 20.0], [5.0, float("inf")])]
    ).is_monotone()


def test_flag_scaled_propagation():
    rng = np.random.default_rng(1)
    bank = _bank(_monotone_rows(rng, 4))
    assert bank.is_monotone()
    assert bank.scaled([2.0, 0.5, 1.0, 3.0]).monotone is True
    # non-positive scale resets the cached flag to unknown
    assert bank.scaled([2.0, -0.5, 1.0, 3.0]).monotone is None


def test_flag_jax_mirrors_numpy_and_survives_fold_in():
    rng = np.random.default_rng(2)
    with enable_x64():
        good = _bank(_monotone_rows(rng, 5))
        bad = ModelBank.from_models(_spike_models())
        assert JaxModelBank.from_bank(good).is_monotone() == good.is_monotone()
        assert JaxModelBank.from_bank(bad).is_monotone() == bad.is_monotone()
        # device-side check (flag unknown after construction without a host
        # bank) agrees with the host check
        jb = JaxModelBank(
            xs=np.asarray(bad.xs), ss=np.asarray(bad.ss), counts=np.asarray(bad.counts)
        )
        assert jb.monotone is None
        assert jb.is_monotone() is False
        # fold_in resets the flag; the lazy recompute sees the new points:
        # a monotone carry turns non-monotone when a speed spike folds in
        jb2 = JaxModelBank.from_bank(good)
        assert jb2.is_monotone()
        spike_s = np.asarray(good.ss).max() * 1e6
        jb2 = jb2.fold_in(np.full(5, 2e4), np.full(5, spike_s))
        assert jb2.monotone is None
        assert jb2.is_monotone() is False
        # ... and a duplicate-x replace can HEAL a violation
        jb_bad = JaxModelBank.from_bank(
            ModelBank.from_point_lists([([10.0, 20.0], [5.0, 50.0])])
        )
        assert jb_bad.is_monotone() is False
        healed = jb_bad.fold_in([20.0], [6.0])  # replace the spike speed
        assert healed.is_monotone() is True


def test_flag_stack_combination():
    rng = np.random.default_rng(3)
    with enable_x64():
        good = JaxModelBank.from_bank(
            _bank(_monotone_rows(rng, 4))
        )
        bad = JaxModelBank.from_bank(ModelBank.from_models(_spike_models(4)))
        good.is_monotone(), bad.is_monotone()
        assert JaxModelBank.stack([good, good]).monotone is True
        assert JaxModelBank.stack([good, bad]).monotone is False
        unknown = good.copy()
        unknown.monotone = None
        st = JaxModelBank.stack([good, unknown])
        assert st.monotone is None
        assert st.is_monotone() is True  # lazy device check resolves it


# ---------------------------------------------------------------------------
# Demotion proofs: non-monotone banks provably take the exact loop
# ---------------------------------------------------------------------------


def test_numpy_auto_keeps_greedy_even_on_monotone_banks(monkeypatch):
    """Monkeypatch the threshold kernel to explode: on the numpy HOST path
    "auto" must never reach it — monotone bank or not — because the lazy
    heap was never the host bottleneck and the threshold pass costs ~one
    extra continuous solve there (the ROADMAP PR 4 niggle).  Only an
    explicit completion="threshold" engages the kernel."""

    def boom(*a, **k):  # pragma: no cover - reaching it IS the assertion
        raise AssertionError("threshold completion engaged")

    monkeypatch.setattr(partition_mod, "_threshold_prefill_bank", boom)
    bad = ModelBank.from_models(_spike_models())
    icaps = _prep_unit_caps(4, 37, None, 1)
    d, _ = _partition_units_bank(bad, 37, list(icaps), min_units=1)  # no raise
    assert sum(d) == 37
    rng = np.random.default_rng(4)
    good = _bank(_monotone_rows(rng, 4))
    d, _ = _partition_units_bank(good, 37, list(icaps), min_units=1)  # no raise
    assert sum(d) == 37
    with pytest.raises(AssertionError, match="threshold completion engaged"):
        _partition_units_bank(
            good, 37, list(icaps), min_units=1, completion="threshold"
        )


def test_jax_auto_demotes_nonmonotone_to_exact(monkeypatch):
    """Spy on the jitted kernel's static completion flag: False for the
    adversarial bank, True for the monotone one."""
    real = mbj._partition_units_jit
    seen = []

    def spy(*args, **kw):
        seen.append(bool(kw.get("completion_fast", False)))
        return real(*args, **kw)

    monkeypatch.setattr(mbj, "_partition_units_jit", spy)
    rng = np.random.default_rng(5)
    with enable_x64():
        bad = JaxModelBank.from_bank(ModelBank.from_models(_spike_models()))
        d = bad.partition_units(37, min_units=1)
        assert int(np.asarray(d).sum()) == 37
        good = JaxModelBank.from_bank(
            _bank(_monotone_rows(rng, 4))
        )
        good.partition_units(37, min_units=1)
    assert seen == [False, True]


def test_nonmonotone_auto_equals_forced_greedy():
    """Demoted adversarial banks produce exactly the per-unit result."""
    bad = ModelBank.from_models(_spike_models())
    icaps = _prep_unit_caps(4, 55, None, 1)
    d_auto, t_auto = _partition_units_bank(bad, 55, list(icaps), min_units=1)
    d_greedy, t_greedy = _partition_units_bank(
        bad, 55, list(icaps), min_units=1, completion="greedy"
    )
    assert d_auto == d_greedy and t_auto == t_greedy
    with enable_x64():
        jb = JaxModelBank.from_bank(bad)
        d_jax = jb.partition_units(55, min_units=1)
        d_jax_g = jb.partition_units(55, min_units=1, completion="greedy")
    assert np.array_equal(np.asarray(d_jax), np.asarray(d_jax_g))
    if BIT_EXACT:
        assert list(map(int, d_jax)) == d_greedy


# ---------------------------------------------------------------------------
# Fast/exact raise identically on infeasible inputs
# ---------------------------------------------------------------------------


def _infeasible_variants(p, n):
    return [
        dict(n=2 * p - 1, caps=None, min_units=2),  # min_units * p > n
        dict(n=n, caps=[0] + [n] * (p - 1), min_units=1),  # cap < min_units
        dict(n=n, caps=[max(n // (2 * p) - 1, 0)] * p, min_units=0),  # sum < n
    ]


@pytest.mark.parametrize("completion", ["threshold", "greedy", "auto"])
def test_infeasible_raises_identically_both_modes(completion):
    rng = np.random.default_rng(6)
    bank = _bank(_monotone_rows(rng, 5))
    store = SpeedStore.from_bank(bank)
    with enable_x64():
        jb = JaxModelBank.from_bank(bank)
        for kw in _infeasible_variants(5, 200):
            with pytest.raises(ValueError):
                store.partition_units(
                    kw["n"], kw["caps"], min_units=kw["min_units"],
                    completion=completion,
                )
            with pytest.raises(ValueError):
                jb.partition_units(
                    kw["n"], kw["caps"], min_units=kw["min_units"],
                    completion=completion,
                )


def test_cap_below_min_units_raises_under_threshold():
    """The silent min_units-shortfall regression, re-locked for the fast
    path: caps[i] < min_units refuses loudly in every completion mode."""
    rng = np.random.default_rng(7)
    bank = _bank(_monotone_rows(rng, 4))
    store = SpeedStore.from_bank(bank)
    with enable_x64():
        jb = JaxModelBank.from_bank(bank)
        for completion in ("threshold", "greedy", "auto"):
            with pytest.raises(ValueError, match="min_units"):
                store.partition_units(
                    20, [1, 20, 20, 20], min_units=2, completion=completion
                )
            with pytest.raises(ValueError, match="min_units"):
                jb.partition_units(
                    20, [1, 20, 20, 20], min_units=2, completion=completion
                )


def test_empty_model_positive_cap_raises_under_threshold():
    bank = ModelBank.from_point_lists([([], []), ([10.0], [5.0])])
    assert bank.is_monotone()  # vacuously — the raise must still fire
    store = SpeedStore.from_bank(bank)
    with pytest.raises(ValueError):
        store.partition_units(10, completion="threshold")
    with enable_x64():
        with pytest.raises(ValueError):
            JaxModelBank.from_bank(bank).partition_units(10, completion="threshold")


def test_unknown_completion_mode_rejected_everywhere():
    rng = np.random.default_rng(8)
    bank = _bank(_monotone_rows(rng, 3))
    with pytest.raises(ValueError, match="completion"):
        _partition_units_bank(bank, 30, [30] * 3, min_units=0, completion="fast")
    with pytest.raises(ValueError, match="completion"):
        SpeedStore.from_bank(bank).partition_units(30, completion="fast")
    with enable_x64():
        with pytest.raises(ValueError, match="completion"):
            JaxModelBank.from_bank(bank).partition_units(30, completion="fast")
    with pytest.raises(ValueError, match="completion"):
        Scheduler(SpeedStore.from_bank(bank), completion="fast")


def test_scalar_backend_refuses_threshold():
    store = SpeedStore.from_models(
        [PiecewiseLinearFPM.from_points([(10.0, 5.0)])] * 3, backend="scalar"
    )
    with pytest.raises(ValueError, match="scalar"):
        store.partition_units(30, completion="threshold")
    # auto and greedy stay on the exact loop without complaint
    assert sum(store.partition_units(30)) == 30
    assert sum(store.partition_units(30, completion="greedy")) == 30


# ---------------------------------------------------------------------------
# Deterministic edges: zero caps, min_units take-back, leftover == 0
# ---------------------------------------------------------------------------


def test_zero_caps_fast_equals_exact():
    rng = np.random.default_rng(9)
    bank = _bank(_monotone_rows(rng, 6))
    caps = [0, 40, 0, 40, 40, 40]
    icaps = _prep_unit_caps(6, 100, caps, 0)
    d_t, _ = _partition_units_bank(
        bank, 100, list(icaps), min_units=0, completion="threshold"
    )
    d_g, _ = _partition_units_bank(
        bank, 100, list(icaps), min_units=0, completion="greedy"
    )
    assert d_t == d_g
    assert d_t[0] == d_t[2] == 0
    assert sum(d_t) == 100


def test_min_units_takeback_path_unaffected_by_completion():
    """When min_units overshoots n the take-back runs and leftover hits 0 —
    the completion (either mode) must be a no-op."""
    rng = np.random.default_rng(10)
    bank = _bank(_monotone_rows(rng, 5))
    icaps = _prep_unit_caps(5, 5, None, 1)
    d_t, _ = _partition_units_bank(
        bank, 5, list(icaps), min_units=1, completion="threshold"
    )
    d_g, _ = _partition_units_bank(
        bank, 5, list(icaps), min_units=1, completion="greedy"
    )
    assert d_t == d_g
    assert sum(d_t) == 5 and all(di >= 1 for di in d_t)


# ---------------------------------------------------------------------------
# Fuzz parity: fast == exact on monotone banks, all three backends
# ---------------------------------------------------------------------------


def _random_monotone_case(rng):
    p = int(rng.integers(1, 9))
    rows = _monotone_rows(rng, p)
    n = int(rng.integers(max(2 * p, 4), 3000))
    min_units = int(rng.integers(0, 3))
    lo = max(1, min_units)
    caps = [lo + int(f * n) for f in rng.uniform(0.0, 1.0, p)]
    if min_units == 0 and p > 1 and rng.random() < 0.3:
        caps[int(rng.integers(0, p))] = 0  # zero-cap row
    return dict(rows=rows, n=n, caps=caps, min_units=min_units)


def _check_completion_parity(case, *, with_jax=True):
    rows, n, caps, min_units = (
        case["rows"], case["n"], case["caps"], case["min_units"],
    )
    p = len(rows)
    if sum(min(c, n) for c in caps) < n:
        return  # infeasible: the raise-parity property's subject
    bank = _bank(rows)
    assert bank.is_monotone()
    icaps = _prep_unit_caps(p, n, caps, min_units)
    d_exact, t_exact = _partition_units_bank(
        bank, n, list(icaps), min_units=min_units, completion="greedy"
    )
    # the host path's "auto" is greedy by design, so the numpy threshold
    # kernel is fuzz-locked by FORCING it; "auto" stays the jax routing.
    d_fast, t_fast = _partition_units_bank(
        bank, n, list(icaps), min_units=min_units, completion="threshold"
    )
    assert sum(d_fast) == n
    assert all(min_units <= di <= ci for di, ci in zip(d_fast, icaps))
    assert t_fast == t_exact
    # the headline contract: bit-identical makespans (allocations agree too
    # on every case ever generated; the makespan is the guaranteed metric)
    assert _makespan(bank, d_fast) == _makespan(bank, d_exact)
    assert d_fast == d_exact
    if not with_jax:
        return
    with enable_x64():
        jb = JaxModelBank.from_bank(bank)
        d_jax_fast = jb.partition_units(n, caps, min_units=min_units)
        d_jax_exact = jb.partition_units(
            n, caps, min_units=min_units, completion="greedy"
        )
    assert int(np.asarray(d_jax_fast).sum()) == n
    assert _makespan(bank, np.asarray(d_jax_fast)) == _makespan(bank, d_exact)
    assert _makespan(bank, np.asarray(d_jax_exact)) == _makespan(bank, d_exact)
    if BIT_EXACT:
        assert list(map(int, d_jax_fast)) == d_fast
        assert list(map(int, d_jax_exact)) == d_exact


def test_completion_parity_smoke():
    """Tier-1 smoke: 25 cases through all backends."""
    rng = np.random.default_rng(1001)
    for _ in range(25):
        _check_completion_parity(_random_monotone_case(rng))


@pytest.mark.slow
def test_completion_parity_fuzz_numpy_lane():
    rng = np.random.default_rng(1002)
    for _ in range(200):
        _check_completion_parity(_random_monotone_case(rng))


@st.composite
def _monotone_cases(draw):
    p = draw(st.integers(min_value=1, max_value=8))
    rows = []
    for _ in range(p):
        k = draw(st.integers(min_value=1, max_value=6))
        xs = sorted(
            set(
                draw(
                    st.lists(
                        st.floats(min_value=1.0, max_value=1e4,
                                  allow_nan=False, allow_infinity=False),
                        min_size=k, max_size=k,
                    )
                )
            )
        )
        ts = sorted(
            draw(
                st.lists(
                    st.floats(min_value=0.1, max_value=50.0,
                              allow_nan=False, allow_infinity=False),
                    min_size=len(xs), max_size=len(xs),
                )
            )
        )
        rows.append([(x, x / t) for x, t in zip(xs, ts)])
    n = draw(st.integers(min_value=max(2 * p, 4), max_value=3000))
    min_units = draw(st.integers(min_value=0, max_value=2))
    lo = max(1, min_units)
    caps = [
        lo + int(f * n)
        for f in draw(
            st.lists(st.floats(min_value=0.0, max_value=1.0),
                     min_size=p, max_size=p)
        )
    ]
    return dict(rows=rows, n=n, caps=caps, min_units=min_units)


@pytest.mark.slow
@given(case=_monotone_cases())
@settings(max_examples=200, deadline=None)
def test_completion_parity_fuzz_hypothesis(case):
    _check_completion_parity(case, with_jax=False)


@pytest.mark.slow
@given(case=_monotone_cases())
@settings(max_examples=200, deadline=None)
def test_completion_parity_fuzz_hypothesis_jax(case):
    _check_completion_parity(case, with_jax=True)


# ---------------------------------------------------------------------------
# Stacked [q, p, k] path: threshold completion per column
# ---------------------------------------------------------------------------


def test_stacked_threshold_matches_per_column_exact():
    rng = np.random.default_rng(1003)
    q, p, n = 5, 6, 700
    cols = [_monotone_rows(rng, p, k_max=6) for _ in range(q)]
    with enable_x64():
        banks = [
            JaxModelBank.from_bank(_bank(c)) for c in cols
        ]
        stacked = JaxModelBank.stack(banks)
        assert stacked.monotone is True
        d_fast = stacked.partition_units(n, min_units=1)  # auto -> threshold
        ns = np.array([n + 41 * j for j in range(q)])
        d_var = stacked.partition_units(ns, min_units=1)
    for j in range(q):
        cb = _bank(cols[j])
        icaps = _prep_unit_caps(p, n, None, 1)
        want, _ = _partition_units_bank(
            cb, n, list(icaps), min_units=1, completion="greedy"
        )
        assert _makespan(cb, np.asarray(d_fast[j])) == _makespan(cb, want)
        if BIT_EXACT:
            assert list(map(int, d_fast[j])) == want
        icaps_v = _prep_unit_caps(p, int(ns[j]), None, 1)
        want_v, _ = _partition_units_bank(
            cb, int(ns[j]), list(icaps_v), min_units=1, completion="greedy"
        )
        if BIT_EXACT:
            assert list(map(int, d_var[j])) == want_v


def test_stacked_with_one_adversarial_column_demotes_only_itself():
    """One spiky column demotes only its OWN lane to the exact loop: the
    per-column ``monotone_lanes`` routing keeps the monotone column on the
    threshold bulk grant while the adversarial one takes the per-unit loop,
    in the same device program; results must equal the per-column exact
    partitions either way."""
    rng = np.random.default_rng(1004)
    p, n = 4, 300
    good = _monotone_rows(rng, p)
    bad = [[(10.0, 5.0), (20.0, 50.0)] for _ in range(p)]
    with enable_x64():
        banks = [
            JaxModelBank.from_bank(_bank(c))
            for c in (good, bad)
        ]
        stacked = JaxModelBank.stack(banks)
        assert stacked.monotone is False
        assert list(stacked.monotone_lanes()) == [True, False]
        d = stacked.partition_units(n, min_units=1)
    for j, c in enumerate((good, bad)):
        cb = _bank(c)
        icaps = _prep_unit_caps(p, n, None, 1)
        want, _ = _partition_units_bank(
            cb, n, list(icaps), min_units=1, completion="greedy"
        )
        if BIT_EXACT:
            assert list(map(int, d[j])) == want


# ---------------------------------------------------------------------------
# Scheduler/SpeedStore routing + the dtype policy
# ---------------------------------------------------------------------------


def test_scheduler_completion_knob_round_trips():
    rng = np.random.default_rng(1005)
    bank = _bank(_monotone_rows(rng, 4))
    sched = Scheduler(
        SpeedStore.from_models(bank.to_models()), n_units=120, completion="greedy"
    )
    part = sched.partition()
    state = sched.state_dict()
    assert state["completion"] == "greedy"
    restored = Scheduler.from_state(state)
    assert restored.completion == "greedy"
    assert restored.partition().allocations == part.allocations
    # auto and greedy agree on a monotone bank through the facade too
    auto = Scheduler(SpeedStore.from_models(bank.to_models()), n_units=120)
    assert auto.partition().allocations == part.allocations


def test_scheduler_threshold_knob_demotes_scalar_stores():
    """The session knob is uniform across paths: 'threshold' on a
    scalar-backed store demotes to the exact loop instead of raising (the
    strict refusal stays on the direct SpeedStore API)."""
    models = [PiecewiseLinearFPM.from_points([(10.0, 5.0 + i)]) for i in range(3)]
    store = SpeedStore.from_models(
        [PiecewiseLinearFPM.from_points(m.as_points()) for m in models],
        backend="scalar",
    )
    sched = Scheduler(store, n_units=60, completion="threshold")
    part = sched.partition()  # no raise: demoted via _completion_for
    assert sum(part.allocations) == 60
    with pytest.raises(ValueError, match="scalar"):
        store.partition_units(60, completion="threshold")


def test_scheduler_state_dict_round_trips_dtype():
    """A float32-store scheduler must restore as a float32 scheduler —
    dtype is part of the full-fidelity persistence contract."""
    models = [
        PiecewiseLinearFPM.from_points([(10.0, 5.0 + i), (100.0, 4.0 + i)])
        for i in range(4)
    ]
    with enable_x64():
        sched = Scheduler(
            SpeedStore.from_models(models, backend="jax", dtype=np.float32),
            n_units=120,
        )
        state = sched.state_dict()
        assert state["dtype"] == "float32"
        restored = Scheduler.from_state(state)
        assert str(restored.store.device_bank(snapshot=False).dtype) == "float32"
        assert restored.partition().allocations == sched.partition().allocations


def _serving_fleet(p: int, seed: int = 0):
    """Heterogeneous monotone fleet shaped like the benchmark's (plateau
    spanning ~3x, cache boost at small x, paging decay past a knee)."""
    rng = np.random.default_rng(seed)
    plateau = rng.uniform(1.0, 3.0, p) * 1e6
    knee = rng.uniform(2e3, 2e4, p)
    rows = []
    for i in range(p):
        xs = np.geomspace(16.0, 8.0 * knee[i], 6)
        ss = np.where(
            xs <= knee[i],
            plateau[i] * (1.0 + 0.4 * np.exp(-xs / 500.0)),
            plateau[i] / (1.0 + 2.0 * (xs - knee[i]) / knee[i]),
        )
        rows.append(list(zip(xs.tolist(), ss.tolist())))
    return rows


@pytest.mark.slow
def test_float32_store_allocations_match_float64_at_p_10k():
    """The ROADMAP dtype decision, locked: a float32 device bank partitions
    a p=10^4 serving fleet (n=10^6) identically to the float64 reference
    (the zero-drift result quantified by the jax_f32_* benchmark columns)."""
    p = 10_000
    n = 100 * p
    bank = _bank(_serving_fleet(p, seed=p))
    assert bank.is_monotone()
    with enable_x64():
        s64 = SpeedStore.from_jax_bank(JaxModelBank.from_bank(bank))
        s32 = SpeedStore.from_jax_bank(
            JaxModelBank.from_bank(bank, dtype=np.float32)
        )
        assert str(s32.device_bank(snapshot=False).dtype) == "float32"
        d64 = s64.partition_units(n, min_units=1)
        d32 = s32.partition_units(n, min_units=1)
    assert d64 == d32
    if BIT_EXACT:
        icaps = _prep_unit_caps(p, n, None, 1)
        d_np, _ = _partition_units_bank(bank, n, list(icaps), min_units=1)
        assert d64 == d_np


def test_float32_store_construction_and_state_round_trip():
    models = [
        PiecewiseLinearFPM.from_points([(10.0, 5.0 + i), (100.0, 4.0 + i)])
        for i in range(4)
    ]
    with enable_x64():
        s32 = SpeedStore.from_models(models, backend="jax", dtype=np.float32)
        assert str(s32.device_bank(snapshot=False).dtype) == "float32"
        d32 = s32.partition_units(200, min_units=1)
        state = s32.state_dict()
        assert state["dtype"] == "float32"
        back = SpeedStore.from_state(state)
        assert str(back.device_bank(snapshot=False).dtype) == "float32"
        assert back.partition_units(200, min_units=1) == d32
        d64 = SpeedStore.from_models(
            [PiecewiseLinearFPM.from_points(m.as_points()) for m in models],
            backend="jax",
        ).partition_units(200, min_units=1)
        # fold_in keeps the policy dtype on the carry
        s32.fold_in([50.0] * 4, [4.5] * 4)
        assert str(s32._carry().dtype) == "float32"
    assert d32 == d64


# ---------------------------------------------------------------------------
# The prefill invariant (white-box): strict bracket leaves >= 1 unit for
# the exact tie-break pass
# ---------------------------------------------------------------------------


def test_prefill_strict_bracket_leaves_boundary_remainder():
    rng = np.random.default_rng(1006)
    for _ in range(50):
        p = int(rng.integers(2, 9))
        bank = _bank(_monotone_rows(rng, p))
        n = int(rng.integers(2 * p, 1500))
        caps = np.full(p, n, dtype=np.int64)
        from repro.core.partition import _continuous_bank

        xs, t_star = _continuous_bank(bank, float(n), [float(n)] * p)
        d0 = np.minimum(np.floor(np.asarray(xs)).astype(np.int64), caps)
        leftover = n - int(d0.sum())
        if leftover <= 0:
            continue
        g, rem = _threshold_prefill_bank(bank, d0, caps, leftover, t_star)
        assert rem >= 1  # count(lo) < leftover is strict
        assert int(g.sum()) - int(d0.sum()) == leftover - rem
        assert np.all(g >= d0) and np.all(g <= caps)
