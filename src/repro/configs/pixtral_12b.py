"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072.  Mistral-nemo-style backbone (head_dim 128, SwiGLU); the
pixtral-ViT frontend is a STUB supplying 256 precomputed patch embeddings
prepended to the text sequence [hf:mistralai/Pixtral-12B-2409; unverified].
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    frontend="vision_stub",
    num_prefix_embeddings=256,
    train_accum=4,
    attn_chunk_threshold=4096,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="pixtral-12b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_prefix_embeddings=8,
        xent_chunk=0,
        remat="none",
    )
