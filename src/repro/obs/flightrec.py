"""The flight recorder: a bounded telemetry ring plus estimate snapshots,
dumped to JSON when a serving incident fires.

A serving fleet runs for hours; an unbounded event list is not an option
and a post-incident rerun rarely reproduces the throttle that caused the
QUARANTINE.  The recorder keeps the LAST ``capacity`` events (the telemetry
ring) and the last ``snapshot_capacity`` estimate snapshots the caller
takes per epoch, so when :meth:`dump` fires — on a QUARANTINE verdict or a
benchmark gate failure — the file already holds the rounds leading up to
the incident: the straggler strikes with their (predicted, observed, ratio)
evidence, the rebalance/fold spans, and what the fleet believed about every
replica at each recent epoch.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Callable, Dict, Optional

from .telemetry import Telemetry

__all__ = ["FlightRecorder"]


class FlightRecorder(Telemetry):
    """A :class:`Telemetry` sink whose event buffer is a ring.

    Install it like any sink (``obs.install(rec)``) — every instrumented
    layer then feeds the ring.  :meth:`snapshot` adds an estimate snapshot
    (any JSON-safe payload; serving loops typically record per-replica
    predicted speeds or the current distributions); :meth:`dump` writes
    everything plus the incident context to ``path``.
    """

    def __init__(
        self,
        *,
        capacity: int = 512,
        snapshot_capacity: int = 32,
        clock: Optional[Callable[[], float]] = None,
    ):
        kw = {"clock": clock} if clock is not None else {}
        super().__init__(capacity=int(capacity), **kw)
        self.snapshots: deque = deque(maxlen=int(snapshot_capacity))

    def snapshot(self, label: str, data: Any) -> None:
        """Record one estimate snapshot (ring-bounded like the events)."""
        self.snapshots.append({
            "t": self.clock(),
            "label": str(label),
            "data": data,
        })

    def dump(
        self,
        path: str,
        *,
        reason: str,
        context: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        """Write the incident file: reason + caller context + the ring of
        events + counter/gauge totals + the snapshot ring.  Returns the
        written payload."""
        payload: Dict[str, Any] = {
            "kind": "flight-recorder",
            "reason": str(reason),
            "context": dict(context or {}),
            "snapshots": list(self.snapshots),
        }
        payload.update(self.to_payload())
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        return payload
