"""Training CLI: real steps on the local device set, DFPA-balanced groups.

Two modes:
  * ``--groups 1`` (default): plain single-group training of a (reduced)
    config — the end-to-end driver used by examples/quickstart.
  * ``--groups N``: heterogeneous multi-group training; each group runs its
    own jit'd accumulation step over its DFPA-allocated units.  On this
    CPU container groups share one device, so per-group heterogeneity is
    emulated by a configurable slowdown factor applied to the *measured*
    step time (the control plane — DFPA, straggler detection, elastic
    rebalancing — is exercised for real).

Usage:
    python -m repro.launch.train --arch gemma2-2b --smoke --steps 20
    python -m repro.launch.train --arch xlstm-350m --smoke --groups 4 \
        --hetero 1.0,1.4,2.0,3.1 --steps 12
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..checkpoint import CheckpointManager, load_checkpoint
from ..core.scheduler import Scheduler
from ..data import SyntheticLMData, UnitBatcher
from ..optim.schedule import warmup_cosine
from ..runtime.straggler import StragglerAction, StragglerDetector
from ..runtime.train_loop import init_train_state, make_train_step

__all__ = ["main"]


def _host_batch(cfg, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
    return {k: jnp.asarray(v) for k, v in batch.items()}


def train_single(cfg, *, steps: int, batch: int, seq: int, lr: float, ckpt_dir=None, log_every=1):
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, key)
    step_fn = jax.jit(make_train_step(cfg, warmup_cosine(lr, max(steps // 10, 1), steps)))
    data = SyntheticLMData(cfg, batch, seq)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    losses = []
    for i in range(steps):
        b = _host_batch(cfg, data.next())
        t0 = time.perf_counter()
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        if i % log_every == 0:
            print(f"step {i:4d} loss {loss:8.4f} gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f}ms", flush=True)
        if mgr and (i + 1) % 50 == 0:
            mgr.save_async(i + 1, state, extra={"data": data.state_dict()})
    if mgr:
        mgr.save_async(steps, state)
        mgr.wait()
    return state, losses


def train_hetero(cfg, *, steps: int, groups: int, hetero: List[float], n_units: int,
                 micro_batch: int, seq: int, lr: float, eps: float = 0.15):
    """Multi-group DFPA-balanced training (per-group grad-accum steps)."""
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, key)
    sched = warmup_cosine(lr, max(steps // 10, 1), steps)
    data = SyntheticLMData(cfg, micro_batch, seq)
    batcher = UnitBatcher(data, micro_batch)
    # One Scheduler session drives the whole control plane: online DFPA
    # observation, repartitioning, and straggler reprofiling.
    ctrl = Scheduler(
        n_units=n_units, num_groups=groups, eps=eps, min_units=1,
        detector=StragglerDetector(),
    )
    # One jit'd step per distinct accumulation length (shared cache).
    step_fns: Dict[int, object] = {}

    def step_for(a: int):
        if a not in step_fns:
            step_fns[a] = jax.jit(make_train_step(cfg, sched, accum_steps=a))
        return step_fns[a]

    print(f"groups={groups} hetero={hetero} units/step={n_units}")
    for i in range(steps):
        units = batcher.global_step_units(n_units, i)
        parts = batcher.split(units, ctrl.d)
        times, losses = [], []
        new_state = None
        for g, part in enumerate(parts):
            if ctrl.d[g] == 0:
                times.append(0.0)
                continue
            gb = {k: jnp.asarray(v) for k, v in part.items()}
            fn = step_for(ctrl.d[g])
            t0 = time.perf_counter()
            out_state, metrics = fn(state, gb)
            jax.block_until_ready(metrics["loss"])
            dt = (time.perf_counter() - t0) * hetero[g]  # emulated heterogeneity
            times.append(dt)
            losses.append(float(metrics["loss"]))
            if new_state is None:
                new_state = out_state  # groups' grads averaged in production;
                # single-device emulation keeps one group's update
        state = new_state
        # straggler scan BEFORE folding times into the models (REPROFILE
        # actions are applied by the facade automatically)
        acts = ctrl.straggler_actions(times)
        for g, act in enumerate(acts):
            if act is not StragglerAction.NONE:
                print(f"    straggler[{g}]: {act.value}", flush=True)
        changed = ctrl.observe(times)
        print(
            f"step {i:3d} loss {np.mean(losses):7.4f} times "
            + "/".join(f"{t*1e3:6.1f}" for t in times)
            + f" d={ctrl.d}{' (rebalanced)' if changed else ''}",
            flush=True,
        )
    print(f"rebalances: {ctrl.rebalances}, final d={ctrl.d}")
    return state, ctrl


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--hetero", default="", help="comma-separated slowdowns per group")
    ap.add_argument("--units", type=int, default=16, help="microbatches per global step")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.groups <= 1:
        train_single(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     lr=args.lr, ckpt_dir=args.ckpt)
    else:
        het = [float(x) for x in args.hetero.split(",")] if args.hetero else [
            1.0 + 0.7 * g for g in range(args.groups)
        ]
        assert len(het) == args.groups
        train_hetero(cfg, steps=args.steps, groups=args.groups, hetero=het,
                     n_units=args.units, micro_batch=args.batch, seq=args.seq, lr=args.lr)


if __name__ == "__main__":
    main()
