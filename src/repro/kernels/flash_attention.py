"""Flash attention (online softmax) Pallas TPU kernel.

Supports the features the assigned archs need: causal, sliding-window
(gemma2/recurrentgemma local layers), attention-logit softcap (gemma2),
GQA (KV-head index map = q_head // group), right-aligned queries (prefill
continuation).  fp32 running max / sum / accumulator in VMEM scratch; KV
innermost grid dim sweeps sequentially so the scratch carries across blocks.

Queries are right-aligned to the keys: query row i sits at absolute position
``i + (Sk - Sq)`` — the standard decode/prefill convention.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["flash_attention_pallas"]

NEG_INF = -2.0e38


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int, softcap: float,
    bq: int, bk: int, nk: int, q_off: int,
):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = pl.program_id(1)
    q_start = qb * bq + q_off  # absolute position of first query row
    k_start = kb * bk

    # Block-level skip: entire KV block out of visible range?
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + bq - 1  # some key <= some query pos
    if window > 0:
        run &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0, 0], preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Kv, Sk, D)
    v: jax.Array,  # (B, Kv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    G = H // Kv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"seq ({Sq},{Sk}) not divisible by blocks ({bq},{bk})")
    nk = Sk // bk
    grid = (B * H, Sq // bq, nk)
    q_off = Sk - Sq

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, nk=nk, q_off=q_off,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda bh, qb, kb: (bh // H, bh % H, qb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda bh, qb, kb: (bh // H, (bh % H) // G, kb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda bh, qb, kb: (bh // H, (bh % H) // G, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda bh, qb, kb: (bh // H, bh % H, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running sum
            pltpu.VMEM((bq, D), jnp.float32),  # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
    return out
