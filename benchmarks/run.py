"""Benchmark driver: one artifact per paper table/figure + kernel bench +
the roofline table (if dry-run results exist).

    PYTHONPATH=src python -m benchmarks.run [--only tableX] [--out DIR]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

from . import paper_tables
from .kernels_bench import kernels_bench
from .roofline import roofline_table

ARTIFACTS = [
    ("table2_dfpa_cost", paper_tables.table2_dfpa_cost),
    ("table3_epsilon", paper_tables.table3_epsilon),
    ("table4_scale", paper_tables.table4_scale),
    ("table5_2d", paper_tables.table5_2d),
    ("fig6_convergence", paper_tables.fig6_convergence),
    ("fig10_compare", paper_tables.fig10_compare),
    ("kernels_bench", kernels_bench),
    ("roofline", roofline_table),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    rc = 0
    for name, fn in ARTIFACTS:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            csv = fn()
            path = os.path.join(args.out, f"{name}.csv")
            with open(path, "w") as f:
                f.write(csv)
            print(f"== {name} ({time.time() - t0:.1f}s) -> {path}")
            print(csv)
        except Exception as e:  # noqa: BLE001
            print(f"== {name} FAILED: {type(e).__name__}: {e}")
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
