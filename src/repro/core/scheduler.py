"""Scheduler: the unified facade over the paper's partitioning lifecycle.

The paper's core contribution is an *online* loop — estimate partial speed
functions during execution, repartition cheaply, repeat.  Before this module
that loop was scattered across free functions with inconsistent knobs
(``partition_units(..., vectorize=, backend=)``, ``dfpa(...)``,
``bank_repartition_2d(...)``, ``BalanceController``, ``StragglerDetector``
wiring, ``elastic_rebalance``), each re-deriving scalar-vs-bank-vs-jax
dispatch per call.  ``Scheduler`` consolidates it behind one session-style
API, constructed from a :class:`~repro.core.speedstore.SpeedStore` (backend
resolved **once**) plus a :class:`Policy`:

  * ``partition(n, caps, min_units)`` — one optimal distribution from the
    current models (the paper's step 3);
  * ``observe(times)``               — fold one round's measured times into
    the estimates (step 5), EMA-smoothed, repartitioning when the imbalance
    exceeds ``eps`` (the online controller previously in
    ``runtime/balance.py``);
  * ``repartition()``                — force a re-partition from the current
    estimates;
  * ``autotune(executor, n, eps)``   — the full DFPA measurement loop of the
    paper (previously ``core/dfpa.py``);
  * ``partition_grid(M, N)``         — the nested 2-D partitioner of §3.2
    (previously ``core/partition2d.py``), policy-selected CPM / FFMPA /
    DFPA-based;
  * ``join(k)`` / ``leave(g)`` / ``resize(...)`` — elastic membership with
    warm-started re-partition (previously ``runtime/elastic.py``);
  * ``straggler_actions(times)``     — FPM-residual health detection with
    automatic reprofiling (previously hand-wired around
    ``runtime/straggler.py``);
  * ``state_dict()`` / ``from_state()`` — full-fidelity persistence: config,
    estimates, EMA state and current distribution round-trip, so a restored
    scheduler produces bit-identical next-round allocations.

Every method returns (where a distribution is produced) a single typed
:class:`Partition` instead of the previous mix of bare lists,
``DFPAResult`` and ``Grid2DResult``; the legacy entry points survive as thin
deprecation shims that delegate here.

The fleet layer (multi-tenant scheduling)
-----------------------------------------

One ``Scheduler`` owns ONE job.  For q *concurrent* jobs over the same
platform, ``repro.fleet.FleetScheduler`` multiplexes this exact per-job
state machine (its rounds are fuzz-locked bit-identical to q independent
``autotune`` loops) while batching the device work: the fleet — not the
per-job stores — owns a single stacked ``[q, p, k]`` ``JaxModelBank`` as a
donated carry, updated in place by one fold-in program per round and
REBUILT ("restacked") lazily from the per-job scalar estimates only when
``admit``/``retire``/``resize`` changes the lane set.  One fleet round is
one stacked repartition + one batched measurement + one stacked fold-in,
regardless of q; ``rebalance`` is the serving steady-state variant (one
program, no measurement).  ``_grid_dfpa`` below drives its per-column inner
DFPA loops through that same driver (one job per column), so a 2-D outer
round is one device program rather than q sequential Python loops.  Partial
estimates persist across sessions in ``repro.fleet.ProfileRegistry``, keyed
by ``(device_class, workload_tag)`` — one entry per hardware class and
workload, NOT per processor, merged back on ``retire`` and consulted on
``admit`` for warm starts.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .executor import BatchedSimulatedExecutor2D, Executor
from .fpm import AnalyticModel, PiecewiseLinearFPM, imbalance
from .hierarchy import Hierarchy
from .modelbank import ModelBank
from .partition2d import _col_times, _flat_imbalance, _rebalance_widths
from .speedstore import SpeedStore

try:  # telemetry is optional: the scheduler runs identically without repro.obs
    from ..obs.telemetry import active as _obs_active
except ImportError:  # pragma: no cover - obs layer absent
    def _obs_active():
        return None

__all__ = ["Policy", "Partition", "Scheduler"]


class Policy(Enum):
    """Which performance-model policy drives the distribution.

    * ``CPM``    — constant performance models (the conventional baseline);
    * ``FFMPA``  — pre-built full functional models (partition once, no
      benchmarking);
    * ``DFPA``   — the paper's algorithm: partial models built online from
      observations (``autotune`` / ``observe``);
    * ``GRID2D`` — the nested 2-D DFPA partitioner of §3.2 (requires
      ``grid=``);
    * ``HIER``   — the two-level path for hierarchically heterogeneous
      platforms (requires ``groups=``): outer ``t*`` over per-group aggregate
      models, inner per-group solves on the groups' own sub-banks
      (``core/hierarchy.py``).
    """

    CPM = "cpm"
    FFMPA = "ffmpa"
    DFPA = "dfpa"
    GRID2D = "grid2d"
    HIER = "hier"


@dataclass
class Partition:
    """One partitioning outcome — the single result type of the facade.

    For 1-D partitions ``allocations[i]`` is processor ``i``'s unit count.
    For grid partitions ``col_widths``/``row_heights`` are authoritative and
    ``allocations`` flattens the row heights column-major
    (``[rows[j][i] for j for i]``).
    """

    allocations: List[int]
    t_star: Optional[float]  # continuous equal-time point (None for grid/loop results)
    makespan: Optional[float]  # estimated (or measured) slowest-processor time
    imbalance: float  # max |t_i - t_j| / t_i over working processors
    converged: bool
    iterations: int
    policy: Policy
    backend: str
    times: Optional[List[float]] = None  # per-processor times backing the metrics
    col_widths: Optional[List[int]] = None  # grid only
    row_heights: Optional[List[List[int]]] = None  # grid only
    diagnostics: Dict[str, Any] = field(default_factory=dict)

    @property
    def d(self) -> List[int]:
        """Alias for ``allocations`` (the paper's output array ``d``)."""
        return self.allocations


def _even(n: int, p: int) -> List[int]:
    base, rem = divmod(n, p)
    return [base + (1 if i < rem else 0) for i in range(p)]


def _probe_neighbour(d, times, seen, caps, min_units):
    """First unseen 1-unit transfer from slower to faster processors (the
    deterministic fixed-point escape of the DFPA loop)."""
    p = len(d)
    order_slow = sorted(range(p), key=lambda i: times[i], reverse=True)
    order_fast = sorted(range(p), key=lambda i: times[i])
    for i in order_slow:
        if d[i] - 1 < min_units:
            continue
        for j in order_fast:
            if i == j:
                continue
            if caps is not None and d[j] + 1 > caps[j]:
                continue
            cand = list(d)
            cand[i] -= 1
            cand[j] += 1
            if tuple(cand) not in seen:
                return cand
    return None


_UNSET = object()


class Scheduler:
    """Session-style facade over the self-adaptable partitioning lifecycle.

    Construct from a :class:`SpeedStore` (or let the constructor build one:
    ``num_groups`` empty estimates for the online loop, or nothing yet for a
    cold ``autotune``), pick a :class:`Policy`, then drive the lifecycle
    methods.  The backend is fixed at construction — no per-call
    ``backend=``/``vectorize=`` anywhere downstream.
    """

    def __init__(
        self,
        store: Optional[SpeedStore] = None,
        *,
        policy: Policy = Policy.DFPA,
        grid: Optional[Sequence[Sequence[Any]]] = None,
        n_units: Optional[int] = None,
        num_groups: Optional[int] = None,
        eps: float = 0.1,
        min_units: int = 0,
        caps: Optional[Sequence[int]] = None,
        smooth: float = 0.5,
        backend: str = "numpy",
        detector: Optional[Any] = None,
        analytic_tol: Optional[float] = None,
        completion: str = "auto",
        groups: Optional[Sequence[int]] = None,
        sharding: Optional[str] = None,
        max_group_knots: int = 64,
        compilation_cache_dir: Optional[str] = None,
    ):
        if backend not in ("scalar", "numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        if completion not in ("auto", "threshold", "greedy"):
            raise ValueError(f"unknown completion mode {completion!r}")
        if sharding not in (None, "shard_map"):
            raise ValueError(f"unknown sharding mode {sharding!r}")
        if policy is Policy.HIER and groups is None:
            raise ValueError("policy=HIER requires a groups= assignment")
        # Integer-completion routing for every partition this session makes:
        # "auto" = threshold-count on monotone banks on the jitted backend
        # (the p=10^5 fast path), exact per-unit greedy otherwise — including
        # always on the numpy host path, where the heap was never the
        # bottleneck; see modelbank.py "completion modes".
        # On the session knob "threshold" means "wherever one exists":
        # scalar-backed stores (non-piecewise models, forced baselines) are
        # demoted to their exact loop by _completion_for — the strict
        # refusal lives on the direct SpeedStore API.
        self.completion = completion
        self.policy = policy
        self.grid = grid
        self.eps = float(eps)
        self.min_units = int(min_units)
        self.caps = list(caps) if caps is not None else None
        self.smooth = float(smooth)
        self.n_units = int(n_units) if n_units is not None else None
        self.analytic_tol = analytic_tol
        self._backend = backend
        if store is None and num_groups is not None:
            store = SpeedStore.empty(int(num_groups), backend=backend)
        self.store = store
        self.detector = detector
        # two-level routing: a groups= assignment sends every flat partition
        # (partition/repartition/observe) through core/hierarchy.py —
        # policy=HIER is the declarative spelling, but any policy may carry
        # groups (e.g. a DFPA loop over a grouped platform).
        self.groups = (
            [int(v) for v in groups] if groups is not None else None
        )
        self.sharding = sharding
        self.max_group_knots = int(max_group_knots)
        self.compilation_cache_dir = compilation_cache_dir
        if compilation_cache_dir is not None and backend == "jax":
            from .modelbank_jax import enable_compilation_cache

            enable_compilation_cache(compilation_cache_dir)
        # online state
        self.d: List[int] = (
            _even(self.n_units, self.num_groups)
            if self.n_units is not None and self.num_groups
            else []
        )
        self._ema: Dict[Tuple[int, int], float] = {}
        self.rebalances = 0
        self.steps_observed = 0

    # -- constructors ---------------------------------------------------------

    @classmethod
    def from_models(
        cls,
        models: Sequence[Any],
        *,
        backend: str = "auto",
        policy: Policy = Policy.DFPA,
        analytic_tol: Optional[float] = None,
        analytic_hi: Optional[float] = None,
        **kw,
    ) -> "Scheduler":
        store = SpeedStore.from_models(
            models, backend=backend, analytic_tol=analytic_tol, analytic_hi=analytic_hi
        )
        return cls(store, policy=policy, backend=store.backend, **kw)

    @classmethod
    def from_speeds(
        cls, speeds: Sequence[float], *, policy: Policy = Policy.CPM, **kw
    ) -> "Scheduler":
        return cls(SpeedStore.from_speeds(speeds), policy=policy, **kw)

    # -- shape / introspection ------------------------------------------------

    @property
    def num_groups(self) -> int:
        return self.store.p if self.store is not None else 0

    @property
    def backend(self) -> str:
        return self.store.backend if self.store is not None else self._backend

    @property
    def models(self) -> List[Any]:
        return self.store.models

    @property
    def dtype(self):
        """The session's device-bank dtype policy — the store's, when one
        exists (propagated to every child/grid/elastic store this session
        creates, so a float32 session stays float32 end to end)."""
        return self.store.dtype if self.store is not None else None

    def _completion_for(self, store: SpeedStore) -> str:
        """The session's completion knob for one store: ``"threshold"`` is
        demoted to ``"auto"`` on scalar-backed stores (they only have the
        exact per-unit loop), so the knob behaves identically on every
        Scheduler path — flat, grid, elastic."""
        if self.completion == "threshold" and store.backend == "scalar":
            return "auto"
        return self.completion

    # -- two-level routing (core/hierarchy.py) --------------------------------

    def set_groups(self, groups: Optional[Sequence[int]]) -> None:
        """Mid-flight group resize: replace (or, with ``None``, clear) the
        two-level assignment; the next partition/observe/repartition routes
        through the new grouping.  Host classes merging or a rack splitting
        in two is a one-call regroup — the models are untouched."""
        if groups is None:
            if self.policy is Policy.HIER:
                raise ValueError("policy=HIER requires a groups= assignment")
            self.groups = None
            return
        if len(groups) != self.num_groups:
            raise ValueError(
                f"groups must be a length-p assignment "
                f"(got {len(groups)} for p={self.num_groups})"
            )
        self.groups = [int(v) for v in groups]

    def _hier_partition(self, n, caps, mu) -> Tuple[List[int], float]:
        if self.backend == "scalar":
            raise ValueError(
                "hierarchical partitioning requires a banked store "
                '(backend "numpy" or "jax")'
            )
        h = Hierarchy.from_bank(
            self.store.bank(),
            self.groups,
            backend="jax" if self.backend == "jax" else "numpy",
            sharding=self.sharding,
            max_group_knots=self.max_group_knots,
            dtype=self.dtype,
        )
        return h.partition_units(
            n, caps, min_units=mu,
            completion=self._completion_for(self.store), with_t=True,
        )

    @property
    def imbalance_estimate(self) -> float:
        ts = [
            m.time(di)
            for m, di in zip(self.store.models, self.d)
            if di > 0 and getattr(m, "num_points", 1)
        ]
        return imbalance(ts)

    # -- one-shot partitioning (paper step 3) ---------------------------------

    def partition(
        self,
        n: Optional[int] = None,
        caps: Optional[Sequence[int]] = None,
        min_units: Optional[int] = None,
        *,
        eps: Optional[float] = None,
        persist_caps: bool = False,
        objective: str = "time",
        energy_cap: Optional[float] = None,
    ) -> Partition:
        """Compute one optimal distribution from the current models.

        In grid mode pass ``n=(M, N)`` (or call :meth:`partition_grid`).
        Updates the scheduler's current distribution ``d``.

        Per-call ``caps`` apply to THIS call only; they no longer overwrite
        the session caps used by every later ``repartition``/``observe``/
        ``autotune``.  Pass ``persist_caps=True`` to opt back into the old
        sticky behaviour.

        ``objective``/``energy_cap`` route the bi-objective dispatch (see
        ``core/energy.py``; call :meth:`attach_energy` first): ``"energy"``
        balances per-processor energy, ``"pareto"`` picks the knee of the
        makespan/energy front — or, with ``energy_cap``, the fastest point
        within the budget.  Not supported in grid or hierarchical mode.
        """
        if self.grid is not None:
            if objective != "time" or energy_cap is not None:
                raise ValueError("grid scheduler: objective='time' only")
            if isinstance(n, (tuple, list)) and len(n) == 2:
                return self.partition_grid(int(n[0]), int(n[1]), eps=eps)
            raise ValueError("grid scheduler: pass n=(M, N) or call partition_grid()")
        if n is None:
            n = self.n_units
        if n is None:
            raise ValueError("no unit count: pass n or construct with n_units")
        n = int(n)
        self.n_units = n
        caps_now = self.caps
        if caps is not None:
            caps_now = list(caps)
            if persist_caps:
                self.caps = list(caps)
        mu = self.min_units if min_units is None else int(min_units)
        tel = _obs_active()
        rec = tel is not None and tel.enabled
        if rec:
            t0 = tel.clock()
        if self.groups is not None:
            if objective != "time" or energy_cap is not None:
                raise ValueError("hierarchical scheduler: objective='time' only")
            d, t_star = self._hier_partition(n, caps_now, mu)
        else:
            d, t_star = self.store.partition(
                n, caps_now, min_units=mu,
                completion=self._completion_for(self.store),
                objective=objective, energy_cap=energy_cap,
            )
        if rec:
            tel.span_at("scheduler.partition", t0, tel.clock(),
                        n=n, objective=objective,
                        hier=self.groups is not None)
        self.d = list(d)
        return self._flat_result(d, t_star, eps=self.eps if eps is None else eps)

    def attach_energy(self, models: Sequence) -> "Scheduler":
        """Attach per-processor energy models (``E_i(x)`` via energy-rate
        FPMs — see ``core/energy.py:energy_model``) enabling the
        ``objective=``/``energy_cap=`` dispatch and :meth:`pareto_front`."""
        self.store.attach_energy(models)
        return self

    def pareto_front(self, n: Optional[int] = None, caps=None, *,
                     min_units: Optional[int] = None, num_points: int = 17):
        """The makespan/total-energy Pareto front for ``n`` units (energy
        models must be attached).  Does not update ``d``."""
        if n is None:
            n = self.n_units
        if n is None:
            raise ValueError("no unit count: pass n or construct with n_units")
        mu = self.min_units if min_units is None else int(min_units)
        return self.store.pareto_front(
            int(n), self.caps if caps is None else caps,
            min_units=mu, num_points=num_points,
            completion=self._completion_for(self.store),
        )

    def repartition(self) -> Partition:
        """Force a re-partition from the current estimates (the facade's
        version of calling the free partitioner again)."""
        old = list(self.d)
        part = self.partition(self.n_units, min_units=self.min_units)
        if old and part.allocations != old:
            self.rebalances += 1
        return part

    def _flat_result(self, d: List[int], t_star: Optional[float], *, eps: float) -> Partition:
        times = self.store.times([float(v) for v in d])
        pts = self.store.num_points
        valid = [
            float(t)
            for t, di, k in zip(times, d, pts)
            if di > 0 and k > 0 and np.isfinite(t)
        ]
        imb = imbalance(valid)
        return Partition(
            allocations=list(d),
            t_star=t_star,
            makespan=max(valid) if valid else None,
            imbalance=imb,
            converged=imb <= eps,
            iterations=0,
            policy=self.policy,
            backend=self.backend,
            times=[float(t) if np.isfinite(t) else 0.0 for t in times],
        )

    # -- the online loop (paper steps 4-5, previously BalanceController) ------

    def observe(self, times: Sequence[float]) -> bool:
        """Fold one round's per-group times in; returns True if the
        distribution changed (callers must re-split the next round's units).

        EMA smoothing (``smooth``) de-noises wall-clock measurements; the
        paper's deterministic-benchmark assumption does not hold for real
        step times.
        """
        if len(times) != self.num_groups:
            raise ValueError("times length != num_groups")
        if self.n_units is None:
            raise ValueError("observe() needs n_units (construct with n_units=...)")
        self.steps_observed += 1
        speeds = [1.0] * self.num_groups
        valid = [False] * self.num_groups
        for i, (di, ti) in enumerate(zip(self.d, times)):
            if di <= 0 or ti <= 0:
                continue
            key = (i, di)
            ema = self._ema.get(key)
            ema = ti if ema is None else (1 - self.smooth) * ema + self.smooth * ti
            self._ema[key] = ema
            speeds[i], valid[i] = di / ema, True
        self.store.fold_in([float(di) for di in self.d], speeds, valid)
        tel = _obs_active()
        if tel is not None and tel.enabled:
            tel.counter("scheduler.observe")
        if imbalance(times) <= self.eps:  # zero-allocation groups are ignored
            return False
        if self.groups is not None:
            new_d, _ = self._hier_partition(self.n_units, self.caps, self.min_units)
        else:
            new_d = self.store.partition_units(
                self.n_units, self.caps, min_units=self.min_units,
                completion=self._completion_for(self.store),
            )
        if new_d == self.d:
            return False
        self.d = new_d
        self.rebalances += 1
        return True

    # -- the DFPA measurement loop (previously core/dfpa.py) ------------------

    def autotune(
        self,
        executor: Executor,
        n: Optional[int] = None,
        eps: Optional[float] = None,
        *,
        max_iter: int = 100,
        caps: Optional[Sequence[int]] = None,
        min_units: Optional[int] = None,
        warm_start_d: Optional[Sequence[int]] = None,
        probe_budget: Optional[int] = None,
    ) -> Partition:
        """Run the paper's DFPA loop over ``executor``:

          1. run the even distribution (or the warm-start partition when the
             store already holds estimates), gather times;
          2. imbalance <= eps -> done;
          3. fold observations into the partial FPM estimates;
          4. re-partition optimally for the current estimates, execute,
             measure; goto 3 — with the deterministic local-probe escape
             when the partitioner reaches a fixed point short of eps.

        Leaves the scheduler warm: the estimates, ``n_units`` and the final
        distribution stay on the session for ``observe``/``join``/``leave``.
        """
        p = executor.num_procs
        if p < 1:
            raise ValueError("need at least one processor")
        n = int(n if n is not None else self.n_units)
        if n < p:
            raise ValueError(f"DFPA requires n >= p (n={n}, p={p})")
        eps = float(eps if eps is not None else self.eps)
        if eps <= 0:
            raise ValueError("eps must be positive")
        if caps is None:
            caps = self.caps
        mu = self.min_units if min_units is None else int(min_units)

        if self.store is None:
            self.store = SpeedStore.empty(p, backend=self._backend)
        elif self.store.p != p:
            raise ValueError(
                f"store has {self.store.p} models but executor has {p} processors"
            )
        store = self.store
        models = store.models

        history: List[Tuple[List[int], List[float]]] = []
        seen: Dict[Tuple[int, ...], List[float]] = {}
        if probe_budget is None:
            probe_budget = 2 * p
        probes_left = probe_budget
        tel = _obs_active()
        rec = tel is not None and tel.enabled
        t_tune = tel.clock() if rec else 0.0

        def measure(d: List[int]) -> List[float]:
            times = executor.run(d)
            history.append((list(d), list(times)))
            seen[tuple(d)] = list(times)
            darr = [float(di) for di in d]
            sarr = [di / ti if (di > 0 and ti > 0) else 1.0 for di, ti in zip(d, times)]
            valid = [di > 0 and ti > 0 for di, ti in zip(d, times)]
            store.fold_in(darr, sarr, valid)  # s_i(d_i) = d_i / t_i
            return list(times)

        def repartition() -> List[int]:
            return store.partition_units(
                n, caps, min_units=mu, completion=self._completion_for(store)
            )

        # Step 1: initial distribution — even split (paper), or the
        # warm-start partition when prior estimates exist (elastic restart).
        if warm_start_d is not None:
            d = list(map(int, warm_start_d))
            if sum(d) != n or len(d) != p:
                raise ValueError("warm_start_d must be a length-p partition of n")
        elif all(getattr(m, "num_points", 0) > 0 for m in models):
            d = repartition()
        else:
            d = _even(n, p)
        times = measure(d)
        it = 1

        best_d, best_t, best_imb = list(d), list(times), imbalance(times)

        def finish(d, t, it, converged, imb) -> Partition:
            self.n_units = n
            self.d = list(d)
            self.eps = eps
            if rec:
                tel.span_at("scheduler.autotune", t_tune, tel.clock(),
                            n=n, iterations=it, converged=bool(converged),
                            imbalance=float(imb),
                            probes_used=probe_budget - probes_left)
            return Partition(
                allocations=list(d),
                t_star=None,
                makespan=max(t) if t else None,
                imbalance=imb,
                converged=converged,
                iterations=it,
                policy=self.policy,
                backend=store.backend,
                times=list(t),
                diagnostics={
                    "history": history,
                    "models": models,
                    "probes_used": probe_budget - probes_left,
                },
            )

        while True:
            imb = imbalance(times)
            if imb < best_imb:
                best_d, best_t, best_imb = list(d), list(times), imb
            if imb <= eps:
                return finish(list(d), list(times), it, True, imb)
            if it >= max_iter:
                return finish(best_d, best_t, it, False, best_imb)
            # Steps 3+5 happened inside measure() (scalar estimates updated,
            # device carry folded on the jax backend); step 4: re-partition.
            d_new = repartition()
            if tuple(d_new) in seen:
                t_seen = seen[tuple(d_new)]
                imb_seen = imbalance(t_seen)
                if imb_seen < best_imb:
                    best_d, best_t, best_imb = list(d_new), list(t_seen), imb_seen
                probe = (
                    _probe_neighbour(d_new, t_seen, seen, caps, mu)
                    if probes_left > 0
                    else None
                )
                if probe is None:
                    return finish(best_d, best_t, it, best_imb <= eps, best_imb)
                probes_left -= 1
                d_new = probe
            d = d_new
            times = measure(d)
            it += 1

    # -- straggler detection (previously hand-wired) --------------------------

    def straggler_actions(self, times: Sequence[float], *, auto_reprofile: bool = True):
        """Scan one round's observed times against the models' predictions;
        returns one ``StragglerAction`` per group.  REPROFILE actions are
        applied automatically (stale estimates invalidated) unless
        ``auto_reprofile=False``; QUARANTINE is reported for the caller to
        act on (``leave(group)``)."""
        from ..runtime.straggler import StragglerAction, StragglerDetector

        if self.detector is None:
            self.detector = StragglerDetector()
        actions = self.detector.update_batch(self.store.bank(), self.d, times)
        if auto_reprofile:
            for g, act in enumerate(actions):
                if act is StragglerAction.REPROFILE:
                    self.reprofile(g)
        return actions

    def reprofile(self, group: int) -> None:
        """Invalidate a group's estimate (keep only the freshest operating
        point so the partitioner stays feasible); the device carry is dropped
        and rebuilt lazily."""
        tel = _obs_active()
        if tel is not None and tel.enabled:
            tel.event("scheduler.reprofile", group=int(group))
        m = self.store.models[group]
        if getattr(m, "num_points", 0) > 1:
            di = self.d[group] if self.d else 0
            pts = [(x, s) for x, s in m.as_points() if x == float(di)]
            self.store.reset_row(group, pts)
        for k in [k for k in self._ema if k[0] == group]:
            del self._ema[k]
        if self.store._jbank is not None:
            self.store.drop_carry()

    # -- elastic membership (previously runtime/elastic.py) -------------------

    def resize(
        self,
        surviving: Sequence[int],
        joined: int = 0,
        *,
        caps=_UNSET,
    ) -> "Scheduler":
        """New scheduler for a changed group set: survivors keep their FPM
        points (§3.2's reuse of previous benchmarks); joiners start from an
        optimistic single-point estimate borrowed from the fastest survivor
        (corrected by their first measurement; optimistic starts avoid
        starving the newcomer).  Re-partitions immediately when every group
        has at least one point."""
        old_models = self.store.models
        models: List[PiecewiseLinearFPM] = [
            PiecewiseLinearFPM.from_points(old_models[i].as_points()) for i in surviving
        ]
        donor = None
        donor_pos = 0
        for pos, m in enumerate(models):
            if m.num_points:
                cand = max(m.as_points(), key=lambda pt: pt[1])
                if donor is None or cand[1] > donor[1]:
                    donor, donor_pos = cand, pos
        for _ in range(joined):
            models.append(
                PiecewiseLinearFPM.from_points([donor]) if donor else PiecewiseLinearFPM()
            )
        if caps is _UNSET:
            if self.caps is None:
                caps = None
            else:
                # Joiners inherit the most generous survivor cap when the
                # session has no unit count yet (n_units is the natural cap
                # otherwise) — a None must never reach _prep_unit_caps.
                join_cap = (
                    self.n_units
                    if self.n_units is not None
                    else max((self.caps[i] for i in surviving), default=None)
                )
                if joined and join_cap is None:  # no survivors, no n_units
                    caps = None
                else:
                    caps = [self.caps[i] for i in surviving] + [join_cap] * joined
        groups = None
        if self.groups is not None:
            # survivors keep their group ids; joiners enter the donor
            # survivor's group (the one whose estimate they borrow) so a
            # hierarchical session stays hierarchical across membership
            # changes.
            groups = [self.groups[i] for i in surviving]
            join_group = groups[donor_pos] if groups else 0
            groups = groups + [join_group] * joined
        # The detector's strike counts are keyed by group index; hand the
        # new scheduler a REMAPPED copy (survivors keep their counts under
        # their new indices, departed groups drop out, joiners start clean)
        # — passing it through unmapped made every survivor inherit its
        # left neighbour's strikes after a leave() and falsely quarantinable.
        detector = (
            self.detector.remap(surviving, joined)
            if self.detector is not None
            else None
        )
        new = Scheduler(
            SpeedStore.from_models(models, backend=self.backend, dtype=self.dtype),
            policy=self.policy,
            n_units=self.n_units,
            eps=self.eps,
            min_units=self.min_units,
            caps=caps,
            smooth=self.smooth,
            backend=self.backend,
            detector=detector,
            completion=self.completion,
            groups=groups,
            sharding=self.sharding,
            max_group_knots=self.max_group_knots,
        )
        if all(m.num_points for m in models) and new.n_units is not None:
            new.d = new.store.partition_units(
                new.n_units, new.caps, min_units=new.min_units,
                completion=new._completion_for(new.store),
            )
        return new

    def _adopt(self, other: "Scheduler") -> None:
        self.store = other.store
        self.d = list(other.d)
        self.caps = other.caps
        self.groups = list(other.groups) if other.groups is not None else None
        self._ema = {}  # group indices shifted; stale EMA keys are invalid
        # ... and so are the detector's strike keys: adopt the remapped
        # detector resize() built (same staleness reason as the EMA reset).
        self.detector = other.detector

    def join(self, count: int = 1, *, caps=_UNSET) -> "Scheduler":
        """``count`` new groups join; warm re-partition, in place."""
        self._adopt(self.resize(list(range(self.num_groups)), joined=count, caps=caps))
        return self

    def leave(self, groups, *, caps=_UNSET) -> "Scheduler":
        """Group (or groups) leave the fleet; survivors keep their estimates
        and the units are redistributed immediately, in place."""
        gone = {int(groups)} if np.isscalar(groups) else {int(g) for g in groups}
        surviving = [i for i in range(self.num_groups) if i not in gone]
        self._adopt(self.resize(surviving, caps=caps))
        return self

    # -- nested 2-D partitioning (previously core/partition2d.py) -------------

    def partition_grid(
        self,
        M: int,
        N: int,
        *,
        eps: Optional[float] = None,
        max_outer: int = 40,
        inner_max_iter: int = 15,
        width_tol: float = 0.02,
        min_units: int = 1,
    ) -> Partition:
        """Partition an ``M x N`` block matrix over the ``p x q`` grid of
        speed functions the scheduler was constructed with, by the policy:

          * ``GRID2D`` / ``DFPA`` — the paper's nested algorithm: per-column
            DFPA row partitions (online partial models), outer column-width
            rebalancing, with all of §3.2's cost optimizations;
          * ``FFMPA`` — full models given, zero benchmark cost (with
            ``analytic_tol`` the analytic models are sample-and-banked onto
            the vectorized path);
          * ``CPM``   — one benchmark round, proportional split.
        """
        if self.grid is None:
            raise ValueError("no grid: construct Scheduler(grid=...) first")
        eps = float(eps if eps is not None else self.eps)
        if self.policy in (Policy.GRID2D, Policy.DFPA):
            return self._grid_dfpa(
                M, N, eps, max_outer=max_outer, inner_max_iter=inner_max_iter,
                width_tol=width_tol, min_units=min_units,
            )
        if self.policy is Policy.FFMPA:
            return self._grid_ffmpa(M, N, eps, max_outer=max_outer)
        if self.policy is Policy.CPM:
            return self._grid_cpm(M, N)
        raise ValueError(f"policy {self.policy} cannot partition a grid")

    def _grid_result(
        self, widths, rows, outer, total_rounds, bench_cost, converged, imb, times
    ) -> Partition:
        flat = [int(r) for col in rows for r in col]
        flat_t = [t for col in times for t in col]
        pos = [t for t in flat_t if t > 0]
        return Partition(
            allocations=flat,
            t_star=None,
            makespan=max(pos) if pos else None,
            imbalance=imb,
            converged=converged,
            iterations=outer,
            policy=self.policy,
            backend=self.backend,
            times=flat_t,
            col_widths=list(widths),
            row_heights=[list(r) for r in rows],
            diagnostics={"total_rounds": total_rounds, "bench_cost": bench_cost,
                         "times": [list(t) for t in times]},
        )

    def _grid_dfpa(
        self, M, N, eps, *, max_outer, inner_max_iter, width_tol, min_units
    ) -> Partition:
        grid = self.grid
        p, q = len(grid), len(grid[0])
        widths = [N // q + (1 if j < N % q else 0) for j in range(q)]
        rows: List[Optional[List[int]]] = [None] * q  # warm-start rows per column
        # FPM estimates per (i, j), in ROW units at the width they were
        # observed; reused across widths by rescaling rows/s by (old_w/new_w).
        fpms: List[List[PiecewiseLinearFPM]] = [
            [PiecewiseLinearFPM() for _ in range(q)] for _ in range(p)
        ]
        fpm_width: List[List[Optional[int]]] = [[None] * q for _ in range(p)]

        total_rounds = 0
        bench_cost = 0.0
        times: List[List[float]] = [[0.0] * p for _ in range(q)]
        prev_widths: Optional[List[int]] = None
        best: Optional[Partition] = None

        # The per-column inner DFPA loops run through the fleet driver: all
        # columns needing a re-benchmark this outer round become jobs of ONE
        # FleetScheduler, so their measurement rounds advance in lock-step —
        # on the jax backend every inner round is a single stacked device
        # program (the ROADMAP "inner-DFPA column batching" item) instead of
        # q sequential Python loops with q separate banks.  Per-column
        # results are bit-identical to the sequential child-Scheduler loops
        # (the fleet parity contract).
        from ..fleet import FleetScheduler, JobSpec

        for outer in range(1, max_outer + 1):
            col_round_costs = [0.0] * q
            run_cols: List[int] = []
            for j in range(q):
                if (
                    prev_widths is not None
                    and rows[j] is not None
                    and widths[j] == prev_widths[j]
                ):
                    # Paper's optimization: width unchanged -> keep the
                    # column's partition; no re-benchmark needed.
                    times[j] = _col_times(grid, j, widths, rows[j])
                else:
                    run_cols.append(j)
            if run_cols:
                fleet = FleetScheduler(p, backend=self._backend, dtype=self.dtype)
                for j in run_cols:
                    w = widths[j]
                    # Rescale surviving FPM points to the new width (g ~
                    # const in w): one batched speed-scale over the column's
                    # model bank.
                    warm = None
                    if all(
                        fpm_width[i][j] is not None and fpms[i][j].num_points > 0
                        for i in range(p)
                    ):
                        col_bank = ModelBank.from_models(
                            [fpms[i][j] for i in range(p)]
                        )
                        scale = [fpm_width[i][j] / w for i in range(p)]
                        warm = col_bank.scaled(scale).to_models()
                    fleet.admit(
                        JobSpec(
                            name=f"col{j}",
                            n=M,
                            eps=eps,
                            min_units=min_units,
                            max_iter=inner_max_iter,
                            completion=self.completion,
                            warm_start_d=rows[j] if rows[j] is not None else None,
                            # Probe fixed points only on the COLD first
                            # partition of a column; warm refinements rely on
                            # the outer width update for fresh information —
                            # unbounded probing churned 2256 rounds / 76%
                            # cost at M=N=768.
                            probe_budget=p if warm is None else 0,
                        ),
                        models=warm,
                    )

                def _col_batch_time(X, cols=tuple(run_cols), ws=tuple(widths)):
                    T = np.zeros_like(X)
                    for k, j in enumerate(cols):
                        w = ws[j]
                        for i in range(p):
                            r = X[k, i]
                            T[k, i] = (
                                (r * w) / grid[i][j](float(r), float(w))
                                if r > 0
                                else 0.0
                            )
                    return T

                fleet.run(
                    BatchedSimulatedExecutor2D(
                        time_fn_batch_2d=_col_batch_time,
                        p=p,
                        q=len(run_cols),
                        job_names=[f"col{j}" for j in run_cols],
                    )
                )
                for j in run_cols:
                    res = fleet.result(f"col{j}")
                    rows[j] = list(res.allocations)
                    times[j] = list(res.times)
                    col_models = res.diagnostics["models"]
                    for i in range(p):
                        fpms[i][j] = col_models[i]
                        fpm_width[i][j] = widths[j]
                    total_rounds += res.iterations
                    col_round_costs[j] = res.diagnostics["bench_cost"]
            # Columns run their inner DFPA in parallel -> cost = slowest col.
            bench_cost += max(col_round_costs) if col_round_costs else 0.0

            imb = _flat_imbalance(times)
            snap = self._grid_result(
                widths, rows, outer, total_rounds, bench_cost, imb <= eps, imb, times
            )
            if best is None or imb < best.imbalance:
                best = snap
            if imb <= eps:
                return snap

            # Outer step (ii): columns' widths ∝ column speed sums (damped).
            # Paper's freeze optimization: revert sub-tolerance width changes
            # (skipping their columns' re-benchmark next round) and hand the
            # residual to the columns that did move.
            prev_widths = list(widths)
            widths = _rebalance_widths(widths, times, rows, N)
            moved = [
                j for j in range(q)
                if abs(widths[j] - prev_widths[j]) > width_tol * prev_widths[j]
            ]
            if moved and len(moved) < q:
                for j in range(q):
                    if j not in moved:
                        widths[j] = prev_widths[j]
                diff = N - sum(widths)
                k = 0
                while diff != 0:
                    j = moved[k % len(moved)]
                    step = 1 if diff > 0 else -1
                    if widths[j] + step >= 1:
                        widths[j] += step
                        diff -= step
                    k += 1
            elif not moved:
                widths = list(prev_widths)

        return self._grid_result(
            best.col_widths, best.row_heights, max_outer, total_rounds,
            bench_cost, best.converged, best.imbalance, best.diagnostics["times"],
        )

    def _grid_cpm(self, M, N) -> Partition:
        """The conventional baseline: ONE benchmark round at the even
        distribution gives each processor a speed constant; rows/columns
        split proportionally.  ``diagnostics["bench_cost"]`` carries the
        single round's cost."""
        grid = self.grid
        p, q = len(grid), len(grid[0])
        w0, r0 = N // q, M // p
        speeds = [[grid[i][j](float(r0), float(w0)) for j in range(q)] for i in range(p)]
        bench_cost = max(
            (r0 * w0) / speeds[i][j] for i in range(p) for j in range(q)
        )
        col_speed = [sum(speeds[i][j] for i in range(p)) for j in range(q)]
        widths = SpeedStore.from_speeds(col_speed).partition_units(N)
        rows = [
            SpeedStore.from_speeds([speeds[i][j] for i in range(p)]).partition_units(M)
            for j in range(q)
        ]
        times = [_col_times(grid, j, widths, rows[j]) for j in range(q)]
        return self._grid_result(
            widths, rows, 1, 1, bench_cost, True, _flat_imbalance(times), times
        )

    def _grid_ffmpa(self, M, N, eps, *, max_outer) -> Partition:
        """FFMPA baseline [18]: the FULL models are given (pre-built), so the
        nested iteration runs entirely on the host with zero benchmark cost.
        Rows are partitioned directly in ROW units.  With ``analytic_tol``
        set the analytic models are sample-and-banked so this baseline rides
        the vectorized bank path; the default keeps the scalar path."""
        grid = self.grid
        p, q = len(grid), len(grid[0])
        widths = [N // q + (1 if j < N % q else 0) for j in range(q)]
        rows: List[List[int]] = [[M // p] * p for _ in range(q)]
        times: List[List[float]] = [[0.0] * p for _ in range(q)]
        best: Optional[Partition] = None
        for outer in range(1, max_outer + 1):
            for j in range(q):
                w = widths[j]
                models = [
                    AnalyticModel(
                        (lambda i_: lambda r: (r * w) / grid[i_][j](float(r), float(w)) if r > 0 else 0.0)(i)
                    )
                    for i in range(p)
                ]
                col_store = SpeedStore.from_models(
                    models,
                    analytic_tol=self.analytic_tol,
                    analytic_hi=float(M) if self.analytic_tol is not None else None,
                    dtype=self.dtype,
                )
                rows[j] = col_store.partition_units(
                    M, min_units=1, completion=self._completion_for(col_store)
                )
                times[j] = _col_times(grid, j, widths, rows[j])
            imb = _flat_imbalance(times)
            if best is None or imb < best.imbalance:
                best = self._grid_result(
                    widths, rows, outer, 0, 0.0, imb <= eps, imb, times
                )
            if imb <= eps:
                return best
            new_widths = _rebalance_widths(widths, times, rows, N)
            if new_widths == widths:
                return best
            widths = new_widths
        return best

    def repartition_grid(
        self,
        fpms: Sequence[Sequence[PiecewiseLinearFPM]],
        fpm_width: Sequence[Sequence[Optional[int]]],
        widths: Sequence[int],
        M: int,
        *,
        min_units: int = 1,
    ) -> List[List[int]]:
        """Re-partition EVERY column's rows from surviving FPM estimates in
        one call — no new benchmarks (the device-side refresh used when
        widths move but no fresh benchmarks are wanted).

        ``fpms[i][j]`` / ``fpm_width[i][j]`` are the per-(row, column)
        estimates and the widths they were observed at; each column's bank is
        rescaled to its current width and, on the jax backend, all ``q``
        banks are stacked into one ``[q, p, k]`` tensor whose ``t*``
        bisections run simultaneously in a single jitted device call.
        Returns ``rows[j][i]``.
        """
        p, q = len(fpms), len(widths)
        for i in range(p):
            for j in range(q):
                if fpm_width[i][j] is None or fpms[i][j].num_points == 0:
                    raise ValueError(f"no FPM estimate for processor ({i}, {j})")
        col_banks = []
        for j in range(q):
            bank = ModelBank.from_models([fpms[i][j] for i in range(p)])
            scale = [fpm_width[i][j] / widths[j] for i in range(p)]
            col_banks.append(bank.scaled(scale))
        if self._backend == "jax":
            from .modelbank_jax import JaxModelBank

            stacked = JaxModelBank.stack(
                [JaxModelBank.from_bank(b, dtype=self.dtype) for b in col_banks]
            )
            d = stacked.partition_units(
                M, min_units=min_units, completion=self.completion
            )
            return [[int(v) for v in row] for row in d]
        rows = []
        for b in col_banks:
            store = SpeedStore.from_bank(b)
            rows.append(
                store.partition_units(
                    M, min_units=min_units, completion=self._completion_for(store)
                )
            )
        return rows

    # -- persistence (self-adaptability across restarts) ----------------------

    def state_dict(self) -> Dict:
        """Full-fidelity session state: config AND estimates AND the EMA /
        distribution state, so ``from_state`` restores a scheduler whose next
        ``observe`` produces bit-identical allocations (the legacy
        ``BalanceController.state_dict`` dropped ``backend``/``smooth`` and
        friends)."""
        store_state = self.store.state_dict()
        return {
            "version": 1,
            "energy_points": store_state.get("energy_points"),
            "policy": self.policy.value,
            "backend": self.backend,
            "n_units": self.n_units,
            "num_groups": self.num_groups,
            "eps": self.eps,
            "min_units": self.min_units,
            "smooth": self.smooth,
            "completion": self.completion,
            "caps": list(self.caps) if self.caps is not None else None,
            "groups": list(self.groups) if self.groups is not None else None,
            "sharding": self.sharding,
            "max_group_knots": self.max_group_knots,
            "d": list(self.d),
            "points": store_state["points"],
            "dtype": store_state["dtype"],
            "ema": [[int(g), int(du), float(v)] for (g, du), v in self._ema.items()],
            "rebalances": self.rebalances,
            "steps_observed": self.steps_observed,
        }

    @classmethod
    def from_state(cls, state: Dict, **overrides) -> "Scheduler":
        """Restore a scheduler saved by :meth:`state_dict`.  ``overrides``
        replace individual config fields (e.g. ``backend="jax"`` to move a
        checkpointed session onto the device path)."""
        cfg = dict(
            policy=Policy(state.get("policy", Policy.DFPA.value)),
            n_units=state.get("n_units"),
            eps=state.get("eps", 0.1),
            min_units=state.get("min_units", 0),
            caps=state.get("caps"),
            smooth=state.get("smooth", 0.5),
            backend=state.get("backend", "numpy"),
            completion=state.get("completion", "auto"),
            groups=state.get("groups"),
            sharding=state.get("sharding"),
            max_group_knots=state.get("max_group_knots", 64),
        )
        cfg.update(overrides)
        backend = cfg.pop("backend")
        dtype = state.get("dtype")
        models = [PiecewiseLinearFPM.from_points(p) for p in state["points"]]
        sched = cls(
            SpeedStore.from_models(
                models, backend=backend,
                dtype=np.dtype(dtype) if dtype is not None else None,
            ),
            backend=backend,
            **cfg,
        )
        sched.d = list(state.get("d", sched.d))
        if state.get("energy_points"):
            sched.store.attach_energy(
                [PiecewiseLinearFPM.from_points(p) for p in state["energy_points"]]
            )
        sched._ema = {(int(g), int(du)): float(v) for g, du, v in state.get("ema", [])}
        sched.rebalances = int(state.get("rebalances", 0))
        sched.steps_observed = int(state.get("steps_observed", 0))
        return sched
